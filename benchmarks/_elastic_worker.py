"""Subprocess worker for bench_elastic: the full elastic drill measured
end-to-end on 8 fake CPU devices.

Per spec it emits CSV rows with:

  drill_shrink      mid-run rank loss at world 4 -> drain/re-plan/
                    reshard/resume at 3, with 2 transient checkpoint-IO
                    faults injected at the drain (absorbed = the retry
                    machinery worked).  within_boundary flags
                    lost_steps <= ckpt_every (recovery resumed from the
                    last step boundary's checkpoint);
  drill_grow        voluntary resize 2 -> 4 at a step boundary via a
                    synchronous drain checkpoint: lost_steps must be 0;
  trajectory_shrink / trajectory_grow
                    post-resize loss trajectory vs an uninterrupted p'
                    run restored from the SAME checkpoint through the
                    same resize path: f32 rows must be bitwise
                    (bitwise flag), and max |dloss| is reported;
  trajectory_int8   the shrink drill on the int8 wire + error feedback
                    (exercises the EF mass-conservation resize):
                    within_tol vs the documented 0.05 envelope;
  replan            per-spec re-plan + static-verify latency at the new
                    world (verified flag; within_budget vs
                    REPLAN_BUDGET_US per spec — re-planning is
                    microseconds of trace-time table rebuilds, never a
                    topology rewrite);
  recovery_steps    recovery-step accounting across the drills: total
                    lost (re-run) steps, worst single drill.

Emits CSV rows on stdout; the gate logic lives in benchmarks/ci_gate.py.
"""
import os
import sys

import re  # noqa: E402 — strip inherited count: XLA keeps the LAST flag
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + _inherited)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.elastic import run_drill  # noqa: E402

#: per-spec re-plan + assert_verified budget.  Measured ~100-300us on
#: CPU; 100ms keeps the claim honest (re-planning is trace-time work,
#: orders below a single training step) with huge headroom for loaded
#: CI runners.
REPLAN_BUDGET_US = 100_000.0
CKPT_EVERY = 3


def emit(name, us, derived=""):
    print(f"elastic/{name},{us:.3f},{derived}")


def report_drill(tag, res, tol=None):
    rep = res["report"]
    lost = res["lost_steps"]
    boundary_ok = (lost == 0) if res["kind"] == "grow" \
        else (0 <= lost <= CKPT_EVERY)
    emit(f"drill_{tag}", rep.total_s * 1e6,
         f"world={res['world']};new_world={res['new_world']};"
         f"event_step={res['event_step']};resumed={res['resumed_step']};"
         f"lost_steps={lost};within_boundary={boundary_ok};"
         f"io_absorbed={rep.io_failures};evicted={rep.evicted};"
         f"restarted={rep.restarted};fired={'+'.join(res['fired'])}")
    if tol is None:
        emit(f"trajectory_{tag}", rep.total_s * 1e6,
             f"bitwise={res['bitwise']};max_err={res['max_abs_diff']:.3g};"
             f"n_steps={len(res['post'])}")
    else:
        emit(f"trajectory_{tag}", rep.total_s * 1e6,
             f"within_tol={res['max_abs_diff'] <= tol};"
             f"max_err_int8={res['max_abs_diff']:.3g};tol={tol};"
             f"n_steps={len(res['post'])}")
    return rep


def main():
    common = dict(arch="qwen3-1.7b", scale_down=True, steps=8, seq_len=16,
                  global_batch=12, ckpt_every=CKPT_EVERY)

    shrink = run_drill(world=4, shrink_at_step=5, fail_rank=2, io_faults=2,
                       **common)
    rep_s = report_drill("shrink", shrink)
    assert rep_s.io_failures == 2, rep_s.io_failures

    grow = run_drill(world=2, grow_at_step=4, grow_to=4, **common)
    rep_g = report_drill("grow", grow)

    # int8 wire + EF: the resize path that folds per-rank residual mass.
    # The documented envelope for compressed-sync trajectory deltas is
    # 0.05 (docs/architecture.md) — the ref run shares the resize path,
    # so the observed delta is 0, but the gate keeps the envelope honest.
    int8 = run_drill(world=4, shrink_at_step=5, fail_rank=1,
                     wire_dtype="int8", **common)
    report_drill("int8", int8, tol=0.05)

    for rep, tag in ((rep_s, "shrink"), (rep_g, "grow")):
        for r in rep.replans:
            ok = r.plan_us <= REPLAN_BUDGET_US
            emit(f"replan_{tag}_p{r.old_p}to{r.new_p}", r.plan_us,
                 f"verified={r.verified};within_budget={ok};"
                 f"budget_us={REPLAN_BUDGET_US:.0f};"
                 f"kind={r.spec.kind}")
    assert rep_s.replans and rep_g.replans

    losts = [shrink["lost_steps"], grow["lost_steps"], int8["lost_steps"]]
    emit("recovery_steps", 0.0,
         f"total_lost={sum(losts)};worst={max(losts)};drills={len(losts)};"
         f"ckpt_every={CKPT_EVERY}")


if __name__ == "__main__":
    main()
