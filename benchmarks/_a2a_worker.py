"""Subprocess worker for bench_a2a: the alltoall(v) plan's structural
guarantees, measured end-to-end on 8 fake CPU devices.

Per case it emits one CSV row with (gated in benchmarks/ci_gate.py):

  cp / theory / cp_delta   lowered-HLO collective-permute count vs
                           ceil(log2 p) — alltoall(v) must keep exactly
                           one ppermute per round, ragged counts and the
                           fused path included (want cp_delta=0);
  widths / bounds /        the alltoallv plan's per-round wire widths vs
  width_ok                 the analytic worst-windowed-count-sum bound
                           (cost_model.alltoallv_round_widths) — must be
                           EQUAL (want width_ok=True);
  ratio                    fused/jnp paired-median wall-clock ratio for
                           the uniform alltoall (interpret-mode Pallas;
                           gated at A2A_RATIO_MAX);
  allclose                 for a2a/moe_ep_parity: moe_dispatch='ep' (2
                           ranks, ragged 3-expert ownership) matches the
                           'global' single-pool dispatch numerically.

Emits CSV rows on stdout; the gate logic lives in benchmarks/ci_gate.py.
"""
import os
import re
import sys
import time

# Strip any inherited device-count flag: XLA keeps the LAST occurrence,
# so a caller's exported count would silently override the 8 needed here.
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + _inherited)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.analysis.hlo_budget import (  # noqa: E402
    count_collective_permutes_lowered)
from repro.core import (CollectiveSpec, alltoallv_round_widths,  # noqa: E402
                        ceil_log2, plan)
from repro.core import collectives as C  # noqa: E402

NDEV = 8
mesh = compat.make_mesh((NDEV,), ("x",))
rng = np.random.default_rng(17)
BLK = 256


def jitted(fn, check_vma=None):
    return jax.jit(compat.shard_map(
        lambda v: fn(v[0])[None], mesh=mesh, in_specs=(P("x"),),
        out_specs=P("x"), check_vma=check_vma))


def count_cp(f, shape):
    return count_collective_permutes_lowered(f, shape)


def timeit(f, x, iters=10):
    f(x).block_until_ready()  # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


theory = ceil_log2(NDEV)

# --- uniform alltoall: jnp vs fused, cp counts, paired-median ratio -------
x = jnp.asarray(rng.standard_normal((NDEV, NDEV, BLK)), jnp.float32)
f_jnp = jitted(lambda v: C.circulant_alltoall(v, "x"))
f_fused = jitted(lambda v: C.circulant_alltoall(v, "x",
                                                use_fused_kernel=True),
                 check_vma=False)
cp_j = count_cp(f_jnp, (NDEV, NDEV, BLK))
cp_f = count_cp(f_fused, (NDEV, NDEV, BLK))
out_j, out_f = np.asarray(f_jnp(x)), np.asarray(f_fused(x))
bitwise = bool((out_j == out_f).all())
# Paired back-to-back reps: per-rep ratios cancel common-mode machine
# load drift; report the median of the paired ratios.
t_j, t_f, ratios = 1e30, 1e30, []
for _ in range(7):
    tf = timeit(f_fused, x)
    tj = timeit(f_jnp, x)
    ratios.append(tf / tj)
    t_j, t_f = min(t_j, tj), min(t_f, tf)
ratio = sorted(ratios)[len(ratios) // 2]
print(f"a2a/alltoall_jnp,{t_j:.3f},"
      f"cp={cp_j};theory={theory};cp_delta={cp_j - theory}")
print(f"a2a/alltoall_fused,{t_f:.3f},"
      f"cp={cp_f};theory={theory};cp_delta={cp_f - theory};"
      f"bitwise={bitwise};ratio={ratio:.3f};unfused_us={t_j:.3f};"
      f"interpret=True")

# --- ragged alltoallv: cp counts + wire width == analytic bound ----------
CASES = {
    "ragged": tuple(tuple((i * 5 + j * 3 + 1) % 4 for j in range(NDEV))
                    for i in range(NDEV)),
    "one_rank": tuple(tuple((i + 1) * BLK if j == NDEV // 2 else 0
                            for j in range(NDEV)) for i in range(NDEV)),
}
for name, counts in CASES.items():
    spec = CollectiveSpec(counts=counts)
    pl = plan(spec, p=NDEV, axis_name="x")
    widths = pl.a2a.round_widths
    bounds = alltoallv_round_widths(counts)
    width_ok = widths == bounds
    in_h = pl.a2a.in_height
    xv = jnp.asarray(rng.standard_normal((NDEV, in_h, 4)), jnp.float32)
    fv = jitted(lambda v, s=spec: C.alltoall(v, "x", spec=s))
    cp = count_cp(fv, (NDEV, in_h, 4))
    us = timeit(fv, xv)
    print(f"a2a/alltoallv_{name},{us:.3f},"
          f"cp={cp};theory={theory};cp_delta={cp - theory};"
          f"widths={'/'.join(map(str, widths))};"
          f"bounds={'/'.join(map(str, bounds))};width_ok={width_ok}")

# --- MoE expert-parallel parity (ragged ownership over the mesh) ---------
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.moe import init_moe, moe_ffn  # noqa: E402

pe, e = 2, 3
mesh2 = compat.make_mesh((pe,), ("x",), devices=jax.devices()[:pe])
cfg = ModelConfig(name="bench-moe", family="moe", n_layers=1, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=128,
                  head_dim=8, n_experts=e, experts_per_token=2,
                  capacity_factor=8.0, dtype="float32",
                  moe_dispatch="ep", ep_axis="x")
params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
xm = jax.random.normal(jax.random.PRNGKey(1), (pe, 16, cfg.d_model),
                       jnp.float32)
fe = jax.jit(compat.shard_map(
    lambda v: moe_ffn(params, cfg, v)[0], mesh=mesh2,
    in_specs=(P("x"),), out_specs=P("x"), check_vma=False))
t0 = time.perf_counter()
out_ep = np.asarray(fe(xm))
compile_plus = (time.perf_counter() - t0) * 1e6
cfg_g = dataclasses.replace(cfg, moe_dispatch="global")
out_g = np.concatenate(
    [np.asarray(moe_ffn(params, cfg_g, xm[r:r + 1])[0])
     for r in range(pe)], axis=0)
ok = bool(np.allclose(out_ep, out_g, rtol=2e-5, atol=2e-5))
us = timeit(fe, xm)
cp = count_collective_permutes_lowered(fe, xm.shape)
# 3 exchanges per layer call (counts alltoallv + buffer out + buffer
# back), ceil(log2 pe) ppermutes each.
theory_ep = 3 * ceil_log2(pe)
print(f"a2a/moe_ep_parity,{us:.3f},"
      f"allclose={ok};cp={cp};theory={theory_ep};"
      f"cp_delta={cp - theory_ep};ranks={pe};experts={e};"
      f"compile_us={compile_plus:.0f}")
