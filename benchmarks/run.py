"""Benchmark harness — one benchmark per paper table/claim.

The paper (Träff 2024) is an algorithms paper: its quantitative content is
Theorem 1/2 (round/volume optimality), Corollaries 1-3 (α-β-γ cost model)
and the Corollary-2 schedule family.  Benchmarks:

  rounds       exact round/block/⊕ counts vs theory (Theorem 1/2)
  cost_model   predicted T(m,p) per algorithm/schedule (Corollary 1/3),
               including the beyond-paper torus hop refinement
  collectives  wall-clock of the shard_map collectives on 8 simulated
               devices (subprocess; structure demo, not TPU perf)
  kernels      Pallas interpret-mode vs jnp-ref timing + allclose
  wire         measured bytes-on-wire per (collective × wire format) from
               compiled HLO vs the analytic codes+scales budget — the
               int8 wire format's ~3.9x β-term reduction, machine-checked
  plans        plan/execute API overhead: spec-driven dispatch retraces
               (want 0; frozen spec + cached plan) and collective-permute
               delta vs the schedule round count (want 0), incl. the
               non-uniform Corollary-3 specs
  a2a          alltoall(v): HLO collective-permutes == ceil(log2 p) for
               uniform, fused AND ragged per-pair counts; alltoallv wire
               widths == the analytic worst-windowed-count-sum bound;
               fused/jnp ratio; MoE ep-vs-global dispatch parity
  overlap      bucketed, software-pipelined grad sync: per-bucket HLO
               collective-permutes == B*ceil(log2 p) per RS (2x for AR),
               pipelined drivers bitwise == one-shot, bucketed ZeRO-1
               step within 1.05x of unbucketed, trajectory within wire
               tolerances
  elastic      rank-failure drills: mid-run shrink (4->3, injected rank
               loss + transient ckpt-IO faults) and grow (2->4) resume
               within one step boundary; re-plan+verify latency per spec;
               post-resize trajectory vs uninterrupted p' reference
  serve        continuous-batching serving: steady-state tokens/s and
               p50/p99 per-boundary latency over a staggered request
               mix, bitwise scheduler-vs-one-shot parity, and the
               broadcast plan's HLO collective-permutes == ceil(log2 p)
               weight fan-out gate
  roofline     re-emit the dry-run roofline table (reads reports/dryrun)

Output: ``name,us_per_call,derived`` CSV rows.
Usage:  PYTHONPATH=src python -m benchmarks.run [--only rounds,kernels]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.3f},{derived}")


# ---------------------------------------------------------------------------
def bench_rounds():
    from repro.core import simulator as sim
    from repro.core.schedule import ceil_log2

    for p in [2, 3, 7, 8, 22, 31, 64, 100, 255, 256, 257, 1000]:
        inputs = [[np.ones(1, np.float64) for _ in range(p)]
                  for _ in range(p)]
        t0 = time.perf_counter()
        _, st = sim.simulate_reduce_scatter(inputs)
        us = (time.perf_counter() - t0) * 1e6
        st.assert_theorem1(p)
        emit(f"rounds/reduce_scatter_p{p}", us,
             f"rounds={st.rounds};blocks={st.blocks_sent[0]};"
             f"theory_rounds={ceil_log2(p)};theory_blocks={p - 1}")
    for p in [8, 22, 64, 257]:
        inputs = [[np.ones(1, np.float64) for _ in range(p)]
                  for _ in range(p)]
        t0 = time.perf_counter()
        _, st = sim.simulate_allreduce(inputs)
        us = (time.perf_counter() - t0) * 1e6
        st.assert_theorem2(p)
        emit(f"rounds/allreduce_p{p}", us,
             f"rounds={st.rounds};blocks={st.blocks_sent[0]};"
             f"theory_rounds={2 * ceil_log2(p)};theory_blocks={2 * (p - 1)}")


# ---------------------------------------------------------------------------
def bench_cost_model():
    from repro.core import cost_model as cm

    model = cm.CommModel.tpu_v5e()
    for p in [16, 64, 256, 1024]:
        for m in [4096, 1 << 20, 1 << 28]:
            rows = {
                "circulant": cm.t_allreduce(m, p, model),
                "circulant_torus": cm.t_allreduce(m, p, model, torus=True),
                "ring": cm.t_ring_allreduce(m, p, model),
                "reduce_bcast": cm.t_bcast_reduce_allreduce(m, p, model),
            }
            best = min(rows, key=rows.get)
            for name, t in rows.items():
                emit(f"cost_model/allreduce_p{p}_m{m}/{name}", t * 1e6,
                     f"best={best}")
        x = cm.crossover_m(p, model)
        emit(f"cost_model/torus_crossover_p{p}", 0.0,
             f"ring_beats_circulant_above_m={x:.3g}")
    # Alltoall: hop-through-intermediate-ranks β volume (Bruck trade-off).
    for p in [16, 64, 256]:
        m = 1 << 20
        entries = cm.a2a_round_entries(p)
        emit(f"cost_model/alltoall_p{p}_m{m}", cm.t_alltoall(m, p, model) * 1e6,
             f"rounds={len(entries)};blocks_sent={sum(entries)};"
             f"volume_amplification={sum(entries) / (p - 1):.2f}x")


# ---------------------------------------------------------------------------
def bench_collectives():
    here = os.path.dirname(os.path.abspath(__file__))
    worker = os.path.join(here, "_collective_worker.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=900, env=env)
    if proc.returncode != 0:
        emit("collectives/ERROR", 0.0, proc.stderr[-200:].replace("\n", " "))
        return
    print(proc.stdout, end="")


# ---------------------------------------------------------------------------
def bench_plans():
    """Plan/execute API overhead gate: spec-driven dispatch must be
    trace-free across repeated calls (frozen spec + lru-cached plan) and
    must add zero collective-permutes over the schedule's round count —
    the pre-redesign kwarg baseline.  Subprocess (needs fake devices)."""
    here = os.path.dirname(os.path.abspath(__file__))
    worker = os.path.join(here, "_plan_worker.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=900, env=env)
    if proc.returncode != 0:
        emit("plans/ERROR", 0.0, proc.stderr[-200:].replace("\n", " "))
        return
    print(proc.stdout, end="")


# ---------------------------------------------------------------------------
def bench_a2a():
    """Alltoall(v) structural gate: round counts, ragged wire widths vs
    the analytic bound, fused ratio, MoE ep parity.  Subprocess (needs
    fake devices)."""
    here = os.path.dirname(os.path.abspath(__file__))
    worker = os.path.join(here, "_a2a_worker.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=900, env=env)
    if proc.returncode != 0:
        emit("a2a/ERROR", 0.0, proc.stderr[-200:].replace("\n", " "))
        return
    print(proc.stdout, end="")


# ---------------------------------------------------------------------------
def bench_overlap():
    """Bucketed/overlapped grad-sync gate: pipelined round budgets,
    bucketed-vs-unbucketed step ratio, trajectory equivalence.
    Subprocess (needs fake devices)."""
    here = os.path.dirname(os.path.abspath(__file__))
    worker = os.path.join(here, "_overlap_worker.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=1200, env=env)
    if proc.returncode != 0:
        emit("overlap/ERROR", 0.0, proc.stderr[-200:].replace("\n", " "))
        return
    print(proc.stdout, end="")


# ---------------------------------------------------------------------------
def bench_elastic():
    """Elastic fault-tolerance gate: shrink/grow drills resume within a
    step boundary with verified re-plans and a reference-matching
    post-resize trajectory.  Subprocess (needs fake devices)."""
    here = os.path.dirname(os.path.abspath(__file__))
    worker = os.path.join(here, "_elastic_worker.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=1800, env=env)
    if proc.returncode != 0:
        emit("elastic/ERROR", 0.0, proc.stderr[-200:].replace("\n", " "))
        return
    print(proc.stdout, end="")


# ---------------------------------------------------------------------------
def bench_serve():
    """Serving gate: continuous-batching throughput + per-boundary p50/
    p99 latency, bitwise scheduler-vs-one-shot parity, and the
    ``kind="broadcast"`` weight-fan-out round counts (HLO collective-
    permutes == ceil(log2 p)).  Subprocess (needs fake devices)."""
    here = os.path.dirname(os.path.abspath(__file__))
    worker = os.path.join(here, "_serve_worker.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=1800, env=env)
    if proc.returncode != 0:
        emit("serve/ERROR", 0.0, proc.stderr[-200:].replace("\n", " "))
        return
    print(proc.stdout, end="")


# ---------------------------------------------------------------------------
def bench_wire():
    here = os.path.dirname(os.path.abspath(__file__))
    worker = os.path.join(here, "_wire_worker.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, worker], capture_output=True,
                          text=True, timeout=900, env=env)
    if proc.returncode != 0:
        emit("wire/ERROR", 0.0, proc.stderr[-200:].replace("\n", " "))
        return
    print(proc.stdout, end="")


# ---------------------------------------------------------------------------
def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels import (fused_block_reduce, fused_round,
                               quantize_blocks)
    from repro.kernels import ref as R

    rng = np.random.default_rng(0)
    for shape in [(256, 512), (1024, 2048)]:
        a = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        b = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        fused_block_reduce(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            out = fused_block_reduce(a, b)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / 5 * 1e6
        ref = R.block_reduce_ref(a, b)
        ok = bool(jnp.allclose(out, ref))
        emit(f"kernels/block_reduce_{shape[0]}x{shape[1]}", us,
             f"allclose={ok};interpret=True")

    # Fused circulant round (fold + next-send layout, one pass) vs the
    # unfused jnp chain (reduce + concat + 2 slices) on one mid-game round
    # shape: live 8 blocks, 4 received, keep/send split at 4.
    def one_round(f):
        @jax.jit
        def run(live, T):
            return f(live, T, nb=4, next_lo=4, op="add")
        return run

    fused_fn = one_round(fused_round)
    unfused_fn = one_round(R.fused_round_ref)

    def timed(f, live, T, iters=20):
        t0 = time.perf_counter()
        for _ in range(iters):
            k, s = f(live, T)
        k.block_until_ready()
        s.block_until_ready()
        return (time.perf_counter() - t0) / iters * 1e6

    for cols in [16384, 65536]:
        live = jnp.asarray(rng.standard_normal((8, cols)), jnp.float32)
        T = jnp.asarray(rng.standard_normal((4, cols)), jnp.float32)
        for f in (fused_fn, unfused_fn):  # warm up both before timing
            k, s = f(live, T)
            k.block_until_ready()
        # Paired back-to-back reps: per-rep ratios cancel common-mode
        # machine-load drift (shared CI runners swing several-x); the
        # reported ratio is the median of the paired ratios.
        t_fused, t_unfused, ratios = 1e30, 1e30, []
        for _ in range(9):
            tf = timed(fused_fn, live, T)
            tu = timed(unfused_fn, live, T)
            ratios.append(tf / tu)
            t_fused, t_unfused = min(t_fused, tf), min(t_unfused, tu)
        ratio = sorted(ratios)[len(ratios) // 2]
        kf, sf = fused_fn(live, T)
        ku, su = unfused_fn(live, T)
        ok = bool(jnp.array_equal(kf, ku) and jnp.array_equal(sf, su))
        emit(f"kernels/fused_round_8x{cols}", t_fused,
             f"bitwise={ok};unfused_us={t_unfused:.3f};"
             f"ratio={ratio:.3f};interpret=True")

    x = jnp.asarray(rng.standard_normal((16, 4096)), jnp.float32)
    t0 = time.perf_counter()
    payload = quantize_blocks(x, group=512)
    comp = payload["codes"].size + payload["scales"].size * 4
    us = (time.perf_counter() - t0) * 1e6
    emit("kernels/quantize_16x4096", us,
         f"compression={x.size * 4 / comp:.2f}x")

    # Compressed round (dequant + fold + requant-next-send, one pass) vs
    # its jnp oracle on the same mid-game round geometry; both jitted —
    # under jit the two are bitwise-equal (identical arithmetic; XLA
    # makes the same contraction choices for both graphs).
    from repro.kernels import fused_round_dq
    from repro.kernels.ref import fused_round_dq_ref, quantize_ref

    def one_dq_round(f):
        @jax.jit
        def run(live, c, s):
            return f(live, c, s, nb=4, next_lo=4, op="add", group=512)
        return run

    dq_fused = one_dq_round(fused_round_dq)
    dq_ref = one_dq_round(fused_round_dq_ref)
    for cols in [16384, 65536]:
        live = jnp.asarray(rng.standard_normal((8, cols)), jnp.float32)
        c, s = quantize_ref(
            jnp.asarray(rng.standard_normal((4, cols)), jnp.float32),
            group=512)
        c, s = jax.device_put(c), jax.device_put(s)

        def timed_dq(f, iters=20):
            t0 = time.perf_counter()
            for _ in range(iters):
                k, sd = f(live, c, s)
            k.block_until_ready()
            return (time.perf_counter() - t0) / iters * 1e6

        for f in (dq_fused, dq_ref):
            k, _ = f(live, c, s)
            k.block_until_ready()
        t_fused, t_ref, ratios = 1e30, 1e30, []
        for _ in range(9):
            tf, tu = timed_dq(dq_fused), timed_dq(dq_ref)
            ratios.append(tf / tu)
            t_fused, t_ref = min(t_fused, tf), min(t_ref, tu)
        ratio = sorted(ratios)[len(ratios) // 2]
        kf, sf = dq_fused(live, c, s)
        ku, su = dq_ref(live, c, s)
        ok = bool(jnp.array_equal(kf, ku)
                  and jnp.array_equal(sf[0], su[0])
                  and jnp.array_equal(sf[1], su[1]))
        emit(f"kernels/fused_round_dq_8x{cols}", t_fused,
             f"bitwise={ok};unfused_us={t_ref:.3f};"
             f"ratio={ratio:.3f};interpret=True")


# ---------------------------------------------------------------------------
def bench_analysis():
    """Static-analysis gate: ``python -m repro.analysis --all`` must exit
    clean (plan verifier sweep, jaxpr lint, HLO audit, repo lint).
    Subprocess — the CLI forces its own fake-device XLA_FLAGS."""
    import tempfile
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
        report_path = tf.name
    try:
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--all",
             "--json", report_path],
            capture_output=True, text=True, timeout=900, env=env, cwd=root)
        us = (time.perf_counter() - t0) * 1e6
        try:
            rep = json.load(open(report_path))
        except (OSError, ValueError):
            rep = None
        if proc.returncode != 0 or rep is None:
            n = rep["n_findings"] if rep else -1
            emit("analysis/ERROR", us,
                 f"findings={n};rc={proc.returncode};"
                 + proc.stdout[-160:].replace("\n", " ").replace(",", " "))
            return
        by_pass = rep["findings_by_pass"]
        for pass_name in rep["passes_run"]:
            emit(f"analysis/{pass_name}", us / len(rep["passes_run"]),
                 f"findings={by_pass.get(pass_name, 0)};"
                 f"waived={len(rep.get('waived', [])) if pass_name == 'repo' else 0};"
                 f"ok={rep['ok']}")
    finally:
        try:
            os.unlink(report_path)
        except OSError:
            pass


# ---------------------------------------------------------------------------
def bench_roofline():
    d = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "reports", "dryrun")
    if not os.path.isdir(d):
        emit("roofline/NO_REPORTS", 0.0, "run repro.launch.dryrun first")
        return
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(".json"):
            continue
        r = json.load(open(os.path.join(d, fn)))
        if r.get("status") != "OK":
            emit(f"roofline/{fn[:-5]}", 0.0, r.get("status", "?")[:60])
            continue
        rl = r["roofline"]
        t_star = max(rl["t_compute_s"], rl["t_memory_s"],
                     rl["t_collective_s"])
        # 2pod records are compiled with --no-correction (mesh-pass only):
        # their collective term misses loop-resident collectives.
        note = (";collective_uncorrected"
                if not r.get("corr_multiplier") and "_2pod" in fn else "")
        emit(f"roofline/{fn[:-5]}", t_star * 1e6,
             f"bottleneck={rl['bottleneck']};"
             f"frac={rl['roofline_fraction']:.4f};"
             f"c={rl['t_compute_s']:.4f};m={rl['t_memory_s']:.4f};"
             f"x={rl['t_collective_s']:.4f}{note}")


BENCHES = {
    "rounds": bench_rounds,
    "cost_model": bench_cost_model,
    "collectives": bench_collectives,
    "kernels": bench_kernels,
    "wire": bench_wire,
    "plans": bench_plans,
    "a2a": bench_a2a,
    "overlap": bench_overlap,
    "elastic": bench_elastic,
    "serve": bench_serve,
    "analysis": bench_analysis,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main()
