"""Subprocess worker for bench_wire: measured bytes-on-wire per
(collective × wire format) on 8 fake CPU devices.

For each of f32 / bf16 / int8-wire the circulant RS and AR are compiled
and the post-SPMD HLO's collective-permute payload bytes are summed
(roofline.analysis.parse_collectives) — the MEASURED wire volume — then
compared against the analytic codes+scales budget:

    RS: (p-1) * wire_width(cols)   bytes/rank     (wire_width = cols + 4*ng
    AR: 2*(p-1) * wire_width(cols)                 for int8; elem_bytes*cols
                                                   uncompressed)

Rows additionally carry the collective-permute count (must equal the
Theorem 1/2 round count — compression must not change the structure) and
the payload reduction vs f32.  Exec time is the paired wall-clock of the
jitted collective (structure demo on CPU, not TPU perf).

Run: python benchmarks/_wire_worker.py
"""
import os
import sys
import time

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import collectives as C  # noqa: E402
from repro.core.schedule import ceil_log2  # noqa: E402
from repro.kernels import wire_width  # noqa: E402
from repro.analysis.hlo_budget import parse_collectives  # noqa: E402

NDEV = 8
GROUP = 512
mesh = compat.make_mesh((NDEV,), ("x",))
rng = np.random.default_rng(0)


def build(fn):
    return jax.jit(compat.shard_map(
        lambda v: fn(v[0])[None], mesh=mesh,
        in_specs=(P("x"),), out_specs=P("x"), check_vma=False))


def timed_us(f, x, iters=5):
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def rows_for(coll: str, n_elem: int):
    p = NDEV
    cols = n_elem // p  # elements per block
    q = ceil_log2(p)
    variants = {
        # label -> (dtype, wire_dtype, bytes-per-elem on the wire)
        "f32": (jnp.float32, None, 4.0),
        "bf16": (jnp.bfloat16, None, 2.0),
        "int8": (jnp.float32, "int8", None),
    }
    mk = {
        "rs": lambda wd: (lambda v: C.circulant_reduce_scatter(
            v, "x", wire_dtype=wd, wire_group=GROUP)),
        "ar": lambda wd: (lambda v: C.circulant_allreduce(
            v, "x", wire_dtype=wd, wire_group=GROUP)),
    }[coll]
    phases = 1 if coll == "rs" else 2
    rounds_want = q * phases
    f32_bytes = None
    for label, (dt, wd, bpe) in variants.items():
        x = jnp.asarray(rng.standard_normal((p, n_elem)), dt)
        f = build(mk(wd))
        us = timed_us(f, x)
        stats = parse_collectives(f.lower(x).compile().as_text())
        n_cp = stats.ops.get("collective-permute", 0)
        cp_bytes = int(stats.raw_bytes_by_op.get("collective-permute", 0))
        if wd == "int8":
            budget = phases * (p - 1) * wire_width(cols, GROUP)
        else:
            budget = int(phases * (p - 1) * cols * bpe)
        assert n_cp == rounds_want, \
            f"{coll}/{label}: {n_cp} collective-permutes, want {rounds_want}"
        extra = ""
        if label == "bf16":
            # The CPU backend widens bf16 collectives to f32, so the
            # measured bytes are a backend artifact — report, don't gate.
            extra = ";note=cpu_widens_bf16"
        else:
            assert cp_bytes <= budget, \
                (f"{coll}/{label}: {cp_bytes} wire bytes exceed the "
                 f"analytic budget {budget}")
            extra = f";within_budget={cp_bytes <= budget}"
        if label == "f32":
            f32_bytes = cp_bytes
        elif f32_bytes:
            extra += f";reduction_vs_f32={f32_bytes / cp_bytes:.3f}"
        print(f"wire/{coll}_p{p}_n{n_elem}_{label},{us:.3f},"
              f"cp_bytes={cp_bytes};budget={budget};rounds={n_cp};"
              f"theory_rounds={rounds_want}{extra}")


for n_elem in (1 << 15, 1 << 18):
    rows_for("rs", n_elem)
    rows_for("ar", n_elem)
