"""Subprocess worker for bench_plans: the plan/execute API's trace-free
and zero-overhead guarantees, measured end-to-end on 8 fake CPU devices.

Per spec it emits one CSV row with:

  retraces       extra jit traces across repeated calls with the SAME
                 spec after the first (want 0 — CollectiveSpec is frozen/
                 hashable and plan() is lru-cached, so spec-driven
                 dispatch must never retrace);
  plan_rebuilds  plan-cache misses beyond the first compile (want 0);
  cp / theory    lowered-HLO collective-permute count vs the schedule's
                 round count (x2 for allreduce) — plan-based dispatch
                 must add ZERO collectives over the pre-redesign kwarg
                 baseline, whose count equalled theory exactly (asserted
                 by the conformance harness since PR 1);
  cp_delta       cp - theory (want 0).

Emits CSV rows on stdout; the gate logic lives in benchmarks/ci_gate.py.
"""
import os
import sys
import time

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.analysis.hlo_budget import (  # noqa: E402
    count_collective_permutes_lowered)
from repro.core import CollectiveSpec, plan  # noqa: E402
from repro.core import collectives as C  # noqa: E402
from repro.core.schedule import ceil_log2, get_skips  # noqa: E402

NDEV = 8
mesh = compat.make_mesh((NDEV,), ("x",))
rng = np.random.default_rng(7)

NONUNIFORM = tuple((i * 5 + 3) % 7 for i in range(NDEV))

CASES = [
    # (name, spec, collective, rounds multiplier)
    ("rs_halving", CollectiveSpec(), "reduce_scatter", 1),
    ("rs_power2", CollectiveSpec(schedule="power2"), "reduce_scatter", 1),
    ("ar_halving", CollectiveSpec(), "allreduce", 2),
    ("rs_int8", CollectiveSpec(wire_dtype="int8"), "reduce_scatter", 1),
    ("rs_nonuniform", CollectiveSpec(counts=NONUNIFORM),
     "reduce_scatter", 1),
    ("ar_nonuniform", CollectiveSpec(counts=NONUNIFORM), "allreduce", 2),
]


def payload_for(spec: CollectiveSpec) -> np.ndarray:
    n = sum(spec.counts) if spec.counts else NDEV * 512
    return rng.standard_normal((NDEV, n)).astype(np.float32)


for name, spec, coll, mult in CASES:
    traces = 0
    entry = getattr(C, coll)

    def body(v, _spec=spec, _entry=entry):
        global traces
        traces += 1
        return _entry(v[0], "x", spec=_spec)[None]

    f = jax.jit(compat.shard_map(body, mesh=mesh,
                                 in_specs=(P("x"),), out_specs=P("x")))
    x = jnp.asarray(payload_for(spec))
    misses0 = plan.cache_stats().misses
    f(x).block_until_ready()          # first call: the one allowed trace
    t0 = time.perf_counter()
    iters = 10
    for _ in range(iters):
        out = f(x)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / iters * 1e6
    retraces = traces - 1
    rebuilds = max(plan.cache_stats().misses - misses0 - 1, 0)

    theory = mult * len(get_skips(NDEV, spec.schedule))
    cp = count_collective_permutes_lowered(f, x.shape)
    print(f"plans/{name},{us:.3f},"
          f"retraces={retraces};plan_rebuilds={rebuilds};"
          f"cp={cp};theory={theory};cp_delta={cp - theory};"
          f"rounds_opt={ceil_log2(NDEV) * mult};backend-registry=ok")
