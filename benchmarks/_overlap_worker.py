"""Subprocess worker for bench_overlap: the bucketed, software-pipelined
grad-sync path measured end-to-end on 8 fake CPU devices.

Per spec it emits CSV rows with:

  rs_pipelined_p8   B-payload pipelined reduce-scatter: lowered-HLO
                    collective-permute count vs B*ceil(log2 p) (cp_delta,
                    want 0 — one ppermute per round per bucket, rounds
                    interleaved at the start_round/finish_round seam) and
                    bitwise equality against the one-shot path;
  ar_pipelined_p8   same for allreduce (RS+AG): cp vs 2*B*ceil(log2 p);
  step_unbucketed / step_bucketed
                    min-of-N ZeRO-1 train-step wall clock on the smoke
                    config at the launcher-default seq_len (the regime
                    the gate is about: sync cost amortized against a
                    realistic step), unbucketed vs bucket_bytes-
                    partitioned; the bucketed row carries ratio = median
                    of paired bucketed/unbucketed reps (want <= 1.05 —
                    bucketing must not cost a serial slowdown);
  step_hlo          lowered bucketed train step: data-axis collective-
                    permutes vs 2*B*ceil(log2 d) (cp_delta, want 0);
  trajectory        short bucketed-f32 training run bitwise-equal to
                    unbucketed (bitwise flag) and bucketed int8+EF within
                    the documented wire tolerance of it (within_tol).

Emits CSV rows on stdout; the gate logic lives in benchmarks/ci_gate.py.
"""
import os
import sys
import time

import re  # noqa: E402 — strip inherited count: XLA keeps the LAST flag
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + _inherited)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.analysis.hlo_budget import (  # noqa: E402
    count_collective_permutes)
from repro.configs import get_config  # noqa: E402
from repro.core import CollectiveSpec, plan  # noqa: E402
from repro.core.schedule import ceil_log2  # noqa: E402
from repro.data import for_model  # noqa: E402
from repro.models import ShardingRecipe, build  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.optim.zero1 import (GradSyncConfig, is_zero_leaf,  # noqa: E402
                               plan_grad_buckets)
from repro.train import build as build_step  # noqa: E402

NDEV = 8
rng = np.random.default_rng(11)

# --------------------------------------------------------------------------
# Pipelined RS / AR on a 1-D mesh: per-bucket round budget + bitwise check.
# --------------------------------------------------------------------------
mesh1 = compat.make_mesh((NDEV,), ("x",))
q = ceil_log2(NDEV)
SHAPES = [(NDEV * 8,), (NDEV * 4,), (NDEV * 6,)]
B = len(SHAPES)
pl = plan(CollectiveSpec(), p=NDEV, axis_name="x")


def sharded(fn, nshapes):
    return jax.jit(compat.shard_map(
        lambda *vs: tuple(o[None] for o in fn([v[0] for v in vs])),
        mesh=mesh1, in_specs=tuple(P("x") for _ in range(nshapes)),
        out_specs=tuple(P("x") for _ in range(nshapes)), check_vma=False))


def timed(f, xs, iters=10):
    outs = f(*xs)
    jax.block_until_ready(outs)
    t0 = time.perf_counter()
    for _ in range(iters):
        outs = f(*xs)
    jax.block_until_ready(outs)
    return (time.perf_counter() - t0) / iters * 1e6


xs = [jnp.asarray(rng.standard_normal((NDEV, *s)).astype(np.float32))
      for s in SHAPES]

f_one = sharded(lambda vs: [pl.reduce_scatter(v) for v in vs], B)
f_pipe = sharded(lambda vs: pl.reduce_scatter_pipelined(vs), B)
bitwise = all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(f_one(*xs), f_pipe(*xs)))
avals = [jax.ShapeDtypeStruct((NDEV, *s), jnp.float32) for s in SHAPES]
cp = count_collective_permutes(f_pipe.lower(*avals).as_text())
us = timed(f_pipe, xs)
print(f"overlap/rs_pipelined_p{NDEV},{us:.3f},"
      f"bitwise={bitwise};cp={cp};theory={B * q};"
      f"cp_delta={cp - B * q};buckets={B}")

f_ar_pipe = sharded(
    lambda vs: pl.allgather_pipelined(pl.reduce_scatter_pipelined(vs)), B)
f_ar_one = sharded(
    lambda vs: [pl.allgather(pl.reduce_scatter(v)) for v in vs], B)
bitwise = all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(f_ar_one(*xs), f_ar_pipe(*xs)))
cp = count_collective_permutes(f_ar_pipe.lower(*avals).as_text())
us = timed(f_ar_pipe, xs)
print(f"overlap/ar_pipelined_p{NDEV},{us:.3f},"
      f"bitwise={bitwise};cp={cp};theory={2 * B * q};"
      f"cp_delta={cp - 2 * B * q};buckets={B}")

# --------------------------------------------------------------------------
# ZeRO-1 smoke config: bucketed vs unbucketed train step.
# --------------------------------------------------------------------------
DATA, MODEL = 4, 2
mesh = compat.make_mesh((DATA, MODEL), ("data", "model"))
cfg = get_config("qwen3-1.7b").scaled_down(n_layers=2, vocab_size=64)
opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50,
                      weight_decay=0.01)
pipe = for_model(cfg, seq_len=128, global_batch=8, seed=3)
BUCKET_BYTES = 1 << 18


def make_step(**sync_kw):
    recipe = ShardingRecipe(data_axes=("data",), model_axis="model")
    model = build(cfg, recipe=recipe, remat=False)
    with compat.use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
    sync = GradSyncConfig(quant_group=64, **sync_kw)  # impl defaults to circulant
    built = build_step("zero1", model, opt_cfg, mesh=mesh, recipe=recipe,
                       sync=sync)
    opt = jax.device_put(built.init_opt(params), built.opt_spec(params))
    return model, built, params, opt


def batch_at(step, built):
    return {k: jax.device_put(
        jnp.asarray(v), NamedSharding(mesh, built.batch_spec))
        for k, v in pipe.batch_at(step).items()}


def run_steps(built, params, opt, n):
    losses = []
    with compat.use_mesh(mesh):
        for step in range(n):
            params, opt, m = built.step_fn(params, opt, batch_at(step, built))
            losses.append(float(m["loss"]))
    return np.array(losses), params, opt


def time_step(built, params, opt, iters):
    b = batch_at(0, built)
    with compat.use_mesh(mesh):
        p2, o2, m = built.step_fn(params, opt, b)  # compile + warm
        jax.block_until_ready((p2, o2, m))
        t0 = time.perf_counter()
        for _ in range(iters):
            p2, o2, m = built.step_fn(params, opt, b)
        jax.block_until_ready((p2, o2, m))
    return (time.perf_counter() - t0) / iters * 1e6


model_u, built_u, params_u, opt_u = make_step()
model_b, built_b, params_b, opt_b = make_step(bucket_bytes=BUCKET_BYTES)

# Paired back-to-back reps: per-rep ratios cancel common-mode machine-load
# drift on shared runners; report min-of-reps times + the median ratio.
t_u, t_b, ratios = 1e30, 1e30, []
for _ in range(9):
    tu = time_step(built_u, params_u, opt_u, iters=5)
    tb = time_step(built_b, params_b, opt_b, iters=5)
    ratios.append(tb / tu)
    t_u, t_b = min(t_u, tu), min(t_b, tb)
ratio = sorted(ratios)[len(ratios) // 2]

# Bucket geometry of this config, for the per-bucket round budget.
abs_params = jax.eval_shape(model_b.init, jax.random.PRNGKey(0))
zshapes = [l.shape for l in jax.tree.leaves(abs_params)
           if is_zero_leaf(l.shape, DATA, GradSyncConfig().min_shard_numel)]
n_buckets = len(plan_grad_buckets(zshapes, DATA, BUCKET_BYTES, 4))
qd = ceil_log2(DATA)

print(f"overlap/step_unbucketed,{t_u:.3f},buckets=1")
print(f"overlap/step_bucketed,{t_b:.3f},"
      f"buckets={n_buckets};unbucketed_us={t_u:.3f};ratio={ratio:.3f}")

# Per-bucket round budget in the lowered train step: every bucket runs one
# circulant RS (q ppermutes) + one AG (q more) over the data axis; nothing
# else in the step emits a collective-permute (model-axis sync is psum).
b0 = batch_at(0, built_b)
with compat.use_mesh(mesh):
    hlo = jax.jit(built_b.step_fn).lower(params_b, opt_b, b0).as_text()
cp = count_collective_permutes(hlo)
theory = 2 * n_buckets * qd
print(f"overlap/step_hlo,0.000,"
      f"cp={cp};theory={theory};cp_delta={cp - theory};"
      f"buckets={n_buckets};rounds_per_rs={qd}")

# --------------------------------------------------------------------------
# Trajectory: bucketed f32 bitwise == unbucketed; bucketed int8+EF within
# the documented wire tolerance (README §Compressed wire format: 0.05 on
# the smoke config).
# --------------------------------------------------------------------------
N_STEPS = 4
TOL = 0.05
losses_u, _, _ = run_steps(built_u, params_u, opt_u, N_STEPS)
losses_b, _, _ = run_steps(built_b, params_b, opt_b, N_STEPS)
bitwise = bool(np.array_equal(losses_u, losses_b))
_, built_c, params_c, opt_c = make_step(bucket_bytes=BUCKET_BYTES,
                                        wire_dtype="int8")
losses_c, _, _ = run_steps(built_c, params_c, opt_c, N_STEPS)
err = float(np.abs(losses_c - losses_u).max())
print(f"overlap/trajectory,0.000,"
      f"bitwise={bitwise};max_err_int8={err:.2e};tol={TOL};"
      f"within_tol={err < TOL};steps={N_STEPS}")
