"""Subprocess worker for bench_collectives: wall-clock of the shard_map
collectives on 8 simulated CPU devices.  Emits CSV rows on stdout."""
import os
import sys
import time

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import CollectiveSpec  # noqa: E402
from repro.core import collectives as C  # noqa: E402

NDEV = 8
mesh = compat.make_mesh((NDEV,), ("x",))
rng = np.random.default_rng(0)


def timed(fn, x, iters=10):
    # check_vma=False: required for the fused rows (0.4.x shard_map has no
    # replication rule for pallas_call); harmless for the jnp rows.
    f = jax.jit(compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                                 in_specs=(P("x"),), out_specs=P("x"),
                                 check_vma=False))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


for n_elem in [1 << 12, 1 << 18, 1 << 22]:
    x = rng.standard_normal((NDEV, n_elem)).astype(np.float32)
    rows = {
        "circulant_rs": lambda v: C.circulant_reduce_scatter(v, "x"),
        "circulant_rs_pow2": lambda v: C.circulant_reduce_scatter(
            v, "x", schedule="power2"),
        "circulant_rs_fused": lambda v: C.circulant_reduce_scatter(
            v, "x", use_fused_kernel=True),
        "ring_rs": lambda v: C.ring_reduce_scatter(v, "x"),
        "xla_rs": lambda v: C.xla_reduce_scatter(v, "x"),
        "circulant_rs_int8": lambda v: C.circulant_reduce_scatter(
            v, "x", wire_dtype="int8"),
        "circulant_ar": lambda v: C.circulant_allreduce(v, "x"),
        "circulant_ar_fused": lambda v: C.circulant_allreduce(
            v, "x", use_fused_kernel=True),
        "circulant_ar_int8": lambda v: C.circulant_allreduce(
            v, "x", wire_dtype="int8"),
        "ring_ar": lambda v: C.ring_allreduce(v, "x"),
        "xla_psum": lambda v: C.xla_allreduce(v, "x"),
        # plan/execute API rows: same collectives through CollectiveSpec
        # dispatch (overhead must be invisible — plans are cached).
        "spec_rs": lambda v: C.reduce_scatter(
            v, "x", spec=CollectiveSpec()),
        "spec_ar_int8": lambda v: C.allreduce(
            v, "x", spec=CollectiveSpec(wire_dtype="int8")),
    }
    for name, fn in rows.items():
        us = timed(fn, x)
        print(f"collectives/{name}_n{n_elem},{us:.3f},ndev={NDEV}")

# Non-uniform (Corollary 3) reduce-scatter: worst case, one column holds
# the whole vector — every round ships ~n_elem rows from one rank.
for n_elem in [1 << 12, 1 << 18]:
    counts = [0] * NDEV
    counts[NDEV // 2] = n_elem
    spec = CollectiveSpec(counts=tuple(counts))
    x = rng.standard_normal((NDEV, n_elem)).astype(np.float32)
    us = timed(lambda v: C.reduce_scatter(v, "x", spec=spec), x)
    print(f"collectives/spec_rs_onecol_n{n_elem},{us:.3f},ndev={NDEV}")
