"""CI benchmark gate: run the smoke benchmarks, archive them as JSON, fail on violations.

Runs ``benchmarks.run --only rounds,kernels,wire`` in a subprocess (the rounds bench itself
raises on any ``assert_theorem1/2`` violation and the wire bench on any round-count or byte-
budget violation, which this gate surfaces as failures), parses the CSV into ``BENCH_ci.json``
(the perf-trajectory artifact CI uploads per commit), and additionally asserts:

* static-analysis rows (``analysis/``): ``python -m repro.analysis --all`` (plan verifier
  sweep, jaxpr lint, HLO audit, repo-invariant lint) reports zero findings — ratcheted
  repo-lint exemptions live in ``analysis_ratchet.json`` and are waived, not counted;

* no ``ERROR`` rows and every kernel ``allclose``/``bitwise`` flag true (the Pallas kernels agree
  with their jnp oracles);
* the fused round kernels (plain AND compressed-dq) stay within ``FUSED_RATIO_MAX`` of their
  unfused jnp chains in interpret mode — a regression backstop, not a speedup claim: on shared
  CI runners interpret-mode timing is noisy, so the bound is deliberately loose (on a quiet
  machine the median ratio is ~1.0 at the benched shapes; the compiled TPU path is where the
  fused pass wins);
* compressed-wire rows: every asserted row is ``within_budget`` (measured collective-permute
  bytes <= the analytic codes+scales budget), int8 rows show >= ``WIRE_REDUCTION_MIN`` payload
  reduction vs f32, and the collective-permute count equals the Theorem 1/2 round count;
* plan/execute rows (``plans/``): spec-driven dispatch is trace-free (zero jit retraces and
  zero plan-cache rebuilds across repeated calls with the same ``CollectiveSpec``) and adds
  zero collective-permutes over the schedule's round count — including the non-uniform
  (Corollary 3) specs;
* alltoall(v) rows (``a2a/``): HLO collective-permute count == ceil(log2 p) for the uniform,
  fused AND ragged (per-pair counts) forms; the alltoallv wire widths equal the analytic
  worst-windowed-count-sum bound exactly; the fused/jnp uniform alltoall stays within
  ``A2A_RATIO_MAX``; and the MoE expert-parallel dispatch (``moe_dispatch='ep'``, ragged
  expert ownership) matches the single-pool 'global' reference (``allclose=True``);
* bucketed-overlap rows (``overlap/``): the pipelined multi-payload RS/AR and the bucketed
  ZeRO-1 train step lower to exactly B * ceil(log2 p) collective-permutes per RS (2x for
  allreduce) — one ppermute per round per bucket, nothing extra from the round seam
  (``cp_delta == 0``); the pipelined drivers are bitwise-equal to the one-shot path; the
  bucketed step stays within ``OVERLAP_RATIO_MAX`` of the unbucketed step (median of paired
  reps at the launcher-default seq_len); and the bucketed int8+EF trajectory stays inside the
  documented wire tolerance (``within_tol``);
* elastic drill rows (``elastic/``): the mid-run shrink (rank loss at world 4 -> 3, with
  transient checkpoint-IO faults injected during recovery) and grow (2 -> 4) drills both
  resume ``within_boundary`` (lost steps <= ckpt_every; zero for the grow path's synchronous
  drain checkpoint) without falling back to a clean restart; every re-planned spec passes
  ``assert_verified`` within the per-spec latency budget (``within_budget``); and the
  post-resize loss trajectory matches an uninterrupted p' run restored from the same
  checkpoint — f32 bitwise (generic ``bitwise`` check), int8+EF inside the documented 0.05
  envelope (``within_tol``);
* serving rows (``serve/``): the continuous-batching scheduler reports steady-state
  throughput (tokens/s) and p50/p99 per-boundary latency; every request's scheduler token
  stream is bitwise-identical to one-shot ``generate`` (the ``parity`` row's generic
  ``bitwise`` flag); the ``kind="broadcast"`` weight fan-out lowers to exactly ceil(log2 p)
  collective-permutes (``cp_delta == 0``) and the 3-replica weight push reconstructs every
  leaf bit-exactly.

Usage:  PYTHONPATH=src python -m benchmarks.ci_gate [--out BENCH_ci.json]
Exit code 0 iff every check passes.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

# Catches structural regressions (an extra pass would land near 3x), with
# headroom for shared-runner noise: interpret-mode medians have been
# observed up to ~1.3 on a loaded machine at the smaller benched shape.
FUSED_RATIO_MAX = 2.0
# int8 wire = 1 + 4/group bytes/elem vs 4 for f32 -> 3.97x at group=512;
# 3.0 leaves room for smaller groups without letting a scales-bloat or
# padding regression through.
WIRE_REDUCTION_MIN = 3.0
# The fused alltoall does the same ppermutes and only fuses the final
# source-ordering pass, so its interpret-mode ratio sits near 1.0 (0.9
# observed); 1.5 catches a structural regression (an extra buffer copy
# per round lands well above it).
A2A_RATIO_MAX = 1.5
# Bucketing trades per-leaf collectives for bucket assembly; at the
# launcher-default seq_len the sync path is amortized against real step
# work and the paired-rep median sits at ~1.0, so 1.05 catches a real
# serialization regression (a lost overlap seam lands well above it).
OVERLAP_RATIO_MAX = 1.05
ONLY = "rounds,kernels,wire,plans,a2a,overlap,elastic,serve,analysis"


def parse_csv(text: str) -> list[dict]:
    rows = []
    for line in text.strip().splitlines():
        if not line or line.startswith("name,"):
            continue
        name, us, derived = (line.split(",", 2) + ["", ""])[:3]
        try:
            us_val = float(us)
        except ValueError:
            continue  # diagnostic/non-CSV stdout line, not a benchmark row
        fields = {}
        for tok in derived.split(";"):
            if "=" in tok:
                key, val = tok.split("=", 1)
                fields[key] = val
        rows.append({"name": name, "us_per_call": us_val, "derived": derived, "fields": fields})
    return rows


def check(rows: list[dict]) -> list[str]:
    failures = []
    for row in rows:
        if "ERROR" in row["name"]:
            failures.append(f"{row['name']}: {row['derived']}")
        for flag in ("allclose", "bitwise"):
            if row["fields"].get(flag, "True") != "True":
                failures.append(f"{row['name']}: {flag}={row['fields'][flag]}")
        if "ratio" in row["fields"] and "fused_round" in row["name"]:
            ratio = float(row["fields"]["ratio"])
            if ratio > FUSED_RATIO_MAX:
                msg = f"{row['name']}: fused/unfused ratio {ratio:.3f} > {FUSED_RATIO_MAX}"
                failures.append(msg + " (interpret-mode noise backstop)")
        if row["name"].startswith("wire/"):
            f = row["fields"]
            if "within_budget" in f and f["within_budget"] != "True":
                failures.append(
                    f"{row['name']}: wire bytes exceed the codes+scales budget "
                    f"(cp_bytes={f.get('cp_bytes')}, budget={f.get('budget')})"
                )
            if f.get("rounds") != f.get("theory_rounds"):
                failures.append(
                    f"{row['name']}: {f.get('rounds')} collective-permutes, "
                    f"want {f.get('theory_rounds')} (compression must not change rounds)"
                )
            if row["name"].endswith("_int8") and "reduction_vs_f32" in f:
                red = float(f["reduction_vs_f32"])
                if red < WIRE_REDUCTION_MIN:
                    failures.append(
                        f"{row['name']}: payload reduction {red:.2f}x < {WIRE_REDUCTION_MIN}x"
                    )
        if row["name"].startswith("a2a/"):
            f = row["fields"]
            if f.get("cp_delta") != "0":
                failures.append(
                    f"{row['name']}: {f.get('cp')} collective-permutes, "
                    f"want {f.get('theory')} (alltoall(v) must keep one "
                    f"ppermute per round)"
                )
            if "width_ok" in f and f["width_ok"] != "True":
                failures.append(
                    f"{row['name']}: alltoallv wire widths {f.get('widths')} "
                    f"!= analytic worst-window bound {f.get('bounds')}"
                )
            if "ratio" in f and "fused" in row["name"]:
                ratio = float(f["ratio"])
                if ratio > A2A_RATIO_MAX:
                    failures.append(
                        f"{row['name']}: fused/jnp ratio {ratio:.3f} > "
                        f"{A2A_RATIO_MAX} (interpret-mode noise backstop)"
                    )
        if row["name"].startswith("overlap/"):
            f = row["fields"]
            if "cp_delta" in f and f["cp_delta"] != "0":
                failures.append(
                    f"{row['name']}: {f.get('cp')} collective-permutes, "
                    f"want {f.get('theory')} (one ppermute per round per "
                    f"bucket; the multi-call seam must add zero)"
                )
            if "ratio" in f:
                ratio = float(f["ratio"])
                if ratio > OVERLAP_RATIO_MAX:
                    failures.append(
                        f"{row['name']}: bucketed/unbucketed step ratio "
                        f"{ratio:.3f} > {OVERLAP_RATIO_MAX}"
                    )
            if "within_tol" in f and f["within_tol"] != "True":
                failures.append(
                    f"{row['name']}: bucketed int8+EF trajectory err "
                    f"{f.get('max_err_int8')} outside wire tolerance "
                    f"{f.get('tol')}"
                )
        if row["name"].startswith("elastic/"):
            f = row["fields"]
            if "within_boundary" in f and f["within_boundary"] != "True":
                failures.append(
                    f"{row['name']}: lost_steps={f.get('lost_steps')} — "
                    f"recovery must resume from the last step-boundary "
                    f"checkpoint (<= ckpt_every; 0 for grow)"
                )
            if "restarted" in f and f["restarted"] != "False":
                failures.append(
                    f"{row['name']}: drill fell back to a clean restart "
                    f"(drain -> re-plan -> reshard -> resume must succeed "
                    f"in-process)"
                )
            if "verified" in f and f["verified"] != "True":
                failures.append(
                    f"{row['name']}: re-planned spec failed "
                    f"assert_verified at the new world"
                )
            if "within_budget" in f and f["within_budget"] != "True":
                failures.append(
                    f"{row['name']}: re-plan + verify took "
                    f"{row['us_per_call']:.0f}us > budget "
                    f"{f.get('budget_us')}us per spec"
                )
            if "within_tol" in f and f["within_tol"] != "True":
                failures.append(
                    f"{row['name']}: int8+EF post-resize trajectory err "
                    f"{f.get('max_err_int8')} outside the documented "
                    f"envelope {f.get('tol')}"
                )
        if row["name"].startswith("serve/"):
            f = row["fields"]
            if "cp_delta" in f and f["cp_delta"] != "0":
                failures.append(
                    f"{row['name']}: {f.get('cp')} collective-permutes, "
                    f"want {f.get('theory')} (broadcast weight fan-out "
                    f"must keep one ppermute per round, ceil(log2 p) "
                    f"total)"
                )
            if "tokens_per_s" in f and float(f["tokens_per_s"]) <= 0:
                failures.append(
                    f"{row['name']}: non-positive serving throughput "
                    f"({f.get('tokens_per_s')} tokens/s)"
                )
            if "p99_ms" in f and float(f["p99_ms"]) <= 0:
                failures.append(
                    f"{row['name']}: non-positive p99 decode-boundary "
                    f"latency"
                )
        if row["name"].startswith("analysis/"):
            f = row["fields"]
            if f.get("findings", "0") != "0":
                failures.append(
                    f"{row['name']}: {f.get('findings')} static-analysis "
                    f"findings (run `python -m repro.analysis --all` "
                    f"locally; pre-existing repo-lint exemptions belong in "
                    f"analysis_ratchet.json)"
                )
            if f.get("ok", "True") != "True":
                failures.append(f"{row['name']}: analysis report not ok")
        if row["name"].startswith("plans/"):
            f = row["fields"]
            if f.get("retraces") != "0":
                failures.append(
                    f"{row['name']}: {f.get('retraces')} retraces across "
                    f"repeated calls with the same CollectiveSpec (plan "
                    f"construction must be trace-free)"
                )
            if f.get("plan_rebuilds") != "0":
                failures.append(
                    f"{row['name']}: plan cache rebuilt "
                    f"{f.get('plan_rebuilds')}x for one spec (lru cache "
                    f"must hit)"
                )
            if f.get("cp_delta") != "0":
                failures.append(
                    f"{row['name']}: spec-driven dispatch emits "
                    f"{f.get('cp')} collective-permutes, want "
                    f"{f.get('theory')} (plan layer must add zero)"
                )
    names = {row["name"] for row in rows}
    if not any(n.startswith("rounds/") for n in names):
        failures.append("no rounds/ benchmark rows produced")
    if not any("fused_round" in n for n in names):
        failures.append("no kernels/fused_round rows produced")
    if not any(n.startswith("wire/") and n.endswith("_int8") for n in names):
        failures.append("no wire/*_int8 compressed-payload rows produced")
    if not any(n.startswith("plans/") for n in names):
        failures.append("no plans/ trace-free dispatch rows produced")
    if "plans/rs_nonuniform" not in names:
        failures.append("no plans/rs_nonuniform (Corollary 3) row produced")
    if not any(n.startswith("a2a/alltoallv") for n in names):
        failures.append("no a2a/alltoallv ragged-counts rows produced")
    if "a2a/moe_ep_parity" not in names:
        failures.append("no a2a/moe_ep_parity (ep vs global dispatch) row "
                        "produced")
    for req in ("overlap/rs_pipelined_p8", "overlap/ar_pipelined_p8",
                "overlap/step_bucketed", "overlap/step_hlo",
                "overlap/trajectory"):
        if req not in names:
            failures.append(f"no {req} bucketed-overlap row produced")
    for req in ("elastic/drill_shrink", "elastic/drill_grow",
                "elastic/trajectory_shrink", "elastic/trajectory_grow",
                "elastic/trajectory_int8", "elastic/recovery_steps"):
        if req not in names:
            failures.append(f"no {req} elastic-drill row produced")
    if not any(n.startswith("elastic/replan_") for n in names):
        failures.append("no elastic/replan_* per-spec re-plan latency rows "
                        "produced")
    for req in ("serve/throughput", "serve/latency", "serve/parity",
                "serve/weight_fanout"):
        if req not in names:
            failures.append(f"no {req} serving row produced")
    if not any(n.startswith("serve/broadcast_rounds_") for n in names):
        failures.append("no serve/broadcast_rounds_* round-count rows "
                        "produced")
    for pass_name in ("verify", "jaxpr", "hlo", "repo"):
        if f"analysis/{pass_name}" not in names:
            failures.append(f"no analysis/{pass_name} static-analysis row "
                            f"produced")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ci.json")
    args = ap.parse_args(argv)

    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(here, "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", ONLY],
        capture_output=True,
        text=True,
        timeout=1800,
        env=env,
        cwd=here,
    )
    if proc.returncode != 0:
        # rounds raises on Theorem 1/2 violations — surface the traceback.
        print(proc.stdout)
        print(proc.stderr, file=sys.stderr)
        print("FAIL: benchmarks.run exited nonzero (assert_theorem violation or crash)")
        return 1

    rows = parse_csv(proc.stdout)
    failures = check(rows)
    report = {
        "benchmarks": ONLY,
        "rows": rows,
        "failures": failures,
        "fused_ratio_max": FUSED_RATIO_MAX,
        "wire_reduction_min": WIRE_REDUCTION_MIN,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(proc.stdout)
    print(f"wrote {args.out} ({len(rows)} rows)")
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}")
        return 1
    print("BENCH GATE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
