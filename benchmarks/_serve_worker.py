"""Subprocess worker for bench_serve: continuous-batching serving gate
on 8 fake CPU devices.

Rows emitted:

  throughput        steady-state scheduler tokens/s over a staggered
                    request mix (second run; the first run eats compile);
  latency           p50 / p99 per-decode-boundary latency of the same
                    run (a boundary = evict + admit (with any B=1
                    prefills) + one batched paged decode);
  parity            bitwise flag: every request's scheduler token stream
                    == the one-shot ``ServeEngine.generate`` stream for
                    that request alone (greedy; the continuous-batching
                    invariant);
  broadcast_rounds_pP
                    HLO collective-permute count of the
                    ``kind="broadcast"`` plan under shard_map at p ∈
                    {5, 8} vs ceil(log2 p) — cp_delta must be 0 (Träff
                    arXiv:2407.18004's round-optimal all-broadcast);
  weight_fanout     multi-replica weight push over the broadcast plan:
                    3 replicas, all leaves reconstructed bitwise
                    (``ReplicaSet.push_weights`` asserts per-leaf).

Emits CSV rows on stdout; the gate logic lives in benchmarks/ci_gate.py.
"""
import os
import sys
import time

import re  # noqa: E402 — strip inherited count: XLA keeps the LAST flag
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + _inherited)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core import collectives as C  # noqa: E402
from repro.core import conformance as conf  # noqa: E402
from repro.core.schedule import ceil_log2  # noqa: E402
from repro.core.spec import CollectiveSpec  # noqa: E402
from repro.models import build  # noqa: E402
from repro.serve import ReplicaSet, Scheduler, ServeEngine  # noqa: E402

MAX_LEN = 24
MAX_BATCH = 3
KV_BLOCK = 4
# (prompt_len, max_new) mix: more requests than slots, uneven lengths ->
# staggered admissions, early evictions, block reuse mid-run.
REQUESTS = [(8, 4), (5, 6), (11, 3), (7, 5), (9, 4), (6, 6)]


def emit(name, us, derived=""):
    print(f"serve/{name},{us:.3f},{derived}")


def drive(sched, prompts):
    """Submit the mix, drive to idle, return per-boundary latencies."""
    rids = [sched.submit(tok, mn) for tok, (_, mn) in zip(prompts, REQUESTS)]
    lat = []
    while not sched.idle:
        t0 = time.perf_counter()
        sched.step()
        lat.append(time.perf_counter() - t0)
    return rids, sched.run(), np.asarray(lat)


def bench_scheduler():
    cfg = get_config("internlm2-1.8b").scaled_down(n_layers=2, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model=model, params=params, max_len=MAX_LEN)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, (pl,)).astype(np.int32)
               for pl, _ in REQUESTS]

    refs = [engine.generate(tok[None], mn)[0]
            for tok, (_, mn) in zip(prompts, REQUESTS)]

    drive(Scheduler(engine, MAX_BATCH, KV_BLOCK), prompts)  # compile pass
    sched = Scheduler(engine, MAX_BATCH, KV_BLOCK)
    t0 = time.perf_counter()
    rids, done, lat = drive(sched, prompts)
    total_s = time.perf_counter() - t0

    n_tok = sum(len(done[r]) for r in rids)
    emit("throughput", total_s * 1e6,
         f"tokens_per_s={n_tok / total_s:.1f};tokens={n_tok};"
         f"requests={len(rids)};max_batch={MAX_BATCH};"
         f"decode_steps={sched.n_decode_steps};"
         f"prefills={sched.n_prefills};kv_block={KV_BLOCK}")
    emit("latency", float(np.mean(lat)) * 1e6,
         f"p50_ms={np.percentile(lat, 50) * 1e3:.3f};"
         f"p99_ms={np.percentile(lat, 99) * 1e3:.3f};"
         f"boundaries={lat.size}")
    bitwise = all(np.array_equal(done[r], ref)
                  for r, ref in zip(rids, refs))
    emit("parity", 0.0,
         f"bitwise={bitwise};requests={len(rids)};"
         f"vs=one_shot_generate")
    return model, params


def bench_broadcast_rounds():
    spec = CollectiveSpec(kind="broadcast", schedule="power2")
    for p in (5, 8):
        mesh = compat.make_mesh((p,), ("x",), devices=jax.devices()[:p])
        fn = lambda v: C.broadcast(v, "x", spec=spec)  # noqa: E731
        t0 = time.perf_counter()
        cp = conf.count_collective_permutes(mesh, p, fn)
        us = (time.perf_counter() - t0) * 1e6
        theory = ceil_log2(p)
        emit(f"broadcast_rounds_p{p}", us,
             f"cp={cp};theory={theory};cp_delta={cp - theory};"
             f"schedule=power2")


def bench_weight_fanout(model, params):
    rs = ReplicaSet(model, max_len=MAX_LEN, replicas=3)
    t0 = time.perf_counter()
    stats = rs.push_weights(params)   # asserts per-leaf bitwise equality
    us = (time.perf_counter() - t0) * 1e6
    emit("weight_fanout", us,
         f"bitwise=True;replicas=3;rounds={stats['rounds']};"
         f"leaves={stats['n_leaves']};bytes={stats['bytes']}")


def main():
    model, params = bench_scheduler()
    bench_broadcast_rounds()
    bench_weight_fanout(model, params)


if __name__ == "__main__":
    main()
