"""Checkpoint manager: atomic save/restore, async double-buffering,
retention, elastic resharding, and exact-resume training."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, reshard_flat
from repro.configs import get_config
from repro.data import for_model
from repro.models import build
from repro.optim.adamw import AdamWConfig
from repro.train import build as build_step


@pytest.fixture()
def setup(tmp_path):
    cfg = get_config("internlm2-1.8b").scaled_down(n_layers=1, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, str(tmp_path / "ckpt")


def test_save_restore_roundtrip(setup):
    cfg, model, params, d = setup
    mgr = CheckpointManager(d)
    opt_flat = {"m": np.arange(10.0), "v": np.ones(10), "step": np.int32(7)}
    mgr.save(7, params, opt_flat, {"data_cursor": 7})
    step, params2, opt2, manifest = mgr.restore(None, params)
    assert step == 7 and manifest["data_cursor"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, params2)
    np.testing.assert_array_equal(opt2["m"], opt_flat["m"])


def test_async_save_and_retention(setup):
    cfg, model, params, d = setup
    mgr = CheckpointManager(d, keep_last=2)
    for s in [1, 2, 3, 4]:
        mgr.save_async(s, params, {"step": np.int32(s)})
    mgr.wait()
    assert mgr.completed_steps() == [3, 4]


def test_restore_rejects_config_mismatch(setup):
    cfg, model, params, d = setup
    mgr = CheckpointManager(d)
    mgr.save(1, params, {})
    other = build(get_config("internlm2-1.8b").scaled_down(
        n_layers=2, vocab_size=64), recipe=None).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        mgr.restore(1, other)


def test_elastic_reshard_flat():
    full = np.arange(100.0)
    # 4-way shards reassemble exactly into 2-way shards
    four = [reshard_flat(full, 4, r) for r in range(4)]
    two = [reshard_flat(full, 2, r) for r in range(2)]
    np.testing.assert_array_equal(np.concatenate(four), np.concatenate(two))
    # padded case
    odd = np.arange(7.0)
    shards = [reshard_flat(odd, 4, r) for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards)[:7], odd)


def test_exact_resume_trajectory(setup, tmp_path):
    """Train 6 steps; separately train 3, checkpoint, restore, train 3 more:
    identical final loss (exact resume — the restart drill's core)."""
    cfg, model, params, d = setup
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=20)
    pipe = for_model(cfg, seq_len=8, global_batch=4)
    built = build_step("single", model, opt_cfg)

    def run(params, opt, lo, hi):
        losses = []
        for s in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            params, opt, m = built.step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
        return params, opt, losses

    p0 = model.init(jax.random.PRNGKey(1))
    o0 = built.init_opt(p0)
    _, _, straight = run(p0, o0, 0, 6)

    p1, o1, first = run(model.init(jax.random.PRNGKey(1)),
                        built.init_opt(p0), 0, 3)
    mgr = CheckpointManager(str(tmp_path / "resume"))
    opt_flat = {"m_0": None}
    # store opt as flat arrays
    leaves, treedef = jax.tree.flatten(o1)
    opt_flat = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    mgr.save(3, p1, opt_flat, {"data_cursor": 3})

    step, p2, opt2, man = mgr.restore(None, p0)
    o2 = jax.tree.unflatten(treedef, [jnp.asarray(opt2[f"leaf_{i}"])
                                      for i in range(len(leaves))])
    _, _, second = run(p2, o2, man["data_cursor"], 6)
    np.testing.assert_allclose(first + second, straight, rtol=1e-6)
