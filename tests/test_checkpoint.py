"""Checkpoint manager: atomic save/restore, async double-buffering,
retention, elastic resharding, crash-leftover sweeping, corruption
fallback, transient-IO fault injection, and exact-resume training."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointError, CheckpointManager, reshard_flat
from repro.ft import CheckpointIOError
from repro.configs import get_config
from repro.data import for_model
from repro.models import build
from repro.optim.adamw import AdamWConfig
from repro.train import build as build_step


@pytest.fixture()
def setup(tmp_path):
    cfg = get_config("internlm2-1.8b").scaled_down(n_layers=1, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, str(tmp_path / "ckpt")


def test_save_restore_roundtrip(setup):
    cfg, model, params, d = setup
    mgr = CheckpointManager(d)
    opt_flat = {"m": np.arange(10.0), "v": np.ones(10), "step": np.int32(7)}
    mgr.save(7, params, opt_flat, {"data_cursor": 7})
    step, params2, opt2, manifest = mgr.restore(None, params)
    assert step == 7 and manifest["data_cursor"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, params2)
    np.testing.assert_array_equal(opt2["m"], opt_flat["m"])


def test_async_save_and_retention(setup):
    cfg, model, params, d = setup
    mgr = CheckpointManager(d, keep_last=2)
    for s in [1, 2, 3, 4]:
        mgr.save_async(s, params, {"step": np.int32(s)})
    mgr.wait()
    assert mgr.completed_steps() == [3, 4]


def test_restore_rejects_config_mismatch(setup):
    cfg, model, params, d = setup
    mgr = CheckpointManager(d)
    mgr.save(1, params, {})
    other = build(get_config("internlm2-1.8b").scaled_down(
        n_layers=2, vocab_size=64), recipe=None).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        mgr.restore(1, other)


def test_elastic_reshard_flat():
    full = np.arange(100.0)
    # 4-way shards reassemble exactly into 2-way shards
    four = [reshard_flat(full, 4, r) for r in range(4)]
    two = [reshard_flat(full, 2, r) for r in range(2)]
    np.testing.assert_array_equal(np.concatenate(four), np.concatenate(two))
    # padded case
    odd = np.arange(7.0)
    shards = [reshard_flat(odd, 4, r) for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards)[:7], odd)


def test_sweep_stale_crash_leftovers(setup):
    """A crash mid-write leaves step_<N>.tmp (or a manifest-less final
    dir from a partial external copy); a fresh manager sweeps both so
    retention and restore never trip over them."""
    cfg, model, params, d = setup
    CheckpointManager(d).save(3, params, {})
    os.makedirs(os.path.join(d, "step_5.tmp"))
    os.makedirs(os.path.join(d, "step_7"))  # no manifest.json inside
    mgr = CheckpointManager(d)
    assert mgr.completed_steps() == [3]
    assert not os.path.exists(os.path.join(d, "step_5.tmp"))
    assert not os.path.exists(os.path.join(d, "step_7"))


def test_background_save_error_surfaces_on_next_call(setup):
    """An async write failure is never swallowed: the NEXT save/wait
    raises CheckpointError carrying the FAILED step."""
    cfg, model, params, d = setup
    boom = [True]

    def hook(step):
        if boom[0]:
            boom[0] = False
            raise CheckpointIOError(f"injected at step {step}")

    mgr = CheckpointManager(d, io_hook=hook)
    mgr.save_async(4, params, {})
    with pytest.raises(CheckpointError) as ei:
        mgr.wait()
    assert ei.value.step == 4
    mgr.save(5, params, {})  # error consumed; manager still usable
    assert mgr.latest_step() == 5


def test_restore_falls_back_on_corrupt_newest(setup):
    """restore(None) skips a truncated newest checkpoint (with a
    warning) and restores the previous completed one; an explicit step
    never falls back — the caller asked for that exact checkpoint."""
    cfg, model, params, d = setup
    mgr = CheckpointManager(d)
    mgr.save(1, params, {"tag": np.int32(1)}, {"data_cursor": 1})
    mgr.save(2, params, {"tag": np.int32(2)}, {"data_cursor": 2})
    with open(os.path.join(d, "step_2", "arrays.npz"), "wb") as f:
        f.write(b"not a zip file")  # truncation/corruption stand-in
    with pytest.warns(RuntimeWarning, match="step_2 is unreadable"):
        step, _, opt, man = mgr.restore(None, params)
    assert step == 1 and int(opt["tag"]) == 1 and man["data_cursor"] == 1
    with pytest.raises(Exception):
        mgr.restore(2, params)  # explicit step: surface the corruption


def test_restore_transient_io_fault_propagates_not_falls_back(setup):
    """A transient io_hook failure during restore is RETRYABLE (the
    elastic controller's backoff owns it) — it must propagate, not be
    mistaken for corruption and silently fall back to an older step."""
    cfg, model, params, d = setup
    mgr = CheckpointManager(d)
    mgr.save(1, params, {})
    mgr.save(2, params, {})
    flaky = [True]

    def hook(step):
        if flaky[0]:
            flaky[0] = False
            raise CheckpointIOError("flaky mount")

    mgr.io_hook = hook
    with pytest.raises(CheckpointIOError):
        mgr.restore(None, params)
    step, _, _, _ = mgr.restore(None, params)  # the retry succeeds
    assert step == 2  # ...at the NEWEST step, not a fallback


def test_exact_resume_trajectory(setup, tmp_path):
    """Train 6 steps; separately train 3, checkpoint, restore, train 3 more:
    identical final loss (exact resume — the restart drill's core)."""
    cfg, model, params, d = setup
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=20)
    pipe = for_model(cfg, seq_len=8, global_batch=4)
    built = build_step("single", model, opt_cfg)

    def run(params, opt, lo, hi):
        losses = []
        for s in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            params, opt, m = built.step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
        return params, opt, losses

    p0 = model.init(jax.random.PRNGKey(1))
    o0 = built.init_opt(p0)
    _, _, straight = run(p0, o0, 0, 6)

    p1, o1, first = run(model.init(jax.random.PRNGKey(1)),
                        built.init_opt(p0), 0, 3)
    mgr = CheckpointManager(str(tmp_path / "resume"))
    opt_flat = {"m_0": None}
    # store opt as flat arrays
    leaves, treedef = jax.tree.flatten(o1)
    opt_flat = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    mgr.save(3, p1, opt_flat, {"data_cursor": 3})

    step, p2, opt2, man = mgr.restore(None, p0)
    o2 = jax.tree.unflatten(treedef, [jnp.asarray(opt2[f"leaf_{i}"])
                                      for i in range(len(leaves))])
    _, _, second = run(p2, o2, man["data_cursor"], 6)
    np.testing.assert_allclose(first + second, straight, rtol=1e-6)
