"""Docs stay true: markdown link check + executable paper_map snippets.

Mirrors the CI docs job in-process so `pytest -x -q` catches docs rot
locally: every relative link/anchor in README.md + docs/*.md must
resolve (repro.analysis.doc_lint), and every `>>>` snippet in the docs
tree must run and print exactly what the page claims (doctest).  The
checker itself is mutation-tested — a broken link, a bad anchor, and an
absolute path must each be flagged.
"""
import doctest
import pathlib

from repro.analysis import doc_lint

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_repo_markdown_links_resolve():
    findings = doc_lint.run(ROOT)
    assert not findings, "\n".join(str(f) for f in findings)


def test_doc_files_cover_readme_and_docs_tree():
    names = [p.relative_to(ROOT).as_posix() for p in doc_lint.doc_files(ROOT)]
    assert "README.md" in names
    assert "docs/paper_map.md" in names
    assert "docs/architecture.md" in names


def test_docs_doctests_pass():
    ran_any = False
    for md in sorted((ROOT / "docs").glob("*.md")):
        res = doctest.testfile(
            str(md), module_relative=False,
            optionflags=doctest.NORMALIZE_WHITESPACE)
        assert res.failed == 0, f"doctest failures in {md}"
        ran_any = ran_any or res.attempted > 0
    assert ran_any, "no doctests found under docs/ (paper_map.md snippets)"


def test_doc_lint_flags_breakage(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "a.md").write_text("# A\n\n## Sub section\n")
    (tmp_path / "README.md").write_text(
        "# Title\n\n"
        "[ok](docs/a.md)\n"
        "[ok-anchor](docs/a.md#sub-section)\n"
        "[missing](docs/missing.md)\n"
        "[bad-anchor](docs/a.md#nope)\n"
        "[abs](/etc/passwd)\n"
        "[bad-self](#zzz)\n"
        "[web-skipped](https://example.com/x)\n"
        "```\n[fenced-ignored](nope.md)\n```\n"
        "inline `[code-span-ignored](nope.md)` too\n")
    msgs = [f.message for f in doc_lint.run(tmp_path)]
    assert len(msgs) == 4, msgs
    assert any("docs/missing.md" in m for m in msgs)
    assert any("#nope" in m for m in msgs)
    assert any("absolute link" in m for m in msgs)
    assert any("'#zzz'" in m for m in msgs)


def test_github_slug_rules():
    slugs = doc_lint.heading_slugs(
        "# Hello, World!\n## Hello, World!\n### `plan()` → run\n")
    # duplicates get -1 suffixes; punctuation drops; spaces become '-'
    assert "hello-world" in slugs and "hello-world-1" in slugs
    assert any(s.startswith("plan") for s in slugs)
