"""Compressed int8 wire format: pack/unpack, the fused dequant-⊕-requant
round kernel vs its jnp oracle, the quantize kernels on ragged shapes and
bf16, per-group scale correctness, and the wire-aware cost model.

Kernel-vs-oracle comparisons run BOTH sides under jit: the arithmetic is
identical, and under jit XLA makes the same contraction (FMA) choices for
both graphs, so equality is bitwise.  (Eager dispatch may differ from the
jitted kernel by ~1 ulp — that is XLA's choice, not the kernel's.)
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import cost_model as cm
from repro.kernels import (DEFAULT_GROUP, fused_round_dq, pack_wire,
                           quantize_rows, unpack_wire, wire_ngroups,
                           wire_width)
from repro.kernels import ref as R
from repro.kernels.quantize import _EPS, _INV127, dequant_add, quantize

RNG = np.random.default_rng(31)

# Ragged geometries the conformance harness hits: 7 and 515 columns,
# rows not divisible by the row tile, single elements.
RAGGED_SHAPES = [(3, 7), (130, 515), (5, 130), (7, 515), (1, 1), (9, 4)]


def _rand(shape, dtype=jnp.float32, scale=2.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# quantize / dequant_add on ragged shapes (pad-and-slice inside the kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", RAGGED_SHAPES)
@pytest.mark.parametrize("group", [4, 128, 512])
def test_quantize_kernel_ragged_matches_ref(shape, group):
    x = _rand(shape)
    codes, scales = quantize(x, group=group, interpret=True)
    codes_r, scales_r = R.quantize_ref(x, group=group)
    assert codes.shape == x.shape
    assert scales.shape == (shape[0], wire_ngroups(shape[1], group))
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_r))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(scales_r))


@pytest.mark.parametrize("shape", RAGGED_SHAPES)
def test_dequant_add_ragged_matches_ref(shape):
    g = 64
    x, acc = _rand(shape), _rand(shape)
    codes, scales = R.quantize_ref(x, group=g)
    got = jax.jit(functools.partial(dequant_add, group=g, interpret=True))(
        acc, codes, scales)
    want = jax.jit(functools.partial(R.dequant_add_ref, group=g))(
        acc, codes, scales)
    assert got.shape == shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_bf16_and_scale_correctness(dtype):
    """Per-group scales must equal amax/127 (+eps) of the f32 view of the
    group, and codes must round-trip within scale/2 per element."""
    x = _rand((6, 96), dtype, scale=3.0)
    g = 32
    codes, scales = quantize(x, group=g, interpret=True)
    xg = np.asarray(x, np.float32).reshape(6, -1, g)
    amax = np.abs(xg).max(axis=2)
    np.testing.assert_allclose(np.asarray(scales),
                               amax * np.float32(_INV127) + _EPS,
                               rtol=1e-7)
    back = np.asarray(codes, np.float32).reshape(6, -1, g) \
        * np.asarray(scales)[..., None]
    assert (np.abs(back - xg) <= np.asarray(scales)[..., None] / 2
            + 1e-6).all()


def test_quantize_zero_group_is_exact():
    """An all-zero group quantizes to zero codes with the eps floor scale
    (no NaN/inf from the amax=0 corner)."""
    x = jnp.zeros((2, 64), jnp.float32)
    codes, scales = quantize(x, group=32, interpret=True)
    assert not np.isnan(np.asarray(scales)).any()
    np.testing.assert_array_equal(np.asarray(codes), 0)


# ---------------------------------------------------------------------------
# wire pack/unpack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,group", [((4, 16), 4), ((3, 7), 4),
                                         ((2, 515), 128), ((1, 1), 512),
                                         ((8, 512), 512)])
def test_wire_roundtrip_exact(shape, group):
    """pack_wire|unpack_wire is lossless: codes bitwise, scales bitwise
    (f32 bits survive the u8 transport)."""
    codes, scales = R.quantize_ref(_rand(shape), group=group)
    wire = pack_wire(codes, scales)
    assert wire.dtype == jnp.int8
    assert wire.shape == (shape[0], wire_width(shape[1], group))
    codes2, scales2 = unpack_wire(wire, shape[1], group=group)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes2))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(scales2))


def test_wire_roundtrip_extreme_scales():
    """Denormal / huge / eps-floor scales survive the byte transport."""
    codes = jnp.zeros((1, 8), jnp.int8)
    for val in (1e-30, 1e-38, 3.4e38, 1.0):
        scales = jnp.full((1, 1), val, jnp.float32)
        _, s2 = unpack_wire(pack_wire(codes, scales), 8, group=8)
        np.testing.assert_array_equal(np.asarray(scales), np.asarray(s2))


def test_wire_width_accounting():
    assert wire_width(4096, 512) == 4096 + 4 * 8
    assert wire_width(7, 512) == 7 + 4          # one ragged group
    assert wire_width(515, 128) == 515 + 4 * 5  # 4 full + 1 ragged group
    # compression vs f32: 4x cols vs cols + 4*ng
    assert 4 * 4096 / wire_width(4096, 512) > 3.9


def test_unpack_wire_rejects_wrong_width():
    with pytest.raises(ValueError, match="wire has"):
        unpack_wire(jnp.zeros((2, 10), jnp.int8), 8, group=8)


# ---------------------------------------------------------------------------
# fused_round_dq vs oracle
# ---------------------------------------------------------------------------

GEOMETRIES = [(8, 4, 4), (8, 4, 2), (7, 3, 2), (5, 1, 4), (6, 2, 4),
              (2, 1, 1), (4, 4, 4)]


def _dq_pair(lo, nb, next_lo, cols, g, op):
    live = _rand((lo, cols), scale=1.0)
    codes, scales = R.quantize_ref(_rand((nb, cols), scale=3.0), group=g)
    fk = jax.jit(functools.partial(fused_round_dq, nb=nb, next_lo=next_lo,
                                   op=op, group=g, interpret=True))
    fr = jax.jit(functools.partial(R.fused_round_dq_ref, nb=nb,
                                   next_lo=next_lo, op=op, group=g))
    return fk(live, codes, scales), fr(live, codes, scales)


@pytest.mark.parametrize("cols,g", [(16, 4), (128, 128), (512, 128)])
@pytest.mark.parametrize("geometry", GEOMETRIES)
def test_fused_round_dq_geometries(geometry, cols, g):
    lo, nb, next_lo = geometry
    (keep, send), (keep_r, send_r) = _dq_pair(lo, nb, next_lo, cols, g,
                                              "add")
    assert keep.dtype == jnp.float32 and keep.shape == (next_lo, cols)
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep_r))
    assert (send is None) == (send_r is None) == (next_lo == lo)
    if send is not None:
        assert send[0].dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(send[0]),
                                      np.asarray(send_r[0]))
        np.testing.assert_array_equal(np.asarray(send[1]),
                                      np.asarray(send_r[1]))


@pytest.mark.parametrize("op", ["add", "max", "min"])
def test_fused_round_dq_ops(op):
    (keep, send), (keep_r, send_r) = _dq_pair(8, 4, 2, 64, 16, op)
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep_r))
    np.testing.assert_array_equal(np.asarray(send[0]),
                                  np.asarray(send_r[0]))


def test_fused_round_dq_rejects_bad_shapes():
    live = _rand((4, 16))
    codes, scales = R.quantize_ref(_rand((2, 16)), group=4)
    with pytest.raises(ValueError, match="not divisible by group"):
        fused_round_dq(_rand((4, 15)), codes, scales, nb=2, next_lo=2,
                       group=4, interpret=True)
    with pytest.raises(ValueError, match="codes shape"):
        fused_round_dq(live, codes, scales, nb=3, next_lo=2, group=4,
                       interpret=True)
    with pytest.raises(ValueError, match="scales shape"):
        fused_round_dq(live, codes, scales[:, :2], nb=2, next_lo=2,
                       group=4, interpret=True)
    with pytest.raises(ValueError, match="invalid round"):
        fused_round_dq(live, R.quantize_ref(_rand((5, 16)), group=4)[0],
                       R.quantize_ref(_rand((5, 16)), group=4)[1],
                       nb=5, next_lo=2, group=4, interpret=True)


@given(st.integers(1, 10), st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_fused_round_dq_property(lo, ngroups, seed):
    g = 8
    cols = ngroups * g
    nb = 1 + seed % lo
    next_lo = 1 + (seed // 7) % lo
    (keep, send), (keep_r, send_r) = _dq_pair(lo, nb, next_lo, cols, g,
                                              "add")
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep_r))
    if send is not None:
        np.testing.assert_array_equal(np.asarray(send[0]),
                                      np.asarray(send_r[0]))


def test_quantize_rows_wrapper():
    c, s = quantize_rows(_rand((3, 12)), group=4, interpret=True)
    assert c.shape == (3, 12) and s.shape == (3, 3)
    cr, sr = R.quantize_ref(_rand((3, 12)), group=4)
    assert cr.shape == c.shape and sr.shape == s.shape


# ---------------------------------------------------------------------------
# wire-aware cost model
# ---------------------------------------------------------------------------

def test_wire_bytes_per_elem():
    assert cm.wire_bytes_per_elem(4.0) == 4.0
    assert cm.wire_bytes_per_elem(4.0, "int8", 512) == 1.0 + 4.0 / 512
    assert 4.0 / cm.wire_bytes_per_elem(4.0, "int8", 512) > 3.9
    with pytest.raises(ValueError):
        cm.wire_bytes_per_elem(4.0, "fp4")


def test_cost_model_wire_scales_beta_only():
    """int8 wire shrinks the β term ~4x and leaves α (rounds) and γ
    (every element still reduced) untouched."""
    model = cm.CommModel(alpha=1e-6, beta=1e-9, gamma=2.5e-10,
                         elem_bytes=4.0)
    p, m = 22, 1 << 24
    plain = cm.t_allreduce(m, p, model)
    wired = cm.t_allreduce(m, p, model, wire_dtype="int8", wire_group=512)
    assert wired < plain
    # β-dominated regime: the saving approaches the byte ratio
    beta_plain = 2 * model.beta * (p - 1) / p * m
    beta_wired = beta_plain * cm.wire_bytes_per_elem(4.0, "int8", 512) / 4.0
    assert abs((plain - wired) - (beta_plain - beta_wired)) < 1e-12
    # α-dominated regime: compression buys ~nothing
    small = 16
    assert abs(cm.t_allreduce(small, p, model, wire_dtype="int8")
               - cm.t_allreduce(small, p, model)) < model.beta * small * 4


def test_cost_model_wire_group_tradeoff():
    """Smaller groups = more scales on the wire = more β bytes."""
    model = cm.CommModel.tpu_v5e(4)
    p, m = 16, 1 << 26
    t = [cm.t_reduce_scatter(m, p, model, wire_dtype="int8", wire_group=g)
         for g in (64, 512)]
    assert t[0] > t[1]
