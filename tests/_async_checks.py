"""Subprocess worker: multi-call (async) round protocol + pipelined
drivers on N fake CPU devices.

Checks that the software-pipelined executors (`reduce_scatter_pipelined`
/ `allgather_pipelined`) are BITWISE-equal to the one-shot methods on
every async-capable backend (they run the same ops, split at the round
seam), that manual out-of-order interleavings of start_round /
finish_round across two payloads still produce one-shot results, and
that the lowered HLO of a pipelined B-payload RS contains exactly
B * ceil(log2 p) collective-permutes (2x for allreduce) — the per-bucket
round-count invariant of the overlap gate.

Run:  python tests/_async_checks.py <ndev>
"""
import os
import sys

NDEV = int(sys.argv[1]) if len(sys.argv) > 1 else 8
import re  # noqa: E402 — strip inherited count: XLA keeps the LAST flag
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={NDEV} " + _inherited)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import compat  # noqa: E402
from repro.analysis.hlo_budget import (  # noqa: E402
    count_collective_permutes)
from repro.core import CollectiveSpec, plan  # noqa: E402
from repro.core.schedule import ceil_log2  # noqa: E402

mesh = compat.make_mesh((NDEV,), ("x",))
rng = np.random.default_rng(7)
p = NDEV
q = ceil_log2(p)
# Three payload geometries: different block sizes, one with a trailing dim.
SHAPES = [(p * 6,), (p * 3,), (p * 4, 2)]


def run_sharded(fn, xs_global):
    """Run fn(per-rank payload list) under shard_map; inputs are (p, n)
    global arrays sharded on axis 0, unwrapped to v[0] per rank."""
    f = jax.jit(compat.shard_map(
        lambda *vs: tuple(o[None] for o in fn([v[0] for v in vs])),
        mesh=mesh, in_specs=tuple(P("x") for _ in xs_global),
        out_specs=tuple(P("x") for _ in xs_global),
        check_vma=False))  # pallas_call has no shard_map replication rule
    return [np.asarray(o) for o in f(*xs_global)]


def check(name, cond=True):
    if not cond:
        raise AssertionError(f"FAILED: {name}")
    print(f"ok: {name}")


def payloads():
    return [rng.standard_normal((p, *s)).astype(np.float32) for s in SHAPES]


SPECS = [
    ("jnp", CollectiveSpec()),
    ("fused", CollectiveSpec(use_fused_kernel=True)),
    ("jnp+int8", CollectiveSpec(wire_dtype="int8", wire_group=8)),
    ("fused+int8", CollectiveSpec(wire_dtype="int8", wire_group=8,
                                  use_fused_kernel=True)),
]

for label, spec in SPECS:
    pl = plan(spec, p=p, axis_name="x")
    xs = payloads()

    one = run_sharded(lambda vs: [pl.reduce_scatter(v) for v in vs], xs)
    pipe = run_sharded(lambda vs: pl.reduce_scatter_pipelined(vs), xs)
    for a, b in zip(one, pipe):
        assert np.array_equal(a, b), (label, a.shape)
    check(f"pipelined RS bitwise == one-shot [{label}] (p={p})")

    # Allgather: feed each rank a block, compare gathered buffers.
    blocks = [x[:, : x.shape[1] // p] if x.ndim == 2
              else x[:, : x.shape[1] // p, :] for x in xs]
    one = run_sharded(lambda vs: [pl.allgather(v) for v in vs], blocks)
    pipe = run_sharded(lambda vs: pl.allgather_pipelined(vs), blocks)
    for a, b in zip(one, pipe):
        assert np.array_equal(a, b), (label, a.shape)
    check(f"pipelined AG bitwise == one-shot [{label}] (p={p})")


# Manual out-of-order interleaving: start both payloads, then finish in
# swapped order, per round — a schedule _run_pipelined never emits — must
# still be bitwise one-shot (round states are independent).
pl = plan(CollectiveSpec(), p=p, axis_name="x")
xs = payloads()[:2]


def manual_interleave(vs):
    sts = [pl.rs_begin(v) for v in vs]
    while not sts[0].done:
        pl.start_round(sts[0])
        pl.start_round(sts[1])
        pl.finish_round(sts[1])
        pl.finish_round(sts[0])
    return [pl.rs_end(st) for st in sts]


one = run_sharded(lambda vs: [pl.reduce_scatter(v) for v in vs], xs)
man = run_sharded(manual_interleave, xs)
for a, b in zip(one, man):
    assert np.array_equal(a, b)
check(f"manual out-of-order interleaving bitwise == one-shot (p={p})")


# HLO round budget: a pipelined B-payload RS lowers to exactly B*q
# collective-permutes; RS+AG (allreduce) to 2*B*q.  This is the
# per-bucket invariant the `overlap` bench gate asserts.
B = len(SHAPES)


def lower_count(fn, shapes):
    f = jax.jit(compat.shard_map(
        lambda *vs: tuple(o[None] for o in fn([v[0] for v in vs])),
        mesh=mesh, in_specs=tuple(P("x") for _ in shapes),
        out_specs=tuple(P("x") for _ in shapes), check_vma=False))
    avals = [jax.ShapeDtypeStruct((p, *s), jnp.float32) for s in shapes]
    return count_collective_permutes(f.lower(*avals).as_text())


n_rs = lower_count(lambda vs: pl.reduce_scatter_pipelined(vs), SHAPES)
check(f"pipelined RS HLO collective-permutes == B*q = {B * q} "
      f"(got {n_rs})", n_rs == B * q)

n_ar = lower_count(
    lambda vs: pl.allgather_pipelined(pl.reduce_scatter_pipelined(vs)),
    SHAPES)
check(f"pipelined AR HLO collective-permutes == 2*B*q = {2 * B * q} "
      f"(got {n_ar})", n_ar == 2 * B * q)

print("ALL ASYNC CHECKS PASSED")
