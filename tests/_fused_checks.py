"""Subprocess worker: fused-vs-unfused equivalence for every circulant
collective on N fake CPU devices (N non-power-of-two included — the
paper's general case).

For each collective (RS / AG / AR / alltoall) the fused Pallas round path
(``use_fused_kernel=True``, interpret mode on CPU) must be BITWISE equal
to the jnp path: the kernel reorders no arithmetic, it only fuses the
local data movement.  Sweeps non-tile-divisible block sizes (odd cols
exercise the kernel's edge handling), bf16 / int32 payloads, rank-3
payloads, and non-default schedules.

Run:  python tests/_fused_checks.py <ndev>
"""
import os
import sys

NDEV = int(sys.argv[1]) if len(sys.argv) > 1 else 6
import re  # noqa: E402 — strip inherited count: XLA keeps the LAST flag
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={NDEV} " + _inherited)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import compat  # noqa: E402
from repro.core import collectives as C  # noqa: E402

mesh = compat.make_mesh((NDEV,), ("x",))
rng = np.random.default_rng(123)
p = NDEV


def run1(fn, x_global):
    """check_vma=False: pallas_call has no shard_map replication rule on
    0.4.x; numerics are asserted below instead."""
    f = jax.jit(compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                                 in_specs=(P("x"),), out_specs=P("x"),
                                 check_vma=False))
    return np.asarray(f(x_global))


def check(name, cond=True):
    if not cond:
        raise AssertionError(f"FAILED: {name}")
    print(f"ok: {name}")


def both(fn_of_fused, x):
    a = run1(lambda v: fn_of_fused(v, True), x)
    b = run1(lambda v: fn_of_fused(v, False), x)
    return a, b


def make(shape, dtype):
    if dtype == jnp.int32:
        return jnp.asarray(rng.integers(-99, 99, shape), jnp.int32)
    return jnp.asarray(rng.standard_normal(shape), dtype)


# --- reduce-scatter: dtypes × odd (non-tile-divisible) block sizes ---
for dtype in (jnp.float32, jnp.bfloat16, jnp.int32):
    for blk in (4, 515):  # 515 floats/block: no tile boundary divides it
        x = make((p, p * blk), dtype)
        a, b = both(lambda v, f: C.circulant_reduce_scatter(
            v, "x", use_fused_kernel=f), x)
        check(f"RS fused==unfused bitwise [p={p} blk={blk} "
              f"{jnp.dtype(dtype).name}]", np.array_equal(a, b))

# --- schedules (non-default round structures) ---
x = make((p, p * 12), jnp.float32)
for sched in ("power2", "fully_connected", "sqrt"):
    a, b = both(lambda v, f, s=sched: C.circulant_reduce_scatter(
        v, "x", schedule=s, use_fused_kernel=f), x)
    check(f"RS[{sched}] fused==unfused bitwise", np.array_equal(a, b))

# --- rank-3 payload + max op ---
x3 = make((p, p * 5, 3), jnp.float32)
a, b = both(lambda v, f: C.circulant_reduce_scatter(
    v, "x", op="max", use_fused_kernel=f), x3)
check("RS rank-3 op=max fused==unfused bitwise", np.array_equal(a, b))

# --- allgather ---
blocks = make((p, 515), jnp.float32)
a, b = both(lambda v, f: C.circulant_allgather(
    v, "x", use_fused_kernel=f), blocks)
check("AG fused==unfused bitwise", np.array_equal(a, b))
check("AG gathers all blocks",
      np.array_equal(a.reshape(p, p, 515)[0], np.asarray(blocks)))

# --- allreduce (RS + AG composed) ---
for dtype in (jnp.float32, jnp.int32):
    x = make((p, p * 7), dtype)
    a, b = both(lambda v, f: C.circulant_allreduce(
        v, "x", use_fused_kernel=f), x)
    check(f"AR fused==unfused bitwise [{jnp.dtype(dtype).name}]",
          np.array_equal(a, b))

# --- int8 wire format: fused vs unfused compressed rounds must agree
# BITWISE (identical arithmetic, both jitted — the Pallas dq-round kernel
# and its jnp oracle trace to the same XLA graph shapes), and the
# compressed result must sit within the quantization error of the exact
# jnp reduce-scatter ---
for blk in (4, 515):  # 515: ragged quantization group (515 % 512 != 0)
    x = make((p, p * blk), jnp.float32)
    a, b = both(lambda v, f: C.circulant_reduce_scatter(
        v, "x", wire_dtype="int8", use_fused_kernel=f), x)
    check(f"RS int8-wire fused==unfused bitwise [blk={blk}]",
          np.array_equal(a, b))
    exact = run1(lambda v: C.circulant_reduce_scatter(v, "x"), x)
    err = np.abs(a.astype(np.float64) - exact.astype(np.float64)).max()
    check(f"RS int8-wire within quantization error of exact "
          f"[blk={blk}] (max err {err:.3f})", err < 0.05 * p + 0.1)

x = make((p, p * 7), jnp.float32)
a, b = both(lambda v, f: C.circulant_allreduce(
    v, "x", wire_dtype="int8", use_fused_kernel=f), x)
check("AR int8-wire fused==unfused bitwise", np.array_equal(a, b))
for r in range(p):
    np.testing.assert_array_equal(a[r], a[0])
check("AR int8-wire output bitwise-replicated across ranks")

blocks = make((p, 515), jnp.float32)
a, b = both(lambda v, f: C.circulant_allgather(
    v, "x", wire_dtype="int8", use_fused_kernel=f), blocks)
check("AG int8-wire fused==unfused bitwise", np.array_equal(a, b))
err = np.abs(a.reshape(p, p, 515).astype(np.float64)
             - np.asarray(blocks, np.float64)[None]).max()
check(f"AG int8-wire one-quantization error bound (max err {err:.4f})",
      err < 0.05)

# --- alltoall (⊕ = concatenation; fused uses stacked slots + Pallas
# row-permutation for the final source ordering) ---
a2a = make((p, p, 7), jnp.float32)
a, b = both(lambda v, f: C.circulant_alltoall(
    v, "x", use_fused_kernel=f), a2a)
check("A2A fused==unfused bitwise", np.array_equal(a, b))
ref = np.asarray(a2a)
for r in range(p):
    for j in range(p):
        np.testing.assert_array_equal(a[r, j], ref[j, r])
check("A2A fused transposes payloads correctly")

print(f"ALL FUSED CHECKS PASSED (ndev={NDEV})")
