"""Roofline machinery tests: HLO collective parser, analytic-model
validation against FULLY-UNROLLED compiles of reduced configs (where XLA's
cost analysis has no loops to undercount), and the cost-analysis loop
undercount demonstration that motivates the methodology."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.roofline import analysis as A


def test_parse_collectives_explicit_groups():
    hlo = """
  %ag = bf16[64,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3},{4,5,6,7}}
  %ar = f32[1024]{0} all-reduce(%y), replica_groups={{0,1}}
  %cp.1 = f32[32]{0} collective-permute(%z), source_target_pairs=...
  %rs = bf16[16,16]{1,0} reduce-scatter(%w), replica_groups=[4,2]<=[8]
"""
    st = A.parse_collectives(hlo)
    assert st.ops == {"all-gather": 1, "all-reduce": 1,
                      "collective-permute": 1, "reduce-scatter": 1}
    ag = 64 * 128 * 2
    assert st.bytes_by_op["all-gather"] == pytest.approx(ag * 3 / 4)
    assert st.bytes_by_op["all-reduce"] == pytest.approx(1024 * 4 * 2 * 0.5)
    assert st.bytes_by_op["collective-permute"] == 32 * 4
    assert st.bytes_by_op["reduce-scatter"] == pytest.approx(16 * 16 * 2 * 1)


def test_parse_collectives_dtype_breakdown():
    """Compressed (s8-wire) collective traffic is reported per dtype so
    it is visible next to uncompressed traffic in the roofline output."""
    hlo = """
  %cp.1 = s8[7,33024]{1,0} collective-permute(%wire), source_target_pairs=...
  %cp.2 = f32[7,32768]{1,0} collective-permute(%raw), source_target_pairs=...
"""
    st = A.parse_collectives(hlo)
    assert st.raw_bytes_by_dtype == {"s8": 7 * 33024, "f32": 7 * 32768 * 4}
    assert st.ops == {"collective-permute": 2}


def test_parse_start_done_counted_once():
    hlo = """
  %cps = f32[8]{0} collective-permute-start(%x), source_target_pairs=...
  %cpd = f32[8]{0} collective-permute-done(%cps)
"""
    st = A.parse_collectives(hlo)
    assert st.ops == {"collective-permute": 1}


def test_parse_async_tuple_start_bytes_counted_once():
    """Regression: an async collective-permute-start has a TUPLE result
    type aliasing operand + result (+ u32 context scalars).  Summing
    every tuple element double-counted the payload; only the result
    buffer (tuple index 1) may contribute."""
    hlo = """
  %cps = (f32[256,8]{1,0}, f32[256,8]{1,0}, u32[], u32[]) collective-permute-start(%x), source_target_pairs={{0,1},{1,0}}
  %cpd = f32[256,8]{1,0} collective-permute-done(%cps)
"""
    st = A.parse_collectives(hlo)
    assert st.ops == {"collective-permute": 1}
    payload = 256 * 8 * 4
    assert st.raw_bytes_by_op["collective-permute"] == payload
    assert st.bytes_by_op["collective-permute"] == payload
    # the u32 context scalars must not leak into the dtype breakdown
    assert st.raw_bytes_by_dtype == {"f32": payload}


def test_cost_analysis_undercounts_loops():
    """The motivating defect: flops identical for 2 vs 8 scan iterations."""
    def make(nl):
        def f(x, w):
            def body(x, wi):
                return jnp.tanh(x @ wi), None
            x, _ = jax.lax.scan(body, x, w)
            return x.sum()
        return f

    x = jnp.ones((64, 128))
    fl = {}
    for nl in (2, 8):
        w = jnp.ones((nl, 128, 128))
        c = jax.jit(make(nl)).lower(x, w).compile()
        fl[nl] = compat.cost_analysis(c)["flops"]
    assert fl[2] == fl[8], "if this fails, XLA fixed it — drop the " \
        "two-point correction and use raw HLO numbers"


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "internlm2-1.8b"])
def test_analytic_flops_vs_unrolled_hlo(arch):
    """Analytic forward FLOPs must track a FULLY-unrolled HLO compile of a
    reduced config within 25% (HLO includes softmax/norm flops the model
    skips; the analytic side includes only matmul-class terms)."""
    from repro.configs import get_config
    from repro.models import build
    from repro.roofline.analytic import forward_flops_global

    cfg = get_config(arch).scaled_down(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
        d_ff=256, vocab_size=512, scan_unroll=2)
    b, s = 2, 256
    model = build(cfg, recipe=None, remat=False)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)

    def fwd(p, t):
        logits, _ = model.forward_logits(p, t)
        return logits

    comp = jax.jit(fwd).lower(params, tokens).compile()
    hlo_flops = compat.cost_analysis(comp)["flops"]
    ana = forward_flops_global(cfg, s, b, "prefill")
    ratio = hlo_flops / ana
    assert 0.75 < ratio < 1.25, (hlo_flops, ana, ratio)


def test_roofline_terms_and_bottleneck():
    r = A.Roofline(flops_per_chip=197e12 * 0.5,
                   hbm_bytes_per_chip=819e9 * 0.2,
                   collective_bytes_per_chip=50e9 * 0.1,
                   model_flops_per_chip=197e12 * 0.4)
    assert r.t_compute == pytest.approx(0.5)
    assert r.t_memory == pytest.approx(0.2)
    assert r.t_collective == pytest.approx(0.1)
    assert r.bottleneck == "compute"
    assert r.useful_ratio == pytest.approx(0.8)
    assert r.roofline_fraction == pytest.approx(0.8)
