"""Fused round kernel (kernels/fused_round.py) — edge-shape coverage.

Kernel level: interpret-mode equivalence against the kernels/ref.py
oracle over non-tile-divisible column counts, bf16 / int32 payloads, all
ops, and every fold/split geometry class (straddling fold, pure-copy
send, final round).  Collective level: a subprocess worker checks the
fused paths bitwise against the jnp paths for non-power-of-two p.
"""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import fused_round, permute_rows
from repro.kernels import ref as R

HERE = os.path.dirname(os.path.abspath(__file__))
WORKER = os.path.join(HERE, "_fused_checks.py")

RNG = np.random.default_rng(11)

# (lo, nb, next_lo): fold straddles the split (halving), fold inside keep,
# pure-copy send (fully_connected-like), single-block rounds.
GEOMETRIES = [(8, 4, 4), (8, 4, 2), (7, 3, 2), (5, 1, 4), (6, 2, 4), (2, 1, 1)]
COLS = [7, 128, 515]


def _rand(shape, dtype):
    if dtype == jnp.int32:
        return jnp.asarray(RNG.integers(-99, 99, shape), jnp.int32)
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def _assert_round_matches_ref(lo, nb, next_lo, cols, dtype, op):
    live = _rand((lo, cols), dtype)
    received = _rand((nb, cols), dtype)
    keep, send = fused_round(live, received, nb=nb, next_lo=next_lo, op=op, interpret=True)
    keep_ref, send_ref = R.fused_round_ref(live, received, nb=nb, next_lo=next_lo, op=op)
    assert keep.dtype == keep_ref.dtype and keep.shape == keep_ref.shape
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep_ref))
    assert (send is None) == (send_ref is None)
    if send is not None:
        np.testing.assert_array_equal(np.asarray(send), np.asarray(send_ref))


@pytest.mark.parametrize("cols", COLS)
@pytest.mark.parametrize("geometry", GEOMETRIES)
def test_fused_round_geometries(geometry, cols):
    lo, nb, next_lo = geometry
    _assert_round_matches_ref(lo, nb, next_lo, cols, jnp.float32, "add")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("op", ["add", "max", "min"])
def test_fused_round_dtypes_ops(dtype, op):
    _assert_round_matches_ref(8, 4, 2, 515, dtype, op)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.int32])
def test_fused_round_final_round(dtype):
    # next_lo == lo: keep only, no send buffer (the last schedule round).
    live = _rand((1, 130), dtype)
    received = _rand((1, 130), dtype)
    keep, send = fused_round(live, received, nb=1, next_lo=1, interpret=True)
    assert send is None
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(R.block_reduce_ref(live, received)))


def test_fused_round_rejects_bad_rounds():
    live = _rand((4, 16), jnp.float32)
    with pytest.raises(ValueError, match="invalid round"):
        fused_round(live, _rand((5, 16), jnp.float32), nb=5, next_lo=2, interpret=True)
    with pytest.raises(ValueError, match="received shape"):
        fused_round(live, _rand((3, 16), jnp.float32), nb=2, next_lo=2, interpret=True)
    with pytest.raises(ValueError, match="2-D"):
        fused_round(live[0], _rand((4, 16), jnp.float32), nb=2, next_lo=2, interpret=True)


@pytest.mark.parametrize("cols", COLS)
def test_permute_rows_matches_ref(cols):
    x = _rand((9, cols), jnp.float32)
    perm = list(RNG.permutation(9))
    got = permute_rows(x, perm, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(R.permute_rows_ref(x, perm)))


def test_permute_rows_rejects_non_permutation():
    with pytest.raises(ValueError, match="not a permutation"):
        permute_rows(_rand((4, 8), jnp.float32), [0, 1, 2, 2], interpret=True)


@given(st.integers(1, 10), st.integers(1, 97), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_fused_round_property(lo, cols, seed):
    nb = 1 + seed % lo
    next_lo = 1 + (seed // 7) % lo
    _assert_round_matches_ref(lo, nb, next_lo, cols, jnp.float32, "add")


@pytest.mark.parametrize("ndev", [4, 6])
def test_fused_collectives_subprocess(ndev):
    """Fused RS/AG/AR/alltoall bitwise-equal to the jnp paths on fake
    devices; ndev=6 is the non-power-of-two case the paper targets."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # worker sets its own device count
    proc = subprocess.run(
        [sys.executable, WORKER, str(ndev)],
        capture_output=True,
        text=True,
        timeout=600,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"fused checks failed for ndev={ndev}:\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    assert f"ALL FUSED CHECKS PASSED (ndev={ndev})" in proc.stdout
