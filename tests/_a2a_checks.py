"""Subprocess worker: alltoall(v) execution checks on 8 fake CPU devices.

Meshes of size p in {2, 3, 5, 8} carved from the 8 devices.  Per p:

  * fused alltoall bitwise-equal to the jnp path (stacked-slot buffers +
    Pallas permute_rows vs list-of-arrays) for f32, bf16 AND int32, and
    for SINGLE-ROW blocks (blk=1 — the degenerate slot geometry);
  * both agree with the host transpose reference and XLA's native
    all-to-all baseline;
  * ragged alltoallv (incl. zero-count rows) vs the numpy simulator;
  * HLO collective-permute count == ceil(log2 p) for halving, fused and
    unfused, uniform and ragged.

Plus the MoE expert-parallel parity check: ``moe_dispatch='ep'`` over a
2-rank mesh with RAGGED expert ownership (3 experts) matches the
``'global'`` single-pool dispatch numerically, token for token.

Run:  python tests/_a2a_checks.py
"""
import os
import sys

NDEV = 8
import re  # noqa: E402 — strip inherited count: XLA keeps the LAST flag
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={NDEV} " + _inherited)

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import compat  # noqa: E402
from repro.analysis.hlo_budget import (  # noqa: E402
    count_collective_permutes_lowered)
from repro.core import CollectiveSpec, ceil_log2  # noqa: E402
from repro.core import collectives as C  # noqa: E402
from repro.core import simulator as sim  # noqa: E402

rng = np.random.default_rng(31)


def check(name, cond=True):
    if not cond:
        raise AssertionError(f"FAILED: {name}")
    print(f"ok: {name}")


def run1(mesh, fn, xg, check_vma=None):
    f = jax.jit(compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                                 in_specs=(P("x"),), out_specs=P("x"),
                                 check_vma=check_vma))
    return np.asarray(f(xg))


def count_cp(mesh, fn, shape, check_vma=None):
    f = jax.jit(compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                                 in_specs=(P("x"),), out_specs=P("x"),
                                 check_vma=check_vma))
    return count_collective_permutes_lowered(f, shape)


def payload(p, blk, dtype):
    if dtype == jnp.int32:
        return jnp.asarray(rng.integers(-99, 99, (p, p, blk)), jnp.int32)
    x = rng.standard_normal((p, p, blk)).astype(np.float32)
    return jnp.asarray(x, dtype)


for p in (2, 3, 5, 8):
    mesh = compat.make_mesh((p,), ("x",),
                            devices=jax.devices()[:p])
    # --- uniform: fused vs jnp bitwise, dtypes, single-row blocks ---
    for dtype in (jnp.float32, jnp.bfloat16, jnp.int32):
        for blk in (1, 4):  # blk=1: single-row blocks
            x = payload(p, blk, dtype)
            out_jnp = run1(mesh, lambda v: C.circulant_alltoall(v, "x"), x)
            out_fused = run1(
                mesh, lambda v: C.circulant_alltoall(
                    v, "x", use_fused_kernel=True), x, check_vma=False)
            np.testing.assert_array_equal(out_fused, out_jnp)
            xh = np.asarray(x)
            for r in range(p):
                for j in range(p):
                    np.testing.assert_array_equal(out_jnp[r, j], xh[j, r])
            out_xla = run1(
                mesh, lambda v: C.alltoall(
                    v, "x", spec=CollectiveSpec(kind="xla")), x)
            np.testing.assert_array_equal(out_xla, out_jnp)
            check(f"alltoall p={p} blk={blk} {np.dtype(x.dtype).name}: "
                  f"fused == jnp == transpose == xla")
    n_cp = count_cp(mesh, lambda v: C.circulant_alltoall(v, "x"),
                    (p, p, 4))
    n_cp_f = count_cp(mesh, lambda v: C.circulant_alltoall(
        v, "x", use_fused_kernel=True), (p, p, 4), check_vma=False)
    check(f"alltoall p={p}: {n_cp}/{n_cp_f} collective-permutes == "
          f"ceil_log2 {ceil_log2(p)}",
          n_cp == ceil_log2(p) and n_cp_f == ceil_log2(p))

    # --- ragged alltoallv vs simulator (zero-count rows included) ---
    counts = tuple(tuple((i * 3 + j * 5) % 4 for j in range(p))
                   for i in range(p))
    if sum(sum(r) for r in counts) == 0:
        counts = tuple(tuple(1 for _ in range(p)) for _ in range(p))
    send_tot = [sum(r) for r in counts]
    in_h = max(max(send_tot), 1)
    inputs = [[rng.standard_normal((counts[r][d], 3)).astype(np.float32)
               for d in range(p)] for r in range(p)]
    xg = np.zeros((p, in_h, 3), np.float32)
    for r in range(p):
        j = 0
        for d in range(p):
            c = counts[r][d]
            xg[r, j:j + c] = inputs[r][d]
            j += c
    spec = CollectiveSpec(counts=counts)
    out = run1(mesh, lambda v: C.alltoall(v, "x", spec=spec),
               jnp.asarray(xg))
    W, stats = sim.simulate_alltoallv(inputs)
    for r in range(p):
        j = 0
        for s in range(p):
            c = counts[s][r]
            np.testing.assert_array_equal(out[r, j:j + c], W[r][s])
            j += c
        assert (out[r, j:] == 0).all()
    n_cp = count_cp(mesh, lambda v: C.alltoall(v, "x", spec=spec),
                    (p, in_h, 3))
    check(f"alltoallv p={p}: matches simulator, {n_cp} collective-"
          f"permutes == ceil_log2", n_cp == ceil_log2(p))

# ---------------------------------------------------------------------------
# MoE expert-parallel parity: ep == global, ragged ownership (e=3, p=2)
# ---------------------------------------------------------------------------
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.moe import init_moe, moe_ffn  # noqa: E402

pe, e = 2, 3
mesh = compat.make_mesh((pe,), ("x",), devices=jax.devices()[:pe])
cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                  n_kv_heads=2, d_ff=32, vocab_size=64, head_dim=8,
                  n_experts=e, experts_per_token=2, capacity_factor=8.0,
                  dtype="float32", moe_dispatch="ep", ep_axis="x")
params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (pe, 6, cfg.d_model),
                      jnp.float32)

f = jax.jit(compat.shard_map(
    lambda v: (lambda o: (o[0], o[1][None]))(moe_ffn(params, cfg, v)),
    mesh=mesh, in_specs=(P("x"),), out_specs=(P("x"), P("x")),
    check_vma=False))
out_ep, aux_ep = f(x)
cfg_g = dataclasses.replace(cfg, moe_dispatch="global")
per_shard = [np.asarray(moe_ffn(params, cfg_g, x[r:r + 1])[0])
             for r in range(pe)]
np.testing.assert_allclose(np.asarray(out_ep),
                           np.concatenate(per_shard, axis=0),
                           rtol=2e-5, atol=2e-5)
out_g, aux_g = moe_ffn(params, cfg_g, x)
np.testing.assert_allclose(np.asarray(out_ep), np.asarray(out_g),
                           rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(np.asarray(aux_ep), np.asarray(aux_g),
                           rtol=1e-5, atol=1e-6)
check(f"moe ep parity pe={pe} e={e} (ragged ownership): "
      f"ep == global, aux matches")

# ---------------------------------------------------------------------------
# zero1 + ep routing: build_zero1 pre-plans the ep exchanges, forces the
# fully-manual region, and a real step runs (loss finite, params update)
# ---------------------------------------------------------------------------
from repro.models import ShardingRecipe, build as build_model  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.optim.zero1 import GradSyncConfig  # noqa: E402
from repro.train import build as build_step  # noqa: E402

mcfg = ModelConfig(name="t2", family="moe", n_layers=2, d_model=16,
                   n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                   head_dim=8, n_experts=3, experts_per_token=2,
                   capacity_factor=4.0, dtype="float32",
                   moe_dispatch="ep", ep_axis="model")
mesh22 = compat.make_mesh((2, 2), ("data", "model"),
                          devices=jax.devices()[:4])
recipe = ShardingRecipe(data_axes=("data",), model_axis="model")
model = build_model(mcfg, recipe=recipe)
built = build_step("zero1", model, AdamWConfig(lr=1e-3, total_steps=2),
                   mesh=mesh22, recipe=recipe, sync=GradSyncConfig())
mparams = model.init(jax.random.PRNGKey(0))
opt = built.init_opt(mparams)
opt = jax.device_put(opt, built.opt_spec(mparams))
tok = rng.integers(0, 64, (4, 8)).astype(np.int32)
batch = {"tokens": jnp.asarray(tok), "targets": jnp.asarray(tok)}
with compat.use_mesh(mesh22):
    p2, o2, metrics = built.step_fn(mparams, opt, batch)
    loss = float(metrics["loss"])
check(f"zero1 + moe_dispatch=ep step on (2, 2) mesh: loss {loss:.3f} finite",
      np.isfinite(loss))
# a bad ep axis fails fast at build time, before any tracing
try:
    bad = ModelConfig(name="t3", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                      head_dim=8, n_experts=3, experts_per_token=2,
                      dtype="float32", moe_dispatch="ep", ep_axis="nosuch")
    build_step("zero1", build_model(bad, recipe=recipe),
               AdamWConfig(lr=1e-3, total_steps=1), mesh=mesh22,
               recipe=recipe, sync=GradSyncConfig())
    check("ep with unknown axis must fail fast", False)
except ValueError as err:
    check(f"ep with unknown axis fails fast ({err})", "nosuch" in str(err))

print("ALL A2A CHECKS PASSED")
