"""Subprocess worker: validates the shard_map collectives on N fake CPU
devices against the numpy simulator oracle and checks HLO structure
(collective-permute counts = Theorem 1/2 round counts).

Run:  python tests/_multidev_checks.py <ndev>
Exits 0 on success; prints a failure trace otherwise.

Convention: global inputs are (p, ...) arrays sharded on axis 0, so each
rank's shard has leading dim 1; collective lambdas unwrap with v[0] and
rewrap with out[None].
"""
import os
import sys

NDEV = int(sys.argv[1]) if len(sys.argv) > 1 else 8
import re  # noqa: E402 — strip inherited count: XLA keeps the LAST flag
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={NDEV} " + _inherited)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import compat  # noqa: E402
from repro.analysis.hlo_budget import (  # noqa: E402
    count_collective_permutes_lowered)
from repro.core import collectives as C  # noqa: E402
from repro.core import simulator as sim  # noqa: E402
from repro.core.schedule import ceil_log2  # noqa: E402

mesh = compat.make_mesh((NDEV,), ("x",))
rng = np.random.default_rng(42)

p = NDEV
BLK = 6


def run1(fn, x_global):
    """Apply per-rank fn under shard_map; fn sees v[0], returns out;
    result is stacked (p, ...)."""
    f = jax.jit(compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                                 in_specs=(P("x"),), out_specs=P("x")))
    return np.asarray(f(x_global))


def check(name, cond=True):
    if not cond:
        raise AssertionError(f"FAILED: {name}")
    print(f"ok: {name}")


def make_global(extra=()):  # (p, p*BLK, *extra): row r = rank r's input vector
    return rng.standard_normal((p, p * BLK, *extra)).astype(np.float32)


def sim_inputs(xg):
    return [[xg[r, i * BLK:(i + 1) * BLK] for i in range(p)] for r in range(p)]


# ---------------------------------------------------------------------------
# reduce-scatter: all schedules + baselines vs simulator oracle
# ---------------------------------------------------------------------------
xg = make_global()
W_oracle, stats = sim.simulate_reduce_scatter(sim_inputs(xg))
stats.assert_theorem1(p)

scheds = ["halving", "power2", "fully_connected", "sqrt"]
for sched in scheds:
    out = run1(lambda v, s=sched: C.circulant_reduce_scatter(v, "x", schedule=s), xg)
    for r in range(p):
        np.testing.assert_allclose(out[r], W_oracle[r], rtol=2e-5, atol=2e-5)
    check(f"circulant_reduce_scatter[{sched}] == oracle (p={p})")

from repro.core import CollectiveSpec  # noqa: E402

impls = ["ring", "xla"] + (["recursive_halving"] if p & (p - 1) == 0 else [])
for impl in impls:
    out = run1(lambda v, i=impl: C.reduce_scatter(
        v, "x", spec=CollectiveSpec(kind=i)), xg)
    for r in range(p):
        np.testing.assert_allclose(out[r], W_oracle[r], rtol=2e-5, atol=2e-5)
    check(f"reduce_scatter[spec kind={impl}] == oracle (p={p})")

# legacy impl= string dispatch: still works, but warns DeprecationWarning
import warnings  # noqa: E402

with warnings.catch_warnings(record=True) as _rec:
    warnings.simplefilter("always")
    out = run1(lambda v: C.reduce_scatter(v, "x", impl="ring"), xg)
for r in range(p):
    np.testing.assert_allclose(out[r], W_oracle[r], rtol=2e-5, atol=2e-5)
check("legacy impl= dispatch works and deprecates",
      any(issubclass(w.category, DeprecationWarning) for w in _rec))

# ---------------------------------------------------------------------------
# Non-uniform counts (paper Corollary 3) via CollectiveSpec(counts=...)
# ---------------------------------------------------------------------------
counts = tuple((i * 5 + 3) % 7 for i in range(p))
offs = np.concatenate([[0], np.cumsum(counts)])
N, bmax = int(sum(counts)), int(max(counts))
xnu = rng.standard_normal((p, N)).astype(np.float32)
inputs_nu = [[xnu[r, offs[i]:offs[i + 1]] for i in range(p)]
             for r in range(p)]
W_nu, st_nu = sim.simulate_reduce_scatter(inputs_nu)
st_nu.assert_theorem1(p)
spec_nu = CollectiveSpec(counts=counts)
out = run1(lambda v: C.reduce_scatter(v, "x", spec=spec_nu), xnu)
for r in range(p):
    np.testing.assert_allclose(out[r, :counts[r]], W_nu[r],
                               rtol=2e-5, atol=2e-5)
    assert (out[r, counts[r]:] == 0).all()
check(f"non-uniform reduce_scatter counts={counts} == simulator (p={p})")
out = run1(lambda v: C.allreduce(v, "x", spec=spec_nu), xnu)
ref_nu = xnu.astype(np.float64).sum(axis=0)
for r in range(p):
    np.testing.assert_allclose(out[r], ref_nu, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(out[r], out[0])
check(f"non-uniform allreduce replicated (p={p})")

# Higher-rank payloads (matrix blocks).
xg2 = make_global(extra=(3,))
inputs2 = [[xg2[r, i * BLK:(i + 1) * BLK] for i in range(p)] for r in range(p)]
W2, _ = sim.simulate_reduce_scatter(inputs2)
out = run1(lambda v: C.circulant_reduce_scatter(v, "x"), xg2)
for r in range(p):
    np.testing.assert_allclose(out[r], W2[r], rtol=2e-5, atol=2e-5)
check("circulant_reduce_scatter rank-3 payload")

# max-reduction (commutative non-add op)
outmax = run1(lambda v: C.circulant_reduce_scatter(v, "x", op="max"), xg)
Wmax, _ = sim.simulate_reduce_scatter(sim_inputs(xg), op=np.maximum)
for r in range(p):
    np.testing.assert_allclose(outmax[r], Wmax[r], rtol=1e-6)
check("circulant_reduce_scatter op=max")

# bf16 payload
outb = run1(lambda v: C.circulant_reduce_scatter(v.astype(jnp.bfloat16), "x"),
            xg)
for r in range(p):
    np.testing.assert_allclose(outb[r].astype(np.float32), W_oracle[r],
                               rtol=0.05, atol=0.2)
check("circulant_reduce_scatter bf16")

# compressed rounds: int8 payload, error bounded by quantization noise
from repro.kernels import make_compressors  # noqa: E402

comp, decomp = make_compressors(group=BLK, backend="jnp")
outc = run1(lambda v: C.circulant_reduce_scatter(
    v.reshape(p, BLK), "x", compress=comp, decompress=decomp).reshape(BLK), xg)
scale = np.abs(xg).max() / 127.0
for r in range(p):
    np.testing.assert_allclose(outc[r], W_oracle[r], atol=scale * p, rtol=0.1)
check("circulant_reduce_scatter int8-compressed rounds")

# ---------------------------------------------------------------------------
# allgather
# ---------------------------------------------------------------------------
blocks = rng.standard_normal((p, BLK)).astype(np.float32)
for sched in scheds:
    out = run1(lambda v, s=sched: C.circulant_allgather(v, "x", schedule=s),
               blocks)
    out = out.reshape(p, p, BLK)
    for r in range(p):
        np.testing.assert_array_equal(out[r], blocks)
    check(f"circulant_allgather[{sched}] (p={p})")

# ---------------------------------------------------------------------------
# allreduce: value == sum, replication, determinism
# ---------------------------------------------------------------------------
ref_sum = xg.sum(axis=0)
for sched in scheds:
    out = run1(lambda v, s=sched: C.circulant_allreduce(v, "x", schedule=s), xg)
    np.testing.assert_allclose(out[0], ref_sum, rtol=2e-5, atol=2e-5)
    for r in range(1, p):
        np.testing.assert_array_equal(out[r], out[0])
    check(f"circulant_allreduce[{sched}] == sum, replicated (p={p})")

out1 = run1(lambda v: C.circulant_allreduce(v, "x"), xg)
out2 = run1(lambda v: C.circulant_allreduce(v, "x"), xg)
np.testing.assert_array_equal(out1, out2)
check("circulant_allreduce bit-determinism")

out = run1(lambda v: C.ring_allreduce(v, "x"), xg)
np.testing.assert_allclose(out[0], ref_sum, rtol=2e-5, atol=2e-5)
check("ring_allreduce == sum")

# ---------------------------------------------------------------------------
# alltoall by concatenation (paper §4)
# ---------------------------------------------------------------------------
a2a_in = rng.standard_normal((p, p, BLK)).astype(np.float32)  # [src, dst, blk]
out = run1(lambda v: C.circulant_alltoall(v, "x"), a2a_in)
for r in range(p):
    for j in range(p):
        np.testing.assert_array_equal(out[r, j], a2a_in[j, r])
check(f"circulant_alltoall (p={p})")

# ---------------------------------------------------------------------------
# HLO structure: Theorem 1/2 round counts visible as collective-permutes
# ---------------------------------------------------------------------------
def count_cp(fn):
    f = jax.jit(compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                                 in_specs=(P("x"),), out_specs=P("x")))
    return count_collective_permutes_lowered(f, (p, p * BLK))


q = ceil_log2(p)
n_rs = count_cp(lambda v: C.circulant_reduce_scatter(v, "x"))
check(f"HLO: RS has {q} collective-permutes (got {n_rs})", n_rs == q)
n_ar = count_cp(lambda v: C.circulant_allreduce(v, "x"))
check(f"HLO: AR has {2 * q} collective-permutes (got {n_ar})", n_ar == 2 * q)
n_ring = count_cp(lambda v: C.ring_reduce_scatter(v, "x"))
check(f"HLO: ring RS has {p - 1} collective-permutes (got {n_ring})",
      n_ring == p - 1)

# ---------------------------------------------------------------------------
# Hierarchical (2-axis) allreduce on a (2, NDEV//2) mesh
# ---------------------------------------------------------------------------
if NDEV % 2 == 0 and NDEV >= 4:
    mesh2 = compat.make_mesh((2, NDEV // 2), ("pod", "data"))
    n2 = NDEV // 2
    f = jax.jit(compat.shard_map(
        lambda v: C.hierarchical_allreduce(v[0, 0], ("data", "pod"))[None, None],
        mesh=mesh2, in_specs=(P("pod", "data"),),
        out_specs=P("pod", "data")))
    tot = 8 * n2
    x2 = rng.standard_normal((2, n2, tot)).astype(np.float32)
    out = np.asarray(f(x2))
    ref = x2.sum(axis=(0, 1))
    for i in range(2):
        for j in range(n2):
            np.testing.assert_allclose(out[i, j], ref, rtol=2e-5, atol=2e-5)
    check("hierarchical_allreduce over (data, pod)")

print(f"ALL MULTIDEV CHECKS PASSED (ndev={NDEV})")
