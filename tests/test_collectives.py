"""Integration tests for the shard_map circulant collectives.

The heavy numerical checks run in a subprocess with
``--xla_force_host_platform_device_count=N`` so that the main pytest
process keeps seeing exactly ONE device (required: smoke tests/benches
must not inherit fake-device state).  ``tests/_multidev_checks.py``
validates, per device count:

  * circulant RS/AG/AR for all four Corollary-2 schedules vs the numpy
    simulator oracle (which itself asserts Theorem 1/2 counts),
  * ring / recursive-halving / XLA-native baselines vs the same oracle,
    dispatched through CollectiveSpec (plus the deprecated impl= string),
  * non-uniform counts (paper Corollary 3) reduce-scatter/allreduce via
    CollectiveSpec(counts=...) vs the simulator,
  * alltoall-by-concatenation (paper §4),
  * bit-determinism of the float reduction,
  * HLO structure: exactly ceil(log2 p) collective-permutes for RS and
    2*ceil(log2 p) for AR (Theorem 1/2 visible in the IR),
  * hierarchical (pod, data) allreduce on a 2-axis mesh.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.abspath(__file__))
WORKER = os.path.join(HERE, "_multidev_checks.py")


def _run(ndev: int) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # worker sets its own device count
    proc = subprocess.run(
        [sys.executable, WORKER, str(ndev)],
        capture_output=True, text=True, timeout=600, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidev checks failed for ndev={ndev}:\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout


@pytest.mark.parametrize("ndev", [8, 6])
def test_multidev_collectives(ndev):
    out = _run(ndev)
    assert f"ALL MULTIDEV CHECKS PASSED (ndev={ndev})" in out


def test_main_process_still_single_device():
    """Worker fake-device state must not leak into the main process: the
    main-process device count matches this process' OWN environment (1
    when XLA_FLAGS is unset; CI pins an explicit count)."""
    import re

    import jax
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                  os.environ.get("XLA_FLAGS", ""))
    expected = int(m.group(1)) if m else 1
    assert jax.device_count() == expected
