"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
grad step + prefill/decode on CPU.  Asserts shapes, finiteness and that
decode-with-cache matches teacher-forced logits (cache correctness).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALIASES, get_config
from repro.models import build

ARCH_IDS = list(ALIASES)


def make_batch(cfg, key, batch=2, seq=16):
    ks = jax.random.split(key, 4)
    tokens = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
    targets = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size)
    b = {"tokens": tokens, "targets": targets}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(ks[2], (batch, seq, cfg.d_model))
    if cfg.family == "vlm":
        b["image_embeds"] = jax.random.normal(
            ks[2], (batch, cfg.n_image_tokens, cfg.d_model))
    return b


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).scaled_down()
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return request.param, cfg, model, params


def test_full_config_loads_and_counts(arch_setup):
    arch_id, *_ = arch_setup
    full = get_config(arch_id)
    n = full.param_count()
    assert n > 1e7, f"{arch_id}: param count {n} suspiciously small"
    if full.is_moe:
        assert full.active_param_count() < n


def test_forward_and_loss_finite(arch_setup):
    arch_id, cfg, model, params = arch_setup
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch_id}: non-finite loss"


def test_grad_step_finite(arch_setup):
    arch_id, cfg, model, params = arch_setup
    batch = make_batch(cfg, jax.random.PRNGKey(2))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert bool(jnp.isfinite(loss))
    flat, _ = jax.tree.flatten(grads)
    assert flat, "no grads"
    for g in flat:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch_id}: non-finite grad"
    # gradients actually flow to the embedding
    gnorm = sum(float(jnp.abs(g).sum()) for g in flat)
    assert gnorm > 0


def test_logits_shape(arch_setup):
    arch_id, cfg, model, params = arch_setup
    batch = make_batch(cfg, jax.random.PRNGKey(3))
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = batch["frames"]
    if cfg.family == "vlm":
        extras["image_embeds"] = batch["image_embeds"]
    logits, aux = model.forward_logits(params, batch["tokens"], **extras)
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_prefill_decode_matches_forward(arch_setup):
    """Teacher-forced logits at position t must equal decode-with-cache
    logits after consuming tokens [0..t] — validates every cache path."""
    arch_id, cfg, model, params = arch_setup
    b, s, max_len = 2, 8, 16
    key = jax.random.PRNGKey(4)
    batch = make_batch(cfg, key, batch=b, seq=s)
    tokens = batch["tokens"]
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = batch["frames"]
    if cfg.family == "vlm":
        extras["image_embeds"] = batch["image_embeds"]

    ref_logits, _ = model.forward_logits(params, tokens, **extras)
    cache, logits_prefill = model.prefill(params, tokens, max_len, **extras)
    np.testing.assert_allclose(np.asarray(logits_prefill),
                               np.asarray(ref_logits[:, -1]),
                               rtol=2e-2, atol=2e-3)
    # one decode step past the prompt
    nxt = jnp.argmax(logits_prefill, -1).astype(tokens.dtype)
    cache2, logits_step = model.decode_step(params, cache, nxt,
                                            jnp.asarray(s, jnp.int32))
    assert logits_step.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits_step)))
    # and the step must equal teacher-forcing on the extended sequence
    ext = jnp.concatenate([tokens, nxt[:, None]], 1)
    ref2, _ = model.forward_logits(params, ext, **extras)
    np.testing.assert_allclose(np.asarray(logits_step),
                               np.asarray(ref2[:, -1]),
                               rtol=2e-2, atol=2e-3)


def test_param_specs_structure_matches(arch_setup):
    from repro.models import ShardingRecipe, make_param_specs
    arch_id, cfg, model, params = arch_setup
    recipe = ShardingRecipe(data_axes=("data",), model_axis="model",
                            mode="tp_fsdp")
    specs = make_param_specs(params, recipe)
    jax.tree.map(lambda p, s: None, params, specs)  # structure identical
    flat = jax.tree.leaves(specs)
    assert any(sp != jax.sharding.PartitionSpec() for sp in flat)
