"""Per-kernel allclose tests vs the ref.py oracles: shape × dtype sweeps in
interpret mode (CPU container; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import (dequant_accumulate, dequantize_blocks,
                           fused_block_reduce, quantize_blocks)
from repro.kernels import ref as R
from repro.kernels.block_reduce import block_reduce
from repro.kernels.quantize import dequant_add, quantize

RNG = np.random.default_rng(7)

SHAPES = [(8, 128), (16, 256), (256, 512), (8, 384), (3, 7), (1, 1),
          (130, 515)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("op", ["add", "max", "min"])
def test_block_reduce_matches_ref(shape, dtype, op):
    a = jnp.asarray(RNG.standard_normal(shape), dtype)
    b = jnp.asarray(RNG.standard_normal(shape), dtype)
    got = fused_block_reduce(a, b, op=op)
    want = R.block_reduce_ref(a, b, op=op)
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=0, atol=0)


def test_block_reduce_raw_kernel_tile_aligned():
    """Direct pallas_call path (no padding) on exactly tile-aligned input."""
    a = jnp.asarray(RNG.standard_normal((512, 1024)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((512, 1024)), jnp.float32)
    got = block_reduce(a, b, op="add", row_tile=256, col_tile=512,
                       interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(a + b))


@pytest.mark.parametrize("rank", [1, 3, 4])
def test_block_reduce_nd_payloads(rank):
    shape = tuple([4] * (rank - 1) + [96])
    a = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    b = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    got = fused_block_reduce(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(a + b))


@pytest.mark.parametrize("shape", [(4, 512), (2, 1024), (8, 384), (1, 512),
                                   (5, 130)])
@pytest.mark.parametrize("group", [128, 512])
def test_quantize_roundtrip_error_bound(shape, group):
    x = jnp.asarray(RNG.standard_normal(shape) * 3.0, jnp.float32)
    payload = quantize_blocks(x, group=group)
    back = dequantize_blocks(payload)
    assert back.shape == x.shape
    # Symmetric int8: |err| <= scale/2 per element; scale = amax/127.
    g = min(group, int(np.shape(x)[1]))
    cols = x.shape[1]
    pc = (-cols) % g
    xp = np.pad(np.asarray(x), ((0, 0), (0, pc)))
    xg = xp.reshape(shape[0], -1, g)
    amax = np.abs(xg).max(axis=2)
    bound = (amax / 127.0) / 2 + 1e-6
    err = np.abs(np.asarray(back) - np.asarray(x))
    errg = np.pad(err, ((0, 0), (0, pc))).reshape(shape[0], -1, g)
    assert (errg.max(axis=2) <= bound + 1e-7).all()


@pytest.mark.parametrize("shape", [(4, 512), (8, 384)])
def test_quantize_kernel_matches_ref(shape):
    x = jnp.asarray(RNG.standard_normal(shape) * 2.0, jnp.float32)
    g = 128
    pc = (-shape[1]) % g
    xp = jnp.pad(x, ((0, 0), (0, pc)))
    codes_k, scales_k = quantize(xp, group=g, row_tile=1, interpret=True)
    codes_r, scales_r = R.quantize_ref(xp, group=g)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_allclose(np.asarray(scales_k),
                               np.asarray(scales_r), rtol=1e-6)


@pytest.mark.parametrize("shape", [(4, 512), (2, 256)])
def test_dequant_add_fused_matches_ref(shape):
    x = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    acc = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    g = 128
    codes, scales = R.quantize_ref(x, group=g)
    got = dequant_add(acc, codes, scales, group=g, row_tile=1, interpret=True)
    want = R.dequant_add_ref(acc, codes, scales, group=g)
    # fp32 FMA contraction in the kernel vs separate mul+add in the ref
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_dequant_accumulate_wrapper():
    x = jnp.asarray(RNG.standard_normal((3, 700)), jnp.float32)
    acc = jnp.asarray(RNG.standard_normal((3, 700)), jnp.float32)
    payload = quantize_blocks(x, group=256)
    got = dequant_accumulate(acc, payload)
    want = acc + dequantize_blocks(payload)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 20), st.integers(1, 600), st.sampled_from(["add", "max"]))
@settings(max_examples=25, deadline=None)
def test_block_reduce_property(rows, cols, op):
    a = jnp.asarray(RNG.standard_normal((rows, cols)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((rows, cols)), jnp.float32)
    got = fused_block_reduce(a, b, op=op)
    want = R.block_reduce_ref(a, b, op=op)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_compression_ratio():
    """int8+scales payload is ~3.5-4x smaller than f32 (β-term win)."""
    x = jnp.zeros((16, 4096), jnp.float32)
    payload = quantize_blocks(x, group=512)
    raw = x.size * 4
    comp = payload["codes"].size * 1 + payload["scales"].size * 4
    assert raw / comp > 3.5
