"""Subprocess worker: ZeRO-1 training via the paper's collectives on a
(data=4, model=2) fake-device mesh must match single-device AdamW training
step-for-step.  Also checks: HLO round counts in the train step, all
grad-sync impls agree, int8-compressed sync stays close, and the
no-ZeRO allreduce baseline agrees.

Run: python tests/_zero1_checks.py
"""
import os
import sys

import re  # noqa: E402 — strip inherited count: XLA keeps the LAST flag
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + _inherited)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.analysis.hlo_budget import (  # noqa: E402
    count_collective_permutes)
from repro.configs import get_config  # noqa: E402
from repro.data import for_model  # noqa: E402
from repro.models import ShardingRecipe, build  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.optim.zero1 import GradSyncConfig  # noqa: E402
from repro.train import build as build_step  # noqa: E402
from repro.core.schedule import ceil_log2  # noqa: E402

mesh = compat.make_mesh((4, 2), ("data", "model"))
cfg = get_config("qwen3-1.7b").scaled_down(n_layers=2, vocab_size=64)
opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50,
                      weight_decay=0.01)
pipe = for_model(cfg, seq_len=16, global_batch=8, seed=3)
N_STEPS = 8


def run_single():
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    built = build_step("single", model, opt_cfg)
    opt = built.init_opt(params)
    losses = []
    for step in range(N_STEPS):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, opt, m = built.step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    return np.array(losses), params


def run_zero1(impl, schedule="halving", wire=None, error_feedback=True,
              **sync_kw):
    recipe = ShardingRecipe(data_axes=("data",), model_axis="model")
    model = build(cfg, recipe=recipe, remat=False)
    with compat.use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
    sync = GradSyncConfig(impl=impl, schedule=schedule, wire_dtype=wire,
                          error_feedback=error_feedback, quant_group=64,
                          **sync_kw)
    built = build_step("zero1", model, opt_cfg, mesh=mesh, recipe=recipe,
                       sync=sync)
    opt = built.init_opt(params)
    opt = jax.device_put(opt, built.opt_spec(params))
    losses = []
    with compat.use_mesh(mesh):
        for step in range(N_STEPS):
            batch = {k: jax.device_put(
                jnp.asarray(v), NamedSharding(mesh, built.batch_spec))
                for k, v in pipe.batch_at(step).items()}
            params, opt, m = built.step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
    return np.array(losses), params, opt


def check(name, cond=True):
    if not cond:
        raise AssertionError(f"FAILED: {name}")
    print(f"ok: {name}")


ref_losses, ref_params = run_single()
check(f"single-device baseline trains (loss {ref_losses[0]:.4f} -> "
      f"{ref_losses[-1]:.4f})", ref_losses[-1] < ref_losses[0])

for impl, sched in [("circulant", "halving"), ("circulant", "power2"),
                    ("ring", "halving"), ("xla", "halving"),
                    ("allreduce", "halving")]:
    losses, params, _ = run_zero1(impl, sched)
    err = np.abs(losses - ref_losses).max()
    check(f"zero1[{impl}:{sched}] matches single-device losses "
          f"(max err {err:.2e})", err < 5e-3)

# int8 wire-compressed gradient sync + error feedback: the DOCUMENTED
# tolerance for the compressed trajectory vs the uncompressed baseline on
# this smoke config is 0.05 (README §Compressed wire format); it must
# also still train.
losses_c, _, opt_c = run_zero1("circulant", wire="int8")
check(f"zero1[circulant+int8+EF] trains (loss {losses_c[0]:.4f} -> "
      f"{losses_c[-1]:.4f})", losses_c[-1] < losses_c[0])
err_c = np.abs(losses_c - ref_losses).max()
check(f"zero1[circulant+int8+EF] within documented tolerance of baseline "
      f"(max err {err_c:.2e} < 0.05)", err_c < 0.05)

# EF state is real: residuals exist, are per-rank (leading dim = DP world
# for sharded leaves), and are non-zero after training steps.
ef_leaves = jax.tree.leaves(opt_c.ef)
check(f"EF residual state present ({len(ef_leaves)} leaves)",
      len(ef_leaves) > 0)
big_ef = opt_c.ef["layers"]["attn"]["wq"]
check(f"EF residual per-rank leading dim == DP world ({big_ef.shape})",
      big_ef.shape[0] == 4 and big_ef.shape[1:] == ref_params["layers"][
          "attn"]["wq"].shape)
ef_norm = float(sum(jnp.sum(jnp.abs(l)) for l in ef_leaves))
check(f"EF residuals non-zero after training (sum |e| = {ef_norm:.3g})",
      ef_norm > 0)

# Bucketed, software-pipelined sync (GradSyncConfig.bucket_bytes): the
# uncompressed bucketed trajectory must be BITWISE-identical to the
# unbucketed one (the circulant fold order depends only on the block
# index, which the bucket layout preserves), and the int8+EF bucketed
# trajectory must stay within the documented wire tolerance — per-bucket
# EF residual accounting rides the same per-leaf residuals.
losses_ub, params_ub, _ = run_zero1("circulant")
losses_b, params_b, _ = run_zero1("circulant", bucket_bytes=1 << 18)
check(f"zero1[bucketed f32] losses BITWISE == unbucketed "
      f"({losses_b[-1]:.6f})", np.array_equal(losses_b, losses_ub))
pw = all(jnp.array_equal(a, b).item() for a, b in
         zip(jax.tree.leaves(params_ub), jax.tree.leaves(params_b)))
check("zero1[bucketed f32] final params BITWISE == unbucketed", pw)

losses_bc, _, opt_bc = run_zero1("circulant", wire="int8",
                                 bucket_bytes=1 << 18)
err_bc = np.abs(losses_bc - ref_losses).max()
check(f"zero1[bucketed int8+EF] within documented tolerance of baseline "
      f"(max err {err_bc:.2e} < 0.05)", err_bc < 0.05)
ef_norm_b = float(sum(jnp.sum(jnp.abs(l))
                      for l in jax.tree.leaves(opt_bc.ef)))
check(f"bucketed EF residuals accumulate per bucket "
      f"(sum |e| = {ef_norm_b:.3g})", ef_norm_b > 0)

# EF off: still trains within the loose tolerance, and the optimizer
# state carries NO residual tree.
losses_noef, _, opt_noef = run_zero1("circulant", wire="int8",
                                     error_feedback=False)
check(f"zero1[circulant+int8, no EF] trains and stays loosely close "
      f"(max err {np.abs(losses_noef - ref_losses).max():.2e} < 0.15)",
      np.abs(losses_noef - ref_losses).max() < 0.15)
check("no EF residual state when error_feedback=False",
      opt_noef.ef is None)

# Optimizer-state sharding: m has 1/4 of padded flat length per device.
recipe = ShardingRecipe(data_axes=("data",), model_axis="model")
model = build(cfg, recipe=recipe, remat=False)
with compat.use_mesh(mesh):
    params = model.init(jax.random.PRNGKey(0))
built = build_step("zero1", model, opt_cfg, mesh=mesh, recipe=recipe,
                   sync=GradSyncConfig())
opt = jax.device_put(built.init_opt(params), built.opt_spec(params))
# zero leaves must be sharded 1/4 along dim 0 on the data axis
big_m = opt.m["layers"]["attn"]["wq"]
shard_rows = {s.data.shape[0] for s in big_m.addressable_shards}
check(f"optimizer m zero-leaf sharded 1/4 along dim0 ({shard_rows}, "
      f"global {big_m.shape})", shard_rows == {big_m.shape[0] // 4})
# ZeRO memory win: total optimizer bytes per device ~ 1/4 of replicated
opt_bytes_per_dev = sum(
    s.data.nbytes for l in jax.tree.leaves(opt.m) + jax.tree.leaves(opt.v)
    if hasattr(l, "addressable_shards")
    for s in l.addressable_shards if s.device == jax.devices()[0])
full_bytes = sum(l.nbytes for l in jax.tree.leaves(opt.m)
                 + jax.tree.leaves(opt.v))
check(f"ZeRO-1 opt bytes/device {opt_bytes_per_dev} <~ full/4 "
      f"({full_bytes // 4})", opt_bytes_per_dev < full_bytes / 4 * 1.3)

# HLO structure: the jitted train step contains the RS + AG rounds
# (2*ceil(log2 4) = 4 collective-permutes) over the data axis.
batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
with compat.use_mesh(mesh):
    lowered = jax.jit(built.step_fn).lower(params, opt, batch)
n_cp = count_collective_permutes(lowered.as_text())
q = ceil_log2(4)
check(f"train-step HLO has >= {2 * q} collective-permutes (got {n_cp})",
      n_cp >= 2 * q)

# ---------------------------------------------------------------------------
# Multi-pod: (pod=2, data=2, model=2) mesh — hierarchical circulant
# RS/AG nested over ('data', 'pod') must also match single-device training.
# ---------------------------------------------------------------------------
mesh3 = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
recipe3 = ShardingRecipe(data_axes=("pod", "data"), model_axis="model")
model3 = build(cfg, recipe=recipe3, remat=False)
with compat.use_mesh(mesh3):
    params3 = model3.init(jax.random.PRNGKey(0))
built3 = build_step("zero1", model3, opt_cfg, mesh=mesh3, recipe=recipe3,
                    sync=GradSyncConfig())
opt3 = jax.device_put(built3.init_opt(params3), built3.opt_spec(params3))
losses3 = []
with compat.use_mesh(mesh3):
    for step in range(N_STEPS):
        batch = {k: jax.device_put(
            jnp.asarray(v), NamedSharding(mesh3, built3.batch_spec))
            for k, v in pipe.batch_at(step).items()}
        params3, opt3, m3 = built3.step_fn(params3, opt3, batch)
        losses3.append(float(m3["loss"]))
err3 = np.abs(np.array(losses3) - ref_losses).max()
check(f"zero1 MULTI-POD (pod,data,model)=(2,2,2) matches single-device "
      f"(max err {err3:.2e})", err3 < 5e-3)

print("ALL ZERO1 CHECKS PASSED")
