"""Simulator tests = direct validation of the paper's Theorems 1 & 2 and the
§4 all-to-all observation, over many p (powers of two and not)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import simulator as sim
from repro.core.schedule import ceil_log2

RNG = np.random.default_rng(0)


def make_inputs(p, blk, dtype=np.float64, seed=0):
    rng = np.random.default_rng(seed)
    return [[rng.standard_normal(blk).astype(dtype) for _ in range(p)]
            for _ in range(p)]


@pytest.mark.parametrize("p", [2, 3, 4, 5, 7, 8, 13, 16, 22, 31, 32, 57, 64, 100])
def test_reduce_scatter_correct_and_theorem1(p):
    inputs = make_inputs(p, blk=5)
    W, stats = sim.simulate_reduce_scatter(inputs)
    ref = sim.ref_reduce_scatter(inputs)
    for r in range(p):
        np.testing.assert_allclose(W[r], ref[r], rtol=1e-10, atol=1e-10)
    stats.assert_theorem1(p)


@pytest.mark.parametrize("p", [2, 3, 5, 8, 22, 37, 64])
def test_allreduce_correct_and_theorem2(p):
    inputs = make_inputs(p, blk=3)
    W, stats = sim.simulate_allreduce(inputs)
    ref = sim.ref_allreduce(inputs)
    for r in range(p):
        for i in range(p):
            np.testing.assert_allclose(W[r][i], ref[r][i], rtol=1e-10)
    stats.assert_theorem2(p)


@pytest.mark.parametrize("p", [2, 3, 6, 17, 32])
def test_allgather_correct(p):
    blocks = [RNG.standard_normal(4) for _ in range(p)]
    out, stats = sim.simulate_allgather(blocks)
    for r in range(p):
        for j in range(p):
            np.testing.assert_array_equal(out[r][j], blocks[j])
    assert stats.rounds == ceil_log2(p)
    assert all(b == p - 1 for b in stats.blocks_sent)


@given(st.integers(2, 40), st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_reduce_scatter_property(p, blk, seed):
    inputs = make_inputs(p, blk, seed=seed)
    W, stats = sim.simulate_reduce_scatter(inputs)
    ref = sim.ref_reduce_scatter(inputs)
    for r in range(p):
        np.testing.assert_allclose(W[r], ref[r], rtol=1e-9, atol=1e-9)
    stats.assert_theorem1(p)


@pytest.mark.parametrize("schedule", ["halving", "power2", "fully_connected",
                                      "sqrt"])
@pytest.mark.parametrize("p", [2, 5, 16, 22, 40])
def test_corollary2_schedules_all_correct(p, schedule):
    """Corollary 2: any valid skip sequence solves the problem (with its own
    round count); volume stays p-1 blocks."""
    inputs = make_inputs(p, blk=3)
    W, stats = sim.simulate_reduce_scatter(inputs, schedule=schedule)
    ref = sim.ref_reduce_scatter(inputs)
    for r in range(p):
        np.testing.assert_allclose(W[r], ref[r], rtol=1e-10)
    assert all(b == p - 1 for b in stats.blocks_sent)


def test_irregular_blocks_mpi_reduce_scatter_flavor():
    """Blocks of different sizes per column (MPI_Reduce_scatter): the
    algorithm works as long as column sizes are consistent (paper §2.1)."""
    p = 9
    rng = np.random.default_rng(3)
    sizes = rng.integers(1, 7, size=p)
    inputs = [[rng.standard_normal(sizes[i]) for i in range(p)]
              for _ in range(p)]
    W, stats = sim.simulate_reduce_scatter(inputs)
    ref = sim.ref_reduce_scatter(inputs)
    for r in range(p):
        np.testing.assert_allclose(W[r], ref[r], rtol=1e-10)
    stats.assert_theorem1(p)


def test_single_nonempty_block_reduce_to_root_corollary3():
    """Extreme case of Corollary 3: all elements in one column == MPI_Reduce
    to that root."""
    p, m = 12, 24
    rng = np.random.default_rng(5)
    root = 7
    inputs = [[rng.standard_normal(m) if i == root else np.zeros(0)
               for i in range(p)] for _ in range(p)]
    W, stats = sim.simulate_reduce_scatter(inputs)
    ref = sum(inputs[r][root] for r in range(p))
    np.testing.assert_allclose(W[root], ref, rtol=1e-10)
    stats.assert_theorem1(p)


@pytest.mark.parametrize("p", [2, 4, 6, 11, 16, 22])
def test_alltoall_by_concatenation(p):
    """Paper §4: reduce-scatter with ⊕ = concatenation solves all-to-all in
    ceil(log2 p) rounds."""
    rng = np.random.default_rng(9)
    inputs = [[rng.standard_normal(3) for _ in range(p)] for _ in range(p)]
    out, stats = sim.simulate_alltoall(inputs)
    for r in range(p):
        for j in range(p):
            np.testing.assert_array_equal(out[r][j], inputs[j][r])
    assert stats.rounds == ceil_log2(p)


def test_alltoall_volume_amplification_reported():
    """The A2A volume exceeds p-1 blocks (Bruck trade-off) — quantified."""
    p = 16
    inputs = [[np.ones(1) for _ in range(p)] for _ in range(p)]
    _, stats = sim.simulate_alltoall(inputs)
    assert stats.blocks_sent[0] > p - 1
    # For pow2 p under halving==doubling: exactly (p/2)*log2(p)
    assert stats.blocks_sent[0] == (p // 2) * ceil_log2(p)


def test_commutative_but_order_sensitive_op_is_deterministic():
    """All ranks reduce in the same schedule order ⇒ identical results for a
    fixed p (determinism claim, DESIGN §6) even for float addition."""
    p = 22
    inputs = make_inputs(p, blk=7, dtype=np.float32, seed=11)
    W1, _ = sim.simulate_reduce_scatter(inputs)
    W2, _ = sim.simulate_reduce_scatter(inputs)
    for a, b in zip(W1, W2):
        np.testing.assert_array_equal(a, b)


def test_noncommutative_op_breaks_without_right_order():
    """§2.1 closing remark: the algorithm heavily exploits commutativity —
    a non-commutative ⊕ gives a different (wrong) result in general."""
    p = 6
    rng = np.random.default_rng(13)
    inputs = [[rng.standard_normal(2) for _ in range(p)] for _ in range(p)]

    def noncomm(a, b):  # 'first' projection mixed with subtraction
        return a - 2 * b

    W, _ = sim.simulate_reduce_scatter(inputs, op=noncomm)
    # Sequential rank-order fold:
    ref = sim.ref_reduce_scatter(inputs, op=noncomm)
    diffs = [np.abs(W[r] - ref[r]).max() for r in range(p)]
    assert max(diffs) > 1e-9
