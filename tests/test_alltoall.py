"""Alltoall(v) plan-layer tests — everything that needs NO devices:

* ``alltoall_moves`` trajectory properties (delivery, distinct-skip
  paths, Bruck volume == the simulator's per-rank block counters);
* ``A2APlan`` table properties: round widths equal the analytic worst
  windowed count sum, real rows partition per-entry hops, zero-count
  pairs contribute no rows, output rows are the (src, r) pairs in source
  order;
* p=1 identity, spec validation for the counts matrix, the backend
  registry entry, and the cost model's hop-amplified alltoall terms;
* the ep helpers' static index maps (ragged expert ownership).

The multi-device execution checks (fused-vs-jnp bitwise at p∈{2,3,5,8},
bf16/int32 payloads, single-row blocks, alltoallv vs the simulator, and
the MoE ep-vs-global parity) run in the ``tests/_a2a_checks.py``
subprocess worker driven from the bottom of this file.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BACKENDS, CollectiveSpec, CommModel,
                        a2a_round_entries, alltoall_moves,
                        alltoallv_round_widths, ceil_log2, plan,
                        t_alltoall, t_alltoallv, t_reduce_scatter)
from repro.core import simulator as sim
from repro.core.schedule import get_skips
from tests._hypothesis_compat import given, settings, st

SCHEDULES = ("halving", "power2", "fully_connected", "sqrt")
AX = "x"

HERE = os.path.dirname(os.path.abspath(__file__))
WORKER = os.path.join(HERE, "_a2a_checks.py")


def _plan(p, **kw):
    return plan(CollectiveSpec(**kw), p=p, axis_name=AX)


def _matrix_cases():
    return [
        ((0, 2, 1), (1, 0, 2), (2, 1, 0)),               # ragged, zero diag
        ((0, 0, 5, 0), (0, 0, 1, 0), (0, 0, 0, 0), (0, 0, 2, 0)),  # one rank
        ((1, 1), (1, 1)),                                # uniform p=2
        ((3,),),                                         # p=1
        tuple(tuple((i * 3 + j) % 4 for j in range(5)) for i in range(5)),
    ]


# ---------------------------------------------------------------------------
# Trajectories
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=2, max_value=48), st.sampled_from(SCHEDULES))
def test_moves_deliver_every_offset(p, schedule):
    """Every destination offset's hop path is a subset of DISTINCT skips
    summing to the offset (Corollary 2's decomposition, walked by the
    send windows), and the round count matches the schedule."""
    moves = alltoall_moves(p, schedule)
    assert len(moves) == len(get_skips(p, schedule))
    path: dict[int, list[int]] = {d: [] for d in range(p)}
    for skip, moved in moves:
        for d, shift in moved:
            assert shift == sum(path[d]), "shift must equal skips so far"
            path[d].append(skip)
    for d in range(1, p):
        assert sum(path[d]) == d
        assert len(set(path[d])) == len(path[d])  # distinct skips
    assert path[0] == []  # self payload never moves


@pytest.mark.parametrize("p", [2, 3, 5, 8, 12])
def test_moves_volume_matches_simulator(p):
    """sum(len(moved)) per rank == the simulator's blocks_sent counter —
    the Bruck volume amplification, cross-checked end to end."""
    inputs = [[np.ones(1) for _ in range(p)] for _ in range(p)]
    _, stats = sim.simulate_alltoall(inputs)
    want = sum(a2a_round_entries(p))
    assert all(b == want for b in stats.blocks_sent), \
        (stats.blocks_sent, want)
    assert stats.rounds == ceil_log2(p)


# ---------------------------------------------------------------------------
# A2APlan tables
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("counts", _matrix_cases())
@pytest.mark.parametrize("schedule", ("halving", "power2",
                                      "fully_connected"))
def test_a2a_round_widths_are_worst_window(counts, schedule):
    p = len(counts)
    pl = _plan(p, schedule=schedule, counts=counts)
    assert pl.backend == "alltoallv"
    assert pl.a2a.round_widths == alltoallv_round_widths(counts, schedule)


@pytest.mark.parametrize("counts", _matrix_cases())
def test_a2a_tables_route_every_row_per_hop(counts):
    """Table Theorem-1 analogue: over all rounds, the rows of pair
    (src, dst) are gathered exactly hops(dst-src) times in total (once
    per hop of its offset), zero-count pairs never appear, and sentinel
    padding is trailing."""
    p = len(counts)
    pl = _plan(p, counts=counts)
    a2a = pl.a2a
    N = a2a.total
    hops = {d: 0 for d in range(p)}
    for _, moved in alltoall_moves(p, "halving"):
        for d, _ in moved:
            hops[d] += 1
    gathered: dict[int, int] = {}
    for tab in a2a.round_tables:
        for r in range(p):
            real = [int(v) for v in tab[r] if v != N]
            # trailing sentinel only
            assert list(tab[r][:len(real)]) == real
            for v in real:
                gathered[v] = gathered.get(v, 0) + 1
    offs = a2a.pair_offsets
    for src in range(p):
        for dst in range(p):
            d = (dst - src) % p
            for row in range(offs[src, dst],
                             offs[src, dst] + counts[src][dst]):
                assert gathered.get(row, 0) == hops[d], \
                    f"pair ({src},{dst}) row {row}: gathered " \
                    f"{gathered.get(row, 0)}x, want {hops[d]}"
    # output rows: exactly the (src, r) pairs in source order
    for r in range(p):
        want = [row for src in range(p)
                for row in range(offs[src, r],
                                 offs[src, r] + counts[src][r])]
        real = [int(v) for v in a2a.out_rows[r] if v != N]
        assert real == want


def test_a2a_zero_count_rows_in_tables():
    """A rank with an all-zero counts row originates nothing — no row of
    a (0, dst) pair exists anywhere — yet it still receives its column
    (out_rows has exactly recv_total real rows), its seed table is all
    sentinel, and every wire keeps width >= 1 so sentinel-only rounds
    still cost exactly one collective-permute."""
    counts = ((0, 0, 0), (2, 0, 1), (1, 3, 0))
    pl = _plan(3, counts=counts)
    a2a = pl.a2a
    assert a2a.send_total == (0, 3, 4)
    assert a2a.recv_total == (3, 3, 1)
    assert all(v == a2a.total for v in a2a.seed_dst[0])  # seeds nothing
    for tab in a2a.round_tables:
        assert tab.shape[1] >= 1
    for r in range(3):
        real = [int(v) for v in a2a.out_rows[r] if v != a2a.total]
        assert len(real) == a2a.recv_total[r]
    assert len(pl.rs_rounds) == ceil_log2(3)


# ---------------------------------------------------------------------------
# p=1 identity + validation + registry
# ---------------------------------------------------------------------------

def test_p1_identity():
    x = jnp.arange(6.0).reshape(1, 6)
    assert (_plan(1).alltoall(x) == x).all()
    xv = jnp.arange(8.0).reshape(4, 2)
    out = _plan(1, counts=((4,),)).alltoall(xv)
    assert (out == xv).all()


def test_counts_matrix_validation():
    with pytest.raises(ValueError, match="square"):
        CollectiveSpec(counts=((1, 2), (1,)))
    with pytest.raises(ValueError, match="non-negative"):
        CollectiveSpec(counts=((1, -2), (0, 1)))
    with pytest.raises(ValueError, match="at least one"):
        CollectiveSpec(counts=((0, 0), (0, 0)))
    with pytest.raises(ValueError, match="circulant"):
        CollectiveSpec(kind="xla", counts=((1, 1), (1, 1)))
    with pytest.raises(ValueError, match="wire_dtype"):
        _plan(2, counts=((1, 1), (1, 1)), wire_dtype="int8")
    with pytest.raises(ValueError, match="fused"):
        _plan(2, counts=((1, 1), (1, 1)), use_fused_kernel=True)
    # matrix counts are alltoall-only
    with pytest.raises(ValueError, match="alltoall"):
        _plan(2, counts=((1, 1), (1, 1))).reduce_scatter(jnp.ones((2, 2)))
    with pytest.raises(ValueError, match="alltoall"):
        _plan(2, counts=((1, 1), (1, 1))).allgather(jnp.ones((2, 2)))
    # flat counts stay RS/AG-only
    with pytest.raises(NotImplementedError, match="counts"):
        _plan(4, counts=(1, 2, 3, 4)).alltoall(jnp.ones((4, 2)))
    # wrong input height fails loudly
    with pytest.raises(ValueError, match="in_height"):
        _plan(2, counts=((1, 1), (1, 1))).alltoall(jnp.ones((3, 2)))
    # normalization: lists and np ints hash like plain tuples
    s1 = CollectiveSpec(counts=[[np.int64(1), 2], [3, 4]])
    s2 = CollectiveSpec(counts=((1, 2), (3, 4)))
    assert s1 == s2 and hash(s1) == hash(s2) and s1.counts_matrix


def test_backend_registry_alltoall():
    assert "alltoallv" in BACKENDS
    assert BACKENDS["alltoallv"] == ("alltoall",)
    assert "alltoall" in BACKENDS["xla"]
    assert _plan(4, counts=((1,) * 4,) * 4).backend == "alltoallv"
    assert _plan(4, kind="xla").backend == "xla"
    with pytest.raises(ValueError, match="does not implement alltoall"):
        _plan(4, kind="ring").alltoall(jnp.ones((4, 2)))


def test_a2a_plan_cached():
    from repro.core import plan_cache_info
    spec = CollectiveSpec(counts=((1, 2), (3, 4)))
    before = plan_cache_info().misses
    a = plan(spec, p=2, axis_name=AX)
    b = plan(CollectiveSpec(counts=((1, 2), (3, 4))), p=2, axis_name=AX)
    assert a is b
    assert plan_cache_info().misses <= before + 1


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def test_t_alltoall_hop_volume():
    model = CommModel.tpu_v5e()
    for p in (4, 7, 16):
        entries = a2a_round_entries(p)
        assert len(entries) == ceil_log2(p)
        assert sum(entries) >= p - 1  # amplified vs reduce-scatter
        t = t_alltoall(1 << 20, p, model)
        # same round count as reduce-scatter but amplified volume (and no
        # γ): the β term alone must already cost at least RS's β term.
        assert t > t_reduce_scatter(1 << 20, p, model) * 0.5
    assert t_alltoall(100, 1, model) == 0.0


def test_t_alltoallv_matches_widths():
    model = CommModel(alpha=1.0, beta=1.0, gamma=0.0)
    counts = ((0, 2, 1), (1, 0, 2), (2, 1, 0))
    widths = alltoallv_round_widths(counts)
    want = sum(1.0 + w for w in widths)
    assert t_alltoallv(counts, model) == pytest.approx(want)
    assert t_alltoallv(((5,),), model) == 0.0


def test_alltoallv_one_rank_widths_worst_case():
    """All payload to one destination: every round's wire is dominated by
    whoever currently holds the big rows."""
    p = 6
    one = [[0] * p for _ in range(p)]
    for i in range(p):
        one[i][2] = 7
    widths = alltoallv_round_widths(tuple(tuple(r) for r in one))
    assert all(w >= 7 for w in widths)


# ---------------------------------------------------------------------------
# ep helpers
# ---------------------------------------------------------------------------

def test_expert_owner_grid_ragged():
    from repro.models.dispatch import _ep_expert_grid, expert_owners
    for e, pe in [(8, 4), (6, 4), (3, 2), (5, 3), (4, 1)]:
        own = expert_owners(e, pe)
        assert sum(own) == e and len(own) == pe
        assert max(own) - min(own) <= 1
        pad_idx, inv_idx = _ep_expert_grid(own, e)
        own_max = max(own)
        assert pad_idx.shape == (pe * own_max,)
        # every real expert appears exactly once, phantoms are sentinel e
        real = [v for v in pad_idx if v != e]
        assert sorted(real) == list(range(e))
        for ex in range(e):
            assert pad_idx[inv_idx[ex]] == ex


def test_capacity_clamped_for_tiny_pools():
    from repro.models.dispatch import capacity

    class Cfg:
        capacity_factor = 1.25
        experts_per_token = 2
        n_experts = 8

    assert capacity(Cfg, 1) == 2           # N*K = 2 < old floor of 8
    assert capacity(Cfg, 2) == 4
    assert capacity(Cfg, 100) % 8 == 0 and capacity(Cfg, 100) >= 8


def test_ep_collective_specs():
    from repro.models.dispatch import ep_collective_specs

    class Cfg:
        n_experts = 6
        ep_axis = "model"

    buf, cnt = ep_collective_specs(Cfg, 4)
    assert buf.counts is None
    assert cnt.counts_matrix and cnt.counts == ((2, 2, 1, 1),) * 4


# ---------------------------------------------------------------------------
# Multi-device execution checks (subprocess: 8 fake devices)
# ---------------------------------------------------------------------------

def test_a2a_multidev_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # worker sets its own device count
    proc = subprocess.run(
        [sys.executable, WORKER], capture_output=True, text=True,
        timeout=900, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"a2a multidev checks failed:\n--- stdout ---\n{proc.stdout}\n"
            f"--- stderr ---\n{proc.stderr}")
    assert "ALL A2A CHECKS PASSED" in proc.stdout
