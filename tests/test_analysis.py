"""repro.analysis: the static verifier must kill seeded plan corruptions
(mutation testing), stay silent on every clean plan (property sweep), and
the repo lint / CLI must work end to end.

The mutation suite is the verifier's own test harness: each mutant is a
``dataclasses.replace`` of a REAL plan with one seeded defect — a dropped
skip, swapped row-table entries, an inflated wire width, a duplicated
send — and the verifier must produce at least one finding for every one
of them (a verifier that misses a mutant would wave through the same
corruption at pre-flight time).
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis.hlo_budget import (count_collective_permutes,
                                       parse_collectives)
from repro.analysis.report import Finding, Report
from repro.analysis.verify import (assert_verified, registry_specs,
                                   verify, verify_plan)
from repro.core import CollectiveSpec, plan
from tests._hypothesis_compat import given, settings, st

AX = "x"
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _plan(spec, p):
    return plan(spec, p=p, axis_name=AX)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Clean plans: zero findings (the sweep the CLI gates on)
# ---------------------------------------------------------------------------

@given(st.integers(min_value=2, max_value=64),
       st.sampled_from(["halving", "power2", "fully_connected", "sqrt"]))
@settings(max_examples=60, deadline=None)
def test_clean_uniform_plans_verify(p, schedule):
    assert verify(CollectiveSpec(schedule=schedule), p=p) == []


@given(st.integers(min_value=2, max_value=24),
       st.sampled_from(["halving", "power2"]))
@settings(max_examples=40, deadline=None)
def test_clean_nonuniform_plans_verify(p, schedule):
    counts = tuple((3 * i + 1) % 5 for i in range(p))
    if sum(counts) == 0:
        counts = (1,) * p
    spec = CollectiveSpec(schedule=schedule, counts=counts)
    assert verify(spec, p=p) == []


@given(st.integers(min_value=2, max_value=12))
@settings(max_examples=20, deadline=None)
def test_clean_alltoallv_plans_verify(p):
    counts = tuple(tuple((i + 2 * j + 1) % 3 for j in range(p))
                   for i in range(p))
    assert verify(CollectiveSpec(counts=counts), p=p) == []


def test_registry_sweep_is_clean():
    for p in (2, 3, 5, 8, 16):
        for spec in registry_specs(p):
            assert verify_plan(_plan(spec, p)) == [], \
                f"{spec.label} @ p={p}"


def test_assert_verified_passes_through_clean_plan():
    pl = _plan(CollectiveSpec(), 8)
    assert assert_verified(pl) is pl


# ---------------------------------------------------------------------------
# Mutation kill: every seeded corruption must be flagged
# ---------------------------------------------------------------------------

def test_mutant_dropped_skip_is_killed():
    pl = _plan(CollectiveSpec(), 8)
    mut = dataclasses.replace(
        pl,
        skips=pl.skips[:-1], rs_rounds=pl.rs_rounds[:-1],
        rs_send_blocks=pl.rs_send_blocks[:-1],
        rs_recv_blocks=pl.rs_recv_blocks[:-1],
        ag_rounds=pl.ag_rounds[1:], ag_send_blocks=pl.ag_send_blocks[1:],
        ag_recv_blocks=pl.ag_recv_blocks[1:])
    findings = verify_plan(mut)
    assert findings, "dropped skip not detected"
    assert _rules(findings) & {"theorem1-partition", "round-count",
                               "schedule-invalid"}
    with pytest.raises(AssertionError):
        assert_verified(mut)


def test_mutant_swapped_table_rows_is_killed():
    spec = CollectiveSpec(counts=(3, 1, 6, 4, 2))
    pl = _plan(spec, 5)
    tab = pl.rs_row_tables[0].copy()
    sent = pl.layout.total
    # swap the first differing non-sentinel entries of two ranks' rows
    swapped = False
    for c1 in range(tab.shape[1]):
        for c2 in range(tab.shape[1]):
            a, b = tab[0, c1], tab[1, c2]
            if a != sent and b != sent and a != b:
                tab[0, c1], tab[1, c2] = b, a
                swapped = True
                break
        if swapped:
            break
    assert swapped
    mut = dataclasses.replace(
        pl, rs_row_tables=(tab,) + pl.rs_row_tables[1:])
    findings = verify_plan(mut)
    assert findings, "swapped row-table entries not detected"
    assert _rules(findings) & {"duplicate-contribution",
                               "incomplete-reduction", "duplicate-send"}


def test_mutant_inflated_width_is_killed():
    spec = CollectiveSpec(counts=(3, 1, 6, 4, 2))
    pl = _plan(spec, 5)
    tab = pl.rs_row_tables[0]
    wide = np.concatenate(
        [tab, np.full((tab.shape[0], 1), pl.layout.total, tab.dtype)],
        axis=1)
    mut = dataclasses.replace(
        pl, rs_row_tables=(wide,) + pl.rs_row_tables[1:])
    findings = verify_plan(mut)
    assert findings, "inflated wire width not detected"
    assert "width-bound" in _rules(findings)


def test_mutant_inflated_a2a_width_is_killed():
    counts = tuple(tuple((i + 2 * j + 1) % 3 for j in range(5))
                   for i in range(5))
    pl = _plan(CollectiveSpec(counts=counts), 5)
    tab = pl.a2a.round_tables[0]
    wide = np.concatenate(
        [tab, np.full((tab.shape[0], 1), pl.a2a.total, tab.dtype)], axis=1)
    mut = dataclasses.replace(
        pl, a2a=dataclasses.replace(
            pl.a2a, round_tables=(wide,) + pl.a2a.round_tables[1:]))
    findings = verify_plan(mut)
    assert findings, "inflated alltoallv width not detected"
    assert "width-bound" in _rules(findings)


def test_mutant_duplicated_send_is_killed():
    pl = _plan(CollectiveSpec(), 8)
    win = list(pl.rs_send_blocks[0])
    assert len(win) >= 2
    dup = (win[0],) + tuple(win[:-1])  # repeat one block, drop one
    mut = dataclasses.replace(
        pl, rs_send_blocks=(dup,) + pl.rs_send_blocks[1:])
    findings = verify_plan(mut)
    assert findings, "duplicated send block not detected"
    assert _rules(findings) & {"duplicate-send", "theorem1-partition",
                               "window-mismatch"}


def test_mutant_self_send_is_killed():
    pl = _plan(CollectiveSpec(), 8)
    bad = dataclasses.replace(pl.rs_rounds[0], skip=0, lo=0)
    mut = dataclasses.replace(
        pl, skips=(0,) + pl.skips[1:],
        rs_rounds=(bad,) + pl.rs_rounds[1:])
    findings = verify_plan(mut)
    assert findings, "self-send round not detected"
    assert _rules(findings) & {"self-send", "schedule-invalid"}


# ---------------------------------------------------------------------------
# HLO budget parser
# ---------------------------------------------------------------------------

def test_count_collective_permutes_both_formats():
    mlir = ('%0 = "stablehlo.collective_permute"(%arg) ...\n'
            '%1 = "stablehlo.collective_permute"(%0) ...\n')
    assert count_collective_permutes(mlir) == 2
    hlo = ("  %a = f32[8]{0} collective-permute(%x), "
           "source_target_pairs={{0,1}}\n"
           "  %b = (f32[8]{0}, f32[8]{0}, u32[], u32[]) "
           "collective-permute-start(%a)\n"
           "  %c = f32[8]{0} collective-permute-done(%b)\n")
    assert count_collective_permutes(hlo) == 2


def test_parse_collectives_async_tuple_payload_once():
    hlo = ("  %s = (bf16[64,4]{1,0}, bf16[64,4]{1,0}, u32[], u32[]) "
           "collective-permute-start(%x), source_target_pairs={{0,1}}\n")
    st_ = parse_collectives(hlo)
    assert st_.ops == {"collective-permute": 1}
    assert st_.raw_bytes_by_op["collective-permute"] == 64 * 4 * 2
    assert st_.raw_bytes_by_dtype == {"bf16": 64 * 4 * 2}


# ---------------------------------------------------------------------------
# Repo lint + ratchet
# ---------------------------------------------------------------------------

def _write(tmp_path, rel, text):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)


def test_repo_lint_rules_fire(tmp_path):
    from repro.analysis import repo_lint
    _write(tmp_path, "src/bad.py", (
        "import jax.experimental.pallas as plx\n"
        "y = plx.pallas_call(lambda: None)\n"
        'n = txt.count("collective_permute")\n'
        'reduce_scatter(x, impl="ring")\n'))
    findings = repo_lint.lint_repo(tmp_path)
    assert _rules(findings) >= {
        "jax-experimental-outside-compat", "pallas-call-outside-kernels",
        "hlo-counter-outside-budget", "bare-impl-string"}


def test_repo_lint_ft_world_via_controller(tmp_path):
    """Rank/world-size reads inside ft/ must go through
    ElasticController.world — runtime device counts are stale
    mid-resize.  The same read OUTSIDE ft/ is fine."""
    from repro.analysis import repo_lint
    bad = ("import jax\n"
           "p = jax.device_count()\n"
           "q = jax.local_device_count()\n")
    _write(tmp_path, "src/repro/ft/sneaky.py", bad)
    _write(tmp_path, "src/repro/launch/fine.py", bad)
    findings = repo_lint.lint_repo(tmp_path)
    hits = [f for f in findings if f.rule == "ft-world-via-controller"]
    assert len(hits) == 2
    assert all(f.where.startswith("src/repro/ft/sneaky.py") for f in hits)


def test_repo_lint_ratchet_waives_and_shrinks(tmp_path):
    from repro.analysis import repo_lint
    _write(tmp_path, "src/bad.py", "import jax.experimental.pallas\n")
    findings = repo_lint.lint_repo(tmp_path)
    assert findings
    repo_lint.save_ratchet(tmp_path, findings)
    fresh, waived = repo_lint.run(tmp_path)
    assert fresh == [] and len(waived) == len(findings)
    # a NEW violation in another file is not covered by the ratchet
    _write(tmp_path, "src/worse.py", "from jax.experimental import pallas\n")
    fresh, waived = repo_lint.run(tmp_path)
    assert [f.where.split(":")[0] for f in fresh] == ["src/worse.py"]


def test_repo_lint_repo_is_clean():
    from repro.analysis import repo_lint
    fresh, _waived = repo_lint.run(ROOT)
    assert fresh == [], "\n".join(f.render() for f in fresh)


def test_only_one_hlo_counter_exists():
    """Exactly one collective-permute counter: every hand-rolled
    ``.count("collective_permute")``/regex outside hlo_budget.py is a
    repo-lint finding AND must not be ratcheted away."""
    from repro.analysis import repo_lint
    waived_counter = [
        k for k in repo_lint.load_ratchet(ROOT)
        if k.endswith("hlo-counter-outside-budget")]
    assert waived_counter == []


# ---------------------------------------------------------------------------
# Report + CLI
# ---------------------------------------------------------------------------

def test_report_shape_and_exit_semantics():
    rep = Report()
    rep.extend("verify", [])
    assert rep.ok
    rep.extend("repo", [Finding(pass_name="repo", rule="r", where="w",
                                message="m")])
    assert not rep.ok
    d = json.loads(rep.as_json())
    assert d["ok"] is False
    assert d["passes_run"] == ["verify", "repo"]
    assert d["findings_by_pass"] == {"repo": 1}


def _run_cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=600)


def test_cli_verify_and_repo_exit_zero(tmp_path):
    out = tmp_path / "report.json"
    r = _run_cli("--verify", "--repo", "--p", "2,3,4,8",
                 "--json", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    rep = json.loads(out.read_text())
    assert rep["ok"] is True
    assert rep["passes_run"] == ["verify", "repo"]


def test_cli_jaxpr_pass_exit_zero():
    r = _run_cli("--jaxpr")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "passes=jaxpr findings=0" in r.stdout
