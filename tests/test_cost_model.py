"""Cost model tests: Corollary 1 closed form == per-round sum; Corollary 3
bound; ring/circulant crossover structure (motivates §Perf schedule work)."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import cost_model as cm
from repro.core.schedule import ceil_log2

MODEL = cm.CommModel(alpha=1e-6, beta=1e-9, gamma=2.5e-10)


@given(st.integers(2, 3000), st.integers(1, 10**9))
@settings(max_examples=100, deadline=None)
def test_corollary1_matches_per_round_sum(p, m):
    t_rounds = cm.t_reduce_scatter(float(m), p, MODEL)
    t_closed = cm.t_corollary1(float(m), p, MODEL)
    assert math.isclose(t_rounds, t_closed, rel_tol=1e-9)


@given(st.integers(2, 500), st.integers(1, 10**7))
@settings(max_examples=50, deadline=None)
def test_corollary3_bound_holds(p, m):
    """Corollary 3 is stated for Algorithm 1's halving schedule
    (ceil(log2 p) rounds, each moving at most m elements).  power2 has the
    same round count so the same bound holds; other Corollary-2 schedules
    obey the generalized q_sched * (alpha + (beta+gamma) m) bound."""
    bound = cm.t_corollary3_bound(float(m), p, MODEL)
    for sched in ["halving", "power2"]:
        assert cm.t_reduce_scatter(float(m), p, MODEL, sched) <= bound + 1e-12
    from repro.core.schedule import get_skips
    for sched in ["fully_connected", "sqrt"]:
        q = len(get_skips(p, sched))
        gen_bound = q * (MODEL.alpha + (MODEL.beta + MODEL.gamma) * m)
        assert cm.t_reduce_scatter(float(m), p, MODEL, sched) <= gen_bound + 1e-12


def test_allreduce_is_two_phase_sum():
    p, m = 22, 1 << 20
    t = cm.t_allreduce(m, p, MODEL)
    t2 = cm.t_reduce_scatter(m, p, MODEL) + cm.t_allgather(m, p, MODEL)
    assert math.isclose(t, t2, rel_tol=1e-12)
    # Theorem 2 closed form: 2*alpha*q + 2*beta*(p-1)/p*m + gamma*(p-1)/p*m
    closed = (2 * MODEL.alpha * ceil_log2(p)
              + (2 * MODEL.beta + MODEL.gamma) * (p - 1) / p * m)
    assert math.isclose(t, closed, rel_tol=1e-9)


def test_latency_regime_circulant_wins():
    """Small m: ceil(log2 p) rounds beat p-1 rounds (the paper's point)."""
    p, m = 256, 64
    assert cm.t_allreduce(m, p, MODEL) < cm.t_ring_allreduce(m, p, MODEL)


def test_bandwidth_regime_topology_oblivious_tie():
    """Large m under the paper's (hop-free) model: circulant == ring volume,
    so circulant still wins on rounds."""
    p, m = 64, 1 << 28
    assert cm.t_allreduce(m, p, MODEL) <= cm.t_ring_allreduce(m, p, MODEL)


def test_torus_hop_amplification_flips_large_m():
    """Beyond-paper: on a torus, large skips burn min(s, p-s) links; for
    large m the ring wins — the crossover exists and is finite."""
    p = 64
    m_small, m_big = 1024, 1 << 26
    assert (cm.t_allreduce(m_small, p, MODEL, torus=True)
            < cm.t_ring_allreduce(m_small, p, MODEL))
    assert (cm.t_allreduce(m_big, p, MODEL, torus=True)
            > cm.t_ring_allreduce(m_big, p, MODEL))
    x = cm.crossover_m(p, MODEL)
    assert 1024 < x < (1 << 26)
