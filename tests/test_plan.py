"""Unit + property tests for the plan/execute collective API
(``core/spec.py`` + ``core/plan.py``) — everything that needs NO devices:

* Theorem 1 as a property of the plan's index tables: across ALL
  schedules and axis sizes, the per-round send block sets partition
  {1, .., p-1} exactly (every non-resident block leaves exactly once),
  and the recv sets mirror them;
* non-uniform (Corollary 3) row tables: per-rank row sets partition the
  non-resident rows exactly, wire widths equal the worst windowed count
  sum, padding entries use the sentinel row;
* plan() caching: same spec -> same object, no rebuild (the trace-free
  guarantee the CI ``plans`` gate measures end-to-end);
* spec validation and the deprecation of the kwarg-era surfaces
  (``impl=`` string dispatch, ``GradSyncConfig(compress=...)``);
* the consolidated padding path (``pad_to_multiple`` / ``_as_blocks``
  through ``BlockLayout``).
"""
import warnings

import numpy as np
import pytest

from repro.core import (BACKENDS, BlockLayout, CollectiveSpec, plan,
                        plan_cache_info)
from repro.core.schedule import ceil_log2, get_skips
from repro.core.spec import as_spec
from tests._hypothesis_compat import given, settings, st

SCHEDULES = ("halving", "power2", "fully_connected", "sqrt")
AX = "x"


def _plan(p, **kw):
    return plan(CollectiveSpec(**kw), p=p, axis_name=AX)


# ---------------------------------------------------------------------------
# Theorem 1 as a property of the block index tables
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=2, max_value=64),
       st.sampled_from(SCHEDULES))
def test_send_blocks_partition_nonresident(p, schedule):
    """Every plan's per-round send tables partition exactly the p-1
    non-resident rotated blocks {1, .., p-1} — Theorem 1's 'each block
    sent exactly once', for every schedule and p."""
    pl = _plan(p, schedule=schedule)
    sent = [i for window in pl.rs_send_blocks for i in window]
    assert sorted(sent) == list(range(1, p))
    # recv sets mirror the send sets shifted to the buffer head, same
    # total count (p-1 receives + p-1 reductions per rank).
    assert sum(len(w) for w in pl.rs_recv_blocks) == p - 1
    for w_send, w_recv in zip(pl.rs_send_blocks, pl.rs_recv_blocks):
        assert len(w_send) == len(w_recv)
        assert w_recv == tuple(range(len(w_send)))
    # allgather replays the same windows in reverse order.
    assert sorted(i for w in pl.ag_recv_blocks for i in w) == \
        list(range(1, p))
    assert pl.ag_recv_blocks == tuple(reversed(pl.rs_send_blocks))


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=2, max_value=48))
def test_optimal_schedules_round_count(p):
    for schedule in ("halving", "power2"):
        pl = _plan(p, schedule=schedule)
        assert len(pl.rs_rounds) == ceil_log2(p)
        assert pl.skips == get_skips(p, schedule)


# ---------------------------------------------------------------------------
# Non-uniform (Corollary 3) row tables
# ---------------------------------------------------------------------------

def _counts_cases():
    return [
        (3, 1, 4, 1, 5),          # ragged
        (0, 0, 17, 0),            # all in one column (paper's worst case)
        (2, 0, 3, 0, 1, 0),       # zero-count ranks
        (4, 4, 4, 4),             # uniform expressed as counts
        (1, 7),                   # p=2
    ]


@pytest.mark.parametrize("counts", _counts_cases())
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_nonuniform_row_tables_partition(counts, schedule):
    """Row-table Theorem 1: per rank, the union of the real (non-
    sentinel) send rows over all rounds is exactly the rows of every
    OTHER rank's block — each row leaves exactly once."""
    p = len(counts)
    pl = _plan(p, schedule=schedule, counts=counts)
    layout = pl.layout
    N = layout.total
    offs = layout.offsets
    for r in range(p):
        rows = [int(v) for tab in pl.rs_row_tables
                for v in tab[r] if v != N]
        own = set(range(offs[r], offs[r] + counts[r]))
        assert sorted(rows) == sorted(set(range(N)) - own), \
            f"rank {r}: send rows must cover exactly the non-resident rows"
        assert len(rows) == N - counts[r]  # no duplicates


@pytest.mark.parametrize("counts", _counts_cases())
def test_nonuniform_wire_width_is_worst_window(counts):
    """Each round's wire width equals the worst windowed count sum over
    ranks — the per-round quantity Corollary 3's bound maximizes."""
    p = len(counts)
    pl = _plan(p, counts=counts)
    for rp, tab in zip(pl.rs_rounds, pl.rs_row_tables):
        widths = [sum(counts[(r + i) % p] for i in range(rp.lo, rp.hi))
                  for r in range(p)]
        assert tab.shape == (p, max(max(widths), 1))
        # padding entries are the sentinel row, trailing per rank
        for r in range(p):
            real = [v for v in tab[r] if v != pl.layout.total]
            assert len(real) == widths[r]
            assert list(tab[r][:len(real)]) == real


def test_one_column_worst_case_width():
    """Concentrated counts: every round's wire carries the full vector
    (the Corollary 3 worst case the ISSUE singles out)."""
    counts = (0, 0, 0, 21, 0, 0)
    pl = _plan(6, counts=counts)
    for tab in pl.rs_row_tables:
        assert tab.shape[1] == 21


# ---------------------------------------------------------------------------
# plan() caching — the trace-free property
# ---------------------------------------------------------------------------

def test_plan_cache_returns_same_object():
    spec = CollectiveSpec(schedule="power2", counts=(2, 3, 1))
    before = plan_cache_info().misses
    a = plan(spec, p=3, axis_name=AX)
    b = plan(spec, p=3, axis_name=AX)
    c = plan(CollectiveSpec(schedule="power2", counts=(2, 3, 1)),
             p=3, axis_name=AX)
    assert a is b is c
    assert plan_cache_info().misses <= before + 1
    # a different axis name or p is a different plan
    assert plan(spec, p=3, axis_name="y") is not a


def test_plan_cache_stats_identity():
    # plan.cache_stats()/plan.clear() and the legacy plan_cache_info()
    # observe the SAME lru cache: a hit through plan() moves both.
    spec = CollectiveSpec(schedule="halving")
    plan(spec, p=5, axis_name=AX)
    s0, legacy0 = plan.cache_stats(), plan_cache_info()
    assert (s0.hits, s0.misses) == (legacy0.hits, legacy0.misses)
    plan(spec, p=5, axis_name=AX)  # cached: one hit, zero misses
    s1 = plan.cache_stats()
    assert s1.hits == s0.hits + 1
    assert s1.misses == s0.misses
    assert callable(plan.clear)


def test_spec_hashable_and_normalized():
    s1 = CollectiveSpec(counts=(np.int64(2), np.int64(3)))
    s2 = CollectiveSpec(counts=(2, 3))
    assert s1 == s2 and hash(s1) == hash(s2)
    assert s1.with_(schedule="power2").schedule == "power2"
    assert as_spec(s1) is s1
    assert as_spec("ring").kind == "ring"
    assert as_spec(schedule="sqrt").schedule == "sqrt"


# ---------------------------------------------------------------------------
# Spec validation
# ---------------------------------------------------------------------------

def test_spec_validation_errors():
    with pytest.raises(ValueError, match="unknown kind"):
        CollectiveSpec(kind="nccl")
    with pytest.raises(ValueError, match="unknown wire_dtype"):
        CollectiveSpec(wire_dtype="fp4")
    with pytest.raises(ValueError, match="non-negative"):
        CollectiveSpec(counts=(1, -1))
    with pytest.raises(ValueError, match="at least one"):
        CollectiveSpec(counts=(0, 0))
    with pytest.raises(ValueError, match="circulant"):
        CollectiveSpec(kind="ring", counts=(1, 2))
    # broadcast moves payload bits verbatim: no compression, no fold
    # kernel, no per-rank counts
    with pytest.raises(ValueError, match="wire_dtype"):
        CollectiveSpec(kind="broadcast", wire_dtype="int8")
    with pytest.raises(ValueError, match="fused"):
        CollectiveSpec(kind="broadcast", use_fused_kernel=True)
    with pytest.raises(ValueError, match="circulant"):
        CollectiveSpec(kind="broadcast", counts=(1, 2))


def test_plan_validation_errors():
    with pytest.raises(ValueError, match="wire_dtype"):
        _plan(4, counts=(1, 2, 3, 4), wire_dtype="int8")
    with pytest.raises(ValueError, match="fused"):
        _plan(4, counts=(1, 2, 3, 4), use_fused_kernel=True)
    with pytest.raises(ValueError, match="named op"):
        _plan(4, counts=(1, 2, 3, 4), op=lambda a, b: a + b)
    with pytest.raises(ValueError, match="named op"):
        _plan(4, wire_dtype="int8", op=lambda a, b: a + b)
    with pytest.raises(ValueError, match="counts has"):
        _plan(5, counts=(1, 2, 3, 4))
    # auto-fused + callable op silently keeps the jnp backend
    assert _plan(4, op=lambda a, b: a + b).backend == "jnp"
    # unsupported combinations fail loudly instead of silently degrading
    import jax.numpy as jnp
    with pytest.raises(NotImplementedError, match="wire_dtype"):
        _plan(4, wire_dtype="int8").alltoall(jnp.ones((4, 2)))
    with pytest.raises(NotImplementedError, match="counts"):
        _plan(4, counts=(1, 2, 3, 4)).alltoall(jnp.ones((4, 2)))
    hook = lambda x: x  # noqa: E731
    with pytest.raises(ValueError, match="circulant"):
        _plan(4, kind="ring").reduce_scatter(jnp.ones(8), compress=hook,
                                             decompress=hook)
    with pytest.raises(ValueError, match="mutually exclusive"):
        _plan(4, wire_dtype="int8").reduce_scatter(
            jnp.ones(8), compress=hook, decompress=hook)
    with pytest.raises(ValueError, match="non-uniform"):
        _plan(4, counts=(1, 2, 3, 4)).reduce_scatter(
            jnp.ones(10), compress=hook, decompress=hook)


def test_backend_registry():
    assert _plan(4).backend in BACKENDS
    assert _plan(4, wire_dtype="int8").backend in ("jnp+int8", "fused+int8")
    assert _plan(4, counts=(1, 2, 3, 4)).backend == "nonuniform"
    assert _plan(4, counts=((1,) * 4,) * 4).backend == "alltoallv"
    assert _plan(4, kind="ring").backend == "ring"
    assert _plan(4, kind="broadcast").backend == "broadcast"
    for backend, collectives in BACKENDS.items():
        # every backend implements reduce_scatter except the two
        # single-collective ones (alltoall tables, all-broadcast)
        if backend == "alltoallv":
            assert collectives == ("alltoall",)
        elif backend == "broadcast":
            assert collectives == ("broadcast",)
        else:
            assert "reduce_scatter" in collectives


# ---------------------------------------------------------------------------
# Broadcast plans (kind="broadcast": standalone allgather phase,
# Träff arXiv:2407.18004 — ceil(log2 p) rounds at every p)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", (2, 3, 4, 5, 8))
@pytest.mark.parametrize("schedule", ("halving", "power2"))
def test_broadcast_plan_structure(p, schedule):
    """The broadcast plan's allgather tables deliver every non-resident
    block exactly once in ceil(log2 p) rounds, and the static verifier's
    exactly-once replay accepts it."""
    from repro.analysis import verify
    pl = _plan(p, kind="broadcast", schedule=schedule)
    assert pl.backend == "broadcast"
    assert len(pl.ag_rounds) == ceil_log2(p)
    assert sorted(i for w in pl.ag_recv_blocks for i in w) == \
        list(range(1, p))
    assert verify.assert_verified(pl) is pl
    # one ppermute per round is what conformance's HLO gate then counts
    assert sum(1 for _ in pl.ag_rounds) == ceil_log2(p)


def test_broadcast_plan_cached_and_labeled():
    s = CollectiveSpec(kind="broadcast", schedule="power2")
    assert plan(s, p=5, axis_name=AX) is plan(s, p=5, axis_name=AX)
    assert s.label == "broadcast:power2"


def test_broadcast_rejects_reduce_phases():
    """A broadcast plan has no fold step: the reduce collectives must
    refuse rather than silently allgather."""
    import jax.numpy as jnp
    pl = _plan(4, kind="broadcast")
    for meth in ("reduce_scatter", "allreduce"):
        with pytest.raises((ValueError, KeyError, NotImplementedError)):
            getattr(pl, meth)(jnp.ones(8))


# ---------------------------------------------------------------------------
# Deprecations (kwarg-era surfaces name the CollectiveSpec replacement)
# ---------------------------------------------------------------------------

def test_impl_string_dispatch_deprecated():
    from repro.core import collectives as C
    # No tracing context needed: the warning fires before execution, so
    # catch the axis-name error after asserting the warning.
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        with pytest.raises(Exception):
            C.reduce_scatter(np.zeros(8), "nosuchaxis", impl="ring")
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert dep and "CollectiveSpec" in str(dep[0].message)

    # default (no explicit impl) stays silent
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        with pytest.raises(Exception):
            C.reduce_scatter(np.zeros(8), "nosuchaxis")
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]


def test_gradsync_compress_alias_deprecated():
    from repro.optim.zero1 import GradSyncConfig
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cfg = GradSyncConfig(compress="int8")
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert dep and "wire_dtype" in str(dep[0].message)
    assert cfg.wire == "int8"
    assert cfg.rs_spec().wire_dtype == "int8"
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        clean = GradSyncConfig(wire_dtype="int8")
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert clean.rs_spec().wire_dtype == "int8"
    assert clean.ag_spec().wire_dtype is None  # params reassemble exactly


def test_spec_and_impl_are_exclusive():
    from repro.core import collectives as C
    with pytest.raises(TypeError, match="either spec= or impl="):
        C.reduce_scatter(np.zeros(8), AX, "ring",
                         spec=CollectiveSpec(kind="ring"))
    with pytest.raises(TypeError, match="extra kwargs"):
        C.reduce_scatter(np.zeros(8), AX, spec=CollectiveSpec(),
                         schedule="power2")


# ---------------------------------------------------------------------------
# The consolidated padding path
# ---------------------------------------------------------------------------

def test_pad_to_multiple_via_layout():
    import jax.numpy as jnp
    from repro.core import collectives as C
    x = jnp.ones((7, 3))
    padded, pad = C.pad_to_multiple(x, 4)
    assert padded.shape == (8, 3) and pad == 1
    assert np.asarray(padded[7]).sum() == 0
    same, pad0 = C.pad_to_multiple(jnp.ones((8, 3)), 4)
    assert same.shape == (8, 3) and pad0 == 0


def test_block_layout_uniform_and_counts():
    lay = BlockLayout.uniform(4, 10)
    assert lay.counts == (3, 3, 3, 3) and lay.total == 12 and lay.bmax == 3
    assert lay.offsets == (0, 3, 6, 9, 12)
    nl = BlockLayout(counts=(2, 0, 5))
    assert nl.total == 7 and nl.bmax == 5 and not nl.is_uniform
    assert nl.offsets == (0, 2, 2, 7)
    with pytest.raises(ValueError, match="non-uniform"):
        import jax.numpy as jnp
        nl.as_blocks(jnp.ones((7,)))


def test_as_blocks_requires_divisibility():
    import jax.numpy as jnp
    from repro.core import collectives as C
    with pytest.raises(ValueError, match="not divisible"):
        C._as_blocks(jnp.ones((7,)), 4)
    assert C._as_blocks(jnp.ones((8, 2)), 4).shape == (4, 2, 2)


def test_default_wire_group_matches_kernels():
    from repro.core.spec import DEFAULT_WIRE_GROUP
    from repro.kernels import DEFAULT_GROUP
    assert DEFAULT_WIRE_GROUP == DEFAULT_GROUP
