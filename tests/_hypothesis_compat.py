"""``hypothesis`` when installed; a deterministic mini-fallback otherwise.

The real library is preferred (declared in requirements.txt), but tier-1
must never hard-error at collection on a machine without it.  The fallback
implements exactly the subset this suite uses — ``given``, ``settings``,
``st.integers``, ``st.sampled_from`` — by running the test body over a
fixed, seeded sample (boundary values first), so property tests keep real
coverage instead of skipping wholesale.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 30

    class _Integers:
        def __init__(self, min_value, max_value):
            self.lo, self.hi = int(min_value), int(max_value)

        def examples(self, rng, n):
            vals = [self.lo, self.hi, min(self.hi, self.lo + 1),
                    (self.lo + self.hi) // 2]
            while len(vals) < n:
                vals.append(rng.randint(self.lo, self.hi))
            return vals[:n]

    class _SampledFrom:
        def __init__(self, elements):
            self.elements = list(elements)

        def examples(self, rng, n):
            return [self.elements[i % len(self.elements)] for i in range(n)]

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

    st = _Strategies()

    def settings(*, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None,
                 **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            n = min(getattr(fn, "_compat_max_examples",
                            _DEFAULT_MAX_EXAMPLES), 100)
            seed = zlib.crc32(fn.__name__.encode())

            def wrapper():
                rng = random.Random(seed)
                columns = [s.examples(rng, n) for s in strategies]
                for args in zip(*columns):
                    fn(*args)

            # NB: zero-arg on purpose (pytest must not see fn's params as
            # fixtures), and no functools.wraps (__wrapped__ would expose
            # the original signature to pytest's introspection).
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
