"""Compat-layer smoke tests: every src/repro module imports, and each shim
in repro.compat works under the INSTALLED JAX — future API drift fails
here, in one obvious place, before it breaks a multi-device worker."""
import importlib
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"

# Entry points that mutate process-global state at import time (dryrun
# pins XLA_FLAGS for its own 512-device process) — importing them here
# would leak into this process' environment.
SKIP_IMPORT = {"repro.launch.dryrun"}


def _iter_modules():
    for path in sorted((SRC / "repro").rglob("*.py")):
        rel = path.relative_to(SRC).with_suffix("")
        name = ".".join(rel.parts)
        if name.endswith(".__init__"):
            name = name[: -len(".__init__")]
        if name in SKIP_IMPORT:
            continue
        yield name


@pytest.mark.parametrize("name", list(_iter_modules()))
def test_module_imports(name):
    importlib.import_module(name)


def test_version_flags_consistent():
    assert len(compat.JAX_VERSION) == 3
    if compat.JAX_VERSION >= (0, 5, 0):
        # the new-API surface the repo is written against
        assert compat.HAS_NATIVE_SHARD_MAP or compat.HAS_SET_MESH
    assert compat.HAS_MAKE_MESH == hasattr(jax, "make_mesh")


def test_make_mesh():
    mesh = compat.make_mesh((1,), ("x",))
    assert mesh.axis_names == ("x",)
    assert mesh.shape["x"] == 1


def test_shard_map_full_manual_and_ppermute():
    mesh = compat.make_mesh((1,), ("x",))
    f = jax.jit(compat.shard_map(
        lambda v: compat.ppermute(v, "x", [(0, 0)]) + 1.0,
        mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))
    out = np.asarray(f(jnp.zeros((1, 4))))
    np.testing.assert_array_equal(out, np.ones((1, 4)))


def test_shard_map_pytree_ppermute():
    mesh = compat.make_mesh((1,), ("x",))

    def body(v):
        tree = {"a": v, "b": (v * 2,)}
        out = compat.ppermute(tree, "x", [(0, 0)])
        return out["a"] + out["b"][0]

    f = jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))
    np.testing.assert_array_equal(np.asarray(f(jnp.ones((1, 3)))),
                                  3 * np.ones((1, 3)))


def test_shard_map_partial_manual_axes():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    f = jax.jit(compat.shard_map(
        lambda v: v * 2, mesh=mesh, in_specs=(P("data"),),
        out_specs=P("data"), axis_names={"data"}, check_vma=False))
    np.testing.assert_array_equal(np.asarray(f(jnp.ones((2, 2)))),
                                  2 * np.ones((2, 2)))


def test_axis_size_static():
    mesh = compat.make_mesh((1,), ("x",))

    def body(v):
        p = compat.axis_size("x")
        assert isinstance(p, int), "axis size must be STATIC at trace time"
        return v.reshape(p, -1)[0][None]

    f = jax.jit(compat.shard_map(
        body, mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))
    f(jnp.ones((1, 4)))


def test_use_mesh_activates_bare_spec_constraints():
    mesh = compat.make_mesh((1,), ("x",))
    with compat.use_mesh(mesh):
        f = jax.jit(
            lambda v: jax.lax.with_sharding_constraint(v, P("x")))
        np.testing.assert_array_equal(np.asarray(f(jnp.ones((2,)))),
                                      np.ones((2,)))


def test_cost_analysis_normalized_dict():
    c = jax.jit(lambda x: x @ x).lower(jnp.ones((16, 16))).compile()
    ca = compat.cost_analysis(c)
    assert isinstance(ca, dict)
    assert float(ca.get("flops", 0.0)) > 0


def test_no_direct_legacy_call_sites():
    """The compat layer is the ONLY place allowed to touch the moved APIs
    (mirrors the grep acceptance gate of the compat-layer PR)."""
    bad = []
    roots = [SRC, pathlib.Path(__file__).resolve().parent,
             SRC.parent / "benchmarks", SRC.parent / "examples"]
    for root in roots:
        for path in root.rglob("*.py"):
            if path.name == "compat.py" or path == pathlib.Path(__file__):
                continue
            text = path.read_text()
            for needle in ("jax" + ".shard_map", "jax" + ".set_mesh",
                           "jax" + ".make_mesh",  # split: keep THIS file
                           "lax" + ".axis_size"):  # out of the grep gate
                if needle in text:
                    bad.append(f"{path}: {needle}")
    assert not bad, "direct legacy-API call sites outside compat:\n" + \
        "\n".join(bad)
