"""Cross-implementation conformance sweeps (src/repro/core/conformance.py).

One subprocess per axis size p (XLA locks the fake-device count at first
jax init, so every p needs its own process).  Per p the worker asserts:

  * circulant / ring / recursive-halving / XLA reduce-scatter + allreduce
    against a host numpy reference and the native-XLA baseline,
  * every Corollary-2 schedule (halving, power2, fully_connected, sqrt,
    two_level), ops add/max/min, dtypes f32/bf16/i32,
  * every float circulant case additionally on the int8 wire format
    (tolerance-based — compressed rounds are lossy by design),
  * lowered-HLO collective-permute counts: exactly rounds(schedule) for
    RS and 2*rounds(schedule) for AR, with rounds == ceil(log2 p) for the
    halving/power2 schedules — Theorem 1/2 at every tested p, including
    the non-powers-of-two the paper exists for; the int8 wire path must
    keep the exact same counts (the packed [codes | scale bytes] buffer
    is ONE ppermute payload per round),
  * for composite p, the hierarchical two-axis sweep: nested RS/AG/AR
    over a (p//g, g) mesh vs the host reference, uncompressed and int8.
"""
import os
import subprocess
import sys

import pytest

from repro.core.conformance import (
    A2A_SCHEDULES, DEFAULT_PS, NONUNIFORM_SCHEDULES, OPS, SCHEDULES,
    alltoallv_counts_cases, case_spec, hierarchical_factors,
    nonuniform_counts_cases, sweep_cases, two_level_group)

HERE = os.path.dirname(os.path.abspath(__file__))
WORKER = os.path.join(HERE, "..", "src", "repro", "core", "conformance.py")


@pytest.mark.parametrize("p", DEFAULT_PS)
def test_conformance_sweep(p):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # worker sets its own device count
    proc = subprocess.run(
        [sys.executable, WORKER, str(p)],
        capture_output=True, text=True, timeout=900, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"conformance sweep failed for p={p}:\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    assert f"CONFORMANCE OK (p={p}" in proc.stdout


def test_sweep_covers_required_space():
    """The generated case list spans all impls, schedules, ops and dtypes
    the tentpole promises (static check, no devices needed)."""
    cases = sweep_cases(8)
    assert {c.impl for c in cases} == {
        "circulant", "ring", "recursive_halving", "xla"}
    assert {c.schedule for c in cases if c.impl == "circulant"} == set(
        SCHEDULES)
    assert {c.op for c in cases} == set(OPS)
    assert {c.dtype for c in cases} == {"float32", "bfloat16", "int32"}
    # recursive halving only exists at powers of two
    assert not any(c.impl == "recursive_halving" for c in sweep_cases(6))
    # every circulant case is mirrored on the fused Pallas round path
    plain = {(c.collective, c.schedule, c.op, c.dtype) for c in cases
             if c.impl == "circulant" and not c.fused and c.wire is None}
    fused = {(c.collective, c.schedule, c.op, c.dtype) for c in cases
             if c.impl == "circulant" and c.fused and c.wire is None}
    assert fused == plain and fused
    assert not any(c.fused for c in cases if c.impl != "circulant")
    # ... and every FLOAT circulant case (fused or not) is additionally
    # mirrored on the int8 wire format; int32 and non-circulant impls
    # never get wire cases (quantization needs float payloads).
    for fl in (False, True):
        base = {(c.collective, c.schedule, c.op, c.dtype) for c in cases
                if c.impl == "circulant" and c.fused is fl
                and c.wire is None and c.dtype != "int32"}
        wired = {(c.collective, c.schedule, c.op, c.dtype) for c in cases
                 if c.impl == "circulant" and c.fused is fl
                 and c.wire == "int8"}
        assert wired == base and wired
    assert not any(c.wire for c in cases
                   if c.impl != "circulant" or c.dtype == "int32")


def test_cases_route_through_collective_spec():
    """Every sweep case compiles to a CollectiveSpec — the harness
    exercises the plan/execute API, not the deprecated impl strings."""
    from repro.core.spec import CollectiveSpec
    for p in (6, 8):
        for c in sweep_cases(p):
            spec = case_spec(c, p)
            assert isinstance(spec, CollectiveSpec)
            assert spec.kind == c.impl
            if c.impl == "circulant":
                assert spec.schedule == c.schedule
                assert spec.wire_dtype == c.wire
                assert spec.use_fused_kernel is c.fused


def test_nonuniform_cases_cover_required_space():
    """The Corollary 3 sweep includes the paper's worst case (all blocks
    in one column) and zero-count ranks, at every tested p, and always
    sweeps the two optimal (ceil(log2 p)-round) schedules."""
    assert set(NONUNIFORM_SCHEDULES) >= {"halving", "power2"}
    for p in DEFAULT_PS:
        cases = nonuniform_counts_cases(p)
        assert {"ragged", "one_column", "zero_ranks", "uniform"} <= set(cases)
        for counts in cases.values():
            assert len(counts) == p and sum(counts) > 0
        one_col = cases["one_column"]
        assert sorted(one_col, reverse=True)[1:] == [0] * (p - 1), \
            "one_column must concentrate every element in a single column"
        if p >= 2:
            assert 0 in cases["zero_ranks"], \
                "zero_ranks must include an empty block"


def test_alltoallv_cases_cover_required_space():
    """The alltoall(v) sweep includes uniform, ragged, zero-count-pair
    and all-on-one-rank counts matrices at every tested p, and always
    sweeps both optimal (ceil(log2 p)-round) schedules."""
    assert set(A2A_SCHEDULES) >= {"halving", "power2"}
    for p in DEFAULT_PS:
        cases = alltoallv_counts_cases(p)
        assert {"ragged", "zero_pairs", "one_rank", "uniform"} <= set(cases)
        for counts in cases.values():
            assert len(counts) == p
            assert all(len(row) == p for row in counts)
            assert sum(sum(row) for row in counts) > 0
        one = cases["one_rank"]
        dst = p // 2
        assert all(c == 0 for i, row in enumerate(one)
                   for j, c in enumerate(row) if j != dst), \
            "one_rank must send every payload to a single destination"
        if p >= 2:
            zero = cases["zero_pairs"]
            assert any(c == 0 for row in zero for c in row), \
                "zero_pairs must include empty (src, dst) pairs"
            assert any(sum(row) == 0 for row in zero), \
                "zero_pairs must include a rank that sends nothing"


def test_hierarchical_factors():
    """Composite p gets a (p//g, g) two-axis mesh; primes are skipped."""
    assert hierarchical_factors(12) == (4, 3)
    assert hierarchical_factors(16) == (4, 4)
    assert hierarchical_factors(6) == (3, 2)
    for prime in (2, 3, 5, 7):
        assert hierarchical_factors(prime) is None
    covered = [p for p in DEFAULT_PS if hierarchical_factors(p)]
    assert len(covered) >= 4, "two-axis sweep must cover several p"


def test_default_ps_mostly_non_pow2():
    non_pow2 = [p for p in DEFAULT_PS if p & (p - 1)]
    assert len(non_pow2) >= 4, "non-powers-of-two are the paper's point"


def test_two_level_group_divides():
    for p in DEFAULT_PS:
        g = two_level_group(p)
        assert g >= 1 and p % g == 0
    assert two_level_group(12) == 3
    assert two_level_group(16) == 4
    assert two_level_group(7) == 1  # prime: degenerates to halving
