"""ZeRO-1 integration: the paper's collectives driving gradient sync must
reproduce single-device AdamW training exactly (subprocess, 8 fake devices).

Checks (in tests/_zero1_checks.py): per-impl loss-trajectory equality,
int8-compressed training, optimizer-state sharding 1/world, and the
train-step HLO containing the 2*ceil(log2 p) collective-permutes of
Theorem 2."""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def test_zero1_end_to_end():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_zero1_checks.py")],
        capture_output=True, text=True, timeout=1200, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"zero1 checks failed:\n--- stdout ---\n{proc.stdout}\n"
            f"--- stderr ---\n{proc.stderr}")
    assert "ALL ZERO1 CHECKS PASSED" in proc.stdout
