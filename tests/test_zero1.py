"""ZeRO-1 integration: the paper's collectives driving gradient sync must
reproduce single-device AdamW training exactly (subprocess, 8 fake devices).

Checks (in tests/_zero1_checks.py): per-impl loss-trajectory equality,
int8-compressed training, optimizer-state sharding 1/world, the
train-step HLO containing the 2*ceil(log2 p) collective-permutes of
Theorem 2, and bucketed (bucket_bytes) sync: f32 bitwise-equal to
unbucketed, int8+EF within the wire tolerance.

Device-free here: the bucket partitioner's edge cases and the
GradSyncConfig validation of ``bucket_bytes``."""
import os
import subprocess
import sys

import pytest

from repro.optim.zero1 import GradSyncConfig, plan_grad_buckets

HERE = os.path.dirname(os.path.abspath(__file__))


def _coverage(buckets):
    """leaf -> ordered [lo, hi) segments, in bucket order."""
    cov = {}
    for b in buckets:
        for (li, lo, hi) in b:
            cov.setdefault(li, []).append((lo, hi))
    return cov


def _assert_exact_cover(buckets, shapes, world):
    cov = _coverage(buckets)
    for li, shape in enumerate(shapes):
        rows = (shape[0] + (-shape[0]) % world) // world
        segs = cov.get(li, [])
        assert segs, f"leaf {li} not covered"
        assert segs[0][0] == 0 and segs[-1][1] == rows
        for (_, hi), (lo2, _) in zip(segs, segs[1:]):
            assert hi == lo2, f"gap/overlap in leaf {li}: {segs}"
        assert all(lo < hi for lo, hi in segs)


def test_partitioner_tiny_param_smaller_than_one_block():
    # ld=3 < world=8: pads to one shard row per rank — a single segment.
    buckets = plan_grad_buckets([(3, 16)], 8, 1 << 20, 4)
    assert buckets == [[(0, 0, 1)]]


def test_partitioner_boundary_splits_a_param():
    # One 64-row leaf, bucket target = half its bytes: the leaf must be
    # split across >= 2 buckets with contiguous, disjoint segments.
    shapes = [(64, 32)]
    world = 4
    total = 64 * 32 * 4
    buckets = plan_grad_buckets(shapes, world, total // 2, 4)
    assert len(buckets) >= 2
    assert all(li == 0 for b in buckets for (li, _, _) in b)
    _assert_exact_cover(buckets, shapes, world)


def test_partitioner_multi_leaf_exact_cover():
    shapes = [(10, 4), (3, 8), (64, 2), (7,), (128, 3)]
    for world in (4, 6, 8):  # incl. non-power-of-two
        for bb in (64, 600, 1 << 12, 1 << 30):
            buckets = plan_grad_buckets(shapes, world, bb, 4)
            _assert_exact_cover(buckets, shapes, world)
            assert all(b for b in buckets), "empty bucket"


def test_partitioner_row_larger_than_bucket_gets_own_bucket():
    # One shard row = 1024*4*4 bytes >> bucket_bytes: every bucket is a
    # single one-row segment; never an empty bucket, never starvation.
    buckets = plan_grad_buckets([(8, 1024)], 4, 64, 4)
    assert all(len(b) == 1 and b[0][2] - b[0][1] == 1 for b in buckets)
    _assert_exact_cover(buckets, [(8, 1024)], 4)


def test_partitioner_single_bucket_when_target_huge():
    shapes = [(16, 8), (32, 4)]
    buckets = plan_grad_buckets(shapes, 4, 1 << 40, 4)
    assert len(buckets) == 1
    _assert_exact_cover(buckets, shapes, 4)


def test_partitioner_rejects_nonpositive_target():
    with pytest.raises(ValueError, match="positive"):
        plan_grad_buckets([(8, 8)], 4, 0, 4)


def test_config_validates_bucket_bytes():
    GradSyncConfig(bucket_bytes=None)          # default: off
    GradSyncConfig(bucket_bytes=1 << 20)       # circulant: ok
    with pytest.raises(ValueError, match="positive"):
        GradSyncConfig(bucket_bytes=-1)
    with pytest.raises(ValueError, match="circulant"):
        GradSyncConfig(impl="ring", bucket_bytes=1 << 20)


def test_zero1_end_to_end():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_zero1_checks.py")],
        capture_output=True, text=True, timeout=1200, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"zero1 checks failed:\n--- stdout ---\n{proc.stdout}\n"
            f"--- stderr ---\n{proc.stderr}")
    assert "ALL ZERO1 CHECKS PASSED" in proc.stdout
