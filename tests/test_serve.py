"""Serving engine: batched prefill+decode, greedy == teacher forcing,
temperature sampling shape/finiteness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serve import ServeEngine


def test_generate_greedy_matches_stepwise_forward():
    cfg = get_config("internlm2-1.8b").scaled_down(n_layers=2, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model=model, params=params, max_len=24)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 64, (2, 8)).astype(np.int32)
    out = engine.generate(prompts, 4)
    assert out.shape == (2, 4)
    # greedy reference via repeated full forward
    toks = jnp.asarray(prompts)
    ref = []
    for _ in range(4):
        logits, _ = model.forward_logits(params, toks)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        ref.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], 1)
    np.testing.assert_array_equal(out, np.stack(ref, 1))


def test_generate_temperature_and_cache_bounds():
    cfg = get_config("qwen3-1.7b").scaled_down(n_layers=1, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model=model, params=params, max_len=16,
                         temperature=1.0)
    prompts = np.zeros((3, 8), np.int32)
    out = engine.generate(prompts, 8, key=jax.random.PRNGKey(1))
    assert out.shape == (3, 8)
    assert (out >= 0).all() and (out < 64).all()
    try:
        engine.generate(prompts, 9)
        raise AssertionError("expected cache-bound error")
    except ValueError:
        pass


# ---------------------------------------------------------------------------
# eos early exit (per-request done masks)
# ---------------------------------------------------------------------------

def test_generate_eos_early_exit_freezes_rows():
    from repro.serve import eos_done_mask
    cfg = get_config("internlm2-1.8b").scaled_down(n_layers=2, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model=model, params=params, max_len=32)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, 64, (2, 8)).astype(np.int32)
    ref = engine.generate(prompts, 8)
    # pick row 0's 3rd greedy token as the eos: row 0 stops there, stays
    # frozen to eos; row 1 is identical until ITS first eos hit (if any)
    eos = int(ref[0, 2])
    out = engine.generate(prompts, 8, eos_id=eos)
    assert out.shape == ref.shape
    for b in range(2):
        hits = np.nonzero(ref[b] == eos)[0]
        stop = int(hits[0]) if hits.size else ref.shape[1] - 1
        np.testing.assert_array_equal(out[b, :stop + 1], ref[b, :stop + 1])
        assert (out[b, stop:] == eos).all() or not hits.size
    # the mask helper itself: vector eos with <0 = "no eos for this row"
    nxt = jnp.asarray([5, 7, 9], jnp.int32)
    done = jnp.asarray([False, True, False])
    n2, d2 = eos_done_mask(nxt, done, jnp.asarray([5, 7, -1], jnp.int32))
    np.testing.assert_array_equal(np.asarray(d2), [True, True, False])
    np.testing.assert_array_equal(np.asarray(n2), [5, 7, 9])
    n3, d3 = eos_done_mask(nxt, done, None)
    assert n3 is nxt and d3 is done


# ---------------------------------------------------------------------------
# Paged KV cache: block allocator + gather/write parity with a dense cache
# ---------------------------------------------------------------------------

def test_block_allocator_reuse_never_aliases():
    from repro.serve import BlockAllocator, OutOfBlocks
    import pytest
    al = BlockAllocator(7)          # block 0 = scratch -> 6 usable
    a = al.alloc(3)
    b = al.alloc(2)
    assert not (set(a) & set(b)) and 0 not in a + b
    al.free(a)
    c = al.alloc(4)                 # reuses a's blocks, never b's
    assert not (set(c) & set(b)) and len(set(c)) == 4
    with pytest.raises(OutOfBlocks):
        al.alloc(3)                 # only 2 left
    with pytest.raises(ValueError, match="double free"):
        al.free([c[0], c[0]])
    with pytest.raises(ValueError, match="scratch"):
        al.free([0])


def test_paged_gather_matches_static_cache():
    """Rows read back through a (shuffled) block table are bitwise the
    rows the dense prefill cache holds."""
    from repro.serve import PagedKVCache, blocks_per_request
    cfg = get_config("internlm2-1.8b").scaled_down(n_layers=2, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    max_len, bs = 16, 4
    nb = blocks_per_request(max_len, bs)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, 64, (2, 9)).astype(np.int32)
    dense, _ = model.prefill(params, jnp.asarray(toks), max_len)
    kv = PagedKVCache.create(cfg, 1 + 2 * nb, bs)
    tables = np.asarray([[3, 1, 4, 2], [7, 5, 8, 6]], np.int32)  # shuffled
    for b in range(2):
        kv = kv.write_prefill(tables[b], {"k": dense["k"][:, b],
                                          "v": dense["v"][:, b]})
    got = kv.gather(tables)
    np.testing.assert_array_equal(np.asarray(got["k"]),
                                  np.asarray(dense["k"]))
    np.testing.assert_array_equal(np.asarray(got["v"]),
                                  np.asarray(dense["v"]))


def test_paged_write_token_single_position():
    """write_token moves ONLY row pos[b] of each slot; a second slot at a
    different offset is untouched."""
    from repro.serve import PagedKVCache
    cfg = get_config("internlm2-1.8b").scaled_down(n_layers=1, vocab_size=64)
    kv = PagedKVCache.create(cfg, 5, 4)
    tables = np.asarray([[1, 2], [3, 4]], np.int32)
    pos = np.asarray([5, 2], np.int32)
    d = {"k": jnp.ones((cfg.n_layers, 2, 8, cfg.n_kv_heads, cfg.head_dim)),
         "v": jnp.ones((cfg.n_layers, 2, 8, cfg.n_kv_heads, cfg.head_dim))}
    out = kv.write_token(tables, d, pos).gather(tables)
    k = np.asarray(out["k"])
    written = np.nonzero(k.any(axis=(0, 3, 4)))
    np.testing.assert_array_equal(written[0], [0, 1])
    np.testing.assert_array_equal(written[1], pos)


# ---------------------------------------------------------------------------
# Continuous batching: scheduler tokens == one-shot generate, bitwise
# ---------------------------------------------------------------------------

def _tiny_engine(max_len=24):
    cfg = get_config("internlm2-1.8b").scaled_down(n_layers=2, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model=model, params=params, max_len=max_len)


def test_scheduler_parity_staggered_arrivals():
    """4 requests through 2 decode slots: admissions and evictions are
    staggered, freed blocks are reused mid-run, and every request's
    token stream is BITWISE the one-shot generate() output."""
    from repro.serve import Scheduler
    engine = _tiny_engine()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, (n,)).astype(np.int32)
               for n in (8, 5, 11, 7)]
    maxnew = [4, 6, 3, 5]
    refs = [engine.generate(p[None], m)[0]
            for p, m in zip(prompts, maxnew)]
    sched = Scheduler(engine, max_batch=2, kv_block_size=4)
    rids = [sched.submit(p, m) for p, m in zip(prompts, maxnew)]
    got = sched.run()
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(got[rid], ref)
    # continuous batching actually happened: fewer decode boundaries
    # than sequential serving would need, and all blocks came back
    assert sched.n_decode_steps < sum(maxnew)
    assert sched.alloc.num_free == 2 * sched.blocks_per_req
    assert not sched.alloc._live


def test_scheduler_late_submissions_and_eos():
    """Requests submitted AFTER decoding started join at the next step
    boundary; eos-terminated requests evict early and their stream
    matches one-shot generate with the same eos."""
    from repro.serve import Scheduler
    engine = _tiny_engine()
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, 64, (6,)).astype(np.int32)
    p1 = rng.integers(0, 64, (9,)).astype(np.int32)
    ref0 = engine.generate(p0[None], 6)[0]
    eos = int(ref0[2])  # a token the greedy stream definitely emits
    stop = int(np.nonzero(np.asarray(ref0) == eos)[0][0])
    ref0e = np.asarray(ref0)[:stop + 1]   # up to and incl. FIRST eos hit
    ref1 = engine.generate(p1[None], 5)[0]
    sched = Scheduler(engine, max_batch=2, kv_block_size=4)
    r0 = sched.submit(p0, 6, eos_id=eos)
    sched.step()
    sched.step()
    r1 = sched.submit(p1, 5)       # late arrival, mid-decode
    got = sched.run()
    np.testing.assert_array_equal(got[r0], ref0e)
    assert (np.asarray(ref1) != eos).all()  # r1 never hits r0's eos
    np.testing.assert_array_equal(got[r1], ref1)


def test_scheduler_queue_waits_for_blocks():
    """With a pool sized for ONE request, the second stays queued until
    the first finishes and its blocks return to the free list."""
    from repro.serve import Scheduler
    engine = _tiny_engine()
    rng = np.random.default_rng(4)
    pa = rng.integers(0, 64, (8,)).astype(np.int32)
    pb = rng.integers(0, 64, (8,)).astype(np.int32)
    refa = engine.generate(pa[None], 3)[0]
    refb = engine.generate(pb[None], 3)[0]
    nb = engine.max_len // 8
    sched = Scheduler(engine, max_batch=2, kv_block_size=8,
                      num_blocks=1 + nb)   # room for exactly one request
    ra = sched.submit(pa, 3)
    rb = sched.submit(pb, 3)
    sched.step()
    assert sched.in_flight == 1 and len(sched.waiting) == 1
    got = sched.run()
    np.testing.assert_array_equal(got[ra], refa)
    np.testing.assert_array_equal(got[rb], refb)


# ---------------------------------------------------------------------------
# Multi-replica weight fan-out over the broadcast plan (fake devices ->
# subprocess, like the conformance/async checks)
# ---------------------------------------------------------------------------

def test_replica_broadcast_fanout_subprocess():
    import os
    import re
    import subprocess
    import sys
    env = dict(os.environ)
    inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = \
        "--xla_force_host_platform_device_count=3 " + inherited
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = """
import jax, numpy as np
import jax.numpy as jnp
from repro.configs import get_config
from repro.models import build
from repro.serve import ReplicaSet

cfg = get_config("internlm2-1.8b").scaled_down(n_layers=2, vocab_size=64)
model = build(cfg, recipe=None, remat=False)
params = model.init(jax.random.PRNGKey(0))
rs = ReplicaSet(model, max_len=24, replicas=3)
stats = rs.push_weights(params)
assert stats["rounds"] == 2, stats   # ceil(log2 3)
# fan-out is bitwise: every engine's every leaf == the source leaf
src = jax.tree.leaves(params)
for e in rs.engines:
    for a, b in zip(src, jax.tree.leaves(e.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
rng = np.random.default_rng(0)
prompts = rng.integers(0, 64, (5, 8)).astype(np.int32)
out = rs.generate(prompts, 4)           # round-robin over 3 replicas
ref = rs.engines[0].generate(prompts, 4)
np.testing.assert_array_equal(out, ref)
print("REPLICA-FANOUT-OK")
"""
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "REPLICA-FANOUT-OK" in r.stdout
