"""Serving engine: batched prefill+decode, greedy == teacher forcing,
temperature sampling shape/finiteness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.serve import ServeEngine


def test_generate_greedy_matches_stepwise_forward():
    cfg = get_config("internlm2-1.8b").scaled_down(n_layers=2, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model=model, params=params, max_len=24)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 64, (2, 8)).astype(np.int32)
    out = engine.generate(prompts, 4)
    assert out.shape == (2, 4)
    # greedy reference via repeated full forward
    toks = jnp.asarray(prompts)
    ref = []
    for _ in range(4):
        logits, _ = model.forward_logits(params, toks)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        ref.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], 1)
    np.testing.assert_array_equal(out, np.stack(ref, 1))


def test_generate_temperature_and_cache_bounds():
    cfg = get_config("qwen3-1.7b").scaled_down(n_layers=1, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model=model, params=params, max_len=16,
                         temperature=1.0)
    prompts = np.zeros((3, 8), np.int32)
    out = engine.generate(prompts, 8, key=jax.random.PRNGKey(1))
    assert out.shape == (3, 8)
    assert (out >= 0).all() and (out < 64).all()
    try:
        engine.generate(prompts, 9)
        raise AssertionError("expected cache-bound error")
    except ValueError:
        pass
