"""Subprocess worker: elastic shrink/grow drills on 8 fake devices.

The fast device-free elastic tests (controller state machine, failure
plans, reshard round-trips) live in ``tests/test_ft.py``; this worker
runs the full drain -> re-plan -> reshard -> resume drill end to end:

* rank loss landing EXACTLY on a checkpoint-boundary step: recovery
  must lose ZERO steps (the boundary checkpoint already covers every
  completed step);
* rank loss mid-interval with a transient checkpoint-IO fault injected
  during recovery: lost steps <= ckpt_every and the fault is absorbed
  by the controller's retry/backoff (never a restart fallback);
* voluntary grow to an ODD world (2 -> 3, the any-p claim): zero lost
  steps via the synchronous drain checkpoint.

Every drill also checks the post-resize loss trajectory is BITWISE
equal to an uninterrupted run at p' restored from the same checkpoint,
and that every re-planned spec passed the static verifier.

Run: python tests/_elastic_checks.py
"""
import os
import sys

import re  # noqa: E402 — strip inherited count: XLA keeps the LAST flag
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + _inherited)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.elastic import run_drill  # noqa: E402


def check(name, cond=True):
    if not cond:
        raise AssertionError(f"FAILED: {name}")
    print(f"ok: {name}")


common = dict(arch="qwen3-1.7b", scale_down=True, steps=8, seq_len=16,
              global_batch=12, ckpt_every=3)

# Rank loss at a CHECKPOINT-BOUNDARY step: step 6's checkpoint (written
# after step 5) covers everything completed, so recovery loses nothing.
res = run_drill(world=4, shrink_at_step=6, fail_rank=1, **common)
check(f"boundary shrink 4->3 resumes from step {res['resumed_step']} "
      f"with 0 lost steps", res["lost_steps"] == 0)
check("boundary shrink trajectory bitwise vs uninterrupted p'=3",
      res["bitwise"])
check("boundary shrink did not fall back to restart",
      not res["report"].restarted)

# Mid-interval rank loss + one transient IO fault during recovery.
res = run_drill(world=4, shrink_at_step=5, fail_rank=2, io_faults=1,
                **common)
check(f"mid-interval shrink loses {res['lost_steps']} <= ckpt_every steps",
      0 < res["lost_steps"] <= 3)
check("transient recovery IO fault absorbed by retry",
      res["report"].io_failures == 1 and not res["report"].restarted)
check("mid-interval shrink trajectory bitwise vs uninterrupted p'=3",
      res["bitwise"])
check("old-world plans evicted on resize", res["report"].evicted >= 1)
check("all re-planned specs statically verified",
      res["report"].replans
      and all(r.verified for r in res["report"].replans))

# Voluntary GROW to an odd world — circulant plans need no power-of-two
# padding (Theorem 1/2 at any p), so 3 is as good a world as 4.
res = run_drill(world=2, grow_at_step=4, grow_to=3, **common)
check("grow 2->3 (odd p') loses zero steps (synchronous drain ckpt)",
      res["lost_steps"] == 0)
check("grow 2->3 trajectory bitwise vs uninterrupted p'=3",
      res["bitwise"])
check("grow re-planned specs at p'=3 verified",
      all(r.new_p == 3 and r.verified for r in res["report"].replans))

print("ALL ELASTIC CHECKS PASSED")
