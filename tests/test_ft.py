"""Fault-tolerance: watchdog straggler policy on synthetic traces, the
failure-injection restart drill (training survives a mid-run crash and
reproduces the uninterrupted loss trajectory), rank-level failure plans,
the elastic controller's drain -> re-plan -> reshard -> resume state
machine (fake clock: retry/backoff, deadline, restart fallback), and the
ZeRO-1 reshard round-trip semantics (m/v lossless at any p -> p' -> p,
EF residual mass conservation).

The full elastic drill on fake devices runs in a subprocess
(``tests/_elastic_checks.py``) so this process keeps seeing one device.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointError, CheckpointManager
from repro.configs import get_config
from repro.data import for_model
from repro.ft import (CheckpointIOError, ElasticAbort, ElasticConfig,
                      ElasticController, FailureInjector, FailurePlan,
                      FaultEvent, RankFailure, SimulatedFailure, Watchdog,
                      WatchdogConfig, active_specs)
from repro.models import build
from repro.optim.adamw import AdamWConfig
from repro.optim.zero1 import (GradSyncConfig, Zero1State,
                               resize_zero1_state)
from repro.train import build as build_step

HERE = os.path.dirname(os.path.abspath(__file__))


def test_watchdog_flags_stragglers():
    actions = []
    wd = Watchdog(cfg=WatchdogConfig(warmup=3, patience=2),
                  on_straggler=lambda s, dt: actions.append(s))
    rng = np.random.default_rng(0)
    statuses = []
    for step in range(40):
        dt = 1.0 + 0.01 * rng.standard_normal()
        if step in (20, 21, 22, 23):
            dt = 3.0  # degraded node
        statuses.append(wd.observe(step, dt))
    assert "STRAGGLER" in statuses
    assert actions, "straggler policy callback should have fired"
    assert statuses[30] == "OK", "healthy steps after recovery must be OK"


def test_watchdog_ignores_warmup_compile_spike():
    wd = Watchdog(cfg=WatchdogConfig(warmup=5))
    statuses = [wd.observe(i, 30.0 if i == 0 else 1.0) for i in range(10)]
    assert "STRAGGLER" not in statuses[:5]


def test_restart_drill(tmp_path):
    """Inject a failure at step 4; restart resumes from step-3 checkpoint
    and the combined trajectory equals an uninterrupted run."""
    cfg = get_config("qwen3-1.7b").scaled_down(n_layers=1, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=20)
    pipe = for_model(cfg, seq_len=8, global_batch=4)
    built = build_step("single", model, opt_cfg)
    ckdir = str(tmp_path / "drill")

    def trainer(n_steps, injector=None):
        """A run: resume from latest checkpoint if present."""
        mgr = CheckpointManager(ckdir)
        params = model.init(jax.random.PRNGKey(7))
        opt = built.init_opt(params)
        start = 0
        leaves, treedef = jax.tree.flatten(opt)
        if mgr.latest_step() is not None:
            start, params, opt_arrs, man = mgr.restore(None, params)
            opt = jax.tree.unflatten(
                treedef, [jnp.asarray(opt_arrs[f"leaf_{i}"])
                          for i in range(len(leaves))])
        losses = []
        for step in range(start, n_steps):
            if injector:
                injector.check(step)
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
            params, opt, m = built.step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
            leaves2 = jax.tree.leaves(opt)
            mgr.save(step + 1, params,
                     {f"leaf_{i}": np.asarray(l) for i, l in
                      enumerate(leaves2)}, {"data_cursor": step + 1})
        return losses

    # uninterrupted reference (fresh dir)
    ref_dir, ckdir = ckdir, str(tmp_path / "ref")
    ref = trainer(6)
    ckdir = ref_dir

    # crash at step 4...
    with pytest.raises(SimulatedFailure):
        trainer(6, FailureInjector(fail_at_step=4))
    # ...restart picks up from the last checkpoint and finishes
    tail = trainer(6)
    assert len(tail) == 2  # steps 4, 5
    np.testing.assert_allclose(tail, ref[4:], rtol=1e-6)


# ---------------------------------------------------------------------------
# Watchdog: baseline-poisoning regressions
# ---------------------------------------------------------------------------

def test_watchdog_rebaselines_after_action():
    """A legitimate regime shift performed BY the straggler action (e.g.
    a schedule switch) must not be flagged forever: after on_straggler
    fires the watchdog re-learns the new step-time regime."""
    actions = []
    wd = Watchdog(cfg=WatchdogConfig(warmup=3, patience=2),
                  on_straggler=lambda s, dt: actions.append(s))
    statuses = []
    for step in range(40):
        dt = 1.0 + 0.001 * ((step * 7919) % 13 - 6)  # healthy jitter
        if step >= 15:
            dt = 2.5 + 0.001 * ((step * 7919) % 13 - 6)  # new regime
        statuses.append(wd.observe(step, dt))
    assert actions, "regime shift should have tripped the action once"
    assert wd.rebaselines, "action must re-baseline the statistics"
    # after the re-learned warmup, the 2.5s regime is the new healthy
    post = statuses[wd.rebaselines[0] + wd.cfg.warmup + 2:]
    assert post and all(s == "OK" for s in post), post


def test_watchdog_sigma_floor_survives_constant_warmup():
    """A constant-duration warmup leaves EWVAR ~ 0; the min_rel_sigma
    floor must keep the first micro-jitter step from z-scoring to inf."""
    wd = Watchdog(cfg=WatchdogConfig(warmup=5))
    for i in range(5):
        wd.observe(i, 1.0)  # exactly constant
    assert wd.observe(5, 1.02) == "OK"  # 2% jitter is healthy


# ---------------------------------------------------------------------------
# FailurePlan: rank-level fault schedules
# ---------------------------------------------------------------------------

def test_failure_plan_rank_loss_fires_once():
    fp = FailurePlan(events=(FaultEvent(step=3, kind="rank_loss", rank=2),))
    fp.check(2)  # nothing scheduled here
    with pytest.raises(RankFailure) as ei:
        fp.check(3)
    assert ei.value.rank == 2 and ei.value.step == 3
    fp.check(3)  # a dead rank stays dead: recovery re-visiting step 3
    #              must not re-kill it
    assert len(fp.fired) == 1


def test_failure_plan_slow_link_window():
    fp = FailurePlan(events=(
        FaultEvent(step=4, kind="slow_link", delay_s=0.5, duration=3),
        FaultEvent(step=5, kind="slow_link", delay_s=0.25, duration=1)))
    assert fp.slow_delay(3) == 0.0
    assert fp.slow_delay(4) == 0.5
    assert fp.slow_delay(5) == 0.75  # overlapping windows sum
    assert fp.slow_delay(6) == 0.5
    assert fp.slow_delay(7) == 0.0


def test_failure_plan_io_hook_is_transient():
    fp = FailurePlan(events=(
        FaultEvent(step=2, kind="ckpt_io", duration=2),))
    fp.io_hook(1)  # not armed yet
    for _ in range(2):  # exactly `duration` IO ops fail...
        with pytest.raises(CheckpointIOError):
            fp.io_hook(3)
    fp.io_hook(3)  # ...then IO heals (transient by construction)


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(step=1, kind="meteor_strike")
    with pytest.raises(ValueError):
        FaultEvent(step=-1)
    with pytest.raises(ValueError):
        FaultEvent(step=0, kind="slow_link", delay_s=-1.0)


# ---------------------------------------------------------------------------
# ElasticController: the recovery state machine with a fake clock
# ---------------------------------------------------------------------------

class FakeTime:
    """Injectable clock/sleep: sleep() advances the clock and records
    durations, so backoff schedules are asserted without real waiting."""

    def __init__(self):
        self.now = 0.0
        self.sleeps = []

    def clock(self):
        return self.now

    def sleep(self, s):
        self.sleeps.append(s)
        self.now += s


def _controller(world=4, **cfg_kw):
    ft = FakeTime()
    cfg = ElasticConfig(**cfg_kw)
    return ElasticController(world, cfg, clock=ft.clock,
                             sleep=ft.sleep), ft


def test_elastic_config_validation():
    with pytest.raises(ValueError):
        ElasticConfig(min_world=0)
    with pytest.raises(ValueError):
        ElasticConfig(min_world=4, max_world=2)
    with pytest.raises(ValueError):
        ElasticConfig(recovery_deadline_s=0.0)


def test_propose_world_dedup_clamp_abort():
    ctl, _ = _controller(world=8, min_world=4, max_world=6)
    assert ctl.propose_world([3]) == 6  # 7 survivors clamped to max_world
    assert ctl.propose_world([1, 2, 1, 2]) == 6  # duplicates counted once
    assert ctl.propose_world([0, 1, 2, 3]) == 4
    with pytest.raises(ElasticAbort):
        ctl.propose_world([0, 1, 2, 3, 4])  # 3 survivors < min_world


def test_recover_retries_transient_io_with_backoff():
    ctl, ft = _controller(world=4, io_retries=3, io_backoff_s=0.1)
    attempts = []

    def drain(step):
        attempts.append(step)
        if len(attempts) < 3:
            raise CheckpointIOError("flaky mount")
        return step

    rep, payload = ctl.recover(6, 3, [], drain=drain,
                               reshard=lambda w: f"resharded@{w}")
    assert payload == "resharded@3" and ctl.world == 3
    assert rep.drained == 6 and rep.io_failures == 2
    assert not rep.restarted
    assert ft.sleeps == [0.1, 0.2]  # exponential backoff, per attempt
    assert [n for n, _ in rep.phases] == list(
        ("drain", "replan", "reshard", "resume"))


def test_recover_exhausted_io_falls_back_to_restart():
    ctl, _ = _controller(world=4, io_retries=1, io_backoff_s=0.01)

    def bad_reshard(w):
        raise CheckpointIOError("disk on fire")

    rep, payload = ctl.recover(3, 2, [], drain=lambda s: s,
                               reshard=bad_reshard,
                               restart=lambda: "clean-restart")
    assert rep.restarted and payload == "clean-restart"
    assert rep.io_failures == 2  # 1 + io_retries attempts
    assert ctl.world == 2  # the restart relaunches at the new world


def test_recover_deadline_triggers_restart():
    ctl, ft = _controller(world=4, recovery_deadline_s=5.0)

    def slow_drain(step):
        ft.now += 10.0  # blows the whole-recovery deadline
        return step

    rep, payload = ctl.recover(3, 3, [], drain=slow_drain,
                               reshard=lambda w: "never reached",
                               restart=lambda: "restarted")
    assert rep.restarted and payload == "restarted"


def test_recover_aborts_without_restart_hook():
    ctl, _ = _controller(world=4, io_retries=0)
    with pytest.raises(ElasticAbort):
        ctl.recover(3, 3, [], drain=lambda s: (_ for _ in ()).throw(
            CheckpointIOError("gone")), reshard=lambda w: w)
    assert ctl.world == 4  # failed recovery adopts nothing
    assert ctl.reports and ctl.reports[-1].io_failures == 1


def test_recover_rejects_out_of_bounds_world():
    ctl, _ = _controller(world=4, min_world=2, max_world=6)
    for bad in (1, 7):
        with pytest.raises(ElasticAbort):
            # caller error, NEVER the restart-fallback path
            ctl.recover(0, bad, [], drain=lambda s: s,
                        reshard=lambda w: w, restart=lambda: "no")
    assert not ctl.reports or not any(r.restarted for r in ctl.reports)


def test_recover_retries_background_checkpoint_error():
    """A failed async save surfaces as CheckpointError on the drain's
    mgr.wait() — the retry machinery must cover it like an OSError."""
    ctl, _ = _controller(world=2, io_retries=2, io_backoff_s=0.0)
    calls = []

    def drain(step):
        calls.append(step)
        if len(calls) == 1:
            raise CheckpointError(step, OSError("bg write died"))
        return step

    rep, _ = ctl.recover(4, 1, [], drain=drain, reshard=lambda w: w)
    assert rep.io_failures == 1 and rep.drained == 4


def test_replan_verifies_and_evicts_old_world_plans():
    from repro.core.plan import plan
    sync = GradSyncConfig()
    specs = active_specs(sync)
    assert specs, "default sync must expose data-axis specs"
    for sp in specs:  # warm the cache at the old world
        plan(sp, p=4, axis_name="data")
    ctl, _ = _controller(world=4)
    rep, _ = ctl.recover(5, 3, specs, drain=lambda s: s,
                         reshard=lambda w: w)
    assert len(rep.replans) == len(specs)
    assert all(r.verified and r.old_p == 4 and r.new_p == 3
               for r in rep.replans)
    # rs_spec == ag_spec for the default sync -> one shared cache entry
    assert rep.evicted == len(set(specs))
    assert rep.replan_us >= 0.0


def test_replan_noop_resize_does_not_evict_fresh_plans():
    ctl, _ = _controller(world=4)
    sync = GradSyncConfig()
    rep, _ = ctl.recover(5, 4, active_specs(sync), drain=lambda s: s,
                         reshard=lambda w: w)
    assert rep.evicted == 0  # a no-op "resize" keeps its own plans


def test_active_specs_excludes_model_parallel_roles():
    sync = GradSyncConfig()
    specs = active_specs(sync)
    from repro.train.steps import collective_specs
    assert set(specs) == {sp for role, sp in collective_specs(sync)
                          if role == "data"}


# ---------------------------------------------------------------------------
# ZeRO-1 reshard round-trip semantics (the reshard phase's contract)
# ---------------------------------------------------------------------------

def _global_state(params, world, sync, with_ef):
    """Synthetic GLOBAL (gathered) Zero1State at `world`: zero leaves
    padded to the world multiple with ZERO pad rows (as checkpoints
    store them), EF residuals one full-leaf row per rank."""
    from repro.optim.zero1 import is_zero_leaf
    rng = np.random.default_rng(0)

    def mv(l):
        if not l.shape:
            return jnp.asarray(rng.normal(size=()).astype(np.float32))
        arr = rng.normal(size=l.shape).astype(np.float32)
        if is_zero_leaf(l.shape, world, sync.min_shard_numel):
            pad = (-l.shape[0]) % world
            if pad:
                arr = np.concatenate(
                    [arr, np.zeros((pad, *arr.shape[1:]), arr.dtype)])
        return jnp.asarray(arr)

    ef = None
    if with_ef:
        ef = jax.tree.map(lambda l: jnp.asarray(rng.normal(
            size=(world, *l.shape)).astype(np.float32)), params)
    return Zero1State(m=jax.tree.map(mv, params),
                      v=jax.tree.map(mv, params),
                      step=jnp.asarray(7, jnp.int32), ef=ef)


@pytest.mark.parametrize("p,p2", [(4, 3), (4, 2), (2, 5), (3, 4), (5, 3)])
def test_resize_zero1_mv_roundtrip_lossless(p, p2):
    """m/v survive p -> p' -> p bitwise — including GROW (p' > p) and
    odd worlds on both sides (the any-p claim applied to state)."""
    sync = GradSyncConfig()
    params = {"big": jnp.zeros((10, 128)), "tiny": jnp.zeros((4,)),
              "scalar": jnp.zeros(())}
    s0 = _global_state(params, p, sync, with_ef=False)
    s1 = resize_zero1_state(s0, params, p2, sync)
    s2 = resize_zero1_state(s1, params, p, sync)
    for a, b in zip(jax.tree.leaves((s0.m, s0.v)),
                    jax.tree.leaves((s2.m, s2.v))):
        assert jnp.array_equal(a, b), (a.shape, b.shape)
    assert int(s2.step) == 7
    # shapes at p' are padded to the NEW world's multiple
    assert s1.m["big"].shape[0] % p2 == 0


@pytest.mark.parametrize("p,p2", [(4, 3), (2, 5)])
def test_resize_zero1_ef_mass_conservation(p, p2):
    """EF residuals resize by MASS CONSERVATION: only sum_r ef_r enters
    the reduced gradient, so the total is folded into row 0 and must
    survive p -> p' -> p exactly; per-rank attribution is meaningless
    across a resize (the rank set itself changed)."""
    sync = GradSyncConfig(wire_dtype="int8")
    params = {"big": jnp.zeros((10, 128))}
    s0 = _global_state(params, p, sync, with_ef=True)
    mass0 = np.asarray(s0.ef["big"]).sum(axis=0)
    s1 = resize_zero1_state(s0, params, p2, sync)
    assert s1.ef["big"].shape[0] == p2
    np.testing.assert_array_equal(np.asarray(s1.ef["big"]).sum(axis=0),
                                  mass0)
    np.testing.assert_array_equal(np.asarray(s1.ef["big"])[1:], 0.0)
    s2 = resize_zero1_state(s1, params, p, sync)
    np.testing.assert_array_equal(np.asarray(s2.ef["big"]).sum(axis=0),
                                  mass0)


def test_resize_zero1_refuses_to_drop_ef_mass():
    """Resizing EF-carrying state under a sync with no error feedback
    would silently discard residual mass — it must raise instead."""
    sync_ef = GradSyncConfig(wire_dtype="int8")
    params = {"big": jnp.zeros((10, 128))}
    s0 = _global_state(params, 4, sync_ef, with_ef=True)
    with pytest.raises(ValueError):
        resize_zero1_state(s0, params, 2, GradSyncConfig())


# ---------------------------------------------------------------------------
# The full elastic drill (subprocess: needs 8 fake devices)
# ---------------------------------------------------------------------------

def test_elastic_drill_end_to_end():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_elastic_checks.py")],
        capture_output=True, text=True, timeout=1200, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"elastic checks failed:\n--- stdout ---\n{proc.stdout}\n"
            f"--- stderr ---\n{proc.stderr}")
    assert "ALL ELASTIC CHECKS PASSED" in proc.stdout


def test_data_pipeline_seekable_and_deterministic():
    cfg = get_config("qwen3-1.7b").scaled_down(vocab_size=64)
    pipe = for_model(cfg, seq_len=16, global_batch=8)
    b1 = pipe.batch_at(5)
    b2 = pipe.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipe.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host sharding partitions the global batch
    parts = [pipe.batch_at(5, host_id=h, n_hosts=4)["tokens"]
             for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])
