"""Fault-tolerance: watchdog straggler policy on synthetic traces + the
failure-injection restart drill (training survives a mid-run crash and
reproduces the uninterrupted loss trajectory)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import for_model
from repro.ft import FailureInjector, SimulatedFailure, Watchdog, WatchdogConfig
from repro.models import build
from repro.optim.adamw import AdamWConfig
from repro.train import build as build_step


def test_watchdog_flags_stragglers():
    actions = []
    wd = Watchdog(cfg=WatchdogConfig(warmup=3, patience=2),
                  on_straggler=lambda s, dt: actions.append(s))
    rng = np.random.default_rng(0)
    statuses = []
    for step in range(40):
        dt = 1.0 + 0.01 * rng.standard_normal()
        if step in (20, 21, 22, 23):
            dt = 3.0  # degraded node
        statuses.append(wd.observe(step, dt))
    assert "STRAGGLER" in statuses
    assert actions, "straggler policy callback should have fired"
    assert statuses[30] == "OK", "healthy steps after recovery must be OK"


def test_watchdog_ignores_warmup_compile_spike():
    wd = Watchdog(cfg=WatchdogConfig(warmup=5))
    statuses = [wd.observe(i, 30.0 if i == 0 else 1.0) for i in range(10)]
    assert "STRAGGLER" not in statuses[:5]


def test_restart_drill(tmp_path):
    """Inject a failure at step 4; restart resumes from step-3 checkpoint
    and the combined trajectory equals an uninterrupted run."""
    cfg = get_config("qwen3-1.7b").scaled_down(n_layers=1, vocab_size=64)
    model = build(cfg, recipe=None, remat=False)
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=20)
    pipe = for_model(cfg, seq_len=8, global_batch=4)
    built = build_step("single", model, opt_cfg)
    ckdir = str(tmp_path / "drill")

    def trainer(n_steps, injector=None):
        """A run: resume from latest checkpoint if present."""
        mgr = CheckpointManager(ckdir)
        params = model.init(jax.random.PRNGKey(7))
        opt = built.init_opt(params)
        start = 0
        leaves, treedef = jax.tree.flatten(opt)
        if mgr.latest_step() is not None:
            start, params, opt_arrs, man = mgr.restore(None, params)
            opt = jax.tree.unflatten(
                treedef, [jnp.asarray(opt_arrs[f"leaf_{i}"])
                          for i in range(len(leaves))])
        losses = []
        for step in range(start, n_steps):
            if injector:
                injector.check(step)
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
            params, opt, m = built.step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
            leaves2 = jax.tree.leaves(opt)
            mgr.save(step + 1, params,
                     {f"leaf_{i}": np.asarray(l) for i, l in
                      enumerate(leaves2)}, {"data_cursor": step + 1})
        return losses

    # uninterrupted reference (fresh dir)
    ref_dir, ckdir = ckdir, str(tmp_path / "ref")
    ref = trainer(6)
    ckdir = ref_dir

    # crash at step 4...
    with pytest.raises(SimulatedFailure):
        trainer(6, FailureInjector(fail_at_step=4))
    # ...restart picks up from the last checkpoint and finishes
    tail = trainer(6)
    assert len(tail) == 2  # steps 4, 5
    np.testing.assert_allclose(tail, ref[4:], rtol=1e-6)


def test_data_pipeline_seekable_and_deterministic():
    cfg = get_config("qwen3-1.7b").scaled_down(vocab_size=64)
    pipe = for_model(cfg, seq_len=16, global_batch=8)
    b1 = pipe.batch_at(5)
    b2 = pipe.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipe.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host sharding partitions the global batch
    parts = [pipe.batch_at(5, host_id=h, n_hosts=4)["tokens"]
             for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])
