"""Multi-call (async) round protocol of CollectivePlan.

Device-free here: protocol-order errors (start twice, finish before
start, end early, cross-plan states), backends without a round seam
raising NotImplementedError, and the p == 1 identity path (including the
pipelined drivers).  Execution equivalence — pipelined bitwise ==
one-shot per backend, manual interleavings, per-payload HLO round
budgets — runs in ``tests/_async_checks.py`` on fake devices (one
subprocess per axis size, including a non-power-of-two p)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import CollectiveSpec, RoundState, plan

HERE = os.path.dirname(os.path.abspath(__file__))
AX = "x"


def _plan(p, **kw):
    return plan(CollectiveSpec(**kw), p=p, axis_name=AX)


# ---------------------------------------------------------------------------
# Protocol-order errors (validated before any collective is traced)
# ---------------------------------------------------------------------------

def test_start_after_done_raises():
    pl = _plan(4)
    st = RoundState(plan=pl, phase="rs", nrounds=2, k=2)
    with pytest.raises(ValueError, match="phase complete"):
        pl.start_round(st)


def test_double_start_raises():
    pl = _plan(4)
    st = RoundState(plan=pl, phase="rs", nrounds=2, started=True)
    with pytest.raises(ValueError, match="already started"):
        pl.start_round(st)


def test_finish_before_start_raises():
    pl = _plan(4)
    st = RoundState(plan=pl, phase="rs", nrounds=2)
    with pytest.raises(ValueError, match="no ppermute in flight"):
        pl.finish_round(st)


def test_end_with_rounds_left_raises():
    pl = _plan(4)
    st = RoundState(plan=pl, phase="rs", nrounds=2, k=1)
    with pytest.raises(ValueError, match="unfinished"):
        pl.rs_end(st)


def test_end_wrong_phase_raises():
    pl = _plan(4)
    st = RoundState(plan=pl, phase="rs", nrounds=2, k=2)
    with pytest.raises(ValueError, match="mid-rs"):
        pl.ag_end(st)


def test_foreign_state_raises():
    pl_a = _plan(4)
    pl_b = _plan(4, schedule="power2")
    st = RoundState(plan=pl_b, phase="rs", nrounds=2)
    with pytest.raises(ValueError, match="different plan"):
        pl_a.start_round(st)


# ---------------------------------------------------------------------------
# Backends without a round seam
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["ring", "xla"])
def test_baseline_backends_have_no_async(kind):
    pl = plan(CollectiveSpec(kind=kind), p=4, axis_name=AX)
    with pytest.raises(NotImplementedError, match="multi-call"):
        pl.rs_begin(np.zeros(8, np.float32))
    with pytest.raises(NotImplementedError, match="multi-call"):
        pl.ag_begin(np.zeros(2, np.float32))


def test_nonuniform_has_no_async():
    pl = _plan(4, counts=(3, 1, 4, 1))
    with pytest.raises(NotImplementedError, match="async-capable"):
        pl.rs_begin(np.zeros(9, np.float32))


# ---------------------------------------------------------------------------
# p == 1 identity (fully device-free, including the pipelined drivers)
# ---------------------------------------------------------------------------

def test_p1_identity_roundtrip():
    pl = _plan(1)
    x = np.arange(6, dtype=np.float32)
    st = pl.rs_begin(x)
    assert st.done and st.nrounds == 0
    with pytest.raises(ValueError, match="phase complete"):
        pl.start_round(st)
    assert pl.rs_end(st) is x


def test_p1_pipelined_identity():
    pl = _plan(1)
    xs = [np.arange(4, dtype=np.float32), np.ones((2, 3), np.float32)]
    outs = pl.reduce_scatter_pipelined(xs)
    assert all(o is x for o, x in zip(outs, xs))
    outs = pl.allgather_pipelined(xs)
    assert all(o is x for o, x in zip(outs, xs))


# ---------------------------------------------------------------------------
# Execution equivalence on fake devices (p = 8 and a non-power-of-two 6)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ndev", [8, 6])
def test_async_execution_subprocess(ndev):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "_async_checks.py"), str(ndev)],
        capture_output=True, text=True, timeout=1200, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"async checks failed (ndev={ndev}):\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    assert "ALL ASYNC CHECKS PASSED" in proc.stdout
