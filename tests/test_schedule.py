"""Property + unit tests for the paper's skip schedules (Theorem 1 structure,
Corollary 2 validity, §3 max-run property)."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import schedule as S


@given(st.integers(1, 5000))
def test_halving_skip_count_is_ceil_log2(p):
    skips = S.halving_skips(p)
    assert len(skips) == S.ceil_log2(p)
    assert list(skips) == sorted(skips, reverse=True)
    if p > 1:
        assert skips[-1] == 1


@given(st.integers(2, 2000))
def test_halving_is_valid_corollary2_schedule(p):
    assert S.is_valid_schedule(p, S.halving_skips(p))


@given(st.integers(2, 512))
def test_power2_and_fully_connected_valid(p):
    assert S.is_valid_schedule(p, S.power2_skips(p))
    assert S.is_valid_schedule(p, S.fully_connected_skips(p))


@given(st.integers(2, 512))
def test_sqrt_schedule_valid(p):
    assert S.is_valid_schedule(p, S.sqrt_skips(p))


@given(st.integers(2, 1000))
def test_every_offset_decomposes_greedily_under_halving(p):
    """The paper: any i is a sum of different skips s_k <= i — the greedy
    decomposition exists for the halving schedule."""
    skips = S.halving_skips(p)
    for i in range(1, p):
        parts = S.decompose(i, skips)
        assert sum(parts) == i
        assert len(set(parts)) == len(parts)
        assert all(x in skips for x in parts)


@given(st.integers(2, 2000))
def test_blocks_sent_exactly_p_minus_1(p):
    """Theorem 1 volume: sum over rounds of (s_{k-1} - s_k) == p - 1."""
    plans = S.reduce_scatter_plan(p)
    assert S.total_blocks(plans) == p - 1
    # and the allgather phase mirrors it (Theorem 2's second p-1):
    assert S.total_blocks(S.allgather_plan(p)) == p - 1


@given(st.integers(2, 2000))
def test_max_block_run_at_most_ceil_p_over_2(p):
    """Paper §3: halving scheme never sends a run longer than ceil(p/2)."""
    assert S.max_block_run(S.reduce_scatter_plan(p)) <= (p + 1) // 2


def test_halving_max_run_is_floor_p_over_2_exactly():
    """The longest run under halving is the first round's
    p - ceil(p/2) = floor(p/2) — tight against the paper's ceil(p/2) bound.
    (The paper's remark that straight doubling lacks the property concerns
    Bruck-style buffer rotation copies; in our nested-range formulation
    both schedules keep contiguous, non-wrapping runs.)"""
    for p in range(2, 300):
        assert S.max_block_run(S.reduce_scatter_plan(p)) == p // 2


def test_paper_example_p22_skips():
    """Worked example in §2.1: p=22 gives skips 11, 6, 3, 2, 1."""
    assert S.halving_skips(22) == (11, 6, 3, 2, 1)


def test_paper_example_p22_receive_sources():
    """§2.1 example: processor 21 receives partial sums from 10, 15, 18,
    19, 20 in the five rounds."""
    p = 22
    plans = S.reduce_scatter_plan(p)
    r = 21
    froms = [(r - pl.skip) % p for pl in plans]
    assert froms == [10, 15, 18, 19, 20]


def test_paper_example_p22_round_partial_sums():
    """§2.1 example, full check: per-round arrivals into W at rank 21.

    The paper's display has a small typo — (x_20 + x_9) is printed on the
    skip-2 line but can only arrive with the final skip-1 round (sender 19
    has no incoming path from rank 20 by round 4: 20->19 would need skip
    -1 mod 22 = 21, not in {11,6,3,2}).  We assert the corrected grouping;
    the union and the per-pair bracketing match the paper.
    """
    arrivals = S.reduction_tree(22)
    # Shift to rank-21 view: reduction_tree traces rank 0; the paper's rank
    # is 21, so sources shift by +21 mod 22.
    shifted = {k: tuple(sorted((x + 21) % 22 for x in v))
               for k, v in arrivals.items()}
    assert shifted[0] == (10,)
    assert shifted[1] == (4, 15)
    assert shifted[2] == (1, 7, 12, 18)
    assert shifted[3] == (2, 5, 8, 13, 16, 19)
    assert shifted[4] == (0, 3, 6, 9, 11, 14, 17, 20)
    # Theorem 1: all 21 = p-1 sources arrive exactly once.
    allsrc = sorted(x for v in shifted.values() for x in v)
    assert allsrc == [i for i in range(22) if i != 21]


@given(st.integers(2, 300))
def test_reduction_tree_spans_all_ranks(p):
    arrivals = S.reduction_tree(p)
    seen = [x for v in arrivals.values() for x in v]
    assert len(seen) == p - 1  # each source folded exactly once
    assert set(seen) | {0} == set(range(p))


@given(st.integers(2, 256), st.integers(2, 16))
def test_two_level_schedule_valid(ngroups, group):
    p = ngroups * group
    skips = S.two_level_skips(p, group)
    assert S.is_valid_schedule(p, skips)


def test_invalid_schedules_rejected():
    assert not S.is_valid_schedule(8, (4, 2))          # no trailing 1
    assert not S.is_valid_schedule(8, (2, 4, 1))       # not decreasing
    assert not S.is_valid_schedule(8, (4, 4, 1))       # duplicate
    assert not S.is_valid_schedule(16, (5, 4, 3, 2, 1))  # fold-liveness
    assert not S.is_valid_schedule(10, (7, 2, 1))      # 4..6 unreachable


def test_plan_ranges_partition_1_to_p():
    for p in [2, 3, 7, 22, 64, 100, 257]:
        for sched in ["halving", "power2", "fully_connected", "sqrt"]:
            plans = S.reduce_scatter_plan(p, sched)
            covered = sorted(i for pl in plans for i in range(pl.lo, pl.hi))
            assert covered == list(range(1, p)), (p, sched)
