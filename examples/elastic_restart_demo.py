"""Fault-tolerance drills, in increasing order of ambition:

1. classic restart — crash mid-training, relaunch, verify the loss
   trajectory is bit-identical to an uninterrupted run;
2. elastic SHRINK — a rank dies mid-run at world 4; the elastic
   controller drains to the last checkpoint boundary, re-plans every
   circulant collective at p=3 (statically verified), reshards the
   ZeRO-1 state and resumes — no relaunch, and the post-resize
   trajectory matches an uninterrupted p=3 run from the same checkpoint
   bitwise (the circulant schedules are round-optimal at ANY p, so 3 is
   as good a world as 4).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_restart_demo.py

See ``repro.launch.elastic`` (the drill harness this drives) and
``repro.ft.elastic`` (the controller).
"""
import os
import re
import shutil
import sys
import tempfile

os.environ["XLA_FLAGS"] = (
    re.sub(r"--xla_force_host_platform_device_count=\d+", "",
           os.environ.get("XLA_FLAGS", ""))
    + " --xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.ft import SimulatedFailure
from repro.launch import train as train_mod
from repro.launch.elastic import run_drill


def run(args):
    return train_mod.main(args)


def main():
    d = tempfile.mkdtemp(prefix="drill_")
    base = ["--arch", "qwen3-1.7b", "--scale-down", "--steps", "30",
            "--seq-len", "32", "--global-batch", "4", "--ckpt-every", "10",
            "--log-every", "10", "--lr", "1e-3"]
    print("=== uninterrupted reference run ===")
    ref = run(base + ["--ckpt-dir", os.path.join(d, "ref")])

    print("\n=== run with injected failure at step 17 ===")
    ck = os.path.join(d, "drill")
    try:
        run(base + ["--ckpt-dir", ck, "--fail-at-step", "17"])
        raise AssertionError("expected injected failure")
    except SimulatedFailure as e:
        print(f"crashed as planned: {e}")

    print("\n=== restart: resumes from step-10 checkpoint ===")
    tail = run(base + ["--ckpt-dir", ck])
    np.testing.assert_allclose(tail, ref[10:], rtol=1e-6)
    print("resumed trajectory MATCHES the uninterrupted run exactly ✓")

    print("\n=== elastic shrink: rank 2 of 4 dies; drain -> re-plan -> "
          "reshard -> resume at 3 ===")
    res = run_drill(world=4, shrink_at_step=5, fail_rank=2, steps=8,
                    ckpt_every=3, io_faults=1)
    rep = res["report"]
    print(f"resumed from step {res['resumed_step']} "
          f"({res['lost_steps']} step(s) lost, <= ckpt_every); "
          f"re-planned {len(rep.replans)} verified spec(s) in "
          f"{rep.replan_us:.0f}us, absorbed {rep.io_failures} IO fault(s)")
    assert res["bitwise"], res["max_abs_diff"]
    print("post-resize trajectory matches the uninterrupted p'=3 run "
          "bitwise ✓")
    shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
