"""Fault-tolerance drill: crash mid-training, restart, verify the loss
trajectory is bit-identical to an uninterrupted run; then elastic-reshard
the checkpoint to a different DP world size.

    PYTHONPATH=src python examples/elastic_restart_demo.py
"""
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.checkpoint import reshard_flat
from repro.ft import SimulatedFailure
from repro.launch import train as train_mod


def run(args):
    return train_mod.main(args)


def main():
    d = tempfile.mkdtemp(prefix="drill_")
    base = ["--arch", "qwen3-1.7b", "--scale-down", "--steps", "30",
            "--seq-len", "32", "--global-batch", "4", "--ckpt-every", "10",
            "--log-every", "10", "--lr", "1e-3"]
    print("=== uninterrupted reference run ===")
    ref = run(base + ["--ckpt-dir", os.path.join(d, "ref")])

    print("\n=== run with injected failure at step 17 ===")
    ck = os.path.join(d, "drill")
    try:
        run(base + ["--ckpt-dir", ck, "--fail-at-step", "17"])
        raise AssertionError("expected injected failure")
    except SimulatedFailure as e:
        print(f"crashed as planned: {e}")

    print("\n=== restart: resumes from step-10 checkpoint ===")
    tail = run(base + ["--ckpt-dir", ck])
    np.testing.assert_allclose(tail, ref[10:], rtol=1e-6)
    print("resumed trajectory MATCHES the uninterrupted run exactly ✓")

    print("\n=== elastic reshard: 4-way optimizer shards -> 2-way ===")
    full = np.arange(37.0)
    four = [reshard_flat(full, 4, r) for r in range(4)]
    two = [reshard_flat(full, 2, r) for r in range(2)]
    np.testing.assert_array_equal(
        np.concatenate(four)[:37], np.concatenate(two)[:37])
    print("shards re-split losslessly across world sizes ✓")
    shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
