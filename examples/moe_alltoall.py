"""Walkthrough: round-optimal alltoall(v) and MoE expert parallelism.

Three stops:

1. the uniform circulant alltoall — paper §4's reduce-scatter with
   ⊕ = concatenation, ``ceil(log2 p)`` collective-permutes for any p;
2. the ragged alltoallv — a p×p per-pair ``counts`` matrix compiled to
   per-round row tables (wire width = the worst windowed count sum),
   same round count;
3. MoE expert-parallel dispatch (``moe_dispatch="ep"``): the (E, C, d)
   dispatch buffer rides stop 1, the ragged per-expert routed-token
   counts ride stop 2, and the result matches the single-pool "global"
   dispatch numerically.

    PYTHONPATH=src python examples/moe_alltoall.py
"""
import os
import re
import sys

P_DEVICES = 4
# Strip any inherited device-count flag (XLA keeps the LAST occurrence).
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={P_DEVICES} " + _inherited)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.analysis.hlo_budget import (  # noqa: E402
    count_collective_permutes_lowered)
from repro.core import CollectiveSpec, ceil_log2, plan  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.moe import init_moe, moe_ffn  # noqa: E402


def shmap(mesh, fn, out_specs=None):
    return jax.jit(compat.shard_map(
        lambda v: fn(v[0])[None], mesh=mesh, in_specs=(P("x"),),
        out_specs=out_specs or P("x")))


def main():
    p = P_DEVICES
    mesh = compat.make_mesh((p,), ("x",))
    rng = np.random.default_rng(0)

    # -- 1. uniform alltoall: out[r][j] = in[j][r], ceil(log2 p) rounds --
    blk = 3
    x = rng.standard_normal((p, p, blk)).astype(np.float32)
    spec = CollectiveSpec()  # circulant, halving schedule
    f = shmap(mesh, lambda v: plan(spec, axis_name="x").alltoall(v))
    out = np.asarray(f(jnp.asarray(x)))
    assert all((out[r, j] == x[j, r]).all() for r in range(p)
               for j in range(p))
    cps = count_collective_permutes_lowered(f, (p, p, blk))
    print(f"alltoall p={p}: transposed {p}x{p} blocks in {cps} "
          f"collective-permutes (ceil(log2 p) = {ceil_log2(p)})")

    # -- 2. ragged alltoallv: per-pair counts matrix --------------------
    counts = tuple(tuple((i + 2 * j) % 3 for j in range(p))
                   for i in range(p))  # counts[src][dst] rows
    vspec = CollectiveSpec(counts=counts)
    vplan = plan(vspec, p=p, axis_name="x")
    print(f"alltoallv counts={counts}")
    print(f"  per-round wire widths (worst windowed count sums): "
          f"{vplan.a2a.round_widths}")
    in_h = vplan.a2a.in_height
    xs = np.zeros((p, in_h, 2), np.float32)
    expected = [[None] * p for _ in range(p)]
    for src in range(p):
        j = 0
        for dst in range(p):
            c = counts[src][dst]
            payload = rng.standard_normal((c, 2)).astype(np.float32)
            xs[src, j:j + c] = payload
            expected[dst][src] = payload
            j += c
    fv = shmap(mesh, lambda v: plan(vspec, axis_name="x").alltoall(v))
    outv = np.asarray(fv(jnp.asarray(xs)))
    for r in range(p):
        j = 0
        for src in range(p):
            c = counts[src][r]
            assert (outv[r, j:j + c] == expected[r][src]).all()
            j += c
        assert (outv[r, j:] == 0).all()  # zeroed past this rank's total
    print("  ragged exchange verified against the transpose")

    # -- 3. MoE expert parallelism over the same plan -------------------
    e = 6  # NOT divisible by p=4: expert ownership (2,2,1,1) is ragged,
    #        so the routed-counts exchange is a genuine alltoallv.
    cfg = ModelConfig(
        name="demo-moe", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=128, head_dim=8, n_experts=e,
        experts_per_token=2, capacity_factor=8.0, dtype="float32",
        moe_dispatch="ep", ep_axis="x")
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    xtok = jax.random.normal(jax.random.PRNGKey(1), (p, 8, cfg.d_model))

    def per_rank(v):
        out, _aux = moe_ffn(params, cfg, v[None] if v.ndim == 2 else v)
        return out[0] if v.ndim == 2 else out

    fep = jax.jit(compat.shard_map(
        lambda v: per_rank(v[0])[None], mesh=mesh, in_specs=(P("x"),),
        out_specs=P("x"), check_vma=False))
    out_ep = np.asarray(fep(xtok))

    cfg_g = dataclasses.replace(cfg, moe_dispatch="global")
    out_g = np.concatenate(
        [np.asarray(moe_ffn(params, cfg_g, xtok[r:r + 1])[0])
         for r in range(p)], axis=0)
    np.testing.assert_allclose(out_ep, out_g, rtol=2e-5, atol=2e-5)
    print(f"moe_dispatch='ep' over {p} ranks x {e} experts (ragged "
          f"ownership) == 'global' dispatch ✓")


if __name__ == "__main__":
    main()
