"""Demo: the paper's circulant collectives on 8 simulated devices.

Shows Algorithm 1/2 vs ring vs XLA-native, the Corollary-2 schedule family,
the worked p=22-style round structure, and the HLO evidence (exactly
ceil(log2 p) collective-permutes).

    python examples/collectives_demo.py         (re-execs with 8 devices)
"""
import os
import sys

if "--worker" not in sys.argv:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
    os.execv(sys.executable, [sys.executable, __file__, "--worker"])

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.analysis.hlo_budget import count_collective_permutes_lowered
from repro.core import collectives as C
from repro.core.schedule import (ceil_log2, get_skips, reduction_tree)

P_DEV = 8
mesh = compat.make_mesh((P_DEV,), ("x",))


def shmap(fn):
    return jax.jit(compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                                    in_specs=(P("x"),), out_specs=P("x")))


def main():
    p = P_DEV
    print(f"=== Träff circulant collectives on p={p} simulated devices ===")
    print(f"halving skips (Alg.1): {get_skips(p)}  "
          f"rounds={ceil_log2(p)} (optimal)")
    for sched in ["halving", "power2", "fully_connected", "sqrt"]:
        print(f"  schedule {sched:16s}: skips={get_skips(p, sched)}")

    print("\nreduction tree into W at rank 0 (per round sources):")
    for k, srcs in reduction_tree(p).items():
        print(f"  round {k} (skip {get_skips(p)[k]}): += partial over "
              f"{srcs}")

    rng = np.random.default_rng(0)
    x = rng.standard_normal((p, p * 4)).astype(np.float32)

    rs = shmap(lambda v: C.circulant_reduce_scatter(v, "x"))
    got = np.asarray(rs(x))
    want = x.sum(0).reshape(p, 4)
    print(f"\nreduce-scatter max err vs numpy: "
          f"{np.abs(got - want).max():.2e}")

    ar = shmap(lambda v: C.circulant_allreduce(v, "x"))
    got = np.asarray(ar(x))
    print(f"allreduce max err: {np.abs(got[0] - x.sum(0)).max():.2e} "
          f"(replicated on all {p} ranks: "
          f"{all((got[i] == got[0]).all() for i in range(p))})")

    # HLO structure = the paper's round counts
    def count_cp(fn):
        f = jax.jit(compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                                     in_specs=(P('x'),), out_specs=P('x')))
        return count_collective_permutes_lowered(f, (p, p * 4))

    print(f"\nHLO collective-permutes: RS="
          f"{count_cp(lambda v: C.circulant_reduce_scatter(v, 'x'))} "
          f"(= ceil(log2 {p}) = {ceil_log2(p)}),  AR="
          f"{count_cp(lambda v: C.circulant_allreduce(v, 'x'))} "
          f"(= 2*ceil(log2 {p}) = {2 * ceil_log2(p)}),  ring RS="
          f"{count_cp(lambda v: C.ring_reduce_scatter(v, 'x'))} (= p-1 = "
          f"{p - 1})")

    # wall-clock comparison (CPU simulation — structure, not perf)
    big = rng.standard_normal((p, p * 65536)).astype(np.float32)
    for name, fn in [
            ("circulant AR", lambda v: C.circulant_allreduce(v, "x")),
            ("ring AR", lambda v: C.ring_allreduce(v, "x")),
            ("XLA psum", lambda v: C.xla_allreduce(v, "x"))]:
        f = shmap(fn)
        f(big).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(10):
            out = f(big)
        out.block_until_ready()
        print(f"  {name:14s}: {(time.perf_counter() - t0) / 10 * 1e3:6.2f} "
              f"ms/call (8 fake CPU devices)")


if __name__ == "__main__":
    main()
