"""Demo: non-uniform reduce-scatter (paper Corollary 3) via the
plan/execute API — `CollectiveSpec(counts=...)` → `plan()` → run.

Shows per-rank block sizes (MPI_Reduce_scatter flavor) on 8 simulated
devices: a ragged layout, zero-count ranks, and the paper's worst case
with every element concentrated in one column — all still lowering to
exactly ceil(log2 p) collective-permutes.

    python examples/nonuniform_reduce_scatter.py   (re-execs with 8 devices)
"""
import os
import sys

if "--worker" not in sys.argv:
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
    os.execv(sys.executable, [sys.executable, __file__, "--worker"])

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.analysis.hlo_budget import count_collective_permutes_lowered
from repro.core import CollectiveSpec, plan
from repro.core import collectives as C
from repro.core.schedule import ceil_log2

P_DEV = 8
mesh = compat.make_mesh((P_DEV,), ("x",))


def shmap(fn):
    return jax.jit(compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                                    in_specs=(P("x"),), out_specs=P("x")))


def count_cp(fn, shape):
    return count_collective_permutes_lowered(shmap(fn), shape)


def demo(name: str, counts: tuple[int, ...]):
    p = P_DEV
    spec = CollectiveSpec(counts=counts)
    pl = plan(spec, p=p, axis_name="x")
    N, bmax = sum(counts), max(counts)
    print(f"\n--- {name}: counts={counts} (total {N} rows) ---")
    print(f"  plan backend={pl.backend!r}, skips={pl.skips}, "
          f"rounds={len(pl.rs_rounds)} (= ceil(log2 {p}) = {ceil_log2(p)})")
    for k, tab in enumerate(pl.rs_row_tables):
        print(f"  round {k} (skip {pl.skips[k]}): wire width {tab.shape[1]} "
              f"rows (worst window over ranks)")

    rng = np.random.default_rng(0)
    xg = rng.standard_normal((p, N)).astype(np.float32)
    out = np.asarray(shmap(
        lambda v: C.reduce_scatter(v, "x", spec=spec))(xg))

    offs = np.concatenate([[0], np.cumsum(counts)])
    ref = xg.sum(axis=0)
    err = 0.0
    for r in range(p):
        c = counts[r]
        if c:
            err = max(err, np.abs(out[r, :c] - ref[offs[r]:offs[r] + c]).max())
        assert (out[r, c:] == 0).all(), "rows past this rank's count are zero"
    ncp = count_cp(lambda v: C.reduce_scatter(v, "x", spec=spec), (p, N))
    print(f"  max err vs numpy: {err:.2e};  HLO collective-permutes: {ncp}")
    assert ncp == ceil_log2(p)


def main():
    print(f"=== Corollary 3 non-uniform reduce-scatter on p={P_DEV} "
          f"simulated devices ===")
    demo("ragged", tuple((i * 5 + 3) % 7 for i in range(P_DEV)))
    demo("zero-count ranks", tuple(0 if i % 2 else i + 2
                                   for i in range(P_DEV)))
    demo("one column (worst case)", (0, 0, 0, 35, 0, 0, 0, 0))

    # Round-trip: non-uniform allreduce = RS + allgather(v), replicated.
    counts = tuple((i * 5 + 3) % 7 for i in range(P_DEV))
    spec = CollectiveSpec(counts=counts)
    N = sum(counts)
    rng = np.random.default_rng(1)
    xg = rng.standard_normal((P_DEV, N)).astype(np.float32)
    ar = np.asarray(shmap(lambda v: C.allreduce(v, "x", spec=spec))(xg))
    ok = all((ar[r] == ar[0]).all() for r in range(P_DEV))
    print(f"\nnon-uniform allreduce: max err "
          f"{np.abs(ar[0] - xg.sum(0)).max():.2e}, "
          f"bitwise-replicated on all ranks: {ok}")


if __name__ == "__main__":
    main()
