"""Serve a (reduced) MoE model with batched requests — exercises the MoE
dispatch path, KV caches, and temperature sampling.

    PYTHONPATH=src python examples/serve_moe.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_mod


def main():
    serve_mod.main(["--arch", "phi3.5-moe-42b-a6.6b", "--scale-down",
                    "--batch", "4", "--prompt-len", "16", "--max-new", "12",
                    "--temperature", "0.8"])


if __name__ == "__main__":
    main()
