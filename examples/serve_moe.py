"""Serve a (reduced) MoE model with batched requests — exercises the MoE
dispatch path, KV caches, and temperature sampling.

Since the alltoall refactor this drives the EXPERT-PARALLEL dispatch
path: experts are sharded over 2 (fake-device) ranks and every layer's
(E, C, d) dispatch buffer is exchanged with the circulant alltoall plan
(``--moe-dispatch ep``; see examples/moe_alltoall.py for the API tour).

    PYTHONPATH=src python examples/serve_moe.py
"""
import os
import re
import sys

EP_DEVICES = 2
# Strip any inherited device-count flag (XLA keeps the LAST occurrence).
_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={EP_DEVICES} " + _inherited)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_mod  # noqa: E402


def main():
    serve_mod.main(["--arch", "phi3.5-moe-42b-a6.6b", "--scale-down",
                    "--batch", "4", "--prompt-len", "16", "--max-new", "12",
                    "--temperature", "0.8",
                    "--moe-dispatch", "ep",
                    "--ep-devices", str(EP_DEVICES)])


if __name__ == "__main__":
    main()
