"""Quickstart: train a ~15M-param qwen3-family model for 200 steps on CPU,
with checkpointing, then reload and serve a few tokens.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.data import for_model
from repro.models import build
from repro.optim.adamw import AdamWConfig
from repro.serve import ServeEngine
from repro.train import build as build_step


def main():
    cfg = get_config("qwen3-1.7b").scaled_down(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512)
    print(f"model: {cfg.name} (reduced) ~{cfg.param_count()/1e6:.1f}M params")
    model = build(cfg, recipe=None)
    params = model.init(jax.random.PRNGKey(0))

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=200)
    built = build_step("single", model, opt_cfg)
    opt = built.init_opt(params)
    pipe = for_model(cfg, seq_len=64, global_batch=8)

    import jax.numpy as jnp
    losses = []
    for step in range(200):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, opt, m = built.step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"step {step:4d}  loss {losses[-1]:.4f}")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'LEARNED' if losses[-1] < losses[0] - 0.5 else 'check setup'})")
    assert losses[-1] < losses[0] - 0.5, "expected clear learning progress"

    engine = ServeEngine(model=model, params=params, max_len=80)
    prompts = np.asarray(pipe.batch_at(0)["tokens"][:2, :32])
    out = engine.generate(prompts, 8)
    print("sampled continuations:", out.tolist())


if __name__ == "__main__":
    main()
