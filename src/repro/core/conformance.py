"""Cross-implementation conformance harness for the paper's collectives.

Sweeps every (collective × impl × schedule × op × dtype ×
use_fused_kernel × wire_dtype) combination that is meaningful for a given
axis size ``p`` — int8-wire mirrors use tolerance-based assertions
(compressed rounds are lossy by design) while everything else keeps its
exact checks — plus the alltoall(v) sweep (``run_alltoall``: uniform
blocks and ragged per-pair counts matrices vs the simulator, the host
transpose reference and XLA's native all-to-all, all bitwise) and, for
composite p, a hierarchical two-axis sweep (``run_hierarchical``).  Per
case it asserts:

  (a) agreement with a host-side numpy reference — bitwise for integer and
      order-independent (max/min) reductions, tolerance-based for float
      summation — and, where XLA provides a native baseline (psum_scatter /
      psum / pmax / pmin), agreement with that baseline too;
  (b) for the circulant implementations, that the lowered HLO contains
      exactly ``rounds(schedule)`` collective-permute ops for
      reduce-scatter and ``2 * rounds(schedule)`` for allreduce, where for
      the ceil(log2 p)-round schedules (halving / power2) ``rounds ==
      ceil_log2(p)`` — Theorems 1 and 2 machine-checked at every tested p,
      non-powers-of-two included (they are the paper's whole point).

The numeric checks need ``p`` fake XLA devices, which must be configured
before the first jax import; run this module as its own process:

    python src/repro/core/conformance.py <p>

``tests/test_conformance.py`` drives one subprocess per p in
``DEFAULT_PS``.
"""
import os
import sys

if __name__ == "__main__":  # set device count BEFORE the jax import below
    import re as _re
    _CLI_P = int(sys.argv[1]) if len(sys.argv) > 1 else 8
    # Strip any inherited device-count flag: XLA keeps the LAST occurrence,
    # so a caller's exported =8 would silently override the requested p.
    _inherited = _re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                         os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_CLI_P} " + _inherited)
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", ".."))

import math  # noqa: E402
from dataclasses import dataclass  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax import lax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import collectives as C  # noqa: E402
from repro.core import simulator as sim  # noqa: E402
from repro.core.schedule import ceil_log2, get_skips  # noqa: E402
from repro.core.spec import CollectiveSpec  # noqa: E402

# Non-powers-of-two dominate by design — power-of-two p is the case the
# classic algorithms already handle; the paper's claim is the general one.
DEFAULT_PS = (2, 3, 4, 5, 6, 7, 8, 12, 16)
SCHEDULES = ("halving", "power2", "fully_connected", "sqrt", "two_level")
OPTIMAL_SCHEDULES = ("halving", "power2")   # exactly ceil(log2 p) rounds
OPS = ("add", "max", "min")
DTYPES = ("float32", "bfloat16", "int32")

AXIS = "x"
BLK = 4  # elements per block — tiny on purpose; compile time dominates

_NP_OPS = {"add": np.add, "max": np.maximum, "min": np.minimum}


def two_level_group(p: int) -> int:
    """Intra-group size for the two_level schedule: the divisor of p
    nearest sqrt(p).  1 for primes (two_level degenerates to halving)."""
    divisors = [d for d in range(2, p) if p % d == 0]
    if not divisors:
        return 1
    return min(divisors, key=lambda d: (abs(d - math.sqrt(p)), d))


def schedule_rounds(p: int, schedule: str) -> int:
    """Round count of ``schedule`` at ``p`` ranks (two_level resolves
    its group size first)."""
    group = two_level_group(p) if schedule == "two_level" else None
    return len(get_skips(p, schedule, group=group))


@dataclass(frozen=True)
class Case:
    """One conformance-matrix cell: a (collective, impl, schedule, op,
    dtype, fused, wire) combination to execute and check."""
    collective: str            # reduce_scatter | allreduce
    impl: str                  # circulant | ring | recursive_halving | xla
    schedule: str = "halving"
    op: str = "add"
    dtype: str = "float32"
    fused: bool = False        # use_fused_kernel (circulant only)
    wire: str | None = None    # wire_dtype (circulant only; float dtypes)

    @property
    def label(self) -> str:
        tag = (":fused" if self.fused else "") + \
            (f":wire={self.wire}" if self.wire else "")
        return (f"{self.collective}[{self.impl}:{self.schedule}"
                f":{self.op}:{self.dtype}{tag}]")


def sweep_cases(p: int) -> list[Case]:
    """Every meaningful combination for axis size p, deduplicated: impls ×
    both collectives at the defaults, then schedule / op / dtype sweeps on
    the circulant implementation (the component under test).  Every
    circulant case is mirrored with ``use_fused_kernel=True`` so the fused
    Pallas round kernel is held to the exact same reference checks, and
    every float circulant case (fused and not) is additionally mirrored
    with ``wire_dtype="int8"`` — the compressed rounds are asserted
    against the same references with quantization-aware tolerances."""
    pow2 = p & (p - 1) == 0
    cases: list[Case] = []
    for coll in ("reduce_scatter", "allreduce"):
        impls = ["circulant", "ring", "xla"]
        if coll == "reduce_scatter" and pow2 and p > 1:
            impls.append("recursive_halving")
        base = [Case(coll, impl) for impl in impls]
        base.extend(Case(coll, "circulant", schedule=s)
                    for s in SCHEDULES if s != "halving")
        base.extend(Case(coll, "circulant", op=op)
                    for op in OPS if op != "add")
        base.extend(Case(coll, "circulant", dtype=dt)
                    for dt in DTYPES if dt != "float32")
        base.extend(
            Case(c.collective, c.impl, c.schedule, c.op, c.dtype, fused=True)
            for c in list(base) if c.impl == "circulant")
        base.extend(
            Case(c.collective, c.impl, c.schedule, c.op, c.dtype,
                 fused=c.fused, wire="int8")
            for c in list(base)
            if c.impl == "circulant" and c.dtype != "int32")
        cases.extend(base)
    return cases


# ---------------------------------------------------------------------------
# Execution helpers
# ---------------------------------------------------------------------------

def _shmap1(mesh, fn, check_vma: bool | None = None):
    """Per-rank fn over a (p, ...) global sharded on axis 0 (the repo's
    standard v[0]-unwrap convention).  ``check_vma=False`` is passed only
    for the fused cases — 0.4.x shard_map has no replication rule for
    pallas_call — so the jnp/baseline cases keep exercising the
    replication checker."""
    return jax.jit(compat.shard_map(
        lambda v: fn(v[0])[None], mesh=mesh,
        in_specs=(P(AXIS),), out_specs=P(AXIS), check_vma=check_vma))


def case_spec(case: Case, p: int) -> CollectiveSpec:
    """The CollectiveSpec a sweep case means — every case executes
    through the plan/execute API (the component under test)."""
    if case.impl != "circulant":
        return CollectiveSpec(kind=case.impl, op=case.op)
    return CollectiveSpec(
        kind="circulant", schedule=case.schedule, op=case.op,
        use_fused_kernel=case.fused, wire_dtype=case.wire,
        group=two_level_group(p) if case.schedule == "two_level" else None)


def _impl_fn(case: Case, p: int):
    spec = case_spec(case, p)
    if case.collective == "reduce_scatter":
        return lambda v: C.reduce_scatter(v, AXIS, spec=spec)
    return lambda v: C.allreduce(v, AXIS, spec=spec)


def _xla_baseline_fn(case: Case):
    """Native-XLA reference for the same collective, when one exists."""
    if case.collective == "reduce_scatter":
        if case.op == "add":
            return lambda v: C.xla_reduce_scatter(v, AXIS)
        return None  # psum_scatter is add-only
    if case.op == "add":
        return lambda v: C.xla_allreduce(v, AXIS)
    red = lax.pmax if case.op == "max" else lax.pmin
    return lambda v: red(v, AXIS)


def _make_input(case: Case, p: int, rng: np.random.Generator) -> np.ndarray:
    n = p * BLK
    if case.dtype == "int32":
        return rng.integers(-50, 50, size=(p, n), dtype=np.int64).astype(
            np.int32)
    x = rng.standard_normal((p, n)).astype(np.float32)
    if case.dtype == "bfloat16":
        x = np.asarray(jnp.asarray(x, jnp.bfloat16).astype(jnp.float32))
    return x


def _reference(case: Case, xg: np.ndarray) -> np.ndarray:
    """Host ground truth: op-fold over ranks (float64 accumulation for
    float inputs; exact dtype for integers)."""
    npop = _NP_OPS[case.op]
    work = xg.astype(np.float64) if case.dtype != "int32" else xg
    red = work[0]
    for r in range(1, xg.shape[0]):
        red = npop(red, work[r])
    return red


def _tolerances(case: Case, p: int) -> dict:
    if case.wire == "int8":
        # Quantization-bounded, NOT bitwise (even for max/min): every
        # round requantizes partial sums, so the error budget scales with
        # the round count and the partial-sum magnitude (~sqrt(p) for the
        # N(0,1) inputs).  The bound below holds with ~5x margin at every
        # tested (p, schedule); bf16 inputs are strictly coarser than the
        # int8 grid error so they need no extra term.
        return {"rtol": 0.1, "atol": 0.05 * p + 0.1}
    if case.dtype == "int32" or case.op in ("max", "min"):
        return {"rtol": 0, "atol": 0}
    if case.dtype == "bfloat16":
        return {"rtol": 0.05, "atol": 0.05 * p}
    return {"rtol": 2e-5, "atol": 2e-5}


def run_case(mesh, p: int, case: Case, rng: np.random.Generator) -> None:
    """Execute one case and assert agreement; raises AssertionError with
    the case label on any mismatch."""
    xg = _make_input(case, p, rng)
    dt = jnp.dtype(case.dtype)
    out = np.asarray(_shmap1(mesh, _impl_fn(case, p),
                             check_vma=False if case.fused else None)(
        jnp.asarray(xg, dtype=dt)))
    ref = _reference(case, xg)
    tol = _tolerances(case, p)
    try:
        if case.collective == "reduce_scatter":
            ref_blocks = ref.reshape(p, BLK)
            for r in range(p):
                np.testing.assert_allclose(
                    out[r].astype(np.float64), ref_blocks[r], **tol)
        else:
            for r in range(p):
                np.testing.assert_allclose(
                    out[r].astype(np.float64), ref, **tol)
                # Theorem 2's output is REPLICATED — bitwise, not just close.
                np.testing.assert_array_equal(out[r], out[0])
    except AssertionError as e:
        raise AssertionError(f"{case.label} vs host reference (p={p}): {e}") \
            from None

    base_fn = _xla_baseline_fn(case)
    if base_fn is None:
        return
    base = np.asarray(_shmap1(mesh, base_fn)(jnp.asarray(xg, dtype=dt)))
    try:
        if case.wire is None and (case.dtype == "int32"
                                  or case.op in ("max", "min")):
            np.testing.assert_array_equal(out, base)  # bitwise
        else:
            np.testing.assert_allclose(out.astype(np.float64),
                                       base.astype(np.float64), **tol)
    except AssertionError as e:
        raise AssertionError(f"{case.label} vs XLA baseline (p={p}): {e}") \
            from None


# ---------------------------------------------------------------------------
# HLO structure: Theorem 1/2 round counts
# ---------------------------------------------------------------------------

def _n_collective_permutes(jitted, shape: tuple[int, ...]) -> int:
    """Lowered-HLO collective-permute count of a jitted per-rank wrapper
    on an f32 input of ``shape`` (the repo-wide counter lives in
    ``repro.analysis.hlo_budget``; this shim fixes the f32 dtype)."""
    from repro.analysis.hlo_budget import count_collective_permutes_lowered
    return count_collective_permutes_lowered(jitted, shape)


def count_collective_permutes(mesh, p: int, fn,
                              check_vma: bool | None = None) -> int:
    """Collective-permute count of ``fn`` lowered under shard_map on
    ``mesh`` with the standard (p, p*BLK) conformance payload."""
    return _n_collective_permutes(_shmap1(mesh, fn, check_vma=check_vma),
                                  (p, p * BLK))


def check_round_counts(mesh, p: int) -> dict[str, tuple[int, int]]:
    """Assert RS/AR collective-permute counts for every schedule, on the
    jnp and fused-Pallas round paths AND the int8 wire format (neither
    fusion nor compression may change the communication structure — the
    packed [codes | scale bytes] wire buffer keeps one collective-permute
    per round); returns {schedule[:fused][:w8]: (n_rs, n_ar)}."""
    results = {}
    for sched in SCHEDULES:
        kw = {"schedule": sched}
        if sched == "two_level":
            kw["group"] = two_level_group(p)
        rounds = schedule_rounds(p, sched)
        if sched in OPTIMAL_SCHEDULES:
            assert rounds == ceil_log2(p), \
                f"{sched} must be a ceil(log2 p)-round schedule (p={p})"
        for fused in (False, True):
            for wire in (None, "int8"):
                kwf = dict(kw, use_fused_kernel=fused, wire_dtype=wire)
                cv = False if fused else None
                tag = sched + (":fused" if fused else "") + \
                    (":w8" if wire else "")
                n_rs = count_collective_permutes(
                    mesh, p,
                    lambda v, kwf=kwf: C.circulant_reduce_scatter(
                        v, AXIS, **kwf),
                    check_vma=cv)
                n_ar = count_collective_permutes(
                    mesh, p,
                    lambda v, kwf=kwf: C.circulant_allreduce(v, AXIS, **kwf),
                    check_vma=cv)
                assert n_rs == rounds, \
                    (f"RS[{tag}] p={p}: {n_rs} collective-permutes, "
                     f"want {rounds} (Theorem 1)")
                assert n_ar == 2 * rounds, \
                    (f"AR[{tag}] p={p}: {n_ar} collective-permutes, "
                     f"want {2 * rounds} (Theorem 2)")
                results[tag] = (n_rs, n_ar)
    return results


# ---------------------------------------------------------------------------
# Non-uniform counts (paper Corollary 3) — spec(counts=...) vs simulator
# ---------------------------------------------------------------------------

NONUNIFORM_SCHEDULES = ("halving", "power2", "fully_connected")


def nonuniform_counts_cases(p: int) -> dict[str, tuple[int, ...]]:
    """Per-rank block-size patterns for the Corollary 3 sweep.

    ``one_column`` is the paper's worst case (every element concentrated
    in a single column — each round one rank ships the whole vector);
    ``zero_ranks`` exercises empty blocks; ``ragged`` is a deterministic
    mixed pattern; ``uniform`` must agree with the uniform path.
    """
    ragged = tuple((i * 5 + 3) % 7 for i in range(p))
    if sum(ragged) == 0:
        ragged = (1,) * p
    one_col = [0] * p
    one_col[p // 2] = 4 * p + 3
    zero_ranks = tuple(0 if i % 2 else i + 2 for i in range(p))
    if sum(zero_ranks) == 0:
        zero_ranks = (2,) + (0,) * (p - 1)
    return {
        "ragged": ragged,
        "one_column": tuple(one_col),
        "zero_ranks": zero_ranks,
        "uniform": (BLK,) * p,
    }


def run_nonuniform(p: int, mesh, verbose: bool = False) -> dict:
    """Corollary 3 conformance: ``CollectiveSpec(counts=...)`` reduce-
    scatter (and allreduce) under shard_map vs the numpy simulator (which
    asserts the Theorem 1 counters) AND the host reference, across
    schedules × ops × counts patterns, plus lowered-HLO collective-
    permute counts — still exactly ``rounds(schedule)`` (= ceil(log2 p)
    for halving/power2): ragged counts must not change the communication
    structure."""
    rng = np.random.default_rng(4242 + p)
    n_cases = 0
    rounds: dict[str, tuple[int, int]] = {}
    for name, counts in nonuniform_counts_cases(p).items():
        N, bmax = sum(counts), max(counts)
        offs = np.concatenate([[0], np.cumsum(counts)])
        xg = rng.standard_normal((p, N)).astype(np.float32)
        inputs = [[xg[r, offs[i]:offs[i + 1]] for i in range(p)]
                  for r in range(p)]
        for sched in NONUNIFORM_SCHEDULES:
            for op in ("add", "max"):
                spec = CollectiveSpec(schedule=sched, op=op, counts=counts)
                tag = f"counts[{name}:{sched}:{op}]"
                W, stats = sim.simulate_reduce_scatter(
                    inputs, op=_NP_OPS[op], schedule=sched)
                if sched in OPTIMAL_SCHEDULES:
                    stats.assert_theorem1(p)
                else:
                    assert stats.rounds == schedule_rounds(p, sched)
                    assert all(b == p - 1 for b in stats.blocks_sent)
                out = np.asarray(_shmap1(
                    mesh, lambda v, s=spec: C.reduce_scatter(
                        v, AXIS, spec=s))(jnp.asarray(xg)))
                ref = _ref_nonuniform(xg, op)
                tol = ({"rtol": 0, "atol": 0} if op != "add"
                       else {"rtol": 2e-5, "atol": 2e-5})
                for r in range(p):
                    c = counts[r]
                    np.testing.assert_allclose(
                        out[r, :c].astype(np.float64), W[r], **tol,
                        err_msg=f"{tag} vs simulator (p={p}, rank {r})")
                    np.testing.assert_allclose(
                        out[r, :c].astype(np.float64),
                        ref[offs[r]:offs[r] + c], **tol,
                        err_msg=f"{tag} vs host reference (p={p}, rank {r})")
                    assert (out[r, c:] == 0).all(), \
                        f"{tag}: rows past counts[{r}] must be zero"
                n_cases += 1
        # Allreduce (RS + non-uniform allgather) on the default schedule:
        # replicated full vector, bitwise across ranks.
        spec = CollectiveSpec(counts=counts)
        ar = np.asarray(_shmap1(
            mesh, lambda v, s=spec: C.allreduce(v, AXIS, spec=s))(
            jnp.asarray(xg)))
        ref = _ref_nonuniform(xg, "add")
        for r in range(p):
            np.testing.assert_allclose(
                ar[r].astype(np.float64), ref, rtol=2e-5, atol=2e-5,
                err_msg=f"counts[{name}] allreduce (p={p})")
            np.testing.assert_array_equal(ar[r], ar[0])
        n_cases += 1
        # HLO structure: ragged counts keep one collective-permute per
        # round — ceil(log2 p) for the optimal schedules (Theorem 1 /
        # Corollary 3).
        for sched in NONUNIFORM_SCHEDULES:
            spec = CollectiveSpec(schedule=sched, counts=counts)
            want = schedule_rounds(p, sched)
            n_rs = _n_collective_permutes(_shmap1(
                mesh, lambda v, s=spec: C.reduce_scatter(v, AXIS, spec=s)),
                (p, N))
            n_ar = _n_collective_permutes(_shmap1(
                mesh, lambda v, s=spec: C.allreduce(v, AXIS, spec=s)),
                (p, N))
            if sched in OPTIMAL_SCHEDULES:
                assert want == ceil_log2(p)
            assert n_rs == want, \
                (f"counts[{name}:{sched}] p={p}: {n_rs} collective-"
                 f"permutes, want {want} (Corollary 3 keeps Theorem 1's "
                 f"rounds)")
            assert n_ar == 2 * want, \
                (f"counts[{name}:{sched}] AR p={p}: {n_ar} collective-"
                 f"permutes, want {2 * want}")
            rounds[f"{name}:{sched}"] = (n_rs, n_ar)
        if verbose:
            print(f"ok: counts[{name}] p={p} (sum={N}, bmax={bmax})")
    return {"n_cases": n_cases, "rounds": rounds}


def _ref_nonuniform(xg: np.ndarray, op: str) -> np.ndarray:
    npop = _NP_OPS[op]
    red = xg[0].astype(np.float64)
    for r in range(1, xg.shape[0]):
        red = npop(red, xg[r].astype(np.float64))
    return red


# ---------------------------------------------------------------------------
# Alltoall(v) — uniform + ragged per-pair counts vs simulator + host ref
# ---------------------------------------------------------------------------

A2A_SCHEDULES = ("halving", "power2", "fully_connected")
A2A_DTYPES = ("float32", "bfloat16", "int32")


def alltoallv_counts_cases(p: int) -> dict[str, tuple[tuple[int, ...], ...]]:
    """Per-pair counts matrices for the ragged alltoallv sweep.

    ``ragged`` mixes sizes; ``zero_pairs`` has whole zero-count rows in
    the round tables (every other (src, dst) pair empty, incl. a rank
    that sends nothing); ``one_rank`` concentrates every payload on a
    single destination (the worst windowed sum — each round one rank's
    wire carries a full vector); ``uniform`` must agree with the dense
    alltoall layout.
    """
    ragged = tuple(tuple((i * 3 + j * 5 + 1) % 4 for j in range(p))
                   for i in range(p))
    zero = tuple(tuple(0 if (i + j) % 2 or i == 0 else i + j + 1
                       for j in range(p)) for i in range(p))
    one = [[0] * p for _ in range(p)]
    for i in range(p):
        one[i][p // 2] = i + 1
    return {
        "ragged": ragged,
        "zero_pairs": zero,
        "one_rank": tuple(tuple(r) for r in one),
        "uniform": tuple((BLK,) * p for _ in range(p)),
    }


def _a2a_input(case_dtype: str, shape, rng: np.random.Generator
               ) -> np.ndarray:
    if case_dtype == "int32":
        return rng.integers(-50, 50, size=shape).astype(np.int32)
    x = rng.standard_normal(shape).astype(np.float32)
    if case_dtype == "bfloat16":
        x = np.asarray(jnp.asarray(x, jnp.bfloat16).astype(jnp.float32))
    return x


def run_alltoall(p: int, mesh, verbose: bool = False) -> dict:
    """Alltoall(v) conformance at axis size p.

    Uniform: circulant alltoall across schedules × dtypes × fused, each
    asserted BITWISE against the numpy simulator, the host transpose
    reference, and XLA's native all-to-all (no arithmetic happens, so
    exactness holds for every dtype), with fused == jnp bitwise.  Ragged:
    every ``alltoallv_counts_cases`` matrix across schedules, f32 + i32,
    vs ``simulate_alltoallv`` + host ref, zero rows past each rank's
    receive total.  Both forms assert the lowered-HLO collective-permute
    count == rounds(schedule) — ``ceil(log2 p)`` for halving/power2:
    ragged per-pair counts must not change the communication structure.
    """
    rng = np.random.default_rng(905 + p)
    n_cases = 0
    rounds: dict[str, tuple[int, ...]] = {}

    # --- uniform dense alltoall -------------------------------------------
    for dtype in A2A_DTYPES:
        xg = _a2a_input(dtype, (p, p, BLK), rng)
        dt = jnp.dtype(dtype)
        ref = sim.ref_alltoall(
            [[xg[r, i] for i in range(p)] for r in range(p)])
        W, stats = sim.simulate_alltoall(
            [[xg[r, i] for i in range(p)] for r in range(p)])
        assert stats.rounds == ceil_log2(p)
        for sched in A2A_SCHEDULES:
            spec = CollectiveSpec(schedule=sched)
            outs = {}
            for fused in (False, True):
                s = spec.with_(use_fused_kernel=fused)
                out = np.asarray(_shmap1(
                    mesh, lambda v, s=s: C.alltoall(v, AXIS, spec=s),
                    check_vma=False if fused else None)(
                    jnp.asarray(xg, dtype=dt)))
                outs[fused] = out
                for r in range(p):
                    for j in range(p):
                        np.testing.assert_array_equal(
                            out[r, j],
                            np.asarray(W[r][j]).astype(out.dtype),
                            err_msg=f"alltoall[{sched}:{dtype}"
                                    f"{':fused' if fused else ''}] vs "
                                    f"simulator (p={p}, rank {r})")
                        np.testing.assert_array_equal(
                            out[r, j],
                            np.asarray(ref[r][j]).astype(out.dtype),
                            err_msg=f"alltoall[{sched}:{dtype}] vs host "
                                    f"ref (p={p})")
                n_cases += 1
            np.testing.assert_array_equal(
                outs[True], outs[False],
                err_msg=f"alltoall[{sched}:{dtype}] fused != jnp (p={p})")
        # XLA native baseline (layout contract identical).
        base = np.asarray(_shmap1(
            mesh, lambda v: C.alltoall(
                v, AXIS, spec=CollectiveSpec(kind="xla")))(
            jnp.asarray(xg, dtype=dt)))
        np.testing.assert_array_equal(
            base, outs[False],
            err_msg=f"alltoall[{dtype}] circulant != xla baseline (p={p})")
        n_cases += 1

    # HLO structure (uniform): one collective-permute per round, fused too.
    for sched in A2A_SCHEDULES:
        spec = CollectiveSpec(schedule=sched)
        want = schedule_rounds(p, sched)
        if sched in OPTIMAL_SCHEDULES:
            assert want == ceil_log2(p)
        got = []
        for fused in (False, True):
            s = spec.with_(use_fused_kernel=fused)
            jitted = _shmap1(mesh, lambda v, s=s: C.alltoall(v, AXIS, spec=s),
                             check_vma=False if fused else None)
            n_cp = _n_collective_permutes(jitted, (p, p, BLK))
            assert n_cp == want, \
                (f"alltoall[{sched}{':fused' if fused else ''}] p={p}: "
                 f"{n_cp} collective-permutes, want {want} (Theorem 1's "
                 f"rounds; ceil(log2 p) for the optimal schedules)")
            got.append(n_cp)
        rounds[f"uniform:{sched}"] = tuple(got)

    # --- ragged alltoallv -------------------------------------------------
    for name, counts in alltoallv_counts_cases(p).items():
        send_tot = [sum(row) for row in counts]
        recv_tot = [sum(counts[s][d] for s in range(p)) for d in range(p)]
        in_h = max(max(send_tot), 1)
        for dtype in ("float32", "int32"):
            inputs = [[_a2a_input(dtype, (counts[r][d], 2), rng)
                       for d in range(p)] for r in range(p)]
            xg = np.zeros((p, in_h, 2),
                          np.int32 if dtype == "int32" else np.float32)
            for r in range(p):
                j = 0
                for d in range(p):
                    c = counts[r][d]
                    xg[r, j:j + c] = inputs[r][d]
                    j += c
            W, stats = sim.simulate_alltoallv(inputs)
            ref = sim.ref_alltoall(inputs)
            for sched in A2A_SCHEDULES:
                spec = CollectiveSpec(schedule=sched, counts=counts)
                tag = f"alltoallv[{name}:{sched}:{dtype}]"
                out = np.asarray(_shmap1(
                    mesh, lambda v, s=spec: C.alltoall(v, AXIS, spec=s))(
                    jnp.asarray(xg)))
                for r in range(p):
                    j = 0
                    for s_ in range(p):
                        c = counts[s_][r]
                        np.testing.assert_array_equal(
                            out[r, j:j + c], np.asarray(W[r][s_], out.dtype),
                            err_msg=f"{tag} vs simulator (p={p}, rank {r})")
                        np.testing.assert_array_equal(
                            out[r, j:j + c],
                            np.asarray(ref[r][s_], out.dtype),
                            err_msg=f"{tag} vs host ref (p={p}, rank {r})")
                        j += c
                    assert j == recv_tot[r]
                    assert (out[r, j:] == 0).all(), \
                        f"{tag}: rows past recv total must be zero (p={p})"
                n_cases += 1
        # HLO structure: ragged counts keep one collective-permute per
        # round (= ceil(log2 p) for the optimal schedules).
        for sched in A2A_SCHEDULES:
            spec = CollectiveSpec(schedule=sched, counts=counts)
            want = schedule_rounds(p, sched)
            n_cp = _n_collective_permutes(_shmap1(
                mesh, lambda v, s=spec: C.alltoall(v, AXIS, spec=s)),
                (p, in_h))
            assert n_cp == want, \
                (f"alltoallv[{name}:{sched}] p={p}: {n_cp} collective-"
                 f"permutes, want {want} (ragged per-pair counts must not "
                 f"change the round structure)")
            rounds[f"{name}:{sched}"] = (n_cp,)
        if verbose:
            print(f"ok: alltoallv[{name}] p={p} "
                  f"(total={sum(send_tot)} rows)")
    if verbose:
        print(f"ok: alltoall sweep p={p} ({n_cases} cases)")
    return {"n_cases": n_cases, "rounds": rounds}


# ---------------------------------------------------------------------------
# Hierarchical (multi-axis) sweep — nested RS/AG/AR over a 2-D mesh
# ---------------------------------------------------------------------------

def hierarchical_factors(p: int) -> tuple[int, int] | None:
    """(p // g, g) mesh factorization for the two-axis sweep; None for
    primes (no non-trivial 2-D mesh exists)."""
    g = two_level_group(p)
    if g <= 1:
        return None
    return (p // g, g)


def _shmap2(mesh, fn, check_vma: bool | None = None):
    """Per-rank fn over a (p, ...) global sharded on dim 0 across BOTH
    mesh axes ('x'-major rank order — the layout the nested hierarchical
    collectives produce)."""
    return jax.jit(compat.shard_map(
        lambda v: fn(v[0])[None], mesh=mesh,
        in_specs=(P(("x", "y")),), out_specs=P(("x", "y")),
        check_vma=check_vma))


def run_hierarchical(p: int, verbose: bool = False) -> dict | None:
    """Two-axis conformance: hierarchical_reduce_scatter / allgather /
    allreduce over a (p//g, g) mesh vs the host reference, on the jnp and
    fused paths, uncompressed and int8-wire; plus HLO collective-permute
    counts (= sum of the per-axis round counts).  Returns None for prime
    p (no 2-D factorization)."""
    fac = hierarchical_factors(p)
    if fac is None:
        return None
    a, b = fac
    mesh = compat.make_mesh((a, b), ("x", "y"))
    axes = ("x", "y")
    rng = np.random.default_rng(977 + p)
    n = p * BLK
    xg = rng.standard_normal((p, n)).astype(np.float32)
    ref = xg.astype(np.float64).sum(axis=0)
    ref_blocks = ref.reshape(p, BLK)
    blocks = rng.standard_normal((p, BLK)).astype(np.float32)
    n_cases = 0
    rounds_want = ceil_log2(a) + ceil_log2(b)
    results: dict[str, tuple[int, int]] = {}
    for fused in (False, True):
        cv = False if fused else None
        for wire in (None, "int8"):
            kw = {"use_fused_kernel": fused}
            if wire:
                kw["wire_dtype"] = wire
            tol = ({"rtol": 2e-5, "atol": 2e-5} if wire is None
                   else {"rtol": 0.1, "atol": 0.05 * p + 0.1})
            tag = f"{a}x{b}" + (":fused" if fused else "") + \
                (":w8" if wire else "")
            # RS over ('x', 'y'): rank (rx, ry) ends with linear block
            # rx*b + ry — exactly the P(('x', 'y')) rank order.
            out = np.asarray(_shmap2(
                mesh, lambda v: C.hierarchical_reduce_scatter(
                    v, axes, **kw), cv)(jnp.asarray(xg)))
            for rr in range(p):
                np.testing.assert_allclose(
                    out[rr].astype(np.float64), ref_blocks[rr], **tol,
                    err_msg=f"hierarchical RS[{tag}] p={p}")
            # AG inverts RS's layout: every rank reassembles the blocks
            # in linear rank order, replicated.
            ag = np.asarray(_shmap2(
                mesh, lambda v: C.hierarchical_allgather(v, axes, **kw),
                cv)(jnp.asarray(blocks)))
            ag_tol = ({"rtol": 0, "atol": 0} if wire is None
                      else {"rtol": 0.02, "atol": 0.05})
            for rr in range(p):
                np.testing.assert_allclose(
                    ag[rr].reshape(p, BLK).astype(np.float64),
                    blocks.astype(np.float64), **ag_tol,
                    err_msg=f"hierarchical AG[{tag}] p={p}")
            # AR: replicated full reduce (bitwise-replicated even on the
            # wire path — all ranks dequantize identical codes).
            ar = np.asarray(_shmap2(
                mesh, lambda v: C.hierarchical_allreduce(v, axes, **kw),
                cv)(jnp.asarray(xg)))
            for rr in range(p):
                np.testing.assert_allclose(
                    ar[rr].astype(np.float64), ref, **tol,
                    err_msg=f"hierarchical AR[{tag}] p={p}")
                np.testing.assert_array_equal(ar[rr], ar[0])
            n_cases += 3
            # HLO structure: nested rounds = sum over axes (Theorem 1/2
            # per axis).
            n_rs = _n_collective_permutes(
                _shmap2(mesh, lambda v: C.hierarchical_reduce_scatter(
                    v, axes, **kw), cv), (p, n))
            n_ar = _n_collective_permutes(
                _shmap2(mesh, lambda v: C.hierarchical_allreduce(
                    v, axes, **kw), cv), (p, n))
            assert n_rs == rounds_want, \
                (f"hierarchical RS[{tag}] p={p}: {n_rs} collective-"
                 f"permutes, want {rounds_want}")
            assert n_ar == 2 * rounds_want, \
                (f"hierarchical AR[{tag}] p={p}: {n_ar} collective-"
                 f"permutes, want {2 * rounds_want}")
            results[tag] = (n_rs, n_ar)
            if verbose:
                print(f"ok: hierarchical[{tag}] p={p} RS/AG/AR "
                      f"(rounds {n_rs}/{n_ar})")
    return {"mesh": (a, b), "n_cases": n_cases, "rounds": results}


# ---------------------------------------------------------------------------
# Elastic re-plan conformance (device-free)
# ---------------------------------------------------------------------------

def run_elastic_replan(p: int, verbose: bool = False) -> dict:
    """Every uniform sweep spec must re-plan cleanly at resized worlds —
    shrink, grow, and odd p' (the any-p property the elastic controller
    leans on) — passing the same static verifier ``build_zero1`` runs as
    pre-flight, and selective invalidation of the old world's cache
    entries must not disturb the fresh plans.  Pure schedule work: no
    devices, microseconds per (spec, p').
    """
    from repro.analysis.verify import assert_verified
    from repro.core.plan import plan

    specs = []
    for case in sweep_cases(p):
        sp = case_spec(case, p)
        # counts/group are sized for THIS p — an elastic re-plan carries
        # the SAME spec to a new world, so only world-free specs apply
        # (grad-sync specs are exactly this shape).
        if sp.counts is None and sp.group is None and sp not in specs:
            specs.append(sp)
    worlds = sorted({w for w in (max(2, p - 1), p + 1, 3, 2 * p)
                     if w != p})
    n_replans = 0
    for sp in specs:
        plan(sp, p=p, axis_name=AXIS)  # the "old world" entry
        for p2 in worlds:
            assert_verified(plan(sp, p=p2, axis_name=AXIS))
            n_replans += 1
        evicted = plan.invalidate(p=p, axis_name=AXIS)
        assert evicted >= 1, f"{sp}: old-world plan not evicted"
        for p2 in worlds:  # fresh plans survive the selective eviction
            assert plan(sp, p=p2, axis_name=AXIS) is \
                plan(sp, p=p2, axis_name=AXIS), \
                f"{sp}: p'={p2} plan lost cache identity after invalidate"
        # rebuilding the evicted world must verify again (p -> p' -> p)
        assert_verified(plan(sp, p=p, axis_name=AXIS))
    if verbose:
        print(f"ok: elastic re-plan p={p} -> p'={worlds}: "
              f"{len(specs)} specs x {len(worlds)} worlds verified, "
              f"selective eviction clean")
    return {"n_specs": len(specs), "worlds": worlds,
            "n_replans": n_replans}


# ---------------------------------------------------------------------------
# Broadcast plan kind (Träff, arXiv:2407.18004) — all-broadcast
# ---------------------------------------------------------------------------

BROADCAST_SCHEDULES = OPTIMAL_SCHEDULES + ("fully_connected",)


def run_broadcast(p: int, mesh, verbose: bool = False) -> dict:
    """``kind="broadcast"`` conformance: numeric exactly-once delivery
    and HLO round counts.

    Per schedule × dtype: every rank contributes a (BLK, 2) block; the
    gathered (p*BLK, 2) output must hold rank j's block at row-block j,
    BITWISE, and be replicated across ranks (payloads move uncompressed
    — weight fan-out must be bit-exact).  The lowered HLO must contain
    exactly one collective-permute per schedule round — ceil(log2 p)
    for halving/power2, the broadcast paper's lower bound at any p.
    """
    from repro.analysis.verify import assert_verified
    from repro.core.plan import plan
    rng = np.random.default_rng(777 + p)
    n_cases = 0
    rounds: dict[str, int] = {}
    for sched in BROADCAST_SCHEDULES:
        spec = CollectiveSpec(kind="broadcast", schedule=sched)
        assert_verified(plan(spec, p=p, axis_name=AXIS))
        fn = lambda v, spec=spec: C.broadcast(v, AXIS, spec=spec)
        for dtype in ("float32", "int32"):
            xg = (rng.standard_normal((p, BLK, 2)).astype(dtype)
                  if dtype == "float32" else
                  rng.integers(-50, 50, (p, BLK, 2)).astype(dtype))
            out = np.asarray(_shmap1(mesh, fn)(jnp.asarray(xg)))
            want = xg.reshape(p * BLK, 2)
            for r in range(p):
                np.testing.assert_array_equal(
                    out[r].reshape(p * BLK, 2), want,
                    err_msg=f"broadcast[{sched}:{dtype}] p={p} rank {r}")
            n_cases += 1
        want_rounds = schedule_rounds(p, sched)
        if sched in OPTIMAL_SCHEDULES:
            assert want_rounds == ceil_log2(p)
        n_cp = count_collective_permutes(mesh, p, fn)
        assert n_cp == want_rounds, \
            (f"broadcast[{sched}] p={p}: {n_cp} collective-permutes, "
             f"want {want_rounds} (one ppermute per round)")
        rounds[sched] = n_cp
        if verbose:
            print(f"ok: broadcast[{sched}] p={p}: bitwise all-delivery, "
                  f"HLO cp={n_cp} (ceil_log2={ceil_log2(p)})")
    return {"n_cases": n_cases, "rounds": rounds}


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_sweep(p: int, mesh=None, verbose: bool = False) -> dict:
    """Full conformance sweep at axis size p (requires >= p devices)."""
    if mesh is None:
        mesh = compat.make_mesh((p,), (AXIS,))
    rng = np.random.default_rng(1234 + p)
    cases = sweep_cases(p)
    for case in cases:
        run_case(mesh, p, case, rng)
        if verbose:
            print(f"ok: {case.label}")
    rounds = check_round_counts(mesh, p)
    if verbose:
        for sched, (n_rs, n_ar) in rounds.items():
            print(f"ok: HLO rounds p={p} {sched}: RS={n_rs} AR={n_ar} "
                  f"(ceil_log2={ceil_log2(p)})")
    nonuni = run_nonuniform(p, mesh, verbose=verbose)
    a2a = run_alltoall(p, mesh, verbose=verbose)
    bcast = run_broadcast(p, mesh, verbose=verbose)
    hier = run_hierarchical(p, verbose=verbose)
    elastic = run_elastic_replan(p, verbose=verbose)
    return {"p": p, "n_cases": len(cases), "rounds": rounds,
            "nonuniform": nonuni, "alltoall": a2a, "broadcast": bcast,
            "hierarchical": hier, "elastic": elastic}


def main(argv=None) -> int:
    """CLI: run the full conformance matrix at ``argv[0]`` ranks
    (default 8) on fake devices; exit 0 iff every case passes."""
    argv = argv if argv is not None else sys.argv[1:]
    p = int(argv[0]) if argv else 8
    if jax.device_count() < p:
        print(f"need {p} devices, have {jax.device_count()} "
              f"(set XLA_FLAGS=--xla_force_host_platform_device_count={p})")
        return 2
    report = run_sweep(p, verbose=True)
    hier = report.get("hierarchical")
    hier_note = (f", hierarchical {hier['mesh'][0]}x{hier['mesh'][1]}: "
                 f"{hier['n_cases']} cases" if hier else "")
    nonuni = report["nonuniform"]
    a2a = report["alltoall"]
    bcast = report["broadcast"]
    el = report["elastic"]
    print(f"CONFORMANCE OK (p={p}, {report['n_cases']} cases, "
          f"{len(report['rounds'])} schedules, "
          f"{nonuni['n_cases']} non-uniform cases, "
          f"{a2a['n_cases']} alltoall cases, "
          f"{bcast['n_cases']} broadcast cases, "
          f"{el['n_replans']} elastic re-plans{hier_note})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
