"""Circulant-graph skip schedules for Träff's reduce-scatter / allreduce.

The paper's Algorithm 1 computes skips by repeated halving with round-up:
``s_0 = p, s_{k+1} = ceil(s_k / 2)`` until 1 — giving exactly
``ceil(log2 p)`` communication rounds for ANY p.  Corollary 2 generalises:
any strictly decreasing sequence ``s_0 > s_1 > ... > s_{q-1} = 1`` works
provided every ``0 < i < p`` is a sum of DISTINCT skips.

This module is pure Python (trace-time only): schedules are static with
respect to jit, so every round of the collective lowers to a static-slice
+ collective-permute pair.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Callable, Sequence


def ceil_log2(p: int) -> int:
    """ceil(log2 p) for p >= 1 (0 rounds for p == 1)."""
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    return (p - 1).bit_length()


# ---------------------------------------------------------------------------
# Skip-sequence constructors (Corollary 2 family)
# ---------------------------------------------------------------------------

def halving_skips(p: int) -> tuple[int, ...]:
    """The paper's schedule: repeated halving of p with round-up.

    Returns the per-round skips ``(s_1, s_2, ..., s_q)`` — i.e. the value
    ``s`` AFTER the halving in each while-iteration of Algorithm 1; the
    send in round k uses skip ``s_k`` and block range [s_k, s_{k-1}).
    len == ceil_log2(p).
    """
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    skips = []
    s = p
    while s > 1:
        s = (s + 1) // 2
        skips.append(s)
    return tuple(skips)


def power2_skips(p: int) -> tuple[int, ...]:
    """Straight power-of-two schedule (Bruck-style, paper §2.1 Examples).

    s_0 = p and s_k = largest power of two < s_{k-1}.  Also ceil(log2 p)
    rounds, but block runs can be longer than ceil(p/2) (paper §3 remark).
    """
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    skips = []
    s = p
    while s > 1:
        nxt = 1 << (s - 1).bit_length() - 1  # largest power of two < s
        skips.append(nxt)
        s = nxt
    return tuple(skips)


def fully_connected_skips(p: int) -> tuple[int, ...]:
    """The folklore p-1-round schedule (paper §2.1 Examples): p-1, ..., 1."""
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    return tuple(range(p - 1, 0, -1))


def sqrt_skips(p: int) -> tuple[int, ...]:
    """O(sqrt p)-round schedule (paper §2.1 Examples).

    s_k = p - k*ceil(sqrt p) while > ceil(sqrt p), then halving below.
    """
    if p < 1:
        raise ValueError(f"p must be >= 1, got {p}")
    if p == 1:
        return ()
    c = math.isqrt(p - 1) + 1  # ceil(sqrt(p)) for non-squares; >= 1
    skips: list[int] = []
    s = p - c
    while s > c:
        skips.append(s)
        s -= c
    # Finish with the halving scheme starting from the previous value.
    prev = skips[-1] if skips else p
    s = prev
    while s > 1:
        s = (s + 1) // 2
        if not skips or s < skips[-1]:
            skips.append(s)
    if not skips:
        skips = [1]
    if skips[-1] != 1:
        skips.append(1)
    return tuple(skips)


def two_level_skips(p: int, group: int) -> tuple[int, ...]:
    """Topology-decomposed schedule for hierarchical networks.

    For a folded super-axis of p = n_groups * group ranks where
    consecutive `group` ranks are co-located (e.g. one pod), emit the
    small (intra-group) skips FIRST so that early rounds (which move the
    most blocks under halving ordering reversal) stay on fast links, then
    the large inter-group skips.  Sequence: halving skips of `group`
    (intra), then group * halving skips of n_groups (inter).  Every
    i < p is representable: i = a + group*b with a < group, b < n_groups,
    both greedily representable in their own halving systems.

    Returned in DECREASING order as Corollary 2 requires; the decomposition
    property is what matters, and it holds because the two systems are
    disjoint scales.
    """
    if p % group != 0:
        raise ValueError(f"group {group} must divide p {p}")
    ngroups = p // group
    intra = halving_skips(group)
    inter = tuple(s * group for s in halving_skips(ngroups))
    skips = tuple(sorted(set(intra) | set(inter), reverse=True))
    if p > 1 and (not skips or skips[-1] != 1):
        raise AssertionError("two_level schedule must end at 1")
    return skips


SCHEDULES: dict[str, Callable[[int], tuple[int, ...]]] = {
    "halving": halving_skips,
    "power2": power2_skips,
    "fully_connected": fully_connected_skips,
    "sqrt": sqrt_skips,
}


def get_skips(p: int, schedule: str = "halving", *, group: int | None = None
              ) -> tuple[int, ...]:
    """Per-round skip distances of ``schedule`` at ``p`` ranks — the
    s_k of Corollary 2; ``len(get_skips(p, s))`` is the round count."""
    if schedule == "two_level":
        if group is None:
            raise ValueError("two_level schedule needs group=")
        return two_level_skips(p, group)
    try:
        fn = SCHEDULES[schedule]
    except KeyError:
        raise ValueError(
            f"unknown schedule {schedule!r}; have {sorted(SCHEDULES)} + two_level"
        ) from None
    return fn(p)


# ---------------------------------------------------------------------------
# Corollary-2 validity and structural properties
# ---------------------------------------------------------------------------

def decompose(i: int, skips: Sequence[int]) -> tuple[int, ...]:
    """Greedy decomposition of i as a sum of distinct skips (largest first).

    Raises ValueError if the greedy strategy fails; `is_valid_schedule`
    falls back to exact subset-sum in that case.
    """
    rem = i
    used = []
    for s in sorted(skips, reverse=True):
        if s <= rem:
            rem -= s
            used.append(s)
    if rem != 0:
        raise ValueError(f"greedy decomposition of {i} failed for skips {skips}")
    return tuple(used)


def _subset_sum_reachable(p: int, skips: Sequence[int]) -> bool:
    """Exact check: every 0 < i < p is a sum of distinct skips."""
    reach = 1  # bitmask; bit i set <=> i reachable
    for s in skips:
        reach |= reach << s
    mask = (1 << p) - 1
    return (reach & mask) == mask


def is_valid_schedule(p: int, skips: Sequence[int]) -> bool:
    """Corollary 2 precondition check.

    Beyond the paper's stated condition (every 0 < i < p is a sum of
    distinct skips) we also require the *fold-liveness* condition
    ``s_{k-1} <= 2 * s_k`` (with s_0 = p): in round k the received blocks
    are partial sums for destination offsets [0, s_{k-1} - s_k) and MUST
    fold into still-live blocks R[j], j < s_k.  The paper leaves this
    implicit (all its example schedules satisfy it); without it the
    algorithm would fold into already-sent blocks and lose contributions.
    """
    if p == 1:
        return len(skips) == 0
    sk = list(skips)
    if sorted(sk, reverse=True) != sk or len(set(sk)) != len(sk):
        return False
    if sk[-1] != 1:
        return False
    prev = p
    for s in sk:
        if prev > 2 * s:  # fold-liveness (see docstring)
            return False
        prev = s
    return _subset_sum_reachable(p, sk)


@dataclass(frozen=True)
class RoundPlan:
    """One communication round of Algorithm 1 (forward direction).

    send block range [lo, hi) to rank (r + skip) mod p;
    receive same count from (r - skip) mod p; reduce into [0, hi-lo).
    """
    skip: int
    lo: int
    hi: int

    @property
    def nblocks(self) -> int:
        return self.hi - self.lo


@lru_cache(maxsize=4096)
def reduce_scatter_plan(p: int, schedule: str = "halving",
                        group: int | None = None) -> tuple[RoundPlan, ...]:
    """Round plans for Algorithm 1 under any Corollary-2 schedule.

    For the halving schedule this reproduces the paper exactly:
    round k sends R[s_{k+1} .. s_k - 1].  For a general valid schedule
    with skips s_1 > s_2 > ... > s_q = 1 (we prepend s_0 = p), round k
    sends R[s_k .. s_{k-1} - 1] to (r + s_k) mod p.

    Total blocks sent = sum (s_{k-1} - s_k) = p - 1.   (Theorem 1)
    """
    skips = get_skips(p, schedule, group=group)
    if p > 1 and not is_valid_schedule(p, skips):
        raise ValueError(f"schedule {schedule} invalid for p={p}: {skips}")
    plans = []
    prev = p
    for s in skips:
        plans.append(RoundPlan(skip=s, lo=s, hi=prev))
        prev = s
    return tuple(plans)


def allgather_plan(p: int, schedule: str = "halving",
                   group: int | None = None) -> tuple[RoundPlan, ...]:
    """Reversed skip stack (Algorithm 2's second phase).

    Round with skip s sends R[0 .. s'-s-1] toward (r - s) mod p and
    receives into R[s .. s'-1] from (r + s) mod p, replaying the RS
    rounds backwards.
    """
    return tuple(reversed(reduce_scatter_plan(p, schedule, group)))


def total_blocks(plans: Sequence[RoundPlan]) -> int:
    """Total blocks sent across ``plans`` (Theorem 1 volume: p-1 for a
    full reduce-scatter plan)."""
    return sum(pl.nblocks for pl in plans)


@lru_cache(maxsize=4096)
def alltoall_moves(p: int, schedule: str = "halving",
                   group: int | None = None
                   ) -> tuple[tuple[int, tuple[tuple[int, int], ...]], ...]:
    """Entry trajectories of alltoall-by-concatenation (paper §4).

    In the Bruck-style alltoall, the payload addressed from ``src`` to
    ``dst`` starts in rotated slot ``d = (dst - src) mod p`` and, whenever
    its current slot lies in a round's send window ``[skip, prev)``, hops
    forward by ``skip`` (slot decreases by ``skip``).  The whole walk is
    trace-time data: this returns, per round, ``(skip, moved)`` where
    ``moved`` is the tuple of ``(d, shift)`` pairs — the destination
    offsets whose entries hop this round and the total shift already
    applied to them, i.e. the entry for offset ``d`` currently sits on
    rank ``(src + shift) mod p``.  After the last round every offset has
    reached slot 0 with total shift ``d`` — delivered (asserted).

    Consumed by the plan layer (alltoallv row tables) and the cost model
    (the hop-through-intermediate-ranks β volume: the classic Bruck
    amplification, sum(len(moved)) block sends per rank instead of p-1).
    """
    plans = reduce_scatter_plan(p, schedule, group)
    slot = list(range(p))
    shift = [0] * p
    rounds = []
    for pl in plans:
        moved = []
        for d in range(1, p):
            if pl.lo <= slot[d] < pl.hi:
                moved.append((d, shift[d]))
                slot[d] -= pl.skip
                shift[d] += pl.skip
        rounds.append((pl.skip, tuple(moved)))
    assert all(s == 0 for s in slot), \
        f"alltoall trajectories must end in slot 0 (p={p}, {schedule})"
    assert all(shift[d] == d for d in range(p)), \
        f"total shift must equal the destination offset (p={p}, {schedule})"
    return tuple(rounds)


def max_block_run(plans: Sequence[RoundPlan]) -> int:
    """Longest contiguous block sequence sent in any round.

    Paper §3: for the halving scheme this is <= ceil(p/2)."""
    return max((pl.nblocks for pl in plans), default=0)


# ---------------------------------------------------------------------------
# Spanning-forest tracer (proof-of-invariant instrumentation, §2.1)
# ---------------------------------------------------------------------------

def reduction_tree(p: int, schedule: str = "halving") -> dict[int, tuple[int, ...]]:
    """For destination rank r = 0 (wlog), trace which source ranks' partial
    sums arrive INTO W = R[0] in each round — the paper's worked example
    (p = 22, rank 21; shift by the rank to compare).

    By SPMD symmetry every rank's buffer covers rank-invariant *offset*
    sets: shape[i] = set of offsets o such that on rank r, R[i] currently
    sums V_{(r+o) mod p}.  Initially shape[i] = {0} (each block is the
    rank's own input).  On receive with skip s, shape[j] |= shape[s+j] - s.

    Returns {round_index: sorted tuple of source ranks (rank-0 view) whose
    inputs are folded into W in that round}.  Union over rounds + {0} ==
    all p ranks, each exactly once (Theorem 1's spanning tree).

    NOTE: the paper's displayed p=22 grouping has a small typo — the pair
    (x_20 + x_9) is shown on the skip-2 line but arrives with the final
    skip-1 round (sender 19's R[2] holds only 6 sources when sent; there is
    no skip-path from rank 20 to rank 19).  Our test pins the corrected
    grouping; totals (1+2+4+6+8 = 21 = p-1) match the paper either way.
    """
    plans = reduce_scatter_plan(p, schedule)
    shape: list[set[int]] = [{0} for _ in range(p)]
    arrivals: dict[int, tuple[int, ...]] = {}
    for k, pl in enumerate(plans):
        s = pl.skip
        incoming = [{(o - s) % p for o in shape[pl.lo + j]}
                    for j in range(pl.nblocks)]
        arrivals[k] = tuple(sorted(incoming[0]))  # T[0] folds into W
        for j, inc in enumerate(incoming):
            assert not (shape[j] & inc), "forest subtrees must be disjoint"
            shape[j] |= inc
    all_sources = set().union(*[set(v) for v in arrivals.values()]) | {0}
    assert all_sources == set(range(p)), "spanning tree must cover all ranks"
    assert sum(len(v) for v in arrivals.values()) == p - 1
    return arrivals
