"""Core implementation of Träff 2024: optimal, non-pipelined reduce-scatter
and allreduce on circulant graphs, plus schedules, simulator, cost model and
the JAX shard_map collectives.

The collective API is plan/execute: declare a :class:`CollectiveSpec`,
compile it once with :func:`plan`, run ``plan.reduce_scatter(x)`` etc.
(see ``core/spec.py`` and ``core/plan.py``; ``core/collectives.py`` keeps
the backward-compatible kwarg wrappers)."""
from .spec import (  # noqa: F401
    DEFAULT_WIRE_GROUP,
    KINDS,
    WIRE_DTYPES,
    CollectiveSpec,
    as_spec,
)
from .plan import (  # noqa: F401
    A2APlan,
    BACKENDS,
    BlockLayout,
    CollectivePlan,
    RoundState,
    plan,
    plan_cache_clear,
    plan_cache_info,
)
from .schedule import (  # noqa: F401
    allgather_plan,
    alltoall_moves,
    ceil_log2,
    decompose,
    fully_connected_skips,
    get_skips,
    halving_skips,
    is_valid_schedule,
    max_block_run,
    power2_skips,
    reduce_scatter_plan,
    reduction_tree,
    sqrt_skips,
    total_blocks,
    two_level_skips,
    RoundPlan,
)
from .cost_model import (  # noqa: F401
    CommModel,
    a2a_round_entries,
    alltoallv_round_widths,
    nonuniform_round_widths,
    optimal_bucket_count,
    t_allgather,
    t_allreduce,
    t_bucketed_allreduce,
    t_alltoall,
    t_alltoallv,
    t_corollary1,
    t_corollary3_bound,
    t_reduce_scatter,
    t_ring_allreduce,
    t_ring_reduce_scatter,
)
