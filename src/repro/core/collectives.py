"""Träff's circulant-graph collectives as JAX shard_map primitives.

Every communication round of Algorithm 1/2 lowers to exactly one
``lax.ppermute`` (XLA ``collective-permute``) over a *static* slice of the
rotated block buffer — the TPU ICI executes a collective-permute as a
full-duplex send∥recv, which is precisely the paper's one-ported
bidirectional communication model.  The skip schedule is computed at trace
time (``p`` is static under SPMD), so the lowered HLO contains
``ceil(log2 p)`` collective-permutes for reduce-scatter and
``2*ceil(log2 p)`` for allreduce — Theorem 1/2 made visible in the IR
(asserted by tests and consumed by the roofline analysis).

All functions MUST be called inside a ``shard_map`` (or ``shard_map``-like)
context that binds ``axis_name``.  Baselines implemented alongside:

* ``ring_reduce_scatter`` / ``ring_allreduce`` — p-1 rounds, 1 ICI hop per
  round (bandwidth-optimal on a torus; the paper's [10,11,15] family).
* ``recursive_halving_reduce_scatter`` — power-of-two butterfly.
* ``xla_*`` — XLA's built-in psum / psum_scatter / all_gather for A/B tests.

Payload hooks (``compress``/``decompress``) implement per-round gradient
compression (beyond-paper, §Perf).  The first-class compressed path is
``wire_dtype="int8"``: each round's send payload becomes int8 codes +
per-group f32 scales packed into ONE int8 wire buffer (still exactly one
collective-permute per round), folded on receive by a single fused
dequantize-⊕(-requantize) pass — see the README's compressed wire format
section.

Every circulant collective takes ``use_fused_kernel`` (default ``None`` =
auto): ``True`` routes each round's local buffer work through the fused
Pallas round kernel (``kernels.fused_round``) — fold + next-round send
layout in one HBM pass instead of the slice → jnp-op → concat chain; the
lowered HLO keeps the exact same collective-permute count and the results
are bitwise-identical (the kernel body is static slicing around the same
⊕).  Auto enables Pallas on TPU under a native (post-0.4.x) shard_map
and keeps the jnp path everywhere else: on CPU the kernel would run in
interpret mode (validation, not speed), and the legacy 0.4.x shard_map
needs ``check_vma=False`` for pallas_call, so auto must not flip default
call sites onto it.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.kernels import (DEFAULT_GROUP, fused_round, fused_round_dq,
                           pack_wire, permute_rows, quantize_rows,
                           resolve_fused, unpack_wire)
from repro.kernels import ref as _kref
from .schedule import (allgather_plan, ceil_log2, reduce_scatter_plan)

Array = jax.Array
ReduceFn = Callable[[Array, Array], Array]

_REDUCERS: dict[str, ReduceFn] = {
    "add": lambda a, b: a + b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def _resolve_op(op) -> ReduceFn:
    if callable(op):
        return op
    try:
        return _REDUCERS[op]
    except KeyError:
        raise ValueError(f"unknown reduce op {op!r}") from None


def _as_blocks(x: Array, p: int) -> Array:
    """Reshape leading axis into (p, n/p, *rest). Requires divisibility."""
    n = x.shape[0]
    if n % p != 0:
        raise ValueError(
            f"leading dim {n} not divisible by axis size {p}; pad first "
            f"(see pad_to_multiple)")
    return x.reshape(p, n // p, *x.shape[1:])


def pad_to_multiple(x: Array, p: int) -> tuple[Array, int]:
    """Zero-pad the leading axis of ``x`` to a multiple of ``p``."""
    n = x.shape[0]
    pad = (-n) % p
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
    return x, pad


def _fwd_perm(p: int, s: int) -> list[tuple[int, int]]:
    """Data on rank i goes to rank (i + s) mod p  (paper's to-processor)."""
    return [(i, (i + s) % p) for i in range(p)]


WIRE_DTYPES = (None, "int8")


def _check_wire(wire_dtype, x: Array, op, compress, decompress=None) -> bool:
    """Validate the ``wire_dtype`` kwarg; returns True iff compression is
    requested.  int8 wire needs float payloads and a named ⊕ (the fused
    dequant-fold kernel has no callable-op form), and is mutually
    exclusive with the generic compress/decompress hooks."""
    if wire_dtype is None:
        return False
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(
            f"unknown wire_dtype {wire_dtype!r}; have {WIRE_DTYPES}")
    if compress is not None or decompress is not None:
        raise ValueError(
            "wire_dtype and compress/decompress hooks are mutually "
            "exclusive")
    if op is not None and not isinstance(op, str):
        raise ValueError(
            f"wire_dtype needs a named op ('add'/'max'/'min'), got {op!r}")
    if not jnp.issubdtype(x.dtype, jnp.floating):
        raise ValueError(
            f"wire_dtype='int8' needs a float payload, got {x.dtype}")
    return True


def _bwd_perm(p: int, s: int) -> list[tuple[int, int]]:
    """Data on rank i goes to rank (i - s) mod p  (allgather phase)."""
    return [(i, (i - s) % p) for i in range(p)]


# ---------------------------------------------------------------------------
# Algorithm 1 — reduce-scatter (partitioned all-reduce)
# ---------------------------------------------------------------------------

def circulant_reduce_scatter(
    x: Array,
    axis_name: str,
    *,
    schedule: str = "halving",
    op: str | ReduceFn = "add",
    group: int | None = None,
    compress: Callable[[Array], Any] | None = None,
    decompress: Callable[[Any], Array] | None = None,
    use_fused_kernel: bool | None = None,
    wire_dtype: str | None = None,
    wire_group: int = DEFAULT_GROUP,
) -> Array:
    """Paper Algorithm 1.  ``x``: per-rank input vector, leading dim n
    divisible by p.  Returns rank r's reduced block  (n/p, *rest):
    out_r = op-reduce_i  x_i[r-th block].

    Structure per round k (skips s_1 > ... > s_q from the schedule):
      send R[s_k : s_{k-1}] to (r + s_k) — one ppermute —
      fold the received blocks into R[0 : s_{k-1} - s_k].
    The live buffer shrinks from p blocks to 1; exactly p-1 blocks are
    sent/received/reduced per rank (Theorem 1).  ``group`` parameterizes
    the two_level schedule (intra-group size; ignored otherwise).

    With ``use_fused_kernel`` the per-round fold + next-send assembly runs
    as one Pallas kernel pass (see module docstring); the round structure
    and every ppermute are unchanged.

    ``wire_dtype="int8"`` (default ``None`` = off) compresses every
    round's send payload to int8 codes + per-group f32 scales packed into
    ONE int8 wire buffer (``wire_group`` elements per scale), cutting the
    β-term bytes ~4x at a bounded quantization error; accumulation stays
    f32 and the round/ppermute structure is unchanged.  Lossy — see the
    README's compressed-wire-format section.
    """
    wired = _check_wire(wire_dtype, x, op, compress, decompress)
    reduce_fn = _resolve_op(op)
    p = compat.axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    R = _as_blocks(x, p)
    # Rotated initial copy: R[i] = V[(r + i) mod p]   (paper: the gamma*m copy)
    R = jnp.roll(R, -r, axis=0)
    if wired:
        return _compressed_reduce_scatter_rounds(
            R, axis_name, p, schedule, group, op, wire_group,
            fused=resolve_fused(use_fused_kernel))
    if resolve_fused(use_fused_kernel) and isinstance(op, str):
        return _fused_reduce_scatter_rounds(
            R, axis_name, p, schedule, group, op, compress, decompress)
    if use_fused_kernel and not isinstance(op, str):
        # Explicit request only — auto silently keeps the jnp path.
        raise ValueError(
            "use_fused_kernel needs a named op ('add'/'max'/'min'), "
            f"got callable {op!r}")
    for pl in reduce_scatter_plan(p, schedule, group):
        payload = R[pl.lo:pl.hi]
        if compress is not None:
            payload = compress(payload)
        T = compat.ppermute(payload, axis_name, _fwd_perm(p, pl.skip))
        if decompress is not None:
            T = decompress(T)
        nb = pl.nblocks
        head = reduce_fn(R[:nb], T)
        R = head if nb == pl.lo else jnp.concatenate([head, R[nb:pl.lo]], axis=0)
    return R[0]


def _fused_reduce_scatter_rounds(R: Array, axis_name: str, p: int,
                                 schedule: str, group: int | None, op: str,
                                 compress, decompress) -> Array:
    """Algorithm 1's round loop on the fused Pallas kernel.

    The rotated block buffer is viewed as 2-D ``(blocks, block_numel)``;
    after the prologue slice every round is ppermute → fused_round, with
    the kernel emitting both the shrunken live buffer and the next
    round's contiguous payload.  Identical values and ppermute sequence
    to the jnp path — only the local data movement is fused.
    """
    blk_shape = R.shape[1:]
    R2 = R.reshape(p, -1)
    plans = reduce_scatter_plan(p, schedule, group)
    live = R2[: plans[0].lo]
    send = R2[plans[0].lo : plans[0].hi]
    for k, pl in enumerate(plans):
        payload = send if compress is None else compress(send)
        T = compat.ppermute(payload, axis_name, _fwd_perm(p, pl.skip))
        if decompress is not None:
            T = decompress(T)
        if T.dtype != live.dtype:
            # Match the jnp path, whose concatenate promotes the buffer
            # (e.g. bf16 live vs f32 decompressed payload).
            dt = jnp.result_type(live.dtype, T.dtype)
            live, T = live.astype(dt), T.astype(dt)
        next_lo = plans[k + 1].lo if k + 1 < len(plans) else pl.lo
        live, send = fused_round(live, T, nb=pl.nblocks, next_lo=next_lo,
                                 op=op)
    return live[0].reshape(blk_shape)


def _compressed_reduce_scatter_rounds(R: Array, axis_name: str, p: int,
                                      schedule: str, group: int | None,
                                      op: str, wire_group: int,
                                      fused: bool) -> Array:
    """Algorithm 1's round loop on the int8 wire format.

    The rotated block buffer is promoted to an f32 (blocks, block_numel)
    accumulation buffer whose columns are padded to a whole number of
    quantization groups.  Every round then ppermutes ONE packed int8
    buffer ([codes | scale bytes], see kernels.quantize) and runs a
    single dequantize + ⊕-fold + requantize-next-send pass — the Pallas
    ``fused_round_dq`` kernel when ``fused``, its jnp oracle otherwise
    (bitwise-identical arithmetic; both jitted).  Round count and
    ppermute sequence match the uncompressed path exactly.
    """
    blk_shape, out_dtype = R.shape[1:], R.dtype
    R2 = R.reshape(p, -1).astype(jnp.float32)
    cols = R2.shape[1]
    g = min(wire_group, cols)
    pc = (-cols) % g
    if pc:
        R2 = jnp.pad(R2, ((0, 0), (0, pc)))
    plans = reduce_scatter_plan(p, schedule, group)
    live = R2[: plans[0].lo]
    first = R2[plans[0].lo : plans[0].hi]
    if fused:
        codes, scales = quantize_rows(first, group=g)
    else:
        codes, scales = _kref.quantize_ref(first, group=g)
    wire = pack_wire(codes, scales)
    for k, pl in enumerate(plans):
        Tw = compat.ppermute(wire, axis_name, _fwd_perm(p, pl.skip))
        rc, rs = unpack_wire(Tw, live.shape[1], group=g)
        next_lo = plans[k + 1].lo if k + 1 < len(plans) else pl.lo
        if fused:
            live, send = fused_round_dq(live, rc, rs, nb=pl.nblocks,
                                        next_lo=next_lo, op=op, group=g)
        else:
            live, send = _kref.fused_round_dq_ref(live, rc, rs,
                                                  nb=pl.nblocks,
                                                  next_lo=next_lo, op=op,
                                                  group=g)
        if send is not None:
            wire = pack_wire(*send)
    out = live[0]
    if pc:
        out = out[:cols]
    return out.reshape(blk_shape).astype(out_dtype)


# ---------------------------------------------------------------------------
# Allgather — Algorithm 2's second phase (reversed skip stack), standalone
# ---------------------------------------------------------------------------

def circulant_allgather(
    x: Array,
    axis_name: str,
    *,
    schedule: str = "halving",
    group: int | None = None,
    use_fused_kernel: bool | None = None,
    wire_dtype: str | None = None,
    wire_group: int = DEFAULT_GROUP,
) -> Array:
    """Gather rank blocks in rank order.  ``x``: rank r's block
    (blk, *rest); returns (p*blk, *rest) identical on all ranks.

    Replays the reduce-scatter skips in reverse (the paper's stack): with
    previous bound s' and skip s, send R[0 : s'-s] toward (r - s) and
    receive into R[s : s'] from (r + s).  The buffer grows from 1 block to
    p; p-1 blocks communicated per rank.

    Allgather has no ⊕, so its fused form needs no Pallas: the growing
    concat chain (which recopies the whole buffer every round — O(p log p)
    block traffic) becomes static in-place updates of one preallocated
    (p, blk) buffer (O(p) traffic; XLA turns the static-index
    dynamic-update-slice into an in-place write under jit).  Send payloads
    are buffer prefixes, already contiguous.
    """
    wired = _check_wire(wire_dtype, x, None, None)
    p = compat.axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    if wired:
        return _compressed_allgather_rounds(
            x, axis_name, p, r, schedule, group, wire_group,
            fused=resolve_fused(use_fused_kernel))
    if resolve_fused(use_fused_kernel):
        buf = jnp.zeros((p, *x.shape), x.dtype)
        buf = lax.dynamic_update_slice_in_dim(buf, x[None], 0, axis=0)
        for pl in allgather_plan(p, schedule, group):
            payload = lax.slice_in_dim(buf, 0, pl.nblocks, axis=0)
            T = compat.ppermute(payload, axis_name, _bwd_perm(p, pl.skip))
            # Received blocks land at rows [lo, hi) = [skip, prev bound).
            buf = lax.dynamic_update_slice_in_dim(buf, T, pl.lo, axis=0)
        out = jnp.roll(buf, r, axis=0)
        return out.reshape(p * x.shape[0], *x.shape[1:])
    R = x[None]  # (1, blk, *rest) — rotated coords: R[i] = block of (r+i)
    for pl in allgather_plan(p, schedule, group):
        payload = R[:pl.nblocks]
        T = compat.ppermute(payload, axis_name, _bwd_perm(p, pl.skip))
        R = jnp.concatenate([R, T], axis=0)
    out = jnp.roll(R, r, axis=0)  # un-rotate: out[j] = block of rank j
    return out.reshape(p * x.shape[0], *x.shape[1:])


def _compressed_allgather_rounds(x: Array, axis_name: str, p: int, r,
                                 schedule: str, group: int | None,
                                 wire_group: int, fused: bool) -> Array:
    """Allgather on the int8 wire format.

    Allgather has no ⊕, so each rank quantizes its own block ONCE; the
    rounds then move the packed int8 wire rows unmodified (every element
    is quantized exactly once — the error is a single quantization step).
    ``fused`` selects the preallocated-buffer round structure (static
    in-place updates) vs the concat chain — both move identical bytes and
    one ppermute per round.  All ranks dequantize the same codes, so the
    gathered result is bitwise-replicated (Theorem 2's invariant
    survives compression).
    """
    x2 = x.reshape(1, -1).astype(jnp.float32)
    cols = x2.shape[1]
    g = min(wire_group, cols)
    pc = (-cols) % g
    if pc:
        x2 = jnp.pad(x2, ((0, 0), (0, pc)))
    if fused:
        codes, scales = quantize_rows(x2, group=g)
    else:
        codes, scales = _kref.quantize_ref(x2, group=g)
    row = pack_wire(codes, scales)                 # (1, wc) int8
    wc = row.shape[1]
    if fused:
        buf = jnp.zeros((p, wc), jnp.int8)
        buf = lax.dynamic_update_slice_in_dim(buf, row, 0, axis=0)
        for pl in allgather_plan(p, schedule, group):
            payload = lax.slice_in_dim(buf, 0, pl.nblocks, axis=0)
            T = compat.ppermute(payload, axis_name, _bwd_perm(p, pl.skip))
            buf = lax.dynamic_update_slice_in_dim(buf, T, pl.lo, axis=0)
    else:
        buf = row
        for pl in allgather_plan(p, schedule, group):
            payload = buf[:pl.nblocks]
            T = compat.ppermute(payload, axis_name, _bwd_perm(p, pl.skip))
            buf = jnp.concatenate([buf, T], axis=0)
    codes, scales = unpack_wire(buf, x2.shape[1], group=g)
    vals = _kref.dequant_ref(codes, scales, group=g)   # (p, cols_pad) f32
    if pc:
        vals = vals[:, :cols]
    out = jnp.roll(vals, r, axis=0)  # un-rotate: out[j] = block of rank j
    return out.reshape(p * x.shape[0], *x.shape[1:]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Algorithm 2 — allreduce
# ---------------------------------------------------------------------------

def circulant_allreduce(
    x: Array,
    axis_name: str,
    *,
    schedule: str = "halving",
    op: str | ReduceFn = "add",
    group: int | None = None,
    compress: Callable[[Array], Any] | None = None,
    decompress: Callable[[Any], Array] | None = None,
    use_fused_kernel: bool | None = None,
    wire_dtype: str | None = None,
    wire_group: int = DEFAULT_GROUP,
) -> Array:
    """Paper Algorithm 2: reduce-scatter + reversed allgather.
    2*ceil(log2 p) ppermutes, 2(p-1) blocks moved, p-1 reductions/rank.
    ``wire_dtype="int8"`` compresses both phases (RS partial sums are
    requantized per round; AG blocks are quantized once)."""
    w = circulant_reduce_scatter(
        x, axis_name, schedule=schedule, op=op, group=group,
        compress=compress, decompress=decompress,
        use_fused_kernel=use_fused_kernel, wire_dtype=wire_dtype,
        wire_group=wire_group)
    return circulant_allgather(w, axis_name, schedule=schedule, group=group,
                               use_fused_kernel=use_fused_kernel,
                               wire_dtype=wire_dtype, wire_group=wire_group)


# ---------------------------------------------------------------------------
# All-to-all by concatenation (paper §4)
# ---------------------------------------------------------------------------

def circulant_alltoall(
    x: Array,
    axis_name: str,
    *,
    schedule: str = "halving",
    use_fused_kernel: bool | None = None,
) -> Array:
    """All-to-all in ceil(log2 p) rounds: Algorithm 1 with ⊕ =
    concatenation.  ``x``: (p, blk, *rest); row j is rank r's payload for
    rank j.  Returns (p, blk, *rest); row j is rank j's payload for rank r.

    Trace-time bookkeeping keeps, per live slot, the list of (source-offset,
    array) pairs — the concatenation operator materialized as Python lists
    of same-shaped arrays, so every round is still a single fused ppermute
    over a stacked payload.  Volume is (p/2)*ceil(log2 p) blocks per rank
    (the classic Bruck trade-off: round-optimal, not volume-optimal).

    The fused form keeps each slot as ONE stacked (count, blk) array —
    per-round send assembly concatenates a few contiguous slot buffers
    instead of restacking individual blocks — and lays the final slot into
    source order with one Pallas row-permutation pass (the permutation is
    trace-time metadata).
    """
    p = compat.axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    rot = jnp.roll(x, -r, axis=0)  # rot[i] = payload for dest (r+i)
    if resolve_fused(use_fused_kernel):
        return _fused_alltoall_rounds(rot, axis_name, p, schedule, r,
                                      x.shape[1:])
    # slots[i]: list of (offset o, payload) — payload originated at (r+o).
    slots: list[list[tuple[int, Array]]] = [[(0, rot[i])] for i in range(p)]
    for pl in reduce_scatter_plan(p, schedule):
        s = pl.skip
        # Stack every array sent this round into ONE ppermute payload.
        send_entries = [e for i in range(pl.lo, pl.hi) for e in slots[i]]
        stacked = jnp.stack([a for (_, a) in send_entries], axis=0)
        T = compat.ppermute(stacked, axis_name, _fwd_perm(p, s))
        # Unstack with shifted source offsets; ⊕ = list concatenation.
        idx = 0
        for j in range(pl.nblocks):
            src_slot = pl.lo + j
            for (o, _) in slots[src_slot]:
                slots[j].append((((o - s) % p), T[idx]))
                idx += 1
        assert idx == len(send_entries)
        del slots[pl.lo:]  # slots [lo, hi) were sent; live = [0, s)
    entries = slots[0]
    assert len(entries) == p, f"expected {p} payloads, got {len(entries)}"
    ordered = [a for (_, a) in sorted(entries, key=lambda e: e[0])]
    stacked = jnp.stack(ordered, axis=0)  # stacked[o] = payload from (r+o)
    return jnp.roll(stacked, r, axis=0)   # row j = payload from rank j


def _fused_alltoall_rounds(rot: Array, axis_name: str, p: int, schedule: str,
                           r, blk_shape: tuple) -> Array:
    """Bruck-style rounds over stacked slot buffers (fused alltoall).

    slots[i] is one (count_i, blk) array; offs[i] is the parallel Python
    list of source offsets.  Entry order inside each slot matches the
    unfused list-of-arrays path exactly, so results are bitwise-equal.
    """
    rot2 = rot.reshape(p, -1)
    slots = [lax.slice_in_dim(rot2, i, i + 1, axis=0) for i in range(p)]
    offs: list[list[int]] = [[0] for _ in range(p)]
    for pl in reduce_scatter_plan(p, schedule):
        s = pl.skip
        send = (slots[pl.lo] if pl.nblocks == 1 else
                jnp.concatenate(slots[pl.lo:pl.hi], axis=0))
        T = compat.ppermute(send, axis_name, _fwd_perm(p, s))
        idx = 0
        for j in range(pl.nblocks):
            src_slot = pl.lo + j
            cnt = len(offs[src_slot])
            piece = lax.slice_in_dim(T, idx, idx + cnt, axis=0)
            slots[j] = jnp.concatenate([slots[j], piece], axis=0)
            offs[j] = offs[j] + [(o - s) % p for o in offs[src_slot]]
            idx += cnt
        assert idx == T.shape[0]
        del slots[pl.lo:], offs[pl.lo:]
    assert slots[0].shape[0] == p, \
        f"expected {p} payloads, got {slots[0].shape[0]}"
    order = sorted(range(p), key=lambda i: offs[0][i])
    ordered = permute_rows(slots[0], order)  # ordered[o] = from (r+o)
    out = jnp.roll(ordered, r, axis=0)       # row j = payload from rank j
    return out.reshape(p, *blk_shape)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def ring_reduce_scatter(x: Array, axis_name: str, *,
                        op: str | ReduceFn = "add", **_ignored) -> Array:
    """Classic p-1-round ring reduce-scatter [Patarasuk-Yuan; paper §1].
    Volume-optimal, 1 ICI hop per round, latency linear in p.

    In rotated coordinates the schedule is static: at step t, send
    R[p-1-t] to rank r+1, receive the peer's partial for our R[p-2-t]."""
    reduce_fn = _resolve_op(op)
    p = compat.axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    R = jnp.roll(_as_blocks(x, p), -r, axis=0)
    perm = _fwd_perm(p, 1)
    buf = R[p - 1]
    for t in range(p - 1):
        got = compat.ppermute(buf, axis_name, perm)
        idx = p - 2 - t
        buf = reduce_fn(R[idx], got)
    return buf


def ring_allreduce(x: Array, axis_name: str, *,
                   op: str | ReduceFn = "add", **_ignored) -> Array:
    """Ring RS + ring allgather: 2(p-1) rounds, bandwidth-optimal."""
    p = compat.axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    w = ring_reduce_scatter(x, axis_name, op=op)
    # Ring allgather: pass blocks around; rank r starts with block r.
    blocks = [w]
    perm = _fwd_perm(p, 1)
    for t in range(p - 1):
        blocks.append(compat.ppermute(blocks[-1], axis_name, perm))
    # blocks[t] on rank r is block (r - t) mod p; assemble in rank order.
    stacked = jnp.stack(blocks[::-1], axis=0)  # [p-1-t] -> block r - t
    # stacked[i] = block (r + i - (p-1)) = (r + i + 1) mod p
    out = jnp.roll(stacked, r + 1, axis=0)
    return out.reshape(p * w.shape[0], *w.shape[1:])


def recursive_halving_reduce_scatter(x: Array, axis_name: str, *,
                                     op: str | ReduceFn = "add", **_ignored) -> Array:
    """Hypercube/butterfly reduce-scatter — power-of-two p ONLY (the
    classic algorithm whose non-pow2 awkwardness motivates the paper)."""
    reduce_fn = _resolve_op(op)
    p = compat.axis_size(axis_name)
    if p == 1:
        return x
    if p & (p - 1):
        raise ValueError(f"recursive halving needs power-of-two p, got {p}")
    r = lax.axis_index(axis_name)
    buf = _as_blocks(x, p)  # absolute block coords
    d = p // 2
    while d >= 1:
        lowhalf, highhalf = buf[: buf.shape[0] // 2], buf[buf.shape[0] // 2:]
        bit = (r // d) % 2  # traced scalar: which half this rank keeps
        send = jnp.where(bit == 1, lowhalf, highhalf)
        got = compat.ppermute(send, axis_name,
                              [(i, i ^ d) for i in range(p)])
        keep = jnp.where(bit == 1, highhalf, lowhalf)
        buf = reduce_fn(keep, got)
        d //= 2
    return buf[0]


def xla_reduce_scatter(x: Array, axis_name: str, **_) -> Array:
    p = compat.axis_size(axis_name)
    return lax.psum_scatter(_as_blocks(x, p), axis_name,
                            scatter_dimension=0, tiled=False)


def xla_allreduce(x: Array, axis_name: str, **_) -> Array:
    return lax.psum(x, axis_name)


def xla_allgather(x: Array, axis_name: str, **_) -> Array:
    return lax.all_gather(x, axis_name, axis=0, tiled=True)


# ---------------------------------------------------------------------------
# Dispatchers + multi-axis (hierarchical) wrappers
# ---------------------------------------------------------------------------

RS_IMPLS = {
    "circulant": circulant_reduce_scatter,
    "ring": ring_reduce_scatter,
    "recursive_halving": recursive_halving_reduce_scatter,
    "xla": xla_reduce_scatter,
}
AR_IMPLS = {
    "circulant": circulant_allreduce,
    "ring": ring_allreduce,
    "xla": xla_allreduce,
}
AG_IMPLS = {
    "circulant": circulant_allgather,
    "xla": xla_allgather,
}


def reduce_scatter(x, axis_name, impl="circulant", **kw):
    return RS_IMPLS[impl](x, axis_name, **kw)


def allreduce(x, axis_name, impl="circulant", **kw):
    return AR_IMPLS[impl](x, axis_name, **kw)


def allgather(x, axis_name, impl="circulant", **kw):
    return AG_IMPLS[impl](x, axis_name, **kw)


def hierarchical_reduce_scatter(x, axis_names: Sequence[str],
                                impl="circulant", **kw):
    """Nested RS over multiple mesh axes (e.g. ('data', 'pod')): RS over the
    fastest axis first, then the slower axis on the surviving 1/p_0 shard —
    large skips never cross the slow interconnect with more than m/p_0
    payload (multilane decomposition; DESIGN §2 assumption 2)."""
    out = x
    for ax in axis_names:
        out = reduce_scatter(out, ax, impl=impl, **kw)
    return out


def hierarchical_allgather(x, axis_names: Sequence[str],
                           impl="circulant", **kw):
    """Inverse of hierarchical_reduce_scatter (reverse axis order)."""
    out = x
    for ax in reversed(list(axis_names)):
        out = allgather(out, ax, impl=impl, **kw)
    return out


def hierarchical_allreduce(x, axis_names: Sequence[str],
                           impl="circulant", **kw):
    out = hierarchical_reduce_scatter(x, axis_names, impl=impl, **kw)
    return hierarchical_allgather(out, axis_names, impl=impl, **kw)
