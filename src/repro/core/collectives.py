"""Träff's circulant-graph collectives as JAX shard_map primitives.

Every communication round of Algorithm 1/2 lowers to exactly one
``lax.ppermute`` (XLA ``collective-permute``) over a *static* slice of the
rotated block buffer — the TPU ICI executes a collective-permute as a
full-duplex send∥recv, which is precisely the paper's one-ported
bidirectional communication model.  The skip schedule is computed at trace
time (``p`` is static under SPMD), so the lowered HLO contains
``ceil(log2 p)`` collective-permutes for reduce-scatter and
``2*ceil(log2 p)`` for allreduce — Theorem 1/2 made visible in the IR
(asserted by tests and consumed by the roofline analysis).

Since the plan/execute redesign this module is the THIN WRAPPER layer:
the round loops live in ``core.plan`` as backends of a compiled
:class:`~repro.core.plan.CollectivePlan`, and every function here just
assembles a :class:`~repro.core.spec.CollectiveSpec` and executes its
cached plan.  New code should hold a spec and call ``plan()`` directly::

    from repro.core import CollectiveSpec, plan
    spec = CollectiveSpec(schedule="power2", wire_dtype="int8")
    out = plan(spec, axis_name="x").reduce_scatter(x)

— that is the seam where per-rank block counts (``counts=``, paper
Corollary 3), wire formats, and the fused Pallas backends all plug in.
The ``circulant_*`` kwarg signatures below are kept backward-compatible;
the raw ``impl=`` string dispatch on ``reduce_scatter`` / ``allreduce`` /
``allgather`` is deprecated in favor of ``spec=``.

All functions MUST be called inside a ``shard_map`` (or ``shard_map``-like)
context that binds ``axis_name``.  Baselines implemented alongside:

* ``ring_reduce_scatter`` / ``ring_allreduce`` — p-1 rounds, 1 ICI hop per
  round (bandwidth-optimal on a torus; the paper's [10,11,15] family).
* ``recursive_halving_reduce_scatter`` — power-of-two butterfly.
* ``xla_*`` — XLA's built-in psum / psum_scatter / all_gather for A/B tests.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from .plan import BlockLayout, _fwd_perm, plan, resolve_op
from .spec import DEFAULT_WIRE_GROUP as DEFAULT_GROUP
from .spec import WIRE_DTYPES, CollectiveSpec, as_spec  # noqa: F401  (re-exports)

Array = jax.Array
ReduceFn = Callable[[Array, Array], Array]

_resolve_op = resolve_op  # kwarg-era alias (callers should use plan/spec)


def _as_blocks(x: Array, p: int) -> Array:
    """Reshape leading axis into (p, n/p, *rest). Requires divisibility."""
    return BlockLayout.uniform(p, x.shape[0]).as_blocks(x)


def pad_to_multiple(x: Array, p: int) -> tuple[Array, int]:
    """Zero-pad the leading axis of ``x`` to a multiple of ``p`` — the
    uniform case of the plan's :class:`~repro.core.plan.BlockLayout`
    (non-uniform counts use ``layout.pad`` with their counts table)."""
    return BlockLayout.uniform(p, x.shape[0]).pad(x)


def _circulant_spec(**kw) -> CollectiveSpec:
    # counts (flat tuple or p×p matrix) is normalized by the spec itself.
    return CollectiveSpec(kind="circulant", **kw)


# ---------------------------------------------------------------------------
# Algorithm 1 — reduce-scatter (partitioned all-reduce)
# ---------------------------------------------------------------------------

def circulant_reduce_scatter(
    x: Array,
    axis_name: str,
    *,
    schedule: str = "halving",
    op: str | ReduceFn = "add",
    group: int | None = None,
    compress: Callable[[Array], Any] | None = None,
    decompress: Callable[[Any], Array] | None = None,
    use_fused_kernel: bool | None = None,
    wire_dtype: str | None = None,
    wire_group: int = DEFAULT_GROUP,
    counts: Sequence[int] | None = None,
) -> Array:
    """Paper Algorithm 1.  ``x``: per-rank input vector, leading dim n
    divisible by p.  Returns rank r's reduced block  (n/p, *rest):
    out_r = op-reduce_i  x_i[r-th block].

    Structure per round k (skips s_1 > ... > s_q from the schedule):
      send R[s_k : s_{k-1}] to (r + s_k) — one ppermute —
      fold the received blocks into R[0 : s_{k-1} - s_k].
    The live buffer shrinks from p blocks to 1; exactly p-1 blocks are
    sent/received/reduced per rank (Theorem 1).  ``group`` parameterizes
    the two_level schedule (intra-group size; ignored otherwise).

    ``use_fused_kernel`` routes each round's fold + next-send assembly
    through one Pallas kernel pass; ``wire_dtype="int8"`` compresses every
    round's send payload onto the packed int8 wire format (~4x fewer β
    bytes, lossy); ``counts`` enables the paper's Corollary 3 non-uniform
    variant — per-rank block row sizes, input ``sum(counts)`` rows, output
    ``max(counts)`` rows with rows past this rank's count zeroed.  All
    knobs and their interactions are resolved once by ``plan()`` — this
    wrapper only assembles the :class:`CollectiveSpec`.
    """
    spec = _circulant_spec(schedule=schedule, op=op, group=group,
                           use_fused_kernel=use_fused_kernel,
                           wire_dtype=wire_dtype, wire_group=wire_group,
                           counts=counts)
    return plan(spec, axis_name=axis_name).reduce_scatter(
        x, compress=compress, decompress=decompress)


# ---------------------------------------------------------------------------
# Allgather — Algorithm 2's second phase (reversed skip stack), standalone
# ---------------------------------------------------------------------------

def circulant_allgather(
    x: Array,
    axis_name: str,
    *,
    schedule: str = "halving",
    group: int | None = None,
    use_fused_kernel: bool | None = None,
    wire_dtype: str | None = None,
    wire_group: int = DEFAULT_GROUP,
    counts: Sequence[int] | None = None,
) -> Array:
    """Gather rank blocks in rank order.  ``x``: rank r's block
    (blk, *rest); returns (p*blk, *rest) identical on all ranks.

    Replays the reduce-scatter skips in reverse (the paper's stack): with
    previous bound s' and skip s, send R[0 : s'-s] toward (r - s) and
    receive into R[s : s'] from (r + s).  The buffer grows from 1 block to
    p; p-1 blocks communicated per rank.  With ``counts`` (Corollary 3
    layout) the input is the non-uniform reduce-scatter's
    ``(max(counts), *rest)`` block and the output is ``(sum(counts),
    *rest)`` in rank order, replicated.
    """
    spec = _circulant_spec(schedule=schedule, group=group,
                           use_fused_kernel=use_fused_kernel,
                           wire_dtype=wire_dtype, wire_group=wire_group,
                           counts=counts)
    return plan(spec, axis_name=axis_name).allgather(x)


# ---------------------------------------------------------------------------
# Algorithm 2 — allreduce
# ---------------------------------------------------------------------------

def circulant_allreduce(
    x: Array,
    axis_name: str,
    *,
    schedule: str = "halving",
    op: str | ReduceFn = "add",
    group: int | None = None,
    compress: Callable[[Array], Any] | None = None,
    decompress: Callable[[Any], Array] | None = None,
    use_fused_kernel: bool | None = None,
    wire_dtype: str | None = None,
    wire_group: int = DEFAULT_GROUP,
    counts: Sequence[int] | None = None,
) -> Array:
    """Paper Algorithm 2: reduce-scatter + reversed allgather.
    2*ceil(log2 p) ppermutes, 2(p-1) blocks moved, p-1 reductions/rank.
    ``wire_dtype="int8"`` compresses both phases (RS partial sums are
    requantized per round; AG blocks are quantized once)."""
    spec = _circulant_spec(schedule=schedule, op=op, group=group,
                           use_fused_kernel=use_fused_kernel,
                           wire_dtype=wire_dtype, wire_group=wire_group,
                           counts=counts)
    return plan(spec, axis_name=axis_name).allreduce(
        x, compress=compress, decompress=decompress)


# ---------------------------------------------------------------------------
# All-to-all by concatenation (paper §4)
# ---------------------------------------------------------------------------

def circulant_alltoall(
    x: Array,
    axis_name: str,
    *,
    schedule: str = "halving",
    group: int | None = None,
    use_fused_kernel: bool | None = None,
    counts: Sequence[Sequence[int]] | None = None,
) -> Array:
    """All-to-all in ceil(log2 p) rounds: Algorithm 1 with ⊕ =
    concatenation.  ``x``: (p, blk, *rest); row j is rank r's payload for
    rank j.  Returns (p, blk, *rest); row j is rank j's payload for rank r.

    Volume is amplified — blocks hop through intermediate ranks (the
    classic Bruck trade-off: round-optimal, not volume-optimal; see
    ``cost_model.t_alltoall``).  The fused form keeps each slot as ONE
    stacked buffer and lays the final slot into source order with one
    Pallas row-permutation pass.

    ``counts`` enables the ragged alltoallv variant: a p×p matrix where
    ``counts[src][dst]`` rows travel from src to dst (MPI_Alltoallv).
    Input is then ``(max_r sum(counts[r]), *rest)`` — this rank's payload
    rows in destination order — and the output ``(max_r recv_total_r,
    *rest)`` holds the received rows in source order, zeroed past this
    rank's receive total.  One collective-permute per round either way.
    """
    spec = _circulant_spec(schedule=schedule, group=group,
                           use_fused_kernel=use_fused_kernel,
                           counts=counts)
    return plan(spec, axis_name=axis_name).alltoall(x)


def circulant_alltoallv(
    x: Array,
    axis_name: str,
    counts: Sequence[Sequence[int]],
    *,
    schedule: str = "halving",
    group: int | None = None,
) -> Array:
    """Ragged alltoall (MPI_Alltoallv flavor) — :func:`circulant_alltoall`
    with a required per-pair ``counts`` matrix."""
    return circulant_alltoall(x, axis_name, schedule=schedule, group=group,
                              counts=counts)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def ring_reduce_scatter(x: Array, axis_name: str, *,
                        op: str | ReduceFn = "add", **_ignored) -> Array:
    """Classic p-1-round ring reduce-scatter [Patarasuk-Yuan; paper §1].
    Volume-optimal, 1 ICI hop per round, latency linear in p.

    In rotated coordinates the schedule is static: at step t, send
    R[p-1-t] to rank r+1, receive the peer's partial for our R[p-2-t]."""
    reduce_fn = resolve_op(op)
    p = compat.axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    R = jnp.roll(_as_blocks(x, p), -r, axis=0)
    perm = _fwd_perm(p, 1)
    buf = R[p - 1]
    for t in range(p - 1):
        got = compat.ppermute(buf, axis_name, perm)
        idx = p - 2 - t
        buf = reduce_fn(R[idx], got)
    return buf


def ring_allreduce(x: Array, axis_name: str, *,
                   op: str | ReduceFn = "add", **_ignored) -> Array:
    """Ring RS + ring allgather: 2(p-1) rounds, bandwidth-optimal."""
    p = compat.axis_size(axis_name)
    if p == 1:
        return x
    r = lax.axis_index(axis_name)
    w = ring_reduce_scatter(x, axis_name, op=op)
    # Ring allgather: pass blocks around; rank r starts with block r.
    blocks = [w]
    perm = _fwd_perm(p, 1)
    for t in range(p - 1):
        blocks.append(compat.ppermute(blocks[-1], axis_name, perm))
    # blocks[t] on rank r is block (r - t) mod p; assemble in rank order.
    stacked = jnp.stack(blocks[::-1], axis=0)  # [p-1-t] -> block r - t
    # stacked[i] = block (r + i - (p-1)) = (r + i + 1) mod p
    out = jnp.roll(stacked, r + 1, axis=0)
    return out.reshape(p * w.shape[0], *w.shape[1:])


def recursive_halving_reduce_scatter(x: Array, axis_name: str, *,
                                     op: str | ReduceFn = "add", **_ignored) -> Array:
    """Hypercube/butterfly reduce-scatter — power-of-two p ONLY (the
    classic algorithm whose non-pow2 awkwardness motivates the paper)."""
    reduce_fn = resolve_op(op)
    p = compat.axis_size(axis_name)
    if p == 1:
        return x
    if p & (p - 1):
        raise ValueError(f"recursive halving needs power-of-two p, got {p}")
    r = lax.axis_index(axis_name)
    buf = _as_blocks(x, p)  # absolute block coords
    d = p // 2
    while d >= 1:
        lowhalf, highhalf = buf[: buf.shape[0] // 2], buf[buf.shape[0] // 2:]
        bit = (r // d) % 2  # traced scalar: which half this rank keeps
        send = jnp.where(bit == 1, lowhalf, highhalf)
        got = compat.ppermute(send, axis_name,
                              [(i, i ^ d) for i in range(p)])
        keep = jnp.where(bit == 1, highhalf, lowhalf)
        buf = reduce_fn(keep, got)
        d //= 2
    return buf[0]


def xla_reduce_scatter(x: Array, axis_name: str, **_) -> Array:
    """XLA's native ``psum_scatter`` baseline (compiler-chosen algorithm;
    same block-partition contract as :func:`circulant_reduce_scatter`)."""
    p = compat.axis_size(axis_name)
    return lax.psum_scatter(_as_blocks(x, p), axis_name,
                            scatter_dimension=0, tiled=False)


def xla_allreduce(x: Array, axis_name: str, **_) -> Array:
    """XLA's native ``psum`` allreduce baseline."""
    return lax.psum(x, axis_name)


def xla_allgather(x: Array, axis_name: str, **_) -> Array:
    """XLA's native ``all_gather`` baseline (tiled along axis 0, the
    same layout :func:`circulant_allgather` produces)."""
    return lax.all_gather(x, axis_name, axis=0, tiled=True)


def xla_alltoall(x: Array, axis_name: str, **_) -> Array:
    """XLA's native all-to-all baseline.  Same layout contract as
    :func:`circulant_alltoall`: ``x`` is (p, blk, *rest) with row j the
    payload for rank j; returns row j = payload from rank j."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                          tiled=True)


# ---------------------------------------------------------------------------
# Dispatchers + multi-axis (hierarchical) wrappers
# ---------------------------------------------------------------------------

RS_IMPLS = {
    "circulant": circulant_reduce_scatter,
    "ring": ring_reduce_scatter,
    "recursive_halving": recursive_halving_reduce_scatter,
    "xla": xla_reduce_scatter,
}
AR_IMPLS = {
    "circulant": circulant_allreduce,
    "ring": ring_allreduce,
    "xla": xla_allreduce,
}
AG_IMPLS = {
    "circulant": circulant_allgather,
    "xla": xla_allgather,
}
A2A_IMPLS = {
    "circulant": circulant_alltoall,
    "xla": xla_alltoall,
}


def _warn_impl_string(impl: str, fn: str) -> None:
    warnings.warn(
        f"{fn}(impl={impl!r}) string dispatch is deprecated; build a "
        f"CollectiveSpec(kind={impl!r}, ...) and pass spec= (or call "
        f"repro.core.plan() directly)",
        DeprecationWarning, stacklevel=4)  # _warn -> _dispatch -> wrapper -> caller


def _dispatch(x, axis_name, impl, spec, table, fn_name, method, kw):
    if spec is not None:
        if impl is not None:
            raise TypeError(f"{fn_name}() takes either spec= or impl=, "
                            f"not both")
        if kw:
            raise TypeError(
                f"{fn_name}(spec=...) does not accept extra kwargs "
                f"{sorted(kw)}; fold them into the CollectiveSpec "
                f"(compress/decompress hooks go to the plan method)")
        return getattr(plan(spec, axis_name=axis_name), method)(x)
    if impl is not None:
        _warn_impl_string(impl, fn_name)
    return table[impl or "circulant"](x, axis_name, **kw)


def reduce_scatter(x, axis_name, impl=None, *,
                   spec: CollectiveSpec | None = None, **kw):
    """Reduce-scatter dispatcher.  Preferred: ``spec=CollectiveSpec(...)``
    (plan/execute API).  Passing a raw ``impl=`` string is deprecated."""
    return _dispatch(x, axis_name, impl, spec, RS_IMPLS, "reduce_scatter",
                     "reduce_scatter", kw)


def allreduce(x, axis_name, impl=None, *,
              spec: CollectiveSpec | None = None, **kw):
    """Allreduce dispatcher — see :func:`reduce_scatter`."""
    return _dispatch(x, axis_name, impl, spec, AR_IMPLS, "allreduce",
                     "allreduce", kw)


def allgather(x, axis_name, impl=None, *,
              spec: CollectiveSpec | None = None, **kw):
    """Allgather dispatcher — see :func:`reduce_scatter`."""
    return _dispatch(x, axis_name, impl, spec, AG_IMPLS, "allgather",
                     "allgather", kw)


def alltoall(x, axis_name, impl=None, *,
             spec: CollectiveSpec | None = None, **kw):
    """Alltoall(v) dispatcher — see :func:`reduce_scatter`.  A spec with a
    p×p ``counts`` matrix runs the ragged alltoallv table backend."""
    return _dispatch(x, axis_name, impl, spec, A2A_IMPLS, "alltoall",
                     "alltoall", kw)


def broadcast(x, axis_name, *, spec: CollectiveSpec | None = None, **kw):
    """All-broadcast dispatcher (Träff, arXiv:2407.18004): every rank's
    block ``x`` (blk, *rest) reaches every rank — returns (p*blk, *rest)
    in rank order, bitwise-replicated — in ceil(log2 p) rounds, one
    ppermute per round.  Bare kwargs (``schedule=``...) build the
    ``kind="broadcast"`` spec in place; the serving replicas' weight
    fan-out is the primary consumer."""
    s = as_spec(spec if spec is not None else "broadcast", **kw)
    return plan(s, axis_name=axis_name).broadcast(x)


def reduce_scatter_pipelined(xs: Sequence[Array], axis_name: str, *,
                             spec: CollectiveSpec | None = None) -> list:
    """Software-pipelined reduce-scatter over independent payloads.

    Each payload gets the one-shot result (bitwise-identical — the same
    plan backend runs, split at its round seam), but the rounds are
    interleaved: payload b's round-k ppermute is issued before payload
    b-1's round-k fold, so XLA's latency-hiding scheduler can overlap
    each collective-permute with the previous payload's local fold.
    Total collectives are unchanged (len(xs) * ceil(log2 p)).  This is
    the execution mode the bucketed ZeRO-1 grad sync rides on.
    """
    s = spec if spec is not None else CollectiveSpec()
    return plan(s, axis_name=axis_name).reduce_scatter_pipelined(xs)


def allgather_pipelined(xs: Sequence[Array], axis_name: str, *,
                        spec: CollectiveSpec | None = None) -> list:
    """Software-pipelined allgather — see :func:`reduce_scatter_pipelined`."""
    s = spec if spec is not None else CollectiveSpec()
    return plan(s, axis_name=axis_name).allgather_pipelined(xs)


def hierarchical_reduce_scatter(x, axis_names: Sequence[str],
                                impl=None, *,
                                spec: CollectiveSpec | None = None, **kw):
    """Nested RS over multiple mesh axes (e.g. ('data', 'pod')): RS over the
    fastest axis first, then the slower axis on the surviving 1/p_0 shard —
    large skips never cross the slow interconnect with more than m/p_0
    payload (multilane decomposition; DESIGN §2 assumption 2).

    A two-axis plan is just two nested plans: with ``spec=`` each axis
    compiles and caches its own :class:`CollectivePlan` for the same spec.
    """
    out = x
    for ax in axis_names:
        out = reduce_scatter(out, ax, impl, spec=spec, **kw)
    return out


def hierarchical_allgather(x, axis_names: Sequence[str],
                           impl=None, *,
                           spec: CollectiveSpec | None = None, **kw):
    """Inverse of hierarchical_reduce_scatter (reverse axis order)."""
    out = x
    for ax in reversed(list(axis_names)):
        out = allgather(out, ax, impl, spec=spec, **kw)
    return out


def hierarchical_allreduce(x, axis_names: Sequence[str],
                           impl=None, *,
                           spec: CollectiveSpec | None = None, **kw):
    """Multi-axis allreduce: hierarchical RS over ``axis_names`` in
    order, then hierarchical AG in reverse order (Theorem 2 composed
    per mesh axis; block linearization ``lin = r0*p1 + r1``)."""
    out = hierarchical_reduce_scatter(x, axis_names, impl, spec=spec, **kw)
    return hierarchical_allgather(out, axis_names, impl, spec=spec, **kw)
