"""`CollectiveSpec` — the declarative half of the plan/execute collective API.

The paper's algorithms are fundamentally *plan-then-execute*: the circulant
skip schedule, the per-round send/recv block index sets, and the
Corollary 3 non-uniform-count variant are all computable once from
``(p, schedule, counts)`` before any data moves.  A ``CollectiveSpec``
captures everything that planning needs — and nothing that execution
provides (the payload, the axis size, trace-time hooks):

    spec = CollectiveSpec(kind="circulant", schedule="halving",
                          wire_dtype="int8")
    pl = plan(spec, p, axis_name)        # cached; pure trace-time work
    out = pl.reduce_scatter(x)           # one ppermute per round

Specs are FROZEN and HASHABLE so ``plan()`` can memoize on them: calling a
collective twice with the same spec never replans and never retraces (the
CI ``plans`` gate asserts this).  ``counts`` is the new first-class
citizen: per-rank block row counts for the paper's Corollary 3
non-uniform reduce-scatter (``MPI_Reduce_scatter`` flavor), including the
worst case with every element concentrated in one column and zero-count
ranks.

This module is dependency-light on purpose (no kernel imports): it is the
vocabulary shared by collectives, the ZeRO-1 optimizer, the conformance
harness, and the benchmark workers.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable

#: implementation families plan() knows how to compile.
KINDS = ("circulant", "broadcast", "ring", "recursive_halving", "xla")

#: wire formats understood by the circulant backends (None = uncompressed).
WIRE_DTYPES = (None, "int8")

#: default elements per quantization group (mirrors kernels.quantize
#: without importing it — spec stays dependency-light).
DEFAULT_WIRE_GROUP = 512


@dataclass(frozen=True)
class CollectiveSpec:
    """Everything needed to *plan* a collective, nothing needed to run it.

    kind:             implementation family (``circulant`` is the paper's;
                      ``broadcast`` is Träff's round-optimal all-broadcast
                      sibling, arXiv:2407.18004; ``ring`` /
                      ``recursive_halving`` / ``xla`` are the A/B
                      baselines).
    schedule:         Corollary-2 skip schedule name (circulant only).
    group:            intra-group size for the ``two_level`` schedule.
    op:               reduction ⊕ — a name (``add``/``max``/``min``) or a
                      callable (jnp backend only; named ops unlock the
                      fused and wire backends).
    wire_dtype:       ``None`` (uncompressed) or ``"int8"`` (packed
                      [codes | scale bytes] wire buffer, ~4x fewer β
                      bytes; see README).
    wire_group:       elements per quantization group on the wire.
    use_fused_kernel: ``None`` = auto (Pallas on TPU), ``True``/``False``
                      explicit — same tri-state the kwarg API had.
    counts:           per-rank block row counts for the non-uniform
                      (Corollary 3) variant; ``None`` = uniform blocks.
                      ``reduce_scatter`` consumes a ``sum(counts)``-row
                      input and returns a ``max(counts)``-row block
                      (rows past this rank's count zeroed); ``allgather``
                      / ``allreduce`` invert that layout.  A NESTED p×p
                      tuple is the alltoall(v) flavor: ``counts[src][dst]``
                      rows travel from ``src`` to ``dst`` (MPI_Alltoallv
                      semantics; consumed only by ``plan.alltoall``).
    """

    kind: str = "circulant"
    schedule: str = "halving"
    group: int | None = None
    op: str | Callable = "add"
    wire_dtype: str | None = None
    wire_group: int = DEFAULT_WIRE_GROUP
    use_fused_kernel: bool | None = None
    counts: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown kind {self.kind!r}; have {KINDS}")
        if self.wire_dtype not in WIRE_DTYPES:
            raise ValueError(
                f"unknown wire_dtype {self.wire_dtype!r}; have {WIRE_DTYPES}")
        if self.wire_group < 1:
            raise ValueError(f"wire_group must be >= 1, got {self.wire_group}")
        if self.kind == "broadcast":
            # Broadcast rides the allgather phase only: no reduction op
            # semantics, no per-rank counts, no wire compression (weights
            # fan out bit-exact).  Reject knobs that imply otherwise.
            if self.wire_dtype is not None:
                raise ValueError(
                    "kind='broadcast' distributes payloads bit-exactly; "
                    "wire_dtype compression is not supported")
            if self.use_fused_kernel:
                raise ValueError(
                    "kind='broadcast' has no fold step; the fused round "
                    "kernel does not apply (use_fused_kernel=True invalid)")
        if self.counts is not None:
            if self.kind != "circulant":
                raise ValueError(
                    f"counts= (Corollary 3 / alltoallv) needs "
                    f"kind='circulant', got {self.kind!r}")
            rows = list(self.counts)
            if rows and hasattr(rows[0], "__len__"):
                # p×p per-pair matrix (alltoallv): counts[src][dst].
                counts = tuple(tuple(int(c) for c in row) for row in rows)
                if any(len(row) != len(counts) for row in counts):
                    raise ValueError(
                        f"counts matrix must be square (p×p), got row "
                        f"lengths {[len(r) for r in counts]} for "
                        f"{len(counts)} rows")
                flat = [c for row in counts for c in row]
            else:
                counts = tuple(int(c) for c in rows)
                flat = list(counts)
            if any(c < 0 for c in flat):
                raise ValueError(f"counts must be non-negative, got {counts}")
            if sum(flat) == 0:
                raise ValueError(
                    f"counts must have at least one nonzero entry, "
                    f"got {counts}")
            # Normalize so specs hash/compare by value regardless of the
            # caller's integer/container types (np.int64 vs int, lists).
            object.__setattr__(self, "counts", counts)

    # -- convenience -------------------------------------------------------

    def with_(self, **changes) -> "CollectiveSpec":
        """``dataclasses.replace`` spelled as a method (fluent tweaks)."""
        return replace(self, **changes)

    @property
    def wired(self) -> bool:
        return self.wire_dtype is not None

    @property
    def counts_matrix(self) -> bool:
        """True when ``counts`` is the p×p per-pair (alltoallv) form."""
        return self.counts is not None and isinstance(self.counts[0], tuple)

    @property
    def label(self) -> str:
        """Compact human tag (benchmark rows, conformance case names)."""
        bits = [self.kind]
        if self.kind == "circulant":
            bits.append(self.schedule)
            if isinstance(self.op, str):
                bits.append(self.op)
            if self.use_fused_kernel:
                bits.append("fused")
            if self.wire_dtype:
                bits.append(f"wire={self.wire_dtype}")
            if self.counts is not None:
                tag = "a2av" if self.counts_matrix else "counts"
                bits.append(f"{tag}={len(self.counts)}")
        elif self.kind == "broadcast":
            bits.append(self.schedule)
        return ":".join(bits)


def as_spec(spec_or_kind: "CollectiveSpec | str | None" = None,
            **kw) -> CollectiveSpec:
    """Coerce loose inputs into a ``CollectiveSpec``.

    Accepts an existing spec (returned as-is; kw must be empty), a kind
    string, or bare kwargs.  The single funnel the legacy kwarg wrappers
    use to enter the plan/execute world.
    """
    if isinstance(spec_or_kind, CollectiveSpec):
        if kw:
            raise TypeError(
                f"cannot combine an existing CollectiveSpec with extra "
                f"kwargs {sorted(kw)}")
        return spec_or_kind
    if isinstance(spec_or_kind, str):
        kw = dict(kw, kind=spec_or_kind)
    return CollectiveSpec(**kw)
