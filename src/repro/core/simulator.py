"""Message-passing simulator executing Träff's Algorithms 1 and 2 verbatim.

Pure numpy, no JAX.  Serves two purposes:

1. **Paper validation** — counts communication rounds, blocks sent/received
   and ⊕-applications per processor and asserts the exact Theorem 1/2
   quantities (rounds = ceil(log2 p); blocks = p-1 for reduce-scatter and
   2(p-1) for allreduce; ⊕-applications = p-1).

2. **Numerical oracle** — the JAX shard_map collectives in
   ``repro.core.collectives`` are tested allclose against these results.

The simulator models the paper's communication model exactly: in each
round every processor simultaneously sends one contiguous block range and
receives one (``Send || Recv``); send/receive pairs are matched through a
mailbox, so a round is a synchronous step of the circulant graph.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from .schedule import (
    allgather_plan,
    ceil_log2,
    get_skips,
    reduce_scatter_plan,
)

__all__ = [
    "CommStats", "simulate_reduce_scatter", "simulate_allgather",
    "simulate_allreduce", "simulate_alltoall", "simulate_alltoallv",
    "ref_reduce_scatter", "ref_allreduce", "ref_alltoall",
]

Op = Callable[[np.ndarray, np.ndarray], np.ndarray]


@dataclass
class CommStats:
    """Per-processor communication/computation counters (Theorem 1/2)."""
    rounds: int = 0
    blocks_sent: list[int] = field(default_factory=list)   # per processor
    blocks_recv: list[int] = field(default_factory=list)
    reductions: list[int] = field(default_factory=list)    # ⊕ applications
    send_events: list[tuple[int, int, int, int]] = field(default_factory=list)
    # (round, src, dst, nblocks) — full trace for graph-structure tests

    def assert_theorem1(self, p: int) -> None:
        assert self.rounds == ceil_log2(p), (self.rounds, ceil_log2(p))
        assert all(b == p - 1 for b in self.blocks_sent), self.blocks_sent
        assert all(b == p - 1 for b in self.blocks_recv), self.blocks_recv
        assert all(x == p - 1 for x in self.reductions), self.reductions

    def assert_theorem2(self, p: int) -> None:
        assert self.rounds == 2 * ceil_log2(p), (self.rounds, ceil_log2(p))
        assert all(b == 2 * (p - 1) for b in self.blocks_sent)
        assert all(b == 2 * (p - 1) for b in self.blocks_recv)
        assert all(x == p - 1 for x in self.reductions)


def _check_block_shapes(inputs: Sequence[Sequence[np.ndarray]]) -> int:
    p = len(inputs)
    for r, vec in enumerate(inputs):
        if len(vec) != p:
            raise ValueError(f"processor {r} has {len(vec)} blocks, want {p}")
    # Paper requirement: V_i[r] and V_j[r] must have equal element counts.
    for i in range(p):
        sizes = {np.asarray(inputs[r][i]).shape for r in range(p)}
        if len(sizes) != 1:
            raise ValueError(f"block column {i} has inconsistent shapes {sizes}")
    return p


def simulate_reduce_scatter(
    inputs: Sequence[Sequence[np.ndarray]],
    op: Op = np.add,
    schedule: str = "halving",
) -> tuple[list[np.ndarray], CommStats]:
    """Algorithm 1 (partitioned all-reduce), executed for all p processors.

    ``inputs[r][i]`` is V_r[i].  Returns ``(W, stats)`` where ``W[r]`` is
    the reduction over column r:  W[r] = op-reduce_i  V_i[r].

    Blocks may have different sizes per column (MPI_Reduce_scatter flavor);
    Corollary 3's worst case is exercised by concentrating elements in one
    column.
    """
    p = _check_block_shapes(inputs)
    stats = CommStats(blocks_sent=[0] * p, blocks_recv=[0] * p,
                      reductions=[0] * p)
    # Rotated initial copy: R_r[i] = V_r[(r + i) mod p]
    R = [[np.array(inputs[r][(r + i) % p], copy=True)
          for i in range(p)] for r in range(p)]
    plans = reduce_scatter_plan(p, schedule)
    for k, pl in enumerate(plans):
        stats.rounds += 1
        s = pl.skip
        # Synchronous round: gather all messages first (Send || Recv).
        mailbox = {}
        for r in range(p):
            dst = (r + s) % p
            payload = [R[r][i] for i in range(pl.lo, pl.hi)]
            mailbox[dst] = payload
            stats.blocks_sent[r] += len(payload)
            stats.send_events.append((k, r, dst, len(payload)))
        for r in range(p):
            T = mailbox[r]
            stats.blocks_recv[r] += len(T)
            for i, t in enumerate(T):
                R[r][i] = op(R[r][i], t)
                stats.reductions[r] += 1
    W = [R[r][0] for r in range(p)]
    return W, stats


def simulate_allgather(
    blocks: Sequence[np.ndarray],
    schedule: str = "halving",
) -> tuple[list[list[np.ndarray]], CommStats]:
    """Algorithm 2's second phase standalone: rank r starts with ``blocks[r]``
    and ends with all p blocks in rank order.

    Buffer semantics: R_r[i] will hold the block belonging to rank
    (r + i) mod p (same rotated coordinates as the RS phase).  Rounds
    replay the reversed RS skips: with skip s and previous range bound s',
    send R[0 .. s'-s-1] to (r - s) mod p, receive into R[s .. s'-1] from
    (r + s) mod p.
    """
    p = len(blocks)
    stats = CommStats(blocks_sent=[0] * p, blocks_recv=[0] * p,
                      reductions=[0] * p)
    R: list[list[np.ndarray | None]] = [[None] * p for _ in range(p)]
    for r in range(p):
        R[r][0] = np.array(blocks[r], copy=True)
    for pl in allgather_plan(p, schedule):
        stats.rounds += 1
        s, nb = pl.skip, pl.nblocks
        mailbox = {}
        for r in range(p):
            dst = (r - s) % p
            payload = [R[r][i] for i in range(0, nb)]
            assert all(x is not None for x in payload), "sending unfilled block"
            mailbox[dst] = payload
            stats.blocks_sent[r] += nb
        for r in range(p):
            T = mailbox[r]
            stats.blocks_recv[r] += len(T)
            for i, t in enumerate(T):
                R[r][pl.lo + i] = t
    # Un-rotate: out[r][j] = block of rank j = R[r][(j - r) mod p]
    out = [[R[r][(j - r) % p] for j in range(p)] for r in range(p)]
    for r in range(p):
        for j in range(p):
            assert out[r][j] is not None
    return out, stats  # type: ignore[return-value]


def simulate_allreduce(
    inputs: Sequence[Sequence[np.ndarray]],
    op: Op = np.add,
    schedule: str = "halving",
) -> tuple[list[list[np.ndarray]], CommStats]:
    """Algorithm 2: reduce-scatter phase + reversed allgather phase.

    Returns ``(W, stats)`` with ``W[r][i]`` = fully reduced block i on
    processor r (identical across r; Theorem 2 counters in stats).
    """
    p = _check_block_shapes(inputs)
    W_scat, st1 = simulate_reduce_scatter(inputs, op, schedule)
    out, st2 = simulate_allgather(W_scat, schedule)
    stats = CommStats(
        rounds=st1.rounds + st2.rounds,
        blocks_sent=[a + b for a, b in zip(st1.blocks_sent, st2.blocks_sent)],
        blocks_recv=[a + b for a, b in zip(st1.blocks_recv, st2.blocks_recv)],
        reductions=st1.reductions,
        send_events=st1.send_events,
    )
    return out, stats


def simulate_alltoall(
    inputs: Sequence[Sequence[np.ndarray]],
    schedule: str = "halving",
) -> tuple[list[list[np.ndarray]], CommStats]:
    """All-to-all via reduce-scatter with ⊕ = concatenation (paper §4).

    ``inputs[r][i]`` is the block rank r wants delivered to rank i.
    Implemented exactly as Algorithm 1 where a "block" is a *list* of
    (source_rank, payload) pairs and ⊕ concatenates lists; at the end,
    processor r's W is the list of p payloads addressed to it.

    Blocks may have ANY shape per (src, dst) pair — including empty —
    so this is also the alltoallv (MPI_Alltoallv) oracle; see
    :func:`simulate_alltoallv`.

    Round count is ceil(log2 p) (optimal); volume is amplified (blocks
    travel multiple hops) — the known Bruck trade-off, reported in stats.
    """
    p = len(inputs)
    stats = CommStats(blocks_sent=[0] * p, blocks_recv=[0] * p,
                      reductions=[0] * p)
    # R_r[i]: list of (src, payload) destined for rank (r + i) mod p.
    R = [[[(r, np.array(inputs[r][(r + i) % p], copy=True))]
          for i in range(p)] for r in range(p)]
    for k, pl in enumerate(reduce_scatter_plan(p, schedule)):
        stats.rounds += 1
        s = pl.skip
        mailbox = {}
        for r in range(p):
            dst = (r + s) % p
            payload = [R[r][i] for i in range(pl.lo, pl.hi)]
            mailbox[dst] = payload
            stats.blocks_sent[r] += sum(len(x) for x in payload)
        for r in range(p):
            T = mailbox[r]
            stats.blocks_recv[r] += sum(len(x) for x in T)
            for i, t in enumerate(T):
                R[r][i] = R[r][i] + t  # ⊕ = concatenation
                stats.reductions[r] += 1
    out: list[list[np.ndarray]] = []
    for r in range(p):
        got = {src: payload for src, payload in R[r][0]}
        assert set(got) == set(range(p)), f"rank {r} missing sources"
        out.append([got[j] for j in range(p)])
    return out, stats


def simulate_alltoallv(
    inputs: Sequence[Sequence[np.ndarray]],
    schedule: str = "halving",
) -> tuple[list[list[np.ndarray]], CommStats]:
    """Ragged alltoall oracle: ``inputs[src][dst]`` is the (arbitrarily
    sized, possibly empty) payload src sends to dst.  Round structure is
    identical to :func:`simulate_alltoall` (which already moves payloads
    verbatim); this wrapper only asserts the round count — Theorem 1's
    ``rounds`` survive ragged per-pair counts unchanged."""
    p = len(inputs)
    out, stats = simulate_alltoall(inputs, schedule=schedule)
    assert stats.rounds == len(get_skips(p, schedule)), \
        (stats.rounds, p, schedule)
    return out, stats


# ---------------------------------------------------------------------------
# Reference "one-shot" answers for oracle comparisons
# ---------------------------------------------------------------------------

def ref_alltoall(inputs) -> list[list[np.ndarray]]:
    """Host ground truth for alltoall(v): a transpose of the per-pair
    payload matrix — ``out[r][j] = inputs[j][r]``."""
    p = len(inputs)
    return [[np.array(inputs[j][r], copy=True) for j in range(p)]
            for r in range(p)]


def ref_reduce_scatter(inputs, op=np.add):
    """Oracle: rank r's result block = op-fold of inputs[i][r] over all
    ranks i, in rank order (the sequential reference the simulated
    schedules must reproduce)."""
    p = len(inputs)
    out = []
    for r in range(p):
        acc = np.array(inputs[0][r], copy=True)
        for i in range(1, p):
            acc = op(acc, inputs[i][r])
        out.append(acc)
    return out


def ref_allreduce(inputs, op=np.add):
    """Oracle allreduce: every rank ends with the full reduced block
    column (reduce-scatter oracle replicated p times)."""
    col = ref_reduce_scatter(inputs, op)
    return [list(col) for _ in range(len(inputs))]
