"""Analytic cost models: the paper's α-β-γ model + a TPU ICI torus refinement.

Corollary 1 (uniform blocks, m elements total, p processors):
    T_rs(m, p) = α·ceil(log2 p) + β·(p-1)/p·m + γ·(p-1)/p·m
    T_ar(m, p) = 2α·ceil(log2 p) + 2β·(p-1)/p·m + γ·(p-1)/p·m

Corollary 3 (irregular blocks): T <= ceil(log2 p) · (α + β·m + γ·m).

Torus refinement (beyond paper, §Perf): a collective-permute with skip s on
a p-ring with wraparound traverses hops(s) = min(s, p-s) links; every hop
occupies a link, so the *bandwidth* term of a round is amplified by
hops(s).  The paper's model charges β once per element (topology-oblivious
MPI view); on ICI the per-round charge becomes β·hops(s_k)·m_k.  This is
the quantitative basis for schedule selection on TPU.
"""
from __future__ import annotations

from dataclasses import dataclass

from .schedule import (RoundPlan, allgather_plan, alltoall_moves, ceil_log2,
                       reduce_scatter_plan)


@dataclass(frozen=True)
class CommModel:
    """Homogeneous, linear-affine transmission cost model (paper §2.1).

    alpha: per-round latency [s]
    beta:  per-element transmission time [s/elem]  (elem = one vector elem)
    gamma: per-element reduction time [s/elem]
    elem_bytes: bytes of one UNCOMPRESSED vector element (what beta was
        calibrated against); lets the wire-format scaling below convert a
        compressed bytes-per-element figure back into a beta multiplier.
    """
    alpha: float
    beta: float
    gamma: float
    elem_bytes: float = 4.0

    @staticmethod
    def tpu_v5e(elem_bytes: int = 2) -> "CommModel":
        """v5e-flavored constants: ~1us collective-permute launch latency,
        ~50 GB/s/link ICI, VPU reduce >> link bw so gamma ~ HBM-bound add
        (2 reads + 1 write per elem @ 819 GB/s)."""
        return CommModel(alpha=1e-6,
                         beta=elem_bytes / 50e9,
                         gamma=3 * elem_bytes / 819e9,
                         elem_bytes=elem_bytes)


def wire_bytes_per_elem(elem_bytes: float, wire_dtype: str | None = None,
                        wire_group: int = 512) -> float:
    """Bytes on the wire per payload element under a wire format.

    ``int8`` sends one code byte per element plus one f32 scale per
    ``wire_group`` elements (the packed [codes | scale bytes] buffer of
    kernels.quantize) — ``1 + 4/group`` bytes/elem vs ``elem_bytes``
    uncompressed, i.e. a ~3.9x β-term reduction from f32 at the default
    group of 512."""
    if wire_dtype is None:
        return float(elem_bytes)
    if wire_dtype == "int8":
        return 1.0 + 4.0 / wire_group
    raise ValueError(f"unknown wire_dtype {wire_dtype!r}")


def _wire_scale(model: CommModel, wire_dtype: str | None,
                wire_group: int) -> float:
    """β multiplier for a wire format (1.0 when uncompressed)."""
    if wire_dtype is None:
        return 1.0
    return (wire_bytes_per_elem(model.elem_bytes, wire_dtype, wire_group)
            / model.elem_bytes)


def _round_cost(plans: tuple[RoundPlan, ...], block_elems: float,
                model: CommModel, p: int, *, torus: bool,
                reduce_on_recv: bool, wire_scale: float = 1.0) -> float:
    t = 0.0
    for pl in plans:
        m_k = pl.nblocks * block_elems
        hops = min(pl.skip, p - pl.skip) if torus else 1
        t += model.alpha + model.beta * wire_scale * hops * m_k
        if reduce_on_recv:
            t += model.gamma * m_k
    return t


def t_reduce_scatter(m: float, p: int, model: CommModel,
                     schedule: str = "halving", *, torus: bool = False,
                     wire_dtype: str | None = None,
                     wire_group: int = 512) -> float:
    """Predicted time of Algorithm 1 on m total elements (uniform blocks).
    ``wire_dtype="int8"`` scales the β term to the compressed payload
    (codes + scales bytes); α (round count) and γ (every element is still
    reduced) are unchanged."""
    if p == 1:
        return 0.0
    plans = reduce_scatter_plan(p, schedule)
    return _round_cost(plans, m / p, model, p, torus=torus,
                       reduce_on_recv=True,
                       wire_scale=_wire_scale(model, wire_dtype, wire_group))


def t_allgather(m: float, p: int, model: CommModel,
                schedule: str = "halving", *, torus: bool = False,
                wire_dtype: str | None = None,
                wire_group: int = 512) -> float:
    """Predicted circulant allgather time for an ``m``-element result at
    ``p`` ranks (transport only — no gamma term; Corollary 1 dual of the
    reduce-scatter)."""
    if p == 1:
        return 0.0
    plans = allgather_plan(p, schedule)
    return _round_cost(plans, m / p, model, p, torus=torus,
                       reduce_on_recv=False,
                       wire_scale=_wire_scale(model, wire_dtype, wire_group))


def t_allreduce(m: float, p: int, model: CommModel,
                schedule: str = "halving", *, torus: bool = False,
                wire_dtype: str | None = None,
                wire_group: int = 512) -> float:
    """Algorithm 2 = Algorithm 1 + reversed allgather (Theorem 2)."""
    return (t_reduce_scatter(m, p, model, schedule, torus=torus,
                             wire_dtype=wire_dtype, wire_group=wire_group)
            + t_allgather(m, p, model, schedule, torus=torus,
                          wire_dtype=wire_dtype, wire_group=wire_group))


def t_bucketed_allreduce(m: float, p: int, model: CommModel,
                         nbuckets: int, schedule: str = "halving", *,
                         torus: bool = False, wire_dtype: str | None = None,
                         wire_group: int = 512,
                         overlap: float = 1.0) -> float:
    """Predicted time of the bucketed, software-pipelined allreduce.

    The serial (single-bucket) lower bound is Corollary 1's
    ``α·2⌈log₂p⌉ + β·2(p-1)/p·m + γ·(p-1)/p·m``.  Splitting into B
    buckets pays the round latency B times (every bucket runs its own
    2⌈log₂p⌉ ppermutes), moves the same total β bytes, and lets each
    bucket's fold (γ) work hide under a neighboring bucket's ppermute —
    except the last bucket's, which has nothing left to hide behind.
    ``overlap`` in [0, 1] scales how much of the hideable fold actually
    overlaps (1 = perfect latency-hiding scheduler, 0 = fully serial,
    which recovers ``t_allreduce`` at any B up to the extra α rounds).

    ``t_bucketed_allreduce(m, p, model, 1)`` == ``t_allreduce(m, p,
    model)`` exactly; the α-vs-γ trade is minimized at
    :func:`optimal_bucket_count`.
    """
    if nbuckets < 1:
        raise ValueError(f"nbuckets must be >= 1, got {nbuckets}")
    if not 0.0 <= overlap <= 1.0:
        raise ValueError(f"overlap must be in [0, 1], got {overlap}")
    if p == 1:
        return 0.0
    comm = CommModel(alpha=model.alpha, beta=model.beta, gamma=0.0,
                     elem_bytes=model.elem_bytes)
    t_comm = nbuckets * t_allreduce(m / nbuckets, p, comm, schedule,
                                    torus=torus, wire_dtype=wire_dtype,
                                    wire_group=wire_group)
    t_fold = (t_reduce_scatter(m, p, model, schedule, torus=torus,
                               wire_dtype=wire_dtype, wire_group=wire_group)
              - t_reduce_scatter(m, p, comm, schedule, torus=torus,
                                 wire_dtype=wire_dtype,
                                 wire_group=wire_group))
    hidden = overlap * t_fold * (nbuckets - 1) / nbuckets
    return t_comm + t_fold - hidden


def optimal_bucket_count(m: float, p: int, model: CommModel,
                         schedule: str = "halving") -> int:
    """Bucket count minimizing :func:`t_bucketed_allreduce` at full
    overlap: balancing the extra round latency ``B·rounds·α`` against
    the unhidden fold tail ``γ·(p-1)/p·m / B`` gives
    ``B* = sqrt(γ·(p-1)/p·m / (rounds·α))`` (rounded, clamped to >= 1).
    """
    if p == 1:
        return 1
    rounds = (len(reduce_scatter_plan(p, schedule))
              + len(allgather_plan(p, schedule)))
    fold = model.gamma * (p - 1) / p * m
    if fold <= 0 or model.alpha <= 0:
        return 1
    return max(1, round((fold / (rounds * model.alpha)) ** 0.5))


def t_corollary1(m: float, p: int, model: CommModel) -> float:
    """Closed form of Corollary 1 — must equal t_reduce_scatter(halving)."""
    if p == 1:
        return 0.0
    return (model.alpha * ceil_log2(p)
            + (model.beta + model.gamma) * (p - 1) / p * m)


def t_corollary3_bound(m: float, p: int, model: CommModel) -> float:
    """Upper bound for arbitrary block-size partitions (Corollary 3)."""
    if p == 1:
        return 0.0
    return ceil_log2(p) * (model.alpha + (model.beta + model.gamma) * m)


def nonuniform_round_widths(counts, schedule: str = "halving",
                            group: int | None = None, *,
                            phase: str = "rs") -> tuple[int, ...]:
    """Per-round wire widths (rows) of the non-uniform RS/AG: the worst
    windowed count sum over ranks — the exact per-round quantity
    Corollary 3's bound maximizes over, and the analytic width the plan
    layer's row tables must match (checked by ``repro.analysis``'s plan
    verifier, so a table-construction bug cannot silently widen or
    narrow the wire)."""
    p = len(counts)
    plans = (reduce_scatter_plan(p, schedule, group) if phase == "rs"
             else allgather_plan(p, schedule, group))
    widths = []
    for pl in plans:
        window = (range(pl.lo, pl.hi) if phase == "rs"
                  else range(0, pl.nblocks))
        w = max(sum(counts[(r + i) % p] for i in window) for r in range(p))
        widths.append(max(w, 1))
    return tuple(widths)


def a2a_round_entries(p: int, schedule: str = "halving",
                      group: int | None = None) -> tuple[int, ...]:
    """Blocks each rank sends per round of alltoall-by-concatenation.

    Entries hop through intermediate ranks, so the per-round send count
    is the number of destination offsets whose slot lies in the round's
    window — NOT the p-1 of reduce-scatter.  ``sum(a2a_round_entries(p))``
    is the classic Bruck volume amplification (≈ (p/2)·ceil(log2 p) for
    the halving schedule)."""
    return tuple(len(moved) for _, moved in
                 alltoall_moves(p, schedule, group))


def t_alltoall(m: float, p: int, model: CommModel,
               schedule: str = "halving", *, torus: bool = False) -> float:
    """Predicted time of alltoall-by-concatenation on m total elements
    per rank (uniform p blocks of m/p).  β is charged for the FULL
    hop-through-intermediate-ranks volume (every entry in a round's
    window retransmits); no γ — concatenation does no arithmetic."""
    if p == 1:
        return 0.0
    t = 0.0
    for (skip, moved) in alltoall_moves(p, schedule):
        hops = min(skip, p - skip) if torus else 1
        t += model.alpha + model.beta * hops * len(moved) * (m / p)
    return t


def alltoallv_round_widths(counts, schedule: str = "halving",
                           group: int | None = None) -> tuple[int, ...]:
    """Per-round wire widths (rows) of the ragged alltoallv: the worst
    windowed count sum over ranks — the analytic bound the plan's
    ``A2APlan.round_widths`` must equal (asserted by the CI ``a2a``
    gate), and the β quantity of the Corollary 3 style per-round cost."""
    p = len(counts)
    widths = []
    for _, moved in alltoall_moves(p, schedule, group):
        per_rank = []
        for r in range(p):
            w = 0
            for d, m in moved:
                src = (r - m) % p
                w += counts[src][(src + d) % p]
            per_rank.append(w)
        widths.append(max(max(per_rank), 1) if per_rank else 1)
    return tuple(widths)


def t_alltoallv(counts, model: CommModel, schedule: str = "halving", *,
                elems_per_row: float = 1.0, torus: bool = False) -> float:
    """Predicted alltoallv time for a per-pair ``counts`` row matrix.
    Every round ships one fixed-width wire buffer (SPMD static shapes),
    so β is charged for the worst windowed count sum per round."""
    p = len(counts)
    if p == 1:
        return 0.0
    t = 0.0
    moves = alltoall_moves(p, schedule)
    for (skip, _), w in zip(moves, alltoallv_round_widths(counts, schedule)):
        hops = min(skip, p - skip) if torus else 1
        t += model.alpha + model.beta * hops * w * elems_per_row
    return t


def t_ring_reduce_scatter(m: float, p: int, model: CommModel) -> float:
    """Classic p-1-round ring algorithm [Patarasuk-Yuan]: volume optimal,
    latency linear.  One block of m/p per round, 1 hop."""
    if p == 1:
        return 0.0
    return (p - 1) * (model.alpha + (model.beta + model.gamma) * m / p)


def t_ring_allreduce(m: float, p: int, model: CommModel) -> float:
    """Classic bandwidth-optimal ring allreduce baseline: 2(p-1) rounds
    of m/p-sized messages (latency term 2(p-1)·alpha vs the circulant
    2⌈log2 p⌉·alpha)."""
    if p == 1:
        return 0.0
    return (t_ring_reduce_scatter(m, p, model)
            + (p - 1) * (model.alpha + model.beta * m / p))


def t_bcast_reduce_allreduce(m: float, p: int, model: CommModel) -> float:
    """Naive binomial-tree reduce + broadcast (the detour the paper warns
    against): 2·ceil(log2 p) rounds but FULL vector each round."""
    if p == 1:
        return 0.0
    return 2 * ceil_log2(p) * (model.alpha + model.beta * m) \
        + ceil_log2(p) * model.gamma * m


def crossover_m(p: int, model: CommModel, lo: float = 1.0,
                hi: float = 1e12) -> float:
    """Smallest m where ring allreduce beats circulant allreduce on the
    TORUS model (hop-amplified).  Bisection; returns hi if never."""
    if t_allreduce(hi, p, model, torus=True) <= t_ring_allreduce(hi, p, model):
        return hi
    for _ in range(200):
        mid = (lo + hi) / 2
        if t_allreduce(mid, p, model, torus=True) > t_ring_allreduce(mid, p, model):
            hi = mid
        else:
            lo = mid
    return hi
