"""``plan()`` — compile a :class:`CollectiveSpec` into an executable plan.

This is the execute half of the plan/execute API (see ``core.spec``).  A
``CollectivePlan`` is everything Algorithm 1/2 precomputes before any data
moves, resolved ONCE per ``(spec, p, axis_name)`` and memoized:

* the resolved Corollary-2 skip sequence and per-round
  :class:`~repro.core.schedule.RoundPlan`s for both phases;
* per-round send/recv BLOCK INDEX TABLES — for every round, exactly which
  rotated block indices leave and arrive (Theorem 1's partition of the
  p-1 non-resident blocks, property-tested across all schedules);
* for non-uniform ``counts`` (paper Corollary 3), per-round ROW index
  tables: the per-rank gather/scatter row sets that pack each round's
  ragged send window into one fixed-width wire buffer (SPMD needs static
  shapes, so the wire width is the worst windowed count sum — exactly the
  quantity Corollary 3's bound maximizes over);
* for a p×p per-pair ``counts`` MATRIX (alltoallv, paper §4 ragged), an
  :class:`A2APlan`: seed/round/output row tables over the absolute
  (src, dst) pair layout, walking ``schedule.alltoall_moves`` — same
  one-ppermute-per-round discipline, Bruck hop amplification and all;
* the wire-format layout (int8 codes + packed scale bytes) and a backend
  from a small registry (``jnp``, ``fused``, ``jnp+int8``, ``fused+int8``,
  ``nonuniform``, plus the baseline kinds).

Execution (``plan.reduce_scatter(x)`` etc.) then just replays the tables:
one ``collective-permute`` per round, same HLO structure as the original
kwarg API (asserted by the conformance harness and the CI ``plans`` gate).

Plans are cached with ``functools.lru_cache`` — repeated calls with the
same spec are trace-time dict hits, so spec-driven dispatch adds zero
retraces and zero extra collectives.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.kernels import (fused_round, fused_round_dq, pack_wire, pad2d,
                           permute_rows, quantize_rows, resolve_fused,
                           unpack_wire)
from repro.kernels import ref as _kref
from .schedule import (RoundPlan, allgather_plan, alltoall_moves,
                       reduce_scatter_plan)
from .spec import CollectiveSpec, as_spec

Array = jax.Array
ReduceFn = Callable[[Array, Array], Array]

_REDUCERS: dict[str, ReduceFn] = {
    "add": lambda a, b: a + b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}

#: ops the scatter-fold (non-uniform) and fused/wire backends support.
NAMED_OPS = tuple(_REDUCERS)


def resolve_op(op) -> ReduceFn:
    """Named-or-callable ⊕ resolution (the single kwarg-era helper left;
    every backend goes through it)."""
    if callable(op):
        return op
    try:
        return _REDUCERS[op]
    except KeyError:
        raise ValueError(f"unknown reduce op {op!r}") from None


def _fwd_perm(p: int, s: int) -> list[tuple[int, int]]:
    """Data on rank i goes to rank (i + s) mod p  (paper's to-processor)."""
    return [(i, (i + s) % p) for i in range(p)]


def _bwd_perm(p: int, s: int) -> list[tuple[int, int]]:
    """Data on rank i goes to rank (i - s) mod p  (allgather phase)."""
    return [(i, (i - s) % p) for i in range(p)]


# ---------------------------------------------------------------------------
# Block layout — THE padding path (uniform and non-uniform share it)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BlockLayout:
    """Per-rank block row counts along the leading axis.

    The one place block geometry is derived from: ``pad_to_multiple`` /
    ``_as_blocks`` (uniform), the non-uniform row tables (Corollary 3),
    and the ZeRO-1 leaf padding all consume a layout instead of
    re-deriving ``ceil(n/p)`` locally.
    """

    counts: tuple[int, ...]

    @classmethod
    def uniform(cls, p: int, n: int) -> "BlockLayout":
        """Equal blocks of ``ceil(n/p)`` rows (zero-pad to fit)."""
        if p < 1:
            raise ValueError(f"p must be >= 1, got {p}")
        b = -(-n // p) if n else 0
        return cls(counts=(b,) * p)

    @property
    def p(self) -> int:
        return len(self.counts)

    @property
    def total(self) -> int:
        return sum(self.counts)

    @property
    def bmax(self) -> int:
        return max(self.counts)

    @property
    def offsets(self) -> tuple[int, ...]:
        """Row offset of each block (plus the total as a sentinel)."""
        off, acc = [], 0
        for c in self.counts:
            off.append(acc)
            acc += c
        off.append(acc)
        return tuple(off)

    @property
    def is_uniform(self) -> bool:
        return len(set(self.counts)) <= 1

    def pad(self, x: Array) -> tuple[Array, int]:
        """Zero-pad the leading axis of ``x`` up to ``total`` rows."""
        n = x.shape[0]
        pad = self.total - n
        if pad < 0:
            raise ValueError(
                f"input has {n} rows, layout holds only {self.total}")
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
        return x, pad

    def as_blocks(self, x: Array) -> Array:
        """Reshape the leading axis into (p, bmax, *rest) — uniform only."""
        if not self.is_uniform:
            raise ValueError(
                f"non-uniform layout {self.counts} cannot reshape to "
                f"equal blocks; use the row tables")
        n, p = x.shape[0], self.p
        if n != self.total:
            raise ValueError(
                f"leading dim {n} not divisible by axis size {p}; pad first "
                f"(see pad_to_multiple)")
        return x.reshape(p, self.bmax, *x.shape[1:])

    def window_rows(self, window: Sequence[int]) -> np.ndarray:
        """Per-rank row index table for a rotated block window.

        Row ``r`` lists, in block order, the absolute row indices of
        blocks ``(r + i) mod p`` for ``i`` in ``window``, padded with the
        sentinel ``total`` (a dummy row) to the worst-case window width —
        the quantity Corollary 3's round bound maximizes over.
        """
        p, off, total = self.p, self.offsets, self.total
        widths = [sum(self.counts[(r + i) % p] for i in window)
                  for r in range(p)]
        W = max(widths) if widths else 0
        tab = np.full((p, max(W, 1)), total, dtype=np.int32)
        for r in range(p):
            j = 0
            for i in window:
                c = (r + i) % p
                tab[r, j:j + self.counts[c]] = np.arange(
                    off[c], off[c] + self.counts[c], dtype=np.int32)
                j += self.counts[c]
        return tab


# ---------------------------------------------------------------------------
# Alltoall(v) geometry — per-pair counts compiled to row tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class A2APlan:
    """Trace-time geometry of a ragged alltoallv (per-pair ``counts``).

    The per-rank buffer holds the FULL absolute (src, dst) pair layout
    (``total`` rows + one sentinel row); each rank only ever populates the
    rows of entries it currently holds.  ``round_tables[k]`` is the
    ``(p, W_k)`` absolute-row table of round k: row r lists the buffer
    rows rank r gathers into the wire (its entries hopping this round,
    in ``alltoall_moves`` order), sentinel-padded to the worst windowed
    count sum ``W_k`` over ranks — SPMD needs one static wire shape, and
    that max is exactly the per-round quantity the Corollary 3 style
    bound maximizes over.  Sender and receiver store every entry at the
    same absolute rows, so the receive table of rank r is row
    ``(r - skip) mod p`` of the SAME table.
    """

    counts: tuple[tuple[int, ...], ...]   # [src][dst] rows
    pair_offsets: np.ndarray              # (p, p) absolute row of each pair
    total: int                            # sum of all counts
    send_total: tuple[int, ...]           # per-src row sum
    recv_total: tuple[int, ...]           # per-dst row sum
    in_height: int                        # static input rows: max send_total
    out_height: int                       # static output rows: max recv_total
    seed_src: np.ndarray                  # (p, in_height) input rows gathered
    seed_dst: np.ndarray                  # (p, in_height) buffer rows written
    round_tables: tuple[np.ndarray, ...]  # (p, W_k) wire gather/scatter rows
    out_rows: np.ndarray                  # (p, out_height) output gather rows

    @property
    def round_widths(self) -> tuple[int, ...]:
        """Per-round wire width (rows) — the worst windowed count sum."""
        return tuple(t.shape[1] for t in self.round_tables)


def _build_a2a(counts: tuple[tuple[int, ...], ...], p: int,
               schedule: str, group: int | None) -> A2APlan:
    moves = alltoall_moves(p, schedule, group)
    offs = np.zeros((p, p), np.int64)
    acc = 0
    for s in range(p):
        for dcol in range(p):
            offs[s, dcol] = acc
            acc += counts[s][dcol]
    total = acc
    send_total = tuple(sum(row) for row in counts)
    recv_total = tuple(sum(counts[s][dcol] for s in range(p))
                       for dcol in range(p))
    in_h = max(max(send_total), 1)
    out_h = max(max(recv_total), 1)

    # Seed: rank r's input rows (dst-ordered, rows [0, send_total[r]))
    # scatter into the absolute pair layout; sentinel-padded.
    seed_src = np.full((p, in_h), in_h, dtype=np.int32)   # input sentinel
    seed_dst = np.full((p, in_h), total, dtype=np.int32)  # buffer sentinel
    for r in range(p):
        j = 0
        for dcol in range(p):
            c = counts[r][dcol]
            seed_src[r, j:j + c] = np.arange(j, j + c, dtype=np.int32)
            seed_dst[r, j:j + c] = np.arange(
                offs[r, dcol], offs[r, dcol] + c, dtype=np.int32)
            j += c

    # Table widths come from the cost model's analytic bound (ONE
    # implementation of the worst-windowed-count-sum formula); the row
    # fill below would overrun a too-small width, so the CI width gate
    # stays a real consistency check rather than a copy comparing itself.
    from .cost_model import alltoallv_round_widths
    widths = alltoallv_round_widths(counts, schedule, group)
    tables = []
    for (_, moved), W in zip(moves, widths):
        tab = np.full((p, W), total, dtype=np.int32)
        for r in range(p):
            j = 0
            for d, m in moved:
                src = (r - m) % p
                dst = (src + d) % p
                c = counts[src][dst]
                tab[r, j:j + c] = np.arange(
                    offs[src, dst], offs[src, dst] + c, dtype=np.int32)
                j += c
            assert j <= W, (j, W)
        tables.append(tab)

    out_rows = np.full((p, out_h), total, dtype=np.int32)
    for r in range(p):
        j = 0
        for src in range(p):
            c = counts[src][r]
            out_rows[r, j:j + c] = np.arange(
                offs[src, r], offs[src, r] + c, dtype=np.int32)
            j += c
    return A2APlan(counts=counts, pair_offsets=offs, total=total,
                   send_total=send_total, recv_total=recv_total,
                   in_height=in_h, out_height=out_h,
                   seed_src=seed_src, seed_dst=seed_dst,
                   round_tables=tuple(tables), out_rows=out_rows)


# ---------------------------------------------------------------------------
# The compiled plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class CollectivePlan:
    """Compiled, cached form of a :class:`CollectiveSpec` at axis size p.

    ``rs_send_blocks[k]`` / ``rs_recv_blocks[k]`` are the rotated block
    indices moved in reduce-scatter round k (``ag_*`` likewise for the
    reversed allgather phase); over all rounds the send sets partition
    ``{1, .., p-1}`` exactly (Theorem 1, property-tested).  For
    non-uniform counts, ``rs_row_tables[k]`` is the per-rank
    ``(p, W_k)`` absolute-row gather/scatter table realizing those block
    sets at row granularity.
    """

    spec: CollectiveSpec
    p: int
    axis_name: str
    backend: str
    skips: tuple[int, ...]
    rs_rounds: tuple[RoundPlan, ...]
    ag_rounds: tuple[RoundPlan, ...]
    rs_send_blocks: tuple[tuple[int, ...], ...]
    rs_recv_blocks: tuple[tuple[int, ...], ...]
    ag_send_blocks: tuple[tuple[int, ...], ...]
    ag_recv_blocks: tuple[tuple[int, ...], ...]
    layout: BlockLayout | None          # non-None iff flat spec.counts given
    rs_row_tables: tuple[np.ndarray, ...] | None
    ag_row_tables: tuple[np.ndarray, ...] | None
    a2a: A2APlan | None = None          # non-None iff matrix spec.counts

    # -- layout funnel -----------------------------------------------------

    def layout_for(self, n: int) -> BlockLayout:
        """The layout governing an ``n``-row payload under this plan."""
        if self.layout is not None:
            return self.layout
        return BlockLayout.uniform(self.p, n)

    # -- execution ---------------------------------------------------------

    def reduce_scatter(self, x: Array, *, compress=None,
                       decompress=None) -> Array:
        """Paper Algorithm 1 under this plan (one ppermute per round)."""
        self._check_hooks(compress, decompress)
        self._check_not_a2a("reduce_scatter")
        if self.backend in _BASELINE_RS:
            return _BASELINE_RS[self.backend](self, x)
        if self.p == 1:
            return x
        if self.backend == "nonuniform":
            return _rs_nonuniform(self, x)
        _check_wire_payload(self, x)
        r = lax.axis_index(self.axis_name)
        R = jnp.roll(self.layout_for(x.shape[0]).as_blocks(x), -r, axis=0)
        if self.backend in ("jnp+int8", "fused+int8"):
            return _rs_wire(self, R)
        if self.backend == "fused":
            return _rs_fused(self, R, compress, decompress)
        return _rs_jnp(self, R, compress, decompress)

    def allgather(self, x: Array) -> Array:
        """Algorithm 2's second phase (reversed skip stack) standalone."""
        self._check_not_a2a("allgather")
        if self.backend in _BASELINE_AG:
            return _BASELINE_AG[self.backend](self, x)
        if self.p == 1:
            return x
        if self.backend == "nonuniform":
            return _ag_nonuniform(self, x)
        _check_wire_payload(self, x)
        if self.backend in ("jnp+int8", "fused+int8"):
            return _ag_wire(self, x)
        return _ag_plain(self, x)

    def allreduce(self, x: Array, *, compress=None, decompress=None) -> Array:
        """Paper Algorithm 2: reduce-scatter + reversed allgather."""
        if self.backend in _BASELINE_AR:
            return _BASELINE_AR[self.backend](self, x)
        w = self.reduce_scatter(x, compress=compress, decompress=decompress)
        return self.allgather(w)

    def alltoall(self, x: Array) -> Array:
        """All-to-all by concatenation (paper §4): Algorithm 1 with ⊕ =
        concat.

        Uniform form (``counts=None``): ``x`` is ``(p, blk, *rest)``, row
        j is this rank's payload for rank j; returns the same shape with
        row j the payload FROM rank j.  Ragged form (p×p ``counts``
        matrix, MPI_Alltoallv): ``x`` is ``(in_height, *rest)`` — this
        rank's payload rows concatenated in destination order in rows
        ``[0, send_total[r])`` — and the result is ``(out_height, *rest)``
        with the received rows concatenated in source order, zeroed past
        this rank's receive total.  Backends come from the ``_A2A_IMPLS``
        registry (jnp / fused / alltoallv / xla baseline).
        """
        if self.spec.wired:
            raise NotImplementedError(
                "alltoall does not support wire_dtype (blocks hop through "
                "intermediate ranks; requantizing per hop would compound "
                "the error)")
        if self.layout is not None:
            raise NotImplementedError(
                "alltoall does not support flat (Corollary 3) counts; "
                "pass a p×p per-pair counts matrix for alltoallv")
        impl = _A2A_IMPLS.get(self.backend)
        if impl is None:
            raise ValueError(
                f"backend {self.backend!r} does not implement alltoall; "
                f"have {sorted(_A2A_IMPLS)}")
        if self.p == 1:
            return x
        return impl(self, x)

    # -- validation helpers ------------------------------------------------

    def _check_not_a2a(self, fn: str) -> None:
        if self.a2a is not None:
            raise ValueError(
                f"a p×p per-pair counts matrix is alltoall(v)-only; "
                f"{fn} takes flat per-rank counts (Corollary 3)")

    def _check_hooks(self, compress, decompress) -> None:
        if compress is None and decompress is None:
            return
        if self.spec.wired:
            raise ValueError(
                "wire_dtype and compress/decompress hooks are mutually "
                "exclusive")
        if self.backend == "nonuniform":
            raise ValueError(
                "compress/decompress hooks do not support non-uniform "
                "counts")
        if self.spec.kind != "circulant":
            raise ValueError(
                f"compress/decompress hooks need kind='circulant' "
                f"(per-round payloads), got {self.spec.kind!r}")


def _check_wire_payload(plan: CollectivePlan, x: Array) -> None:
    """int8 wire needs float payloads (quantization grid); checked at
    execution because the spec is payload-agnostic."""
    if plan.spec.wired and not jnp.issubdtype(x.dtype, jnp.floating):
        raise ValueError(
            f"wire_dtype='int8' needs a float payload, got {x.dtype}")


# ---------------------------------------------------------------------------
# plan(): spec -> CollectivePlan, memoized
# ---------------------------------------------------------------------------

_BASELINE_KINDS = ("ring", "recursive_halving", "xla")


def _resolve_backend(spec: CollectiveSpec) -> str:
    """Backend registry key for a spec (the one place the kwarg-era
    ``_resolve_op``/``_check_wire`` decision tables live on)."""
    if spec.kind in _BASELINE_KINDS:
        return spec.kind
    if spec.counts_matrix:
        if spec.wire_dtype is not None:
            raise ValueError(
                "alltoallv (per-pair counts) does not support wire_dtype "
                "(blocks hop through intermediate ranks; requantizing per "
                "hop would compound the error)")
        if spec.use_fused_kernel is True:
            raise ValueError(
                "use_fused_kernel does not support per-pair counts (the "
                "ragged wire is table-gathered, not slot-stacked)")
        return "alltoallv"
    if spec.counts is not None:
        if spec.wire_dtype is not None:
            raise ValueError(
                "non-uniform counts and wire_dtype cannot be combined yet "
                "(quantization groups would straddle ragged blocks)")
        if spec.use_fused_kernel is True:
            raise ValueError(
                "use_fused_kernel does not support non-uniform counts "
                "(the fused round kernel assumes equal blocks)")
        if spec.op not in NAMED_OPS:
            raise ValueError(
                f"non-uniform counts need a named op {NAMED_OPS}, "
                f"got {spec.op!r}")
        return "nonuniform"
    if spec.wire_dtype is not None:
        if not isinstance(spec.op, str):
            raise ValueError(
                f"wire_dtype needs a named op ('add'/'max'/'min'), "
                f"got {spec.op!r}")
        return ("fused+int8" if resolve_fused(spec.use_fused_kernel)
                else "jnp+int8")
    if resolve_fused(spec.use_fused_kernel):
        if not isinstance(spec.op, str):
            if spec.use_fused_kernel:
                # Explicit request only — auto silently keeps the jnp path.
                raise ValueError(
                    "use_fused_kernel needs a named op ('add'/'max'/'min'), "
                    f"got callable {spec.op!r}")
            return "jnp"
        return "fused"
    return "jnp"


@functools.lru_cache(maxsize=4096)
def _plan_cached(spec: CollectiveSpec, p: int, axis_name: str
                 ) -> CollectivePlan:
    backend = _resolve_backend(spec)
    if spec.kind in _BASELINE_KINDS:
        return CollectivePlan(
            spec=spec, p=p, axis_name=axis_name, backend=backend,
            skips=(), rs_rounds=(), ag_rounds=(),
            rs_send_blocks=(), rs_recv_blocks=(),
            ag_send_blocks=(), ag_recv_blocks=(),
            layout=None, rs_row_tables=None, ag_row_tables=None)

    rs = reduce_scatter_plan(p, spec.schedule, spec.group)
    ag = allgather_plan(p, spec.schedule, spec.group)
    rs_send = tuple(tuple(range(pl.lo, pl.hi)) for pl in rs)
    rs_recv = tuple(tuple(range(0, pl.nblocks)) for pl in rs)
    ag_send = tuple(tuple(range(0, pl.nblocks)) for pl in ag)
    ag_recv = tuple(tuple(range(pl.lo, pl.hi)) for pl in ag)

    layout = rs_tables = ag_tables = a2a = None
    if spec.counts is not None:
        if len(spec.counts) != p:
            raise ValueError(
                f"counts has {len(spec.counts)} entries for axis size {p}")
        if spec.counts_matrix:
            a2a = _build_a2a(spec.counts, p, spec.schedule, spec.group)
        else:
            layout = BlockLayout(counts=spec.counts)
            rs_tables = tuple(layout.window_rows(w) for w in rs_send)
            ag_tables = tuple(layout.window_rows(w) for w in ag_send)

    return CollectivePlan(
        spec=spec, p=p, axis_name=axis_name, backend=backend,
        skips=tuple(pl.skip for pl in rs), rs_rounds=rs, ag_rounds=ag,
        rs_send_blocks=rs_send, rs_recv_blocks=rs_recv,
        ag_send_blocks=ag_send, ag_recv_blocks=ag_recv,
        layout=layout, rs_row_tables=rs_tables, ag_row_tables=ag_tables,
        a2a=a2a)


def plan(spec: CollectiveSpec | None = None, p: int | None = None,
         axis_name: str | None = None, **kw) -> CollectivePlan:
    """Compile ``spec`` for axis ``axis_name`` of size ``p`` (cached).

    ``p`` may be omitted inside a shard_map region (resolved from the
    axis).  Bare kwargs build the spec in place::

        plan(p=8, axis_name="x", schedule="power2").reduce_scatter(x)
    """
    spec = as_spec(spec, **kw)
    if axis_name is None:
        raise ValueError("plan() needs an axis_name")
    if p is None:
        p = compat.axis_size(axis_name)
    return _plan_cached(spec, int(p), axis_name)


# Cache introspection rides on plan() itself: ``plan.cache_stats()`` /
# ``plan.clear()``.  Both proxy the lru_cache on _plan_cached, so an
# identity assertion like ``plan(s, ...) is plan(s, ...)`` plus a
# hits/misses delta from cache_stats() observes the same cache.
plan.cache_stats = _plan_cached.cache_info
plan.clear = _plan_cached.cache_clear


def plan_cache_info():
    """Deprecated alias — use ``plan.cache_stats()``."""
    return plan.cache_stats()


def plan_cache_clear() -> None:
    """Deprecated alias — use ``plan.clear()``."""
    plan.clear()


# ---------------------------------------------------------------------------
# Uniform circulant backends (ported verbatim from the kwarg-era loops —
# identical round structure, ppermute sequence and arithmetic)
# ---------------------------------------------------------------------------

def _rs_jnp(plan: CollectivePlan, R: Array, compress, decompress) -> Array:
    """Algorithm 1's round loop, plain jnp ops (always available)."""
    reduce_fn = resolve_op(plan.spec.op)
    p = plan.p
    for pl in plan.rs_rounds:
        payload = R[pl.lo:pl.hi]
        if compress is not None:
            payload = compress(payload)
        T = compat.ppermute(payload, plan.axis_name, _fwd_perm(p, pl.skip))
        if decompress is not None:
            T = decompress(T)
        nb = pl.nblocks
        head = reduce_fn(R[:nb], T)
        R = head if nb == pl.lo else jnp.concatenate([head, R[nb:pl.lo]],
                                                     axis=0)
    return R[0]


def _rs_fused(plan: CollectivePlan, R: Array, compress, decompress) -> Array:
    """Algorithm 1's round loop on the fused Pallas kernel.

    The rotated block buffer is viewed as 2-D ``(blocks, block_numel)``;
    after the prologue slice every round is ppermute → fused_round, with
    the kernel emitting both the shrunken live buffer and the next
    round's contiguous payload.  Identical values and ppermute sequence
    to the jnp path — only the local data movement is fused.
    """
    p, op = plan.p, plan.spec.op
    blk_shape = R.shape[1:]
    R2 = R.reshape(p, -1)
    plans = plan.rs_rounds
    live = R2[: plans[0].lo]
    send = R2[plans[0].lo : plans[0].hi]
    for k, pl in enumerate(plans):
        payload = send if compress is None else compress(send)
        T = compat.ppermute(payload, plan.axis_name, _fwd_perm(p, pl.skip))
        if decompress is not None:
            T = decompress(T)
        if T.dtype != live.dtype:
            # Match the jnp path, whose concatenate promotes the buffer
            # (e.g. bf16 live vs f32 decompressed payload).
            dt = jnp.result_type(live.dtype, T.dtype)
            live, T = live.astype(dt), T.astype(dt)
        next_lo = plans[k + 1].lo if k + 1 < len(plans) else pl.lo
        live, send = fused_round(live, T, nb=pl.nblocks, next_lo=next_lo,
                                 op=op)
    return live[0].reshape(blk_shape)


def _rs_wire(plan: CollectivePlan, R: Array) -> Array:
    """Algorithm 1's round loop on the int8 wire format.

    The rotated block buffer is promoted to an f32 (blocks, block_numel)
    accumulation buffer whose columns are padded to a whole number of
    quantization groups.  Every round then ppermutes ONE packed int8
    buffer ([codes | scale bytes], see kernels.quantize) and runs a
    single dequantize + ⊕-fold + requantize-next-send pass — the Pallas
    ``fused_round_dq`` kernel on the fused backend, its jnp oracle
    otherwise (bitwise-identical arithmetic; both jitted).  Round count
    and ppermute sequence match the uncompressed path exactly.
    """
    p, op = plan.p, plan.spec.op
    fused = plan.backend == "fused+int8"
    blk_shape, out_dtype = R.shape[1:], R.dtype
    R2 = R.reshape(p, -1).astype(jnp.float32)
    cols = R2.shape[1]
    g = min(plan.spec.wire_group, cols)
    R2 = pad2d(R2, 1, g)
    plans = plan.rs_rounds
    live = R2[: plans[0].lo]
    first = R2[plans[0].lo : plans[0].hi]
    if fused:
        codes, scales = quantize_rows(first, group=g)
    else:
        codes, scales = _kref.quantize_ref(first, group=g)
    wire = pack_wire(codes, scales)
    for k, pl in enumerate(plans):
        Tw = compat.ppermute(wire, plan.axis_name, _fwd_perm(p, pl.skip))
        rc, rs = unpack_wire(Tw, live.shape[1], group=g)
        next_lo = plans[k + 1].lo if k + 1 < len(plans) else pl.lo
        if fused:
            live, send = fused_round_dq(live, rc, rs, nb=pl.nblocks,
                                        next_lo=next_lo, op=op, group=g)
        else:
            live, send = _kref.fused_round_dq_ref(live, rc, rs,
                                                  nb=pl.nblocks,
                                                  next_lo=next_lo, op=op,
                                                  group=g)
        if send is not None:
            wire = pack_wire(*send)
    out = live[0]
    if cols != R2.shape[1]:
        out = out[:cols]
    return out.reshape(blk_shape).astype(out_dtype)


def _ag_plain(plan: CollectivePlan, x: Array) -> Array:
    """Allgather rounds, uncompressed.

    Allgather has no ⊕, so its fused form needs no Pallas: the growing
    concat chain (which recopies the whole buffer every round — O(p log p)
    block traffic) becomes static in-place updates of one preallocated
    (p, blk) buffer (O(p) traffic; XLA turns the static-index
    dynamic-update-slice into an in-place write under jit).  Send payloads
    are buffer prefixes, already contiguous.
    """
    p = plan.p
    r = lax.axis_index(plan.axis_name)
    if plan.backend == "fused":
        buf = jnp.zeros((p, *x.shape), x.dtype)
        buf = lax.dynamic_update_slice_in_dim(buf, x[None], 0, axis=0)
        for pl in plan.ag_rounds:
            payload = lax.slice_in_dim(buf, 0, pl.nblocks, axis=0)
            T = compat.ppermute(payload, plan.axis_name,
                                _bwd_perm(p, pl.skip))
            # Received blocks land at rows [lo, hi) = [skip, prev bound).
            buf = lax.dynamic_update_slice_in_dim(buf, T, pl.lo, axis=0)
        out = jnp.roll(buf, r, axis=0)
        return out.reshape(p * x.shape[0], *x.shape[1:])
    R = x[None]  # (1, blk, *rest) — rotated coords: R[i] = block of (r+i)
    for pl in plan.ag_rounds:
        payload = R[:pl.nblocks]
        T = compat.ppermute(payload, plan.axis_name, _bwd_perm(p, pl.skip))
        R = jnp.concatenate([R, T], axis=0)
    out = jnp.roll(R, r, axis=0)  # un-rotate: out[j] = block of rank j
    return out.reshape(p * x.shape[0], *x.shape[1:])


def _ag_wire(plan: CollectivePlan, x: Array) -> Array:
    """Allgather on the int8 wire format.

    Allgather has no ⊕, so each rank quantizes its own block ONCE; the
    rounds then move the packed int8 wire rows unmodified (every element
    is quantized exactly once — the error is a single quantization step).
    The fused backend selects the preallocated-buffer round structure
    (static in-place updates) vs the concat chain — both move identical
    bytes and one ppermute per round.  All ranks dequantize the same
    codes, so the gathered result is bitwise-replicated (Theorem 2's
    invariant survives compression).
    """
    p = plan.p
    fused = plan.backend == "fused+int8"
    r = lax.axis_index(plan.axis_name)
    x2 = x.reshape(1, -1).astype(jnp.float32)
    cols = x2.shape[1]
    g = min(plan.spec.wire_group, cols)
    x2 = pad2d(x2, 1, g)
    if fused:
        codes, scales = quantize_rows(x2, group=g)
    else:
        codes, scales = _kref.quantize_ref(x2, group=g)
    row = pack_wire(codes, scales)                 # (1, wc) int8
    wc = row.shape[1]
    if fused:
        buf = jnp.zeros((p, wc), jnp.int8)
        buf = lax.dynamic_update_slice_in_dim(buf, row, 0, axis=0)
        for pl in plan.ag_rounds:
            payload = lax.slice_in_dim(buf, 0, pl.nblocks, axis=0)
            T = compat.ppermute(payload, plan.axis_name,
                                _bwd_perm(p, pl.skip))
            buf = lax.dynamic_update_slice_in_dim(buf, T, pl.lo, axis=0)
    else:
        buf = row
        for pl in plan.ag_rounds:
            payload = buf[:pl.nblocks]
            T = compat.ppermute(payload, plan.axis_name,
                                _bwd_perm(p, pl.skip))
            buf = jnp.concatenate([buf, T], axis=0)
    codes, scales = unpack_wire(buf, x2.shape[1], group=g)
    vals = _kref.dequant_ref(codes, scales, group=g)   # (p, cols_pad) f32
    if cols != x2.shape[1]:
        vals = vals[:, :cols]
    out = jnp.roll(vals, r, axis=0)  # un-rotate: out[j] = block of rank j
    return out.reshape(p * x.shape[0], *x.shape[1:]).astype(x.dtype)


# ---------------------------------------------------------------------------
# All-to-all by concatenation (paper §4)
# ---------------------------------------------------------------------------

def _a2a_jnp(plan: CollectivePlan, x: Array) -> Array:
    """Bruck-style rounds: trace-time bookkeeping keeps, per live slot,
    the list of (source-offset, array) pairs — the concatenation operator
    materialized as Python lists of same-shaped arrays, so every round is
    still a single fused ppermute over a stacked payload.  Volume is
    (p/2)*ceil(log2 p) blocks per rank (the classic Bruck trade-off:
    round-optimal, not volume-optimal)."""
    p = plan.p
    r = lax.axis_index(plan.axis_name)
    rot = jnp.roll(x, -r, axis=0)  # rot[i] = payload for dest (r+i)
    # slots[i]: list of (offset o, payload) — payload originated at (r+o).
    slots: list[list[tuple[int, Array]]] = [[(0, rot[i])] for i in range(p)]
    for pl in plan.rs_rounds:
        s = pl.skip
        # Stack every array sent this round into ONE ppermute payload.
        send_entries = [e for i in range(pl.lo, pl.hi) for e in slots[i]]
        stacked = jnp.stack([a for (_, a) in send_entries], axis=0)
        T = compat.ppermute(stacked, plan.axis_name, _fwd_perm(p, s))
        # Unstack with shifted source offsets; ⊕ = list concatenation.
        idx = 0
        for j in range(pl.nblocks):
            src_slot = pl.lo + j
            for (o, _) in slots[src_slot]:
                slots[j].append((((o - s) % p), T[idx]))
                idx += 1
        assert idx == len(send_entries)
        del slots[pl.lo:]  # slots [lo, hi) were sent; live = [0, s)
    entries = slots[0]
    assert len(entries) == p, f"expected {p} payloads, got {len(entries)}"
    ordered = [a for (_, a) in sorted(entries, key=lambda e: e[0])]
    stacked = jnp.stack(ordered, axis=0)  # stacked[o] = payload from (r+o)
    return jnp.roll(stacked, r, axis=0)   # row j = payload from rank j


def _a2a_fused(plan: CollectivePlan, x: Array) -> Array:
    """Bruck-style rounds over stacked slot buffers (fused alltoall).

    slots[i] is one (count_i, blk) array; offs[i] is the parallel Python
    list of source offsets.  Entry order inside each slot matches the
    unfused list-of-arrays path exactly, so results are bitwise-equal.
    """
    p = plan.p
    r = lax.axis_index(plan.axis_name)
    blk_shape = x.shape[1:]
    rot = jnp.roll(x, -r, axis=0)
    rot2 = rot.reshape(p, -1)
    slots = [lax.slice_in_dim(rot2, i, i + 1, axis=0) for i in range(p)]
    offs: list[list[int]] = [[0] for _ in range(p)]
    for pl in plan.rs_rounds:
        s = pl.skip
        send = (slots[pl.lo] if pl.nblocks == 1 else
                jnp.concatenate(slots[pl.lo:pl.hi], axis=0))
        T = compat.ppermute(send, plan.axis_name, _fwd_perm(p, s))
        idx = 0
        for j in range(pl.nblocks):
            src_slot = pl.lo + j
            cnt = len(offs[src_slot])
            piece = lax.slice_in_dim(T, idx, idx + cnt, axis=0)
            slots[j] = jnp.concatenate([slots[j], piece], axis=0)
            offs[j] = offs[j] + [(o - s) % p for o in offs[src_slot]]
            idx += cnt
        assert idx == T.shape[0]
        del slots[pl.lo:], offs[pl.lo:]
    assert slots[0].shape[0] == p, \
        f"expected {p} payloads, got {slots[0].shape[0]}"
    order = sorted(range(p), key=lambda i: offs[0][i])
    ordered = permute_rows(slots[0], order)  # ordered[o] = from (r+o)
    out = jnp.roll(ordered, r, axis=0)       # row j = payload from rank j
    return out.reshape(p, *blk_shape)


def _a2a_v(plan: CollectivePlan, x: Array) -> Array:
    """Ragged alltoallv over the per-pair counts matrix.

    Same table discipline as the Corollary 3 reduce-scatter: the buffer
    stays in ABSOLUTE (src, dst) pair order, round k gathers this rank's
    hopping rows through ``a2a.round_tables[k]`` into one fixed-width
    wire buffer (width = the worst windowed count sum over ranks),
    ppermutes it once, and scatter-SETS the received rows through the
    sender's view of the same table (no ⊕ — payloads move verbatim, so
    any dtype works).  Exactly one collective-permute per round —
    ``ceil(log2 p)`` for the optimal schedules, ragged counts included.

    Input ``(in_height, *rest)``: rank r's payload rows, concatenated in
    destination order, in rows ``[0, send_total[r])``.  Output
    ``(out_height, *rest)``: received rows concatenated in source order,
    zeroed past ``recv_total[r]`` (SPMD shapes are rank-invariant;
    callers slice with their static count when they know it).
    """
    a2a, p = plan.a2a, plan.p
    if x.shape[0] != a2a.in_height:
        raise ValueError(
            f"input has {x.shape[0]} rows, counts matrix needs "
            f"in_height={a2a.in_height} (= max per-rank send total)")
    r = lax.axis_index(plan.axis_name)
    blk_shape = x.shape[1:]
    x2 = x.reshape(a2a.in_height, -1)
    cols = x2.shape[1]
    # Input sentinel row (read by seed padding) and buffer sentinel row
    # (written by wire padding, read by gather padding; never data).
    xpad = jnp.concatenate([x2, jnp.zeros((1, cols), x2.dtype)], axis=0)
    buf = jnp.zeros((a2a.total + 1, cols), x2.dtype)
    buf = buf.at[_take_row(a2a.seed_dst, r)].set(
        jnp.take(xpad, _take_row(a2a.seed_src, r), axis=0))
    for k, pl in enumerate(plan.rs_rounds):
        table = a2a.round_tables[k]
        send_rows = _take_row(table, r)
        payload = jnp.take(buf, send_rows, axis=0)
        T = compat.ppermute(payload, plan.axis_name, _fwd_perm(p, pl.skip))
        # Sender (r - skip) gathered exactly the rows this rank must
        # store — both address the same absolute pair layout, so the
        # receive table IS the sender's row of the send table.
        recv_rows = _take_row(table, (r - pl.skip) % p)
        buf = buf.at[recv_rows].set(T)
    out = jnp.take(buf, _take_row(a2a.out_rows, r), axis=0)
    cnt = _take_row(np.asarray(a2a.recv_total, np.int32), r)
    mask = jnp.arange(a2a.out_height) < cnt
    out = jnp.where(mask.reshape(-1, *([1] * (out.ndim - 1))), out, 0)
    return out.reshape(a2a.out_height, *blk_shape)


# ---------------------------------------------------------------------------
# Non-uniform counts (paper Corollary 3) — gather/scatter over row tables
# ---------------------------------------------------------------------------

def _take_row(table: np.ndarray, idx) -> Array:
    """Row ``idx`` (traced rank expression) of a trace-time-constant
    table — one dynamic-slice, no gather fan-out."""
    return lax.dynamic_index_in_dim(jnp.asarray(table), idx, axis=0,
                                    keepdims=False)


def _scatter_fold(buf: Array, rows: Array, T: Array, op: str) -> Array:
    """Fold received wire rows into the buffer at ``rows``.  Real indices
    are unique within a round (each wire row is a distinct (column,
    offset) pair); padding rows all target the dummy sentinel row, which
    is never read back as data."""
    if op == "add":
        return buf.at[rows].add(T)
    if op == "max":
        return buf.at[rows].max(T)
    if op == "min":
        return buf.at[rows].min(T)
    raise ValueError(f"non-uniform counts need a named op, got {op!r}")


def _rs_nonuniform(plan: CollectivePlan, x: Array) -> Array:
    """Corollary 3: reduce-scatter with per-rank block sizes.

    The buffer stays in ABSOLUTE column order (no physical rotation —
    blocks have different sizes, so rotation is encoded in the row
    tables instead).  Round k gathers this rank's rows for the rotated
    send window into a fixed-width wire buffer (width = the worst
    windowed count sum over ranks — SPMD needs one static shape, and
    that max is exactly the per-round quantity Corollary 3 bounds),
    ppermutes it once, and scatter-⊕s the received rows through the
    receiving rank's view of the same table.  Exactly one
    collective-permute per round — Theorem 1's ceil(log2 p) rounds
    survive ragged counts unchanged.

    Input: ``(sum(counts), *rest)`` per rank.  Output:
    ``(max(counts), *rest)`` — this rank's reduced block in rows
    ``[0, counts[r])``, zero rows above (SPMD output shapes must be
    rank-invariant; callers slice with their static count when they
    know it).
    """
    layout, p, op = plan.layout, plan.p, plan.spec.op
    N, bmax = layout.total, layout.bmax
    if x.shape[0] != N:
        raise ValueError(
            f"input has {x.shape[0]} rows, counts {layout.counts} "
            f"need {N}")
    if p == 1:
        return x
    r = lax.axis_index(plan.axis_name)
    blk_shape = x.shape[1:]
    x2 = x.reshape(N, -1)
    cols = x2.shape[1]
    # Row N is the dummy sentinel: padding gathers read it, padding
    # scatters accumulate into it; it is never read back as data.
    buf = jnp.concatenate([x2, jnp.zeros((1, cols), x2.dtype)], axis=0)
    for k, pl in enumerate(plan.rs_rounds):
        table = plan.rs_row_tables[k]
        send_rows = _take_row(table, r)
        payload = jnp.take(buf, send_rows, axis=0)
        T = compat.ppermute(payload, plan.axis_name, _fwd_perm(p, pl.skip))
        # Sender (r - skip) packed exactly the columns this rank must
        # fold — and both store column c at the same absolute rows, so
        # the receive table IS the sender's row of the send table.
        recv_rows = _take_row(table, (r - pl.skip) % p)
        buf = _scatter_fold(buf, recv_rows, T, op)
    # Extract rows [off_r, off_r + counts[r]), padded to bmax and masked.
    ext = jnp.concatenate(
        [buf[:N], jnp.zeros((bmax, cols), x2.dtype)], axis=0)
    start = _take_row(np.asarray(layout.offsets[:p], np.int32), r)
    out = lax.dynamic_slice_in_dim(ext, start, bmax, axis=0)
    cnt = _take_row(np.asarray(layout.counts, np.int32), r)
    mask = jnp.arange(bmax) < cnt
    out = jnp.where(mask.reshape(bmax, *([1] * (out.ndim - 1))), out, 0)
    return out.reshape(bmax, *blk_shape)


def _ag_nonuniform(plan: CollectivePlan, x: Array) -> Array:
    """Allgather(v): inverse layout of :func:`_rs_nonuniform`.

    Input: ``(max(counts), *rest)`` — this rank's block in rows
    ``[0, counts[r])``.  Output: ``(sum(counts), *rest)``, all blocks in
    rank order, identical on every rank (no ⊕ — blocks move verbatim, so
    replication is bitwise).
    """
    layout, p = plan.layout, plan.p
    N, bmax = layout.total, layout.bmax
    if x.shape[0] != bmax:
        raise ValueError(
            f"input has {x.shape[0]} rows, counts {layout.counts} "
            f"need max(counts) = {bmax}")
    if p == 1:
        return x
    r = lax.axis_index(plan.axis_name)
    blk_shape = x.shape[1:]
    x2 = x.reshape(bmax, -1)
    cols = x2.shape[1]
    counts, offs = layout.counts, layout.offsets
    # Seed the (N + sentinel) buffer with this rank's own rows.
    src = np.full((p, bmax), bmax, dtype=np.int32)      # x2 row (or dummy)
    dst = np.full((p, bmax), N, dtype=np.int32)         # buf row (or dummy)
    for rr in range(p):
        src[rr, : counts[rr]] = np.arange(counts[rr], dtype=np.int32)
        dst[rr, : counts[rr]] = np.arange(
            offs[rr], offs[rr] + counts[rr], dtype=np.int32)
    xpad = jnp.concatenate([x2, jnp.zeros((1, cols), x2.dtype)], axis=0)
    buf = jnp.zeros((N + 1, cols), x2.dtype)
    buf = buf.at[_take_row(dst, r)].set(jnp.take(xpad, _take_row(src, r),
                                                 axis=0))
    for k, pl in enumerate(plan.ag_rounds):
        table = plan.ag_row_tables[k]
        send_rows = _take_row(table, r)
        payload = jnp.take(buf, send_rows, axis=0)
        T = compat.ppermute(payload, plan.axis_name, _bwd_perm(p, pl.skip))
        # Received from (r + skip): its send window covers exactly the
        # columns this rank is missing at rotated [skip, prev) — same
        # absolute rows, so the receive table is the sender's row.
        recv_rows = _take_row(table, (r + pl.skip) % p)
        buf = buf.at[recv_rows].set(T)
    return buf[:N].reshape(N, *blk_shape)


# ---------------------------------------------------------------------------
# Baseline backends (ring / recursive_halving / xla) — lazy import of the
# implementations in core.collectives (which imports this module)
# ---------------------------------------------------------------------------

def _baseline(fn_name: str):
    def run(plan: CollectivePlan, x: Array) -> Array:
        from repro.core import collectives as C
        fn = getattr(C, fn_name)
        return fn(x, plan.axis_name, op=plan.spec.op)
    return run


_BASELINE_RS = {
    "ring": _baseline("ring_reduce_scatter"),
    "recursive_halving": _baseline("recursive_halving_reduce_scatter"),
    "xla": _baseline("xla_reduce_scatter"),
}
_BASELINE_AR = {
    "ring": _baseline("ring_allreduce"),
    "xla": _baseline("xla_allreduce"),
}
_BASELINE_AG = {
    "xla": _baseline("xla_allgather"),
}
#: alltoall registry — the uniform circulant loops (lifted from the old
#: special cases in CollectivePlan.alltoall), the ragged table backend,
#: and XLA's native all-to-all as the A/B baseline.
_A2A_IMPLS = {
    "jnp": _a2a_jnp,
    "fused": _a2a_fused,
    "alltoallv": _a2a_v,
    "xla": _baseline("xla_alltoall"),
}

#: backend registry — what plan() can resolve a spec onto, and which
#: collectives each backend implements (introspection for the CI gate
#: and the docs; execution dispatches on the plan's ``backend`` field).
BACKENDS: dict[str, tuple[str, ...]] = {
    "jnp": ("reduce_scatter", "allgather", "allreduce", "alltoall"),
    "fused": ("reduce_scatter", "allgather", "allreduce", "alltoall"),
    "jnp+int8": ("reduce_scatter", "allgather", "allreduce"),
    "fused+int8": ("reduce_scatter", "allgather", "allreduce"),
    "nonuniform": ("reduce_scatter", "allgather", "allreduce"),
    "alltoallv": ("alltoall",),
    "ring": ("reduce_scatter", "allreduce"),
    "recursive_halving": ("reduce_scatter",),
    "xla": ("reduce_scatter", "allgather", "allreduce", "alltoall"),
}
