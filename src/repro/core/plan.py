"""``plan()`` — compile a :class:`CollectiveSpec` into an executable plan.

This is the execute half of the plan/execute API (see ``core.spec``).  A
``CollectivePlan`` is everything Algorithm 1/2 precomputes before any data
moves, resolved ONCE per ``(spec, p, axis_name)`` and memoized:

* the resolved Corollary-2 skip sequence and per-round
  :class:`~repro.core.schedule.RoundPlan`s for both phases;
* per-round send/recv BLOCK INDEX TABLES — for every round, exactly which
  rotated block indices leave and arrive (Theorem 1's partition of the
  p-1 non-resident blocks, property-tested across all schedules);
* for non-uniform ``counts`` (paper Corollary 3), per-round ROW index
  tables: the per-rank gather/scatter row sets that pack each round's
  ragged send window into one fixed-width wire buffer (SPMD needs static
  shapes, so the wire width is the worst windowed count sum — exactly the
  quantity Corollary 3's bound maximizes over);
* for a p×p per-pair ``counts`` MATRIX (alltoallv, paper §4 ragged), an
  :class:`A2APlan`: seed/round/output row tables over the absolute
  (src, dst) pair layout, walking ``schedule.alltoall_moves`` — same
  one-ppermute-per-round discipline, Bruck hop amplification and all;
* the wire-format layout (int8 codes + packed scale bytes) and a backend
  from a small registry (``jnp``, ``fused``, ``jnp+int8``, ``fused+int8``,
  ``nonuniform``, plus the baseline kinds).

Execution (``plan.reduce_scatter(x)`` etc.) then just replays the tables:
one ``collective-permute`` per round, same HLO structure as the original
kwarg API (asserted by the conformance harness and the CI ``plans`` gate).

Plans are cached with ``functools.lru_cache`` — repeated calls with the
same spec are trace-time dict hits, so spec-driven dispatch adds zero
retraces and zero extra collectives.

Two execution modes share each backend's round steps:

* **one-shot** — ``plan.reduce_scatter(x)`` runs begin → q × (start →
  finish) → end in a single call (the classic API); and
* **multi-call (async)** — ``st = plan.rs_begin(x)`` hands the caller a
  :class:`RoundState`; each ``plan.start_round(st)`` issues EXACTLY ONE
  collective-permute and each ``plan.finish_round(st)`` does the local
  fold + next-send assembly (the seam the fused Pallas round kernel
  already separates — see ``kernels.fused_round``), with
  ``plan.rs_end(st)`` / ``plan.ag_end(st)`` extracting the result once
  all rounds are finished.  States of the SAME plan are independent, so
  a caller can interleave rounds of many payloads:
  ``plan.reduce_scatter_pipelined(xs)`` software-pipelines them so
  payload b's round-k ppermute sits between payload b-1's ppermute and
  fold in program order — independent dataflow chains XLA's scheduler
  can overlap.  The bucketed ZeRO-1 gradient sync
  (``optim.zero1``, ``GradSyncConfig.bucket_bytes``) rides this mode.

Async backend-registry contract (``_ASYNC_IMPLS``): a backend opts in by
registering an ops class per phase with ``begin`` / ``start`` /
``finish`` / ``end`` hooks.  ``start`` must issue exactly one
collective-permute and park the wire payload on ``RoundState.inflight``;
``finish`` must be collective-free (local fold + assembling the next
round's send buffer); the one-shot methods are thin drivers over the
same hooks, so both modes are bitwise-identical by construction.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.kernels import (fused_round, fused_round_dq, pack_wire, pad2d,
                           permute_rows, quantize_rows, resolve_fused,
                           unpack_wire)
from repro.kernels import ref as _kref
from .schedule import (RoundPlan, allgather_plan, alltoall_moves,
                       reduce_scatter_plan)
from .spec import CollectiveSpec, as_spec

Array = jax.Array
ReduceFn = Callable[[Array, Array], Array]

_REDUCERS: dict[str, ReduceFn] = {
    "add": lambda a, b: a + b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}

#: ops the scatter-fold (non-uniform) and fused/wire backends support.
NAMED_OPS = tuple(_REDUCERS)


def resolve_op(op) -> ReduceFn:
    """Named-or-callable ⊕ resolution (the single kwarg-era helper left;
    every backend goes through it)."""
    if callable(op):
        return op
    try:
        return _REDUCERS[op]
    except KeyError:
        raise ValueError(f"unknown reduce op {op!r}") from None


def _fwd_perm(p: int, s: int) -> list[tuple[int, int]]:
    """Data on rank i goes to rank (i + s) mod p  (paper's to-processor)."""
    return [(i, (i + s) % p) for i in range(p)]


def _bwd_perm(p: int, s: int) -> list[tuple[int, int]]:
    """Data on rank i goes to rank (i - s) mod p  (allgather phase)."""
    return [(i, (i - s) % p) for i in range(p)]


# ---------------------------------------------------------------------------
# Block layout — THE padding path (uniform and non-uniform share it)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BlockLayout:
    """Per-rank block row counts along the leading axis.

    The one place block geometry is derived from: ``pad_to_multiple`` /
    ``_as_blocks`` (uniform), the non-uniform row tables (Corollary 3),
    and the ZeRO-1 leaf padding all consume a layout instead of
    re-deriving ``ceil(n/p)`` locally.
    """

    counts: tuple[int, ...]

    @classmethod
    def uniform(cls, p: int, n: int) -> "BlockLayout":
        """Equal blocks of ``ceil(n/p)`` rows (zero-pad to fit)."""
        if p < 1:
            raise ValueError(f"p must be >= 1, got {p}")
        b = -(-n // p) if n else 0
        return cls(counts=(b,) * p)

    @property
    def p(self) -> int:
        return len(self.counts)

    @property
    def total(self) -> int:
        return sum(self.counts)

    @property
    def bmax(self) -> int:
        return max(self.counts)

    @property
    def offsets(self) -> tuple[int, ...]:
        """Row offset of each block (plus the total as a sentinel)."""
        off, acc = [], 0
        for c in self.counts:
            off.append(acc)
            acc += c
        off.append(acc)
        return tuple(off)

    @property
    def is_uniform(self) -> bool:
        return len(set(self.counts)) <= 1

    def pad(self, x: Array) -> tuple[Array, int]:
        """Zero-pad the leading axis of ``x`` up to ``total`` rows."""
        n = x.shape[0]
        pad = self.total - n
        if pad < 0:
            raise ValueError(
                f"input has {n} rows, layout holds only {self.total}")
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
        return x, pad

    def as_blocks(self, x: Array) -> Array:
        """Reshape the leading axis into (p, bmax, *rest) — uniform only."""
        if not self.is_uniform:
            raise ValueError(
                f"non-uniform layout {self.counts} cannot reshape to "
                f"equal blocks; use the row tables")
        n, p = x.shape[0], self.p
        if n != self.total:
            raise ValueError(
                f"leading dim {n} not divisible by axis size {p}; pad first "
                f"(see pad_to_multiple)")
        return x.reshape(p, self.bmax, *x.shape[1:])

    def window_rows(self, window: Sequence[int]) -> np.ndarray:
        """Per-rank row index table for a rotated block window.

        Row ``r`` lists, in block order, the absolute row indices of
        blocks ``(r + i) mod p`` for ``i`` in ``window``, padded with the
        sentinel ``total`` (a dummy row) to the worst-case window width —
        the quantity Corollary 3's round bound maximizes over.
        """
        p, off, total = self.p, self.offsets, self.total
        widths = [sum(self.counts[(r + i) % p] for i in window)
                  for r in range(p)]
        W = max(widths) if widths else 0
        tab = np.full((p, max(W, 1)), total, dtype=np.int32)
        for r in range(p):
            j = 0
            for i in window:
                c = (r + i) % p
                tab[r, j:j + self.counts[c]] = np.arange(
                    off[c], off[c] + self.counts[c], dtype=np.int32)
                j += self.counts[c]
        return tab


# ---------------------------------------------------------------------------
# Alltoall(v) geometry — per-pair counts compiled to row tables
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class A2APlan:
    """Trace-time geometry of a ragged alltoallv (per-pair ``counts``).

    The per-rank buffer holds the FULL absolute (src, dst) pair layout
    (``total`` rows + one sentinel row); each rank only ever populates the
    rows of entries it currently holds.  ``round_tables[k]`` is the
    ``(p, W_k)`` absolute-row table of round k: row r lists the buffer
    rows rank r gathers into the wire (its entries hopping this round,
    in ``alltoall_moves`` order), sentinel-padded to the worst windowed
    count sum ``W_k`` over ranks — SPMD needs one static wire shape, and
    that max is exactly the per-round quantity the Corollary 3 style
    bound maximizes over.  Sender and receiver store every entry at the
    same absolute rows, so the receive table of rank r is row
    ``(r - skip) mod p`` of the SAME table.
    """

    counts: tuple[tuple[int, ...], ...]   # [src][dst] rows
    pair_offsets: np.ndarray              # (p, p) absolute row of each pair
    total: int                            # sum of all counts
    send_total: tuple[int, ...]           # per-src row sum
    recv_total: tuple[int, ...]           # per-dst row sum
    in_height: int                        # static input rows: max send_total
    out_height: int                       # static output rows: max recv_total
    seed_src: np.ndarray                  # (p, in_height) input rows gathered
    seed_dst: np.ndarray                  # (p, in_height) buffer rows written
    round_tables: tuple[np.ndarray, ...]  # (p, W_k) wire gather/scatter rows
    out_rows: np.ndarray                  # (p, out_height) output gather rows

    @property
    def round_widths(self) -> tuple[int, ...]:
        """Per-round wire width (rows) — the worst windowed count sum."""
        return tuple(t.shape[1] for t in self.round_tables)


def _build_a2a(counts: tuple[tuple[int, ...], ...], p: int,
               schedule: str, group: int | None) -> A2APlan:
    moves = alltoall_moves(p, schedule, group)
    offs = np.zeros((p, p), np.int64)
    acc = 0
    for s in range(p):
        for dcol in range(p):
            offs[s, dcol] = acc
            acc += counts[s][dcol]
    total = acc
    send_total = tuple(sum(row) for row in counts)
    recv_total = tuple(sum(counts[s][dcol] for s in range(p))
                       for dcol in range(p))
    in_h = max(max(send_total), 1)
    out_h = max(max(recv_total), 1)

    # Seed: rank r's input rows (dst-ordered, rows [0, send_total[r]))
    # scatter into the absolute pair layout; sentinel-padded.
    seed_src = np.full((p, in_h), in_h, dtype=np.int32)   # input sentinel
    seed_dst = np.full((p, in_h), total, dtype=np.int32)  # buffer sentinel
    for r in range(p):
        j = 0
        for dcol in range(p):
            c = counts[r][dcol]
            seed_src[r, j:j + c] = np.arange(j, j + c, dtype=np.int32)
            seed_dst[r, j:j + c] = np.arange(
                offs[r, dcol], offs[r, dcol] + c, dtype=np.int32)
            j += c

    # Table widths come from the cost model's analytic bound (ONE
    # implementation of the worst-windowed-count-sum formula); the row
    # fill below would overrun a too-small width, so the CI width gate
    # stays a real consistency check rather than a copy comparing itself.
    from .cost_model import alltoallv_round_widths
    widths = alltoallv_round_widths(counts, schedule, group)
    tables = []
    for (_, moved), W in zip(moves, widths):
        tab = np.full((p, W), total, dtype=np.int32)
        for r in range(p):
            j = 0
            for d, m in moved:
                src = (r - m) % p
                dst = (src + d) % p
                c = counts[src][dst]
                tab[r, j:j + c] = np.arange(
                    offs[src, dst], offs[src, dst] + c, dtype=np.int32)
                j += c
            assert j <= W, (j, W)
        tables.append(tab)

    out_rows = np.full((p, out_h), total, dtype=np.int32)
    for r in range(p):
        j = 0
        for src in range(p):
            c = counts[src][r]
            out_rows[r, j:j + c] = np.arange(
                offs[src, r], offs[src, r] + c, dtype=np.int32)
            j += c
    return A2APlan(counts=counts, pair_offsets=offs, total=total,
                   send_total=send_total, recv_total=recv_total,
                   in_height=in_h, out_height=out_h,
                   seed_src=seed_src, seed_dst=seed_dst,
                   round_tables=tuple(tables), out_rows=out_rows)


# ---------------------------------------------------------------------------
# Multi-call (async) round protocol state
# ---------------------------------------------------------------------------

@dataclass(eq=False)
class RoundState:
    """In-trace state of one multi-call collective phase.

    Created by :meth:`CollectivePlan.rs_begin` / ``ag_begin`` and
    advanced by ``start_round`` / ``finish_round`` (which MUTATE the
    state in place and return it for chaining).  It holds traced arrays,
    so a state never escapes the trace that created it; the protocol
    order (start → finish per round, end only when ``done``) is enforced
    by the plan methods.

    phase:    ``"rs"`` (Algorithm 1) or ``"ag"`` (reversed skip stack).
    nrounds:  total rounds of the phase (0 for the p == 1 identity).
    k:        rounds fully finished so far.
    started:  a ``start_round`` is in flight, awaiting ``finish_round``.
    inflight: the ppermuted wire payload of the started round.
    data:     backend-private buffers (live/send blocks, packed wire,
              rank index, hooks) — owned by the ``_ASYNC_IMPLS`` ops.
    """

    plan: "CollectivePlan"
    phase: str
    nrounds: int
    k: int = 0
    started: bool = False
    inflight: object = None
    data: dict = field(default_factory=dict)

    @property
    def done(self) -> bool:
        """True once every round is finished (``end`` may be called)."""
        return self.k >= self.nrounds

    @property
    def round(self) -> RoundPlan:
        """The :class:`RoundPlan` of the round being started/finished."""
        rounds = (self.plan.rs_rounds if self.phase == "rs"
                  else self.plan.ag_rounds)
        return rounds[self.k]


# ---------------------------------------------------------------------------
# The compiled plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class CollectivePlan:
    """Compiled, cached form of a :class:`CollectiveSpec` at axis size p.

    ``rs_send_blocks[k]`` / ``rs_recv_blocks[k]`` are the rotated block
    indices moved in reduce-scatter round k (``ag_*`` likewise for the
    reversed allgather phase); over all rounds the send sets partition
    ``{1, .., p-1}`` exactly (Theorem 1, property-tested).  For
    non-uniform counts, ``rs_row_tables[k]`` is the per-rank
    ``(p, W_k)`` absolute-row gather/scatter table realizing those block
    sets at row granularity.
    """

    spec: CollectiveSpec
    p: int
    axis_name: str
    backend: str
    skips: tuple[int, ...]
    rs_rounds: tuple[RoundPlan, ...]
    ag_rounds: tuple[RoundPlan, ...]
    rs_send_blocks: tuple[tuple[int, ...], ...]
    rs_recv_blocks: tuple[tuple[int, ...], ...]
    ag_send_blocks: tuple[tuple[int, ...], ...]
    ag_recv_blocks: tuple[tuple[int, ...], ...]
    layout: BlockLayout | None          # non-None iff flat spec.counts given
    rs_row_tables: tuple[np.ndarray, ...] | None
    ag_row_tables: tuple[np.ndarray, ...] | None
    a2a: A2APlan | None = None          # non-None iff matrix spec.counts

    # -- layout funnel -----------------------------------------------------

    def layout_for(self, n: int) -> BlockLayout:
        """The layout governing an ``n``-row payload under this plan."""
        if self.layout is not None:
            return self.layout
        return BlockLayout.uniform(self.p, n)

    # -- execution ---------------------------------------------------------

    def reduce_scatter(self, x: Array, *, compress=None,
                       decompress=None) -> Array:
        """Paper Algorithm 1 under this plan (one ppermute per round)."""
        self._check_hooks(compress, decompress)
        self._check_not_a2a("reduce_scatter")
        if self.backend in _BASELINE_RS:
            return _BASELINE_RS[self.backend](self, x)
        if self.p == 1:
            return x
        if self.backend == "nonuniform":
            return _rs_nonuniform(self, x)
        st = self.rs_begin(x, compress=compress, decompress=decompress)
        while not st.done:
            self.finish_round(self.start_round(st))
        return self.rs_end(st)

    def allgather(self, x: Array) -> Array:
        """Algorithm 2's second phase (reversed skip stack) standalone."""
        self._check_not_a2a("allgather")
        if self.backend in _BASELINE_AG:
            return _BASELINE_AG[self.backend](self, x)
        if self.p == 1:
            return x
        if self.backend == "nonuniform":
            return _ag_nonuniform(self, x)
        st = self.ag_begin(x)
        while not st.done:
            self.finish_round(self.start_round(st))
        return self.ag_end(st)

    def allreduce(self, x: Array, *, compress=None, decompress=None) -> Array:
        """Paper Algorithm 2: reduce-scatter + reversed allgather."""
        if self.backend in _BASELINE_AR:
            return _BASELINE_AR[self.backend](self, x)
        w = self.reduce_scatter(x, compress=compress, decompress=decompress)
        return self.allgather(w)

    def broadcast(self, x: Array) -> Array:
        """Round-optimal all-broadcast (Träff, arXiv:2407.18004).

        Every rank contributes its block ``x`` of shape ``(blk, *rest)``
        and receives ``(p*blk, *rest)`` — row-block j is rank j's
        contribution, bitwise-replicated on all ranks — in
        ``ceil(log2 p)`` rounds with exactly one ppermute per round.
        Structurally this is Algorithm 2's allgather phase run standalone
        (the reversed skip stack, no reduction ⊕), which is precisely the
        broadcast paper's schedule: with the root's message pre-scattered
        into p blocks, all-broadcast completes the root broadcast, and
        the round count meets the ceil(log2 p) lower bound at ANY p
        (a binomial tree double-delivers at non-powers of two).

        Weight fan-out to serving replicas (``serve/replica.py``) is the
        consumer: payloads move uncompressed (bit-exact), so
        ``wire_dtype`` and ``use_fused_kernel`` are rejected at spec
        construction.
        """
        self._check_not_a2a("broadcast")
        impl = _ASYNC_IMPLS.get((self.backend, "ag"))
        if impl is None:
            raise ValueError(
                f"backend {self.backend!r} does not implement broadcast; "
                f"use kind='broadcast' (or any uniform circulant backend)")
        if self.p == 1:
            return x
        # ag_begin's _check_async requires an "rs" impl (the paired-phase
        # protocol); the broadcast backend is AG-only, so open the state
        # directly and drive the shared round protocol.
        st = RoundState(plan=self, phase="ag", nrounds=len(self.ag_rounds))
        impl.begin(self, st, x)
        while not st.done:
            self.finish_round(self.start_round(st))
        return self.ag_end(st)

    def alltoall(self, x: Array) -> Array:
        """All-to-all by concatenation (paper §4): Algorithm 1 with ⊕ =
        concat.

        Uniform form (``counts=None``): ``x`` is ``(p, blk, *rest)``, row
        j is this rank's payload for rank j; returns the same shape with
        row j the payload FROM rank j.  Ragged form (p×p ``counts``
        matrix, MPI_Alltoallv): ``x`` is ``(in_height, *rest)`` — this
        rank's payload rows concatenated in destination order in rows
        ``[0, send_total[r])`` — and the result is ``(out_height, *rest)``
        with the received rows concatenated in source order, zeroed past
        this rank's receive total.  Backends come from the ``_A2A_IMPLS``
        registry (jnp / fused / alltoallv / xla baseline).
        """
        if self.spec.wired:
            raise NotImplementedError(
                "alltoall does not support wire_dtype (blocks hop through "
                "intermediate ranks; requantizing per hop would compound "
                "the error)")
        if self.layout is not None:
            raise NotImplementedError(
                "alltoall does not support flat (Corollary 3) counts; "
                "pass a p×p per-pair counts matrix for alltoallv")
        impl = _A2A_IMPLS.get(self.backend)
        if impl is None:
            raise ValueError(
                f"backend {self.backend!r} does not implement alltoall; "
                f"have {sorted(_A2A_IMPLS)}")
        if self.p == 1:
            return x
        return impl(self, x)

    # -- multi-call (async) round protocol ---------------------------------

    def rs_begin(self, x: Array, *, compress=None,
                 decompress=None) -> RoundState:
        """Open a multi-call reduce-scatter over ``x`` (async mode).

        Rotates ``x`` into block coordinates and assembles round 0's send
        payload without issuing any collective.  Drive the returned
        :class:`RoundState` with ``start_round`` / ``finish_round`` — one
        (ppermute, fold) pair per round — then ``rs_end``.  Supported on
        the uniform circulant backends (``jnp`` / ``fused`` and their
        ``+int8`` wire forms); baselines, non-uniform counts and
        alltoallv have no round seam to expose and raise.
        """
        self._check_hooks(compress, decompress)
        self._check_not_a2a("rs_begin")
        self._check_async("rs_begin")
        if self.p == 1:
            return RoundState(plan=self, phase="rs", nrounds=0,
                              data={"identity": x})
        _check_wire_payload(self, x)
        st = RoundState(plan=self, phase="rs", nrounds=len(self.rs_rounds))
        _ASYNC_IMPLS[(self.backend, "rs")].begin(self, st, x,
                                                 compress, decompress)
        return st

    def ag_begin(self, x: Array) -> RoundState:
        """Open a multi-call allgather of block ``x`` — see
        :meth:`rs_begin` (allgather replays the skips in reverse and has
        no reduction, so ``finish_round`` is a pure buffer write)."""
        self._check_not_a2a("ag_begin")
        self._check_async("ag_begin")
        if self.p == 1:
            return RoundState(plan=self, phase="ag", nrounds=0,
                              data={"identity": x})
        _check_wire_payload(self, x)
        st = RoundState(plan=self, phase="ag", nrounds=len(self.ag_rounds))
        _ASYNC_IMPLS[(self.backend, "ag")].begin(self, st, x)
        return st

    def start_round(self, st: RoundState) -> RoundState:
        """Issue round ``st.k``'s single collective-permute.

        The wire payload (already assembled by ``begin`` or the previous
        ``finish_round``) is permuted onto ``st.inflight``; no local fold
        happens here, so work independent of this payload — another
        bucket's ``finish_round``, the next layer's backward — can sit
        between ``start_round`` and ``finish_round`` in program order.
        Mutates and returns ``st``.
        """
        if st.plan is not self:
            raise ValueError("RoundState belongs to a different plan")
        if st.done:
            raise ValueError(
                f"{st.phase} phase complete: all {st.nrounds} rounds "
                f"finished (call {st.phase}_end)")
        if st.started:
            raise ValueError(
                f"round {st.k} already started; call finish_round() first")
        _ASYNC_IMPLS[(self.backend, st.phase)].start(self, st)
        st.started = True
        return st

    def finish_round(self, st: RoundState) -> RoundState:
        """Fold round ``st.k``'s received payload and assemble the next
        round's send buffer (collective-free — the fused backend runs
        both in one Pallas pass).  Mutates and returns ``st``."""
        if st.plan is not self:
            raise ValueError("RoundState belongs to a different plan")
        if not st.started:
            raise ValueError(
                f"round {st.k} has no ppermute in flight; call "
                f"start_round() first")
        _ASYNC_IMPLS[(self.backend, st.phase)].finish(self, st)
        st.inflight = None
        st.started = False
        st.k += 1
        return st

    def rs_end(self, st: RoundState) -> Array:
        """Extract the reduced block once every RS round is finished."""
        return self._phase_end(st, "rs")

    def ag_end(self, st: RoundState) -> Array:
        """Extract the gathered (rank-ordered) buffer once every AG round
        is finished."""
        return self._phase_end(st, "ag")

    def _phase_end(self, st: RoundState, phase: str) -> Array:
        if st.plan is not self:
            raise ValueError("RoundState belongs to a different plan")
        if st.phase != phase:
            raise ValueError(
                f"state is mid-{st.phase}, not {phase} (use {st.phase}_end)")
        if st.started or not st.done:
            left = st.nrounds - st.k
            raise ValueError(
                f"{phase}_end with {left} round(s) unfinished "
                f"(started={st.started})")
        if "identity" in st.data:
            return st.data["identity"]
        return _ASYNC_IMPLS[(self.backend, phase)].end(self, st)

    def reduce_scatter_pipelined(self, xs: Sequence[Array], *,
                                 compress=None, decompress=None
                                 ) -> list[Array]:
        """Reduce-scatter many independent payloads with round-level
        software pipelining (the bucketed grad-sync driver).

        All payloads share this plan (same p / schedule / backend, so the
        same round count q); total collectives = ``len(xs) * q`` — exactly
        one ppermute per payload per round, same as running each payload
        alone.  The emitted program order is double-buffered: payload
        b's round-k ppermute is issued BEFORE payload b-1's round-k fold,
        so each fold sits between two independent collectives and the
        XLA latency-hiding scheduler can overlap them.
        """
        sts = [self.rs_begin(x, compress=compress, decompress=decompress)
               for x in xs]
        return self._run_pipelined(sts, "rs")

    def allgather_pipelined(self, xs: Sequence[Array]) -> list[Array]:
        """Allgather counterpart of :meth:`reduce_scatter_pipelined`."""
        return self._run_pipelined([self.ag_begin(x) for x in xs], "ag")

    def _run_pipelined(self, sts: list[RoundState], phase: str
                       ) -> list[Array]:
        q = max((st.nrounds for st in sts), default=0)
        for _ in range(q):
            prev = None
            for st in sts:
                self.start_round(st)
                if prev is not None:
                    self.finish_round(prev)
                prev = st
            if prev is not None:
                self.finish_round(prev)
        end = self.rs_end if phase == "rs" else self.ag_end
        return [end(st) for st in sts]

    # -- validation helpers ------------------------------------------------

    def _check_async(self, fn: str) -> None:
        if (self.backend, "rs") not in _ASYNC_IMPLS:
            supported = sorted({b for (b, _) in _ASYNC_IMPLS})
            raise NotImplementedError(
                f"backend {self.backend!r} has no multi-call round "
                f"protocol ({fn}); async-capable backends: {supported}")

    def _check_not_a2a(self, fn: str) -> None:
        if self.a2a is not None:
            raise ValueError(
                f"a p×p per-pair counts matrix is alltoall(v)-only; "
                f"{fn} takes flat per-rank counts (Corollary 3)")

    def _check_hooks(self, compress, decompress) -> None:
        if compress is None and decompress is None:
            return
        if self.spec.wired:
            raise ValueError(
                "wire_dtype and compress/decompress hooks are mutually "
                "exclusive")
        if self.backend == "nonuniform":
            raise ValueError(
                "compress/decompress hooks do not support non-uniform "
                "counts")
        if self.spec.kind != "circulant":
            raise ValueError(
                f"compress/decompress hooks need kind='circulant' "
                f"(per-round payloads), got {self.spec.kind!r}")


def _check_wire_payload(plan: CollectivePlan, x: Array) -> None:
    """int8 wire needs float payloads (quantization grid); checked at
    execution because the spec is payload-agnostic."""
    if plan.spec.wired and not jnp.issubdtype(x.dtype, jnp.floating):
        raise ValueError(
            f"wire_dtype='int8' needs a float payload, got {x.dtype}")


# ---------------------------------------------------------------------------
# plan(): spec -> CollectivePlan, memoized
# ---------------------------------------------------------------------------

_BASELINE_KINDS = ("ring", "recursive_halving", "xla")


def _resolve_backend(spec: CollectiveSpec) -> str:
    """Backend registry key for a spec (the one place the kwarg-era
    ``_resolve_op``/``_check_wire`` decision tables live on)."""
    if spec.kind in _BASELINE_KINDS:
        return spec.kind
    if spec.kind == "broadcast":
        # Spec validation already rejected wire_dtype / use_fused_kernel;
        # counts= requires kind='circulant', so nothing else to check.
        return "broadcast"
    if spec.counts_matrix:
        if spec.wire_dtype is not None:
            raise ValueError(
                "alltoallv (per-pair counts) does not support wire_dtype "
                "(blocks hop through intermediate ranks; requantizing per "
                "hop would compound the error)")
        if spec.use_fused_kernel is True:
            raise ValueError(
                "use_fused_kernel does not support per-pair counts (the "
                "ragged wire is table-gathered, not slot-stacked)")
        return "alltoallv"
    if spec.counts is not None:
        if spec.wire_dtype is not None:
            raise ValueError(
                "non-uniform counts and wire_dtype cannot be combined yet "
                "(quantization groups would straddle ragged blocks)")
        if spec.use_fused_kernel is True:
            raise ValueError(
                "use_fused_kernel does not support non-uniform counts "
                "(the fused round kernel assumes equal blocks)")
        if spec.op not in NAMED_OPS:
            raise ValueError(
                f"non-uniform counts need a named op {NAMED_OPS}, "
                f"got {spec.op!r}")
        return "nonuniform"
    if spec.wire_dtype is not None:
        if not isinstance(spec.op, str):
            raise ValueError(
                f"wire_dtype needs a named op ('add'/'max'/'min'), "
                f"got {spec.op!r}")
        return ("fused+int8" if resolve_fused(spec.use_fused_kernel)
                else "jnp+int8")
    if resolve_fused(spec.use_fused_kernel):
        if not isinstance(spec.op, str):
            if spec.use_fused_kernel:
                # Explicit request only — auto silently keeps the jnp path.
                raise ValueError(
                    "use_fused_kernel needs a named op ('add'/'max'/'min'), "
                    f"got callable {spec.op!r}")
            return "jnp"
        return "fused"
    return "jnp"


class _PlanCache:
    """LRU memo for compiled plans with SELECTIVE invalidation.

    ``functools.lru_cache`` almost suffices, but the elastic runtime
    (ft/elastic.py) resizes the live world and wants to evict every plan
    compiled for a rank set that no longer exists — both as memory
    hygiene across many resize events and as a hard guarantee that no
    consumer keeps executing a plan whose ``p`` predates the re-plan.
    Same observable API as the lru_cache it replaces: ``info()`` returns
    a CacheInfo-shaped tuple (hits/misses/maxsize/currsize) and entries
    are identical objects across hits (``plan(s, ...) is plan(s, ...)``).
    """

    class CacheInfo(tuple):
        """hits / misses / maxsize / currsize, attribute-accessible."""
        __slots__ = ()

        def __new__(cls, hits, misses, maxsize, currsize):
            return tuple.__new__(cls, (hits, misses, maxsize, currsize))

        hits = property(lambda s: s[0])
        misses = property(lambda s: s[1])
        maxsize = property(lambda s: s[2])
        currsize = property(lambda s: s[3])

        def __repr__(self):
            return (f"CacheInfo(hits={s[0]}, misses={s[1]}, "
                    f"maxsize={s[2]}, currsize={s[3]})"
                    if (s := tuple(self)) else "CacheInfo()")

    def __init__(self, maxsize: int = 4096):
        self.maxsize = maxsize
        self._data: dict = {}
        self._hits = 0
        self._misses = 0

    def get(self, key, build):
        try:
            val = self._data.pop(key)
            self._data[key] = val  # re-insert: LRU recency order
            self._hits += 1
            return val
        except KeyError:
            self._misses += 1
            val = build()
            self._data[key] = val
            while len(self._data) > self.maxsize:
                self._data.pop(next(iter(self._data)))
            return val

    def info(self):
        return self.CacheInfo(self._hits, self._misses, self.maxsize,
                              len(self._data))

    def clear(self):
        self._data.clear()
        self._hits = self._misses = 0

    def invalidate(self, p: int | None = None,
                   axis_name: str | None = None) -> int:
        """Evict every cached plan matching the given filters (``None``
        matches everything); returns the number evicted."""
        doomed = [k for k in self._data
                  if (p is None or k[1] == p)
                  and (axis_name is None or k[2] == axis_name)]
        for k in doomed:
            del self._data[k]
        return len(doomed)


_PLAN_CACHE = _PlanCache(maxsize=4096)


def _plan_cached(spec: CollectiveSpec, p: int, axis_name: str
                 ) -> CollectivePlan:
    return _PLAN_CACHE.get((spec, p, axis_name),
                           lambda: _build_plan(spec, p, axis_name))


def _build_plan(spec: CollectiveSpec, p: int, axis_name: str
                ) -> CollectivePlan:
    backend = _resolve_backend(spec)
    if spec.kind in _BASELINE_KINDS:
        return CollectivePlan(
            spec=spec, p=p, axis_name=axis_name, backend=backend,
            skips=(), rs_rounds=(), ag_rounds=(),
            rs_send_blocks=(), rs_recv_blocks=(),
            ag_send_blocks=(), ag_recv_blocks=(),
            layout=None, rs_row_tables=None, ag_row_tables=None)

    rs = reduce_scatter_plan(p, spec.schedule, spec.group)
    ag = allgather_plan(p, spec.schedule, spec.group)
    rs_send = tuple(tuple(range(pl.lo, pl.hi)) for pl in rs)
    rs_recv = tuple(tuple(range(0, pl.nblocks)) for pl in rs)
    ag_send = tuple(tuple(range(0, pl.nblocks)) for pl in ag)
    ag_recv = tuple(tuple(range(pl.lo, pl.hi)) for pl in ag)

    layout = rs_tables = ag_tables = a2a = None
    if spec.counts is not None:
        if len(spec.counts) != p:
            raise ValueError(
                f"counts has {len(spec.counts)} entries for axis size {p}")
        if spec.counts_matrix:
            a2a = _build_a2a(spec.counts, p, spec.schedule, spec.group)
        else:
            layout = BlockLayout(counts=spec.counts)
            rs_tables = tuple(layout.window_rows(w) for w in rs_send)
            ag_tables = tuple(layout.window_rows(w) for w in ag_send)

    return CollectivePlan(
        spec=spec, p=p, axis_name=axis_name, backend=backend,
        skips=tuple(pl.skip for pl in rs), rs_rounds=rs, ag_rounds=ag,
        rs_send_blocks=rs_send, rs_recv_blocks=rs_recv,
        ag_send_blocks=ag_send, ag_recv_blocks=ag_recv,
        layout=layout, rs_row_tables=rs_tables, ag_row_tables=ag_tables,
        a2a=a2a)


def plan(spec: CollectiveSpec | None = None, p: int | None = None,
         axis_name: str | None = None, **kw) -> CollectivePlan:
    """Compile ``spec`` for axis ``axis_name`` of size ``p`` (cached).

    ``p`` may be omitted inside a shard_map region (resolved from the
    axis).  Bare kwargs build the spec in place::

        plan(p=8, axis_name="x", schedule="power2").reduce_scatter(x)
    """
    spec = as_spec(spec, **kw)
    if axis_name is None:
        raise ValueError("plan() needs an axis_name")
    if p is None:
        p = compat.axis_size(axis_name)
    return _plan_cached(spec, int(p), axis_name)


# Cache introspection rides on plan() itself: ``plan.cache_stats()`` /
# ``plan.clear()`` / ``plan.invalidate(p=..., axis_name=...)``.  All
# proxy the one _PlanCache behind _plan_cached, so an identity assertion
# like ``plan(s, ...) is plan(s, ...)`` plus a hits/misses delta from
# cache_stats() observes the same cache the elastic controller evicts
# from after a world resize.
plan.cache_stats = _PLAN_CACHE.info
plan.clear = _PLAN_CACHE.clear
plan.invalidate = _PLAN_CACHE.invalidate


def plan_cache_info():
    """Deprecated alias — use ``plan.cache_stats()``."""
    return plan.cache_stats()


def plan_cache_clear() -> None:
    """Deprecated alias — use ``plan.clear()``."""
    plan.clear()


# ---------------------------------------------------------------------------
# Uniform circulant backends — multi-call round ops (the one-shot round
# loops of the kwarg era, split at the (start = ppermute) / (finish =
# fold + next-send assembly) seam; identical round structure, ppermute
# sequence and arithmetic in both modes)
# ---------------------------------------------------------------------------

def _rotated_blocks(plan: CollectivePlan, x: Array) -> Array:
    """Rotate ``x`` into block coordinates: R[i] = block of rank (r+i)."""
    r = lax.axis_index(plan.axis_name)
    return jnp.roll(plan.layout_for(x.shape[0]).as_blocks(x), -r, axis=0)


class _RsJnp:
    """Algorithm 1's rounds, plain jnp ops (always available).

    State: the shrinking rotated block buffer ``R``; round k sends
    ``R[lo:hi]`` and folds the received blocks into ``R[:nblocks]``.
    """

    @staticmethod
    def begin(plan, st, x, compress, decompress):
        st.data.update(R=_rotated_blocks(plan, x),
                       compress=compress, decompress=decompress)

    @staticmethod
    def start(plan, st):
        pl = st.round
        payload = st.data["R"][pl.lo:pl.hi]
        if st.data["compress"] is not None:
            payload = st.data["compress"](payload)
        st.inflight = compat.ppermute(payload, plan.axis_name,
                                      _fwd_perm(plan.p, pl.skip))

    @staticmethod
    def finish(plan, st):
        pl, T = st.round, st.inflight
        if st.data["decompress"] is not None:
            T = st.data["decompress"](T)
        R, nb = st.data["R"], pl.nblocks
        head = resolve_op(plan.spec.op)(R[:nb], T)
        st.data["R"] = head if nb == pl.lo else jnp.concatenate(
            [head, R[nb:pl.lo]], axis=0)

    @staticmethod
    def end(plan, st):
        return st.data["R"][0]


class _RsFused:
    """Algorithm 1's rounds on the fused Pallas kernel.

    The rotated block buffer is viewed as 2-D ``(blocks, block_numel)``;
    after the prologue slice every round is ppermute → fused_round, with
    the kernel emitting both the shrunken live buffer and the next
    round's contiguous send payload — the fold/assembly split that makes
    ``finish`` collective-free.  Identical values and ppermute sequence
    to the jnp path — only the local data movement is fused.
    """

    @staticmethod
    def begin(plan, st, x, compress, decompress):
        R = _rotated_blocks(plan, x)
        R2 = R.reshape(plan.p, -1)
        first = plan.rs_rounds[0]
        st.data.update(blk_shape=R.shape[1:],
                       live=R2[: first.lo],
                       send=R2[first.lo: first.hi],
                       compress=compress, decompress=decompress)

    @staticmethod
    def start(plan, st):
        payload = (st.data["send"] if st.data["compress"] is None
                   else st.data["compress"](st.data["send"]))
        st.inflight = compat.ppermute(payload, plan.axis_name,
                                      _fwd_perm(plan.p, st.round.skip))

    @staticmethod
    def finish(plan, st):
        pl, T, live = st.round, st.inflight, st.data["live"]
        if st.data["decompress"] is not None:
            T = st.data["decompress"](T)
        if T.dtype != live.dtype:
            # Match the jnp path, whose concatenate promotes the buffer
            # (e.g. bf16 live vs f32 decompressed payload).
            dt = jnp.result_type(live.dtype, T.dtype)
            live, T = live.astype(dt), T.astype(dt)
        plans = plan.rs_rounds
        next_lo = plans[st.k + 1].lo if st.k + 1 < len(plans) else pl.lo
        live, send = fused_round(live, T, nb=pl.nblocks, next_lo=next_lo,
                                 op=plan.spec.op)
        st.data.update(live=live, send=send)

    @staticmethod
    def end(plan, st):
        return st.data["live"][0].reshape(st.data["blk_shape"])


class _RsWire:
    """Algorithm 1's rounds on the int8 wire format.

    The rotated block buffer is promoted to an f32 (blocks, block_numel)
    accumulation buffer whose columns are padded to a whole number of
    quantization groups.  Every round then ppermutes ONE packed int8
    buffer ([codes | scale bytes], see kernels.quantize) and runs a
    single dequantize + ⊕-fold + requantize-next-send pass — the Pallas
    ``fused_round_dq`` kernel on the fused backend, its jnp oracle
    otherwise (bitwise-identical arithmetic; both jitted).  Round count
    and ppermute sequence match the uncompressed path exactly.
    """

    @staticmethod
    def begin(plan, st, x, compress, decompress):
        fused = plan.backend == "fused+int8"
        R = _rotated_blocks(plan, x)
        R2 = R.reshape(plan.p, -1).astype(jnp.float32)
        cols = R2.shape[1]
        g = min(plan.spec.wire_group, cols)
        R2 = pad2d(R2, 1, g)
        first_round = plan.rs_rounds[0]
        first = R2[first_round.lo: first_round.hi]
        if fused:
            codes, scales = quantize_rows(first, group=g)
        else:
            codes, scales = _kref.quantize_ref(first, group=g)
        st.data.update(blk_shape=R.shape[1:], out_dtype=R.dtype,
                       cols=cols, g=g, fused=fused,
                       live=R2[: first_round.lo],
                       wire=pack_wire(codes, scales))

    @staticmethod
    def start(plan, st):
        st.inflight = compat.ppermute(
            st.data["wire"], plan.axis_name,
            _fwd_perm(plan.p, st.round.skip))

    @staticmethod
    def finish(plan, st):
        pl, live, g = st.round, st.data["live"], st.data["g"]
        rc, rs = unpack_wire(st.inflight, live.shape[1], group=g)
        plans = plan.rs_rounds
        next_lo = plans[st.k + 1].lo if st.k + 1 < len(plans) else pl.lo
        kern = fused_round_dq if st.data["fused"] else _kref.fused_round_dq_ref
        live, send = kern(live, rc, rs, nb=pl.nblocks, next_lo=next_lo,
                          op=plan.spec.op, group=g)
        st.data["live"] = live
        if send is not None:
            st.data["wire"] = pack_wire(*send)

    @staticmethod
    def end(plan, st):
        out = st.data["live"][0]
        cols = st.data["cols"]
        if cols != out.shape[0]:
            out = out[:cols]
        return out.reshape(st.data["blk_shape"]).astype(st.data["out_dtype"])


class _AgPlain:
    """Allgather rounds, uncompressed (backends ``jnp`` and ``fused``).

    Allgather has no ⊕, so its fused form needs no Pallas: the growing
    concat chain (which recopies the whole buffer every round — O(p log p)
    block traffic) becomes static in-place updates of one preallocated
    (p, blk) buffer (O(p) traffic; XLA turns the static-index
    dynamic-update-slice into an in-place write under jit).  Send payloads
    are buffer prefixes, already contiguous.
    """

    @staticmethod
    def begin(plan, st, x):
        r = lax.axis_index(plan.axis_name)
        fused = plan.backend == "fused"
        if fused:
            buf = jnp.zeros((plan.p, *x.shape), x.dtype)
            buf = lax.dynamic_update_slice_in_dim(buf, x[None], 0, axis=0)
        else:
            buf = x[None]  # (1, blk, *rest): rotated, R[i] = block of (r+i)
        st.data.update(buf=buf, r=r, fused=fused, blk=x.shape)

    @staticmethod
    def start(plan, st):
        pl, buf = st.round, st.data["buf"]
        payload = (lax.slice_in_dim(buf, 0, pl.nblocks, axis=0)
                   if st.data["fused"] else buf[:pl.nblocks])
        st.inflight = compat.ppermute(payload, plan.axis_name,
                                      _bwd_perm(plan.p, pl.skip))

    @staticmethod
    def finish(plan, st):
        pl, T, buf = st.round, st.inflight, st.data["buf"]
        if st.data["fused"]:
            # Received blocks land at rows [lo, hi) = [skip, prev bound).
            st.data["buf"] = lax.dynamic_update_slice_in_dim(
                buf, T, pl.lo, axis=0)
        else:
            st.data["buf"] = jnp.concatenate([buf, T], axis=0)

    @staticmethod
    def end(plan, st):
        blk = st.data["blk"]
        # Un-rotate: out[j] = block of rank j.
        out = jnp.roll(st.data["buf"], st.data["r"], axis=0)
        return out.reshape(plan.p * blk[0], *blk[1:])


class _AgWire:
    """Allgather rounds on the int8 wire format.

    Allgather has no ⊕, so each rank quantizes its own block ONCE; the
    rounds then move the packed int8 wire rows unmodified (every element
    is quantized exactly once — the error is a single quantization step).
    The fused backend selects the preallocated-buffer round structure
    (static in-place updates) vs the concat chain — both move identical
    bytes and one ppermute per round.  All ranks dequantize the same
    codes, so the gathered result is bitwise-replicated (Theorem 2's
    invariant survives compression).
    """

    @staticmethod
    def begin(plan, st, x):
        fused = plan.backend == "fused+int8"
        r = lax.axis_index(plan.axis_name)
        x2 = x.reshape(1, -1).astype(jnp.float32)
        cols = x2.shape[1]
        g = min(plan.spec.wire_group, cols)
        x2 = pad2d(x2, 1, g)
        if fused:
            codes, scales = quantize_rows(x2, group=g)
        else:
            codes, scales = _kref.quantize_ref(x2, group=g)
        row = pack_wire(codes, scales)                 # (1, wc) int8
        if fused:
            buf = jnp.zeros((plan.p, row.shape[1]), jnp.int8)
            buf = lax.dynamic_update_slice_in_dim(buf, row, 0, axis=0)
        else:
            buf = row
        st.data.update(buf=buf, r=r, fused=fused, g=g, cols=cols,
                       padded_cols=x2.shape[1], blk=x.shape,
                       out_dtype=x.dtype)

    # Rounds move the packed int8 rows exactly like the plain path.
    start = staticmethod(_AgPlain.start)
    finish = staticmethod(_AgPlain.finish)

    @staticmethod
    def end(plan, st):
        g, cols, blk = st.data["g"], st.data["cols"], st.data["blk"]
        codes, scales = unpack_wire(st.data["buf"], st.data["padded_cols"],
                                    group=g)
        vals = _kref.dequant_ref(codes, scales, group=g)  # (p, cols_pad) f32
        if cols != st.data["padded_cols"]:
            vals = vals[:, :cols]
        out = jnp.roll(vals, st.data["r"], axis=0)  # out[j] = block of j
        return (out.reshape(plan.p * blk[0], *blk[1:])
                .astype(st.data["out_dtype"]))


#: async backend registry — (backend, phase) → round-step ops.  The
#: contract: ``begin`` assembles round 0's send payload (no collective),
#: ``start`` issues exactly one collective-permute onto
#: ``RoundState.inflight``, ``finish`` is collective-free fold +
#: next-send assembly, ``end`` extracts the phase result.  Backends
#: absent here (nonuniform, alltoallv, baselines) only run one-shot.
_ASYNC_IMPLS: dict[tuple[str, str], type] = {
    ("jnp", "rs"): _RsJnp,
    ("fused", "rs"): _RsFused,
    ("jnp+int8", "rs"): _RsWire,
    ("fused+int8", "rs"): _RsWire,
    ("jnp", "ag"): _AgPlain,
    ("fused", "ag"): _AgPlain,
    ("jnp+int8", "ag"): _AgWire,
    ("fused+int8", "ag"): _AgWire,
    # kind="broadcast" (Träff arXiv:2407.18004) is the AG phase run
    # standalone: no ("broadcast", "rs") entry exists on purpose — the
    # plan's only operation is CollectivePlan.broadcast.
    ("broadcast", "ag"): _AgPlain,
}


# ---------------------------------------------------------------------------
# All-to-all by concatenation (paper §4)
# ---------------------------------------------------------------------------

def _a2a_jnp(plan: CollectivePlan, x: Array) -> Array:
    """Bruck-style rounds: trace-time bookkeeping keeps, per live slot,
    the list of (source-offset, array) pairs — the concatenation operator
    materialized as Python lists of same-shaped arrays, so every round is
    still a single fused ppermute over a stacked payload.  Volume is
    (p/2)*ceil(log2 p) blocks per rank (the classic Bruck trade-off:
    round-optimal, not volume-optimal)."""
    p = plan.p
    r = lax.axis_index(plan.axis_name)
    rot = jnp.roll(x, -r, axis=0)  # rot[i] = payload for dest (r+i)
    # slots[i]: list of (offset o, payload) — payload originated at (r+o).
    slots: list[list[tuple[int, Array]]] = [[(0, rot[i])] for i in range(p)]
    for pl in plan.rs_rounds:
        s = pl.skip
        # Stack every array sent this round into ONE ppermute payload.
        send_entries = [e for i in range(pl.lo, pl.hi) for e in slots[i]]
        stacked = jnp.stack([a for (_, a) in send_entries], axis=0)
        T = compat.ppermute(stacked, plan.axis_name, _fwd_perm(p, s))
        # Unstack with shifted source offsets; ⊕ = list concatenation.
        idx = 0
        for j in range(pl.nblocks):
            src_slot = pl.lo + j
            for (o, _) in slots[src_slot]:
                slots[j].append((((o - s) % p), T[idx]))
                idx += 1
        assert idx == len(send_entries)
        del slots[pl.lo:]  # slots [lo, hi) were sent; live = [0, s)
    entries = slots[0]
    assert len(entries) == p, f"expected {p} payloads, got {len(entries)}"
    ordered = [a for (_, a) in sorted(entries, key=lambda e: e[0])]
    stacked = jnp.stack(ordered, axis=0)  # stacked[o] = payload from (r+o)
    return jnp.roll(stacked, r, axis=0)   # row j = payload from rank j


def _a2a_fused(plan: CollectivePlan, x: Array) -> Array:
    """Bruck-style rounds over stacked slot buffers (fused alltoall).

    slots[i] is one (count_i, blk) array; offs[i] is the parallel Python
    list of source offsets.  Entry order inside each slot matches the
    unfused list-of-arrays path exactly, so results are bitwise-equal.
    """
    p = plan.p
    r = lax.axis_index(plan.axis_name)
    blk_shape = x.shape[1:]
    rot = jnp.roll(x, -r, axis=0)
    rot2 = rot.reshape(p, -1)
    slots = [lax.slice_in_dim(rot2, i, i + 1, axis=0) for i in range(p)]
    offs: list[list[int]] = [[0] for _ in range(p)]
    for pl in plan.rs_rounds:
        s = pl.skip
        send = (slots[pl.lo] if pl.nblocks == 1 else
                jnp.concatenate(slots[pl.lo:pl.hi], axis=0))
        T = compat.ppermute(send, plan.axis_name, _fwd_perm(p, s))
        idx = 0
        for j in range(pl.nblocks):
            src_slot = pl.lo + j
            cnt = len(offs[src_slot])
            piece = lax.slice_in_dim(T, idx, idx + cnt, axis=0)
            slots[j] = jnp.concatenate([slots[j], piece], axis=0)
            offs[j] = offs[j] + [(o - s) % p for o in offs[src_slot]]
            idx += cnt
        assert idx == T.shape[0]
        del slots[pl.lo:], offs[pl.lo:]
    assert slots[0].shape[0] == p, \
        f"expected {p} payloads, got {slots[0].shape[0]}"
    order = sorted(range(p), key=lambda i: offs[0][i])
    ordered = permute_rows(slots[0], order)  # ordered[o] = from (r+o)
    out = jnp.roll(ordered, r, axis=0)       # row j = payload from rank j
    return out.reshape(p, *blk_shape)


def _a2a_v(plan: CollectivePlan, x: Array) -> Array:
    """Ragged alltoallv over the per-pair counts matrix.

    Same table discipline as the Corollary 3 reduce-scatter: the buffer
    stays in ABSOLUTE (src, dst) pair order, round k gathers this rank's
    hopping rows through ``a2a.round_tables[k]`` into one fixed-width
    wire buffer (width = the worst windowed count sum over ranks),
    ppermutes it once, and scatter-SETS the received rows through the
    sender's view of the same table (no ⊕ — payloads move verbatim, so
    any dtype works).  Exactly one collective-permute per round —
    ``ceil(log2 p)`` for the optimal schedules, ragged counts included.

    Input ``(in_height, *rest)``: rank r's payload rows, concatenated in
    destination order, in rows ``[0, send_total[r])``.  Output
    ``(out_height, *rest)``: received rows concatenated in source order,
    zeroed past ``recv_total[r]`` (SPMD shapes are rank-invariant;
    callers slice with their static count when they know it).
    """
    a2a, p = plan.a2a, plan.p
    if x.shape[0] != a2a.in_height:
        raise ValueError(
            f"input has {x.shape[0]} rows, counts matrix needs "
            f"in_height={a2a.in_height} (= max per-rank send total)")
    r = lax.axis_index(plan.axis_name)
    blk_shape = x.shape[1:]
    x2 = x.reshape(a2a.in_height, -1)
    cols = x2.shape[1]
    # Input sentinel row (read by seed padding) and buffer sentinel row
    # (written by wire padding, read by gather padding; never data).
    xpad = jnp.concatenate([x2, jnp.zeros((1, cols), x2.dtype)], axis=0)
    buf = jnp.zeros((a2a.total + 1, cols), x2.dtype)
    buf = buf.at[_take_row(a2a.seed_dst, r)].set(
        jnp.take(xpad, _take_row(a2a.seed_src, r), axis=0))
    for k, pl in enumerate(plan.rs_rounds):
        table = a2a.round_tables[k]
        send_rows = _take_row(table, r)
        payload = jnp.take(buf, send_rows, axis=0)
        T = compat.ppermute(payload, plan.axis_name, _fwd_perm(p, pl.skip))
        # Sender (r - skip) gathered exactly the rows this rank must
        # store — both address the same absolute pair layout, so the
        # receive table IS the sender's row of the send table.
        recv_rows = _take_row(table, (r - pl.skip) % p)
        buf = buf.at[recv_rows].set(T)
    out = jnp.take(buf, _take_row(a2a.out_rows, r), axis=0)
    cnt = _take_row(np.asarray(a2a.recv_total, np.int32), r)
    mask = jnp.arange(a2a.out_height) < cnt
    out = jnp.where(mask.reshape(-1, *([1] * (out.ndim - 1))), out, 0)
    return out.reshape(a2a.out_height, *blk_shape)


# ---------------------------------------------------------------------------
# Non-uniform counts (paper Corollary 3) — gather/scatter over row tables
# ---------------------------------------------------------------------------

def _take_row(table: np.ndarray, idx) -> Array:
    """Row ``idx`` (traced rank expression) of a trace-time-constant
    table — one dynamic-slice, no gather fan-out."""
    return lax.dynamic_index_in_dim(jnp.asarray(table), idx, axis=0,
                                    keepdims=False)


def _scatter_fold(buf: Array, rows: Array, T: Array, op: str) -> Array:
    """Fold received wire rows into the buffer at ``rows``.  Real indices
    are unique within a round (each wire row is a distinct (column,
    offset) pair); padding rows all target the dummy sentinel row, which
    is never read back as data."""
    if op == "add":
        return buf.at[rows].add(T)
    if op == "max":
        return buf.at[rows].max(T)
    if op == "min":
        return buf.at[rows].min(T)
    raise ValueError(f"non-uniform counts need a named op, got {op!r}")


def _rs_nonuniform(plan: CollectivePlan, x: Array) -> Array:
    """Corollary 3: reduce-scatter with per-rank block sizes.

    The buffer stays in ABSOLUTE column order (no physical rotation —
    blocks have different sizes, so rotation is encoded in the row
    tables instead).  Round k gathers this rank's rows for the rotated
    send window into a fixed-width wire buffer (width = the worst
    windowed count sum over ranks — SPMD needs one static shape, and
    that max is exactly the per-round quantity Corollary 3 bounds),
    ppermutes it once, and scatter-⊕s the received rows through the
    receiving rank's view of the same table.  Exactly one
    collective-permute per round — Theorem 1's ceil(log2 p) rounds
    survive ragged counts unchanged.

    Input: ``(sum(counts), *rest)`` per rank.  Output:
    ``(max(counts), *rest)`` — this rank's reduced block in rows
    ``[0, counts[r])``, zero rows above (SPMD output shapes must be
    rank-invariant; callers slice with their static count when they
    know it).
    """
    layout, p, op = plan.layout, plan.p, plan.spec.op
    N, bmax = layout.total, layout.bmax
    if x.shape[0] != N:
        raise ValueError(
            f"input has {x.shape[0]} rows, counts {layout.counts} "
            f"need {N}")
    if p == 1:
        return x
    r = lax.axis_index(plan.axis_name)
    blk_shape = x.shape[1:]
    x2 = x.reshape(N, -1)
    cols = x2.shape[1]
    # Row N is the dummy sentinel: padding gathers read it, padding
    # scatters accumulate into it; it is never read back as data.
    buf = jnp.concatenate([x2, jnp.zeros((1, cols), x2.dtype)], axis=0)
    for k, pl in enumerate(plan.rs_rounds):
        table = plan.rs_row_tables[k]
        send_rows = _take_row(table, r)
        payload = jnp.take(buf, send_rows, axis=0)
        T = compat.ppermute(payload, plan.axis_name, _fwd_perm(p, pl.skip))
        # Sender (r - skip) packed exactly the columns this rank must
        # fold — and both store column c at the same absolute rows, so
        # the receive table IS the sender's row of the send table.
        recv_rows = _take_row(table, (r - pl.skip) % p)
        buf = _scatter_fold(buf, recv_rows, T, op)
    # Extract rows [off_r, off_r + counts[r]), padded to bmax and masked.
    ext = jnp.concatenate(
        [buf[:N], jnp.zeros((bmax, cols), x2.dtype)], axis=0)
    start = _take_row(np.asarray(layout.offsets[:p], np.int32), r)
    out = lax.dynamic_slice_in_dim(ext, start, bmax, axis=0)
    cnt = _take_row(np.asarray(layout.counts, np.int32), r)
    mask = jnp.arange(bmax) < cnt
    out = jnp.where(mask.reshape(bmax, *([1] * (out.ndim - 1))), out, 0)
    return out.reshape(bmax, *blk_shape)


def _ag_nonuniform(plan: CollectivePlan, x: Array) -> Array:
    """Allgather(v): inverse layout of :func:`_rs_nonuniform`.

    Input: ``(max(counts), *rest)`` — this rank's block in rows
    ``[0, counts[r])``.  Output: ``(sum(counts), *rest)``, all blocks in
    rank order, identical on every rank (no ⊕ — blocks move verbatim, so
    replication is bitwise).
    """
    layout, p = plan.layout, plan.p
    N, bmax = layout.total, layout.bmax
    if x.shape[0] != bmax:
        raise ValueError(
            f"input has {x.shape[0]} rows, counts {layout.counts} "
            f"need max(counts) = {bmax}")
    if p == 1:
        return x
    r = lax.axis_index(plan.axis_name)
    blk_shape = x.shape[1:]
    x2 = x.reshape(bmax, -1)
    cols = x2.shape[1]
    counts, offs = layout.counts, layout.offsets
    # Seed the (N + sentinel) buffer with this rank's own rows.
    src = np.full((p, bmax), bmax, dtype=np.int32)      # x2 row (or dummy)
    dst = np.full((p, bmax), N, dtype=np.int32)         # buf row (or dummy)
    for rr in range(p):
        src[rr, : counts[rr]] = np.arange(counts[rr], dtype=np.int32)
        dst[rr, : counts[rr]] = np.arange(
            offs[rr], offs[rr] + counts[rr], dtype=np.int32)
    xpad = jnp.concatenate([x2, jnp.zeros((1, cols), x2.dtype)], axis=0)
    buf = jnp.zeros((N + 1, cols), x2.dtype)
    buf = buf.at[_take_row(dst, r)].set(jnp.take(xpad, _take_row(src, r),
                                                 axis=0))
    for k, pl in enumerate(plan.ag_rounds):
        table = plan.ag_row_tables[k]
        send_rows = _take_row(table, r)
        payload = jnp.take(buf, send_rows, axis=0)
        T = compat.ppermute(payload, plan.axis_name, _bwd_perm(p, pl.skip))
        # Received from (r + skip): its send window covers exactly the
        # columns this rank is missing at rotated [skip, prev) — same
        # absolute rows, so the receive table is the sender's row.
        recv_rows = _take_row(table, (r + pl.skip) % p)
        buf = buf.at[recv_rows].set(T)
    return buf[:N].reshape(N, *blk_shape)


# ---------------------------------------------------------------------------
# Baseline backends (ring / recursive_halving / xla) — lazy import of the
# implementations in core.collectives (which imports this module)
# ---------------------------------------------------------------------------

def _baseline(fn_name: str):
    def run(plan: CollectivePlan, x: Array) -> Array:
        from repro.core import collectives as C
        fn = getattr(C, fn_name)
        return fn(x, plan.axis_name, op=plan.spec.op)
    return run


_BASELINE_RS = {
    "ring": _baseline("ring_reduce_scatter"),
    "recursive_halving": _baseline("recursive_halving_reduce_scatter"),
    "xla": _baseline("xla_reduce_scatter"),
}
_BASELINE_AR = {
    "ring": _baseline("ring_allreduce"),
    "xla": _baseline("xla_allreduce"),
}
_BASELINE_AG = {
    "xla": _baseline("xla_allgather"),
}
#: alltoall registry — the uniform circulant loops (lifted from the old
#: special cases in CollectivePlan.alltoall), the ragged table backend,
#: and XLA's native all-to-all as the A/B baseline.
_A2A_IMPLS = {
    "jnp": _a2a_jnp,
    "fused": _a2a_fused,
    "alltoallv": _a2a_v,
    "xla": _baseline("xla_alltoall"),
}

#: backend registry — what plan() can resolve a spec onto, and which
#: collectives each backend implements (introspection for the CI gate
#: and the docs; execution dispatches on the plan's ``backend`` field).
BACKENDS: dict[str, tuple[str, ...]] = {
    "jnp": ("reduce_scatter", "allgather", "allreduce", "alltoall"),
    "fused": ("reduce_scatter", "allgather", "allreduce", "alltoall"),
    "jnp+int8": ("reduce_scatter", "allgather", "allreduce"),
    "fused+int8": ("reduce_scatter", "allgather", "allreduce"),
    "nonuniform": ("reduce_scatter", "allgather", "allreduce"),
    "alltoallv": ("alltoall",),
    "broadcast": ("broadcast",),
    "ring": ("reduce_scatter", "allreduce"),
    "recursive_halving": ("reduce_scatter",),
    "xla": ("reduce_scatter", "allgather", "allreduce", "alltoall"),
}
