"""Continuous-batching request scheduler over the paged KV cache.

The serving loop is the vLLM-style iteration-level scheduler: at EVERY
decode-step boundary, finished requests are evicted (their blocks go
back to the free list) and waiting requests are admitted FCFS up to
``max_batch`` — a new arrival never waits for the whole in-flight batch
to drain.  Prefill and decode are split: an admission runs its own
(B=1) prefill call, so long prompts never sit inside the batched decode
step that in-flight requests are latency-bound on.

Parity contract (tested): with greedy sampling, the token stream each
request receives from the scheduler — under any admission/eviction
interleaving — is BITWISE-identical to running ``ServeEngine.generate``
one-shot on that request alone.  The ingredients: per-request block
tables gather to the same dense (L, B, max_len, Hkv, dh) view a static
cache would hold (stale rows from reused blocks are masked to exactly
zero probability), and ``decode_step`` accepts per-slot (B,) positions
so staggered requests each attend at their own offset.

Collectives never appear here: the engine's prefill/decode closures own
the mesh, and any replica-level communication goes through
``plan()``/``as_spec`` (enforced by the ``serve-collectives-via-plan``
repo-lint rule).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .engine import ServeEngine, eos_done_mask
from .kv_cache import (BlockAllocator, OutOfBlocks, PagedKVCache,
                       blocks_per_request, scratch_table)


@dataclass
class Request:
    """One generation request and its scheduler-owned state."""

    rid: int
    tokens: np.ndarray            # (S,) prompt
    max_new_tokens: int
    eos_id: int | None = None
    # scheduler state --------------------------------------------------
    blocks: list[int] = field(default_factory=list)
    pos: int = 0                  # next decode position (prompt_len + emitted - 1)
    last_token: int = 0
    out: list[int] = field(default_factory=list)
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    def emit(self, token: int) -> None:
        self.out.append(int(token))
        if len(self.out) >= self.max_new_tokens:
            self.done = True
        nxt, done = eos_done_mask(
            jnp.asarray([token], jnp.int32), jnp.asarray([self.done]),
            self.eos_id)
        self.done = bool(done[0])
        self.last_token = int(nxt[0])


class Scheduler:
    """FCFS continuous batching on one :class:`ServeEngine`.

    ``max_batch`` bounds the decode batch; every slot's KV lives in
    paged blocks sized ``kv_block_size`` (``engine.max_len`` must be a
    multiple).  ``num_blocks`` defaults to scratch + full occupancy.
    """

    def __init__(self, engine: ServeEngine, max_batch: int,
                 kv_block_size: int, num_blocks: int | None = None):
        self.engine = engine
        self.max_batch = max_batch
        self.blocks_per_req = blocks_per_request(engine.max_len,
                                                 kv_block_size)
        if num_blocks is None:
            num_blocks = 1 + max_batch * self.blocks_per_req
        self.alloc = BlockAllocator(num_blocks)
        self.kv = PagedKVCache.create(engine.model.cfg, num_blocks,
                                      kv_block_size)
        self.slots: list[Request | None] = [None] * max_batch
        self.waiting: deque[Request] = deque()
        self.finished: dict[int, np.ndarray] = {}
        self._next_rid = 0
        self.n_decode_steps = 0
        self.n_prefills = 0

    # -- request intake ----------------------------------------------------

    def submit(self, tokens: np.ndarray, max_new_tokens: int,
               eos_id: int | None = None) -> int:
        """Queue a request; returns its id (results in ``finished``)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.shape[0] + max_new_tokens > self.engine.max_len:
            raise ValueError(
                f"{tokens.shape[0]}+{max_new_tokens} exceeds cache "
                f"{self.engine.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append(Request(rid=rid, tokens=tokens,
                                    max_new_tokens=max_new_tokens,
                                    eos_id=eos_id))
        return rid

    @property
    def in_flight(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def idle(self) -> bool:
        return not self.waiting and self.in_flight == 0

    # -- the decode-boundary state machine ---------------------------------

    def _evict_finished(self) -> None:
        for i, req in enumerate(self.slots):
            if req is not None and req.done:
                self.alloc.free(req.blocks)
                req.blocks = []
                self.finished[req.rid] = np.asarray(req.out, np.int32)
                self.slots[i] = None

    def _admit(self) -> None:
        """FCFS admissions into free slots; each runs its own (B=1)
        prefill — in-flight decodes never wait inside a prompt pass —
        and samples its first token from the prefill logits, exactly as
        the one-shot generate loop does."""
        for i in range(self.max_batch):
            if not self.waiting or self.slots[i] is not None:
                continue
            try:
                blocks = self.alloc.alloc(self.blocks_per_req)
            except OutOfBlocks:
                return  # FCFS: later arrivals wait behind the head
            req = self.waiting.popleft()
            req.blocks = blocks
            cache, logits = self.engine.prefill_fn(
                self.engine.params, jnp.asarray(req.tokens[None]), {})
            if "mamba" in cache:
                raise NotImplementedError(
                    "paged scheduler covers attention-family caches only")
            self.kv = self.kv.write_prefill(
                blocks, {"k": cache["k"][:, 0], "v": cache["v"][:, 0]})
            self.n_prefills += 1
            req.pos = req.prompt_len
            req.emit(int(jnp.argmax(logits[0])))
            self.slots[i] = req
            if req.done:        # 1-token request (or instant eos)
                self._evict_finished()

    def step(self) -> None:
        """One decode-step boundary: evict, admit, then one batched
        decode over the active slots (inactive lanes run against the
        scratch block and are discarded)."""
        self._evict_finished()
        self._admit()
        active = [r for r in self.slots if r is not None]
        if not active:
            return
        token = np.zeros((self.max_batch,), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        tables = np.stack([scratch_table(self.blocks_per_req)
                           for _ in range(self.max_batch)])
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            token[i] = req.last_token
            pos[i] = req.pos
            tables[i] = np.asarray(req.blocks, np.int32)
        dense = self.kv.gather(tables)
        new_cache, logits = self.engine.decode_fn(
            self.engine.params, dense, jnp.asarray(token),
            jnp.asarray(pos))
        self.kv = self.kv.write_token(tables, new_cache, pos)
        self.n_decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.pos += 1
            req.emit(int(nxt[i]))

    def run(self, max_steps: int | None = None) -> dict[int, np.ndarray]:
        """Drive step() until every submitted request finished (or
        ``max_steps`` boundaries elapsed); returns {rid: (n,) tokens}."""
        steps = 0
        while not self.idle:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self._evict_finished()
        return self.finished
