"""Batched serving engine: prefill + decode with static-shape KV caches.

``ServeEngine`` is the example-facing loop: accepts a batch of prompts,
prefills once, then decodes greedily/temperature-sampled to max_new_tokens.
``build_serve_fns`` returns the jitted prefill/decode closures the launcher
lowers in the dry-run (decode_32k / long_500k cells lower ``decode_fn``).

With a ``mesh``, both closures run inside a fully-manual ``shard_map``
binding every mesh axis — the serving route onto collectives that need a
manual axis, e.g. MoE expert parallelism (``cfg.moe_dispatch='ep'``
exchanges the dispatch buffer over ``cfg.ep_axis`` via the circulant
alltoall plan).  Params and token batches stay replicated across the
mesh (each rank slices its own experts inside the region), so the
generated tokens are identical to the mesh-less path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models import ModelApi


def eos_done_mask(nxt, done, eos_id):
    """Advance per-request done masks for one sampled step.

    ``nxt``: (B,) sampled tokens; ``done``: (B,) bool mask of finished
    requests; ``eos_id``: None (no early exit), an int, or a (B,)
    per-request id vector where ``< 0`` means "no eos for this row".
    Finished rows keep emitting their eos token (so the output stays
    rectangular) and newly-eos rows join the mask.  Both the one-shot
    ``generate`` early-exit and the scheduler's eviction path run on
    this mask.
    """
    if eos_id is None:
        return nxt, done
    eos = jnp.asarray(eos_id, jnp.int32)
    if eos.ndim == 0:
        nxt = jnp.where(done, eos, nxt)
        done = done | (nxt == eos)
    else:
        nxt = jnp.where(done & (eos >= 0), eos, nxt)
        done = done | ((eos >= 0) & (nxt == eos))
    return nxt, done


def build_serve_fns(model: ModelApi, max_len: int, mesh=None):
    def prefill(params, tokens, extras):
        return model.prefill(params, tokens, max_len, **extras)

    def decode(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    if mesh is None:
        return jax.jit(prefill), jax.jit(decode)

    def wrap(fn, n_args):
        # Fully-manual region, everything replicated: the axes exist only
        # to bind names for the manual collectives (ep alltoall).  The
        # replication checker cannot see through rank-indexed expert
        # slices, hence check_vma=False.
        return jax.jit(compat.shard_map(
            fn, mesh=mesh,
            in_specs=tuple(P() for _ in range(n_args)),
            out_specs=P(), check_vma=False))

    return wrap(prefill, 3), wrap(decode, 4)


@dataclass
class ServeEngine:
    model: ModelApi
    params: Any
    max_len: int
    temperature: float = 0.0
    mesh: Any = None

    def __post_init__(self):
        self.prefill_fn, self.decode_fn = build_serve_fns(
            self.model, self.max_len, mesh=self.mesh)

    def generate(self, tokens: np.ndarray, max_new_tokens: int,
                 extras: dict | None = None, key=None,
                 eos_id: int | None = None) -> np.ndarray:
        """tokens: (B, S) prompt batch -> (B, max_new_tokens) completions.

        With ``eos_id``, rows that sample it stop consuming decode
        steps: finished rows are frozen to ``eos_id`` (the output stays
        (B, max_new_tokens)) and the loop exits as soon as every row's
        done mask is set — the same mask the continuous-batching
        scheduler uses to evict finished requests mid-batch.
        """
        extras = extras or {}
        b, s = tokens.shape
        if s + max_new_tokens > self.max_len:
            raise ValueError(f"{s}+{max_new_tokens} exceeds cache {self.max_len}")
        cache, logits = self.prefill_fn(self.params, jnp.asarray(tokens),
                                        extras)
        out = []
        key = key if key is not None else jax.random.PRNGKey(0)
        done = jnp.zeros((b,), bool)
        for i in range(max_new_tokens):
            if self.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / self.temperature,
                                             axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt, done = eos_done_mask(nxt.astype(jnp.int32), done, eos_id)
            out.append(np.asarray(nxt))
            if eos_id is not None and bool(done.all()):
                out.extend([np.full((b,), eos_id, np.int32)]
                           * (max_new_tokens - i - 1))
                break
            cache, logits = self.decode_fn(self.params, cache, nxt,
                                           jnp.asarray(s + i, jnp.int32))
        return np.stack(out, axis=1)
