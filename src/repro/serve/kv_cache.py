"""Paged KV cache: fixed-size blocks, per-request block tables, free-list
allocator.

The one-shot engine sizes a dense ``(L, B, max_len, Hkv, dh)`` cache per
batch; a serving workload with staggered arrivals wastes most of it
(every slot reserves ``max_len`` rows forever).  The paged cache keeps
ONE pool of fixed-size blocks shared by all in-flight requests:

* :class:`BlockAllocator` — host-side free list.  Blocks freed on
  eviction are reused by later admissions; the allocator tracks the live
  set so a double-free or an alias of a live block is an error, not a
  silent corruption (tested in ``tests/test_serve.py``).
* :class:`PagedKVCache` — the device-side pool ``(L, num_blocks,
  block_size, Hkv, dh)`` plus pure functional views: ``gather`` builds
  the dense per-step decode view from a ``(B, blocks_per_req)`` block
  table (bitwise-identical rows to a dense cache holding the same
  tokens), ``write_prefill`` scatters one request's prefilled rows into
  its blocks, ``write_token`` scatters only the single decoded position
  per slot back into the pool.

Block 0 is the reserved SCRATCH block: inactive scheduler slots point
their whole table at it, so padded decode lanes write garbage somewhere
harmless instead of into a live request.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


class OutOfBlocks(RuntimeError):
    """Raised when an admission asks for more blocks than are free (the
    scheduler treats this as "keep the request queued")."""


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` fixed-size cache blocks.

    Block ``scratch`` (default 0) is never handed out — it is the dummy
    target for inactive batch slots.  ``alloc``/``free`` maintain a live
    set; freeing a block twice, freeing scratch, or allocating a block
    that is somehow still live raises instead of aliasing.
    """

    def __init__(self, num_blocks: int, scratch: int = 0):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 scratch), got {num_blocks}")
        self.num_blocks = num_blocks
        self.scratch = scratch
        self._free = [b for b in range(num_blocks) if b != scratch]
        self._live: set[int] = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` blocks off the free list (FIFO reuse order)."""
        if n > len(self._free):
            raise OutOfBlocks(f"need {n} blocks, {len(self._free)} free")
        taken, self._free = self._free[:n], self._free[n:]
        clash = self._live & set(taken)
        if clash:
            raise RuntimeError(f"allocator handed out live blocks {clash}")
        self._live |= set(taken)
        return taken

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if b == self.scratch:
                raise ValueError("cannot free the scratch block")
            if b not in self._live:
                raise ValueError(f"double free of block {b}")
            self._live.discard(b)
            self._free.append(b)


@dataclass(frozen=True)
class PagedKVCache:
    """Device-side block pool; all mutators return a new instance
    (functional, jit-friendly)."""

    k: jax.Array   # (L, num_blocks, block_size, Hkv, dh)
    v: jax.Array
    block_size: int

    @classmethod
    def create(cls, cfg, num_blocks: int, block_size: int) -> "PagedKVCache":
        """Zeroed pool sized from the model config (attention KV only —
        the hybrid family's recurrent mamba state is per-slot constant
        size and has no paging to do)."""
        from repro.models.layers import dtype_of
        if cfg.family == "hybrid":
            raise NotImplementedError(
                "paged KV serving does not cover the hybrid family yet "
                "(its mamba state is unpaged by construction)")
        shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads,
                 cfg.head_dim)
        z = jnp.zeros(shape, dtype_of(cfg))
        return cls(k=z, v=z, block_size=block_size)

    @property
    def num_blocks(self) -> int:
        return self.k.shape[1]

    def gather(self, tables) -> dict:
        """Dense decode view for one step.

        ``tables``: (B, blocks_per_req) int32 block table — row b lists
        slot b's blocks in sequence order.  Returns the ``{"k", "v"}``
        cache dict of shape (L, B, blocks_per_req*block_size, Hkv, dh)
        the model's ``decode_step`` consumes; rows holding the same
        tokens as a dense cache are bitwise-identical to it.
        """
        tables = jnp.asarray(tables, jnp.int32)
        b, nb = tables.shape

        def g(s):
            t = s[:, tables]              # (L, B, nb, bs, Hkv, dh)
            return t.reshape(s.shape[0], b, nb * self.block_size,
                             *s.shape[3:])
        return {"k": g(self.k), "v": g(self.v)}

    def write_prefill(self, blocks: Sequence[int], dense) -> "PagedKVCache":
        """Scatter ONE prefilled request into its blocks.

        ``dense``: the request's cache dict with batch dim stripped —
        k/v of shape (L, S_cap, Hkv, dh), S_cap == len(blocks) *
        block_size (prompt rows written, tail rows zero).
        """
        idx = jnp.asarray(list(blocks), jnp.int32)
        nb = idx.shape[0]

        def w(s, d):
            d = d.reshape(d.shape[0], nb, self.block_size, *d.shape[2:])
            return s.at[:, idx].set(d.astype(s.dtype))
        return replace(self, k=w(self.k, dense["k"]), v=w(self.v, dense["v"]))

    def write_token(self, tables, dense, pos) -> "PagedKVCache":
        """Scatter each slot's single decoded position back to the pool.

        ``dense``: the (L, B, S_cap, Hkv, dh) cache dict returned by
        ``decode_step`` on the gathered view; ``pos``: (B,) per-slot
        positions just written.  Only row ``pos[b]`` of slot b moves —
        block ``tables[b, pos[b]//bs]``, offset ``pos[b] % bs``.
        """
        tables = jnp.asarray(tables, jnp.int32)
        pos = jnp.asarray(pos, jnp.int32)
        bidx = jnp.take_along_axis(
            tables, (pos // self.block_size)[:, None], axis=1)[:, 0]
        off = pos % self.block_size

        def w(s, d):
            vec = jnp.take_along_axis(
                d, pos[None, :, None, None, None], axis=2)[:, :, 0]
            return s.at[:, bidx, off].set(vec.astype(s.dtype))
        return replace(self, k=w(self.k, dense["k"]), v=w(self.v, dense["v"]))


def blocks_per_request(max_len: int, block_size: int) -> int:
    """Block-table length covering ``max_len`` rows; requires exact
    divisibility so the gathered view's length equals the dense cache's
    (the bitwise-parity contract with one-shot generation)."""
    if max_len % block_size:
        raise ValueError(
            f"max_len {max_len} must be a multiple of kv_block_size "
            f"{block_size} (gathered view must match the dense cache)")
    return max_len // block_size


def scratch_table(blocks_per_req: int, scratch: int = 0) -> np.ndarray:
    """Block table of an INACTIVE slot: every entry the scratch block."""
    return np.full((blocks_per_req,), scratch, np.int32)
