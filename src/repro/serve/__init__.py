from .engine import ServeEngine, build_serve_fns, eos_done_mask  # noqa: F401
from .kv_cache import (BlockAllocator, OutOfBlocks,  # noqa: F401
                       PagedKVCache, blocks_per_request, scratch_table)
from .replica import ReplicaSet  # noqa: F401
from .scheduler import Request, Scheduler  # noqa: F401
