from .engine import ServeEngine, build_serve_fns  # noqa: F401
