"""Multi-replica data-parallel serving: broadcast-plan weight fan-out.

A serving deployment runs ``replicas`` copies of the model and splits
request traffic across them.  The one collective such a deployment needs
at weight-push time is a BROADCAST of the (new) parameters from the rank
that holds them to every replica — which is exactly the standalone
allgather phase of the paper's circulant construction, exposed here as
the ``kind="broadcast"`` plan (Träff, arXiv:2407.18004: all-broadcast in
ceil(log2 p) rounds for any p, one ppermute per round).

``ReplicaSet.push_weights`` shards every parameter leaf over a
``(replicas,)`` mesh, runs the broadcast plan so each replica
reconstructs the full leaf, and asserts the reconstruction is BITWISE
identical across replicas before handing the params to the per-replica
engines — the plan moves payload bits untouched (``wire_dtype``
compression is rejected for this kind at spec level), so any mismatch is
a routing bug, not rounding.

All communication goes through ``plan()``-backed dispatchers (enforced
by repo-lint's ``serve-collectives-via-plan`` rule); this module never
issues a raw ``ppermute``.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import collectives as C
from repro.core.spec import CollectiveSpec
from repro.models import ModelApi

from .engine import ServeEngine

REP_AXIS = "rep"


class ReplicaSet:
    """``replicas`` data-parallel :class:`ServeEngine` copies.

    ``devices`` picks the mesh ranks for the weight fan-out (default: the
    first ``replicas`` runtime devices).  ``engine_mesh`` is forwarded to
    every engine — the MoE ``ep``-axis mesh for expert-parallel decode —
    and is independent of the fan-out mesh.  ``schedule`` selects the
    broadcast plan's schedule ("power2"/"halving" give the optimal
    ceil(log2 p) rounds at every p).
    """

    def __init__(self, model: ModelApi, max_len: int, replicas: int, *,
                 temperature: float = 0.0, schedule: str = "power2",
                 devices: Sequence[Any] | None = None, engine_mesh=None):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.replicas = replicas
        self.spec = CollectiveSpec(kind="broadcast", schedule=schedule)
        if replicas > 1:
            devs = list(devices) if devices is not None \
                else jax.devices()[:replicas]
            if len(devs) < replicas:
                raise ValueError(
                    f"{replicas} replicas need {replicas} devices, have "
                    f"{len(devs)} (set xla_force_host_platform_device_count)")
            self.mesh = compat.make_mesh((replicas,), (REP_AXIS,),
                                         devices=devs[:replicas])
        else:
            self.mesh = None
        self.engines = [
            ServeEngine(model=model, params=None, max_len=max_len,
                        temperature=temperature, mesh=engine_mesh)
            for _ in range(replicas)]

    # -- weight distribution -----------------------------------------------

    def _fan_out_leaf(self, leaf) -> jax.Array:
        """One leaf through the broadcast plan: shard rows over the rep
        mesh, all-broadcast so every rank reconstructs all rows, assert
        the p reconstructions are bitwise identical, return one."""
        p = self.replicas
        arr = jnp.asarray(leaf)
        flat = arr.ravel()
        n = flat.size
        pad = (-n) % p
        if pad:
            flat = jnp.concatenate(
                [flat, jnp.zeros((pad,), flat.dtype)])
        rows = flat.reshape(p, -1)

        fn = compat.shard_map(
            lambda v: C.broadcast(v, REP_AXIS, spec=self.spec),
            mesh=self.mesh, in_specs=(P(REP_AXIS),),
            out_specs=P(REP_AXIS), check_vma=False)
        stacked = np.asarray(jax.jit(fn)(rows)).reshape(p, p, -1)
        for r in range(1, p):
            if not np.array_equal(stacked[r], stacked[0]):
                raise AssertionError(
                    f"replica {r} reconstructed different weight bits "
                    f"than replica 0 (broadcast must be bit-exact)")
        return jnp.asarray(stacked[0]).reshape(-1)[:n].reshape(
            arr.shape).astype(arr.dtype)

    def push_weights(self, params) -> dict:
        """Fan ``params`` out to every replica engine; returns stats
        (leaf count, payload bytes, broadcast rounds per leaf)."""
        from repro.core.plan import plan
        from repro.core.schedule import ceil_log2
        leaves, treedef = jax.tree.flatten(params)
        if self.replicas == 1:
            for e in self.engines:
                e.params = params
            return {"n_leaves": len(leaves), "rounds": 0}
        out = [self._fan_out_leaf(leaf) for leaf in leaves]
        full = jax.tree.unflatten(treedef, out)
        for e in self.engines:
            e.params = full
        pl = plan(self.spec, p=self.replicas, axis_name=REP_AXIS)
        rounds = len(pl.ag_rounds)
        assert self.spec.schedule != "power2" or \
            rounds == ceil_log2(self.replicas)
        return {
            "n_leaves": len(leaves),
            "bytes": sum(int(np.asarray(v).nbytes) for v in out),
            "rounds": rounds,
        }

    # -- request dispatch --------------------------------------------------

    def generate(self, tokens: np.ndarray, max_new_tokens: int,
                 extras: dict | None = None,
                 eos_id: int | None = None) -> np.ndarray:
        """Split a (B, S) prompt batch round-robin across replicas and
        reassemble the (B, max_new_tokens) completions in order.  Every
        replica holds identical (bitwise-verified) weights, so the
        output is independent of the split."""
        if any(e.params is None for e in self.engines):
            raise RuntimeError("call push_weights before generate")
        b = tokens.shape[0]
        parts = [list(range(r, b, self.replicas))
                 for r in range(self.replicas)]
        out = np.zeros((b, max_new_tokens), np.int32)
        for eng, rows in zip(self.engines, parts):
            if not rows:
                continue
            out[rows] = eng.generate(tokens[rows], max_new_tokens,
                                     extras=extras, eos_id=eos_id)
        return out
