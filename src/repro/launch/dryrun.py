import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "3")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as its own process (the two lines above run before ANY other
import, since jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k --mesh 1pod --out reports/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh 2pod

Per cell it lowers the appropriate step (train_step for train shapes;
prefill/serve decode_step for inference shapes), compiles for the
production mesh, prints ``memory_analysis()`` (proof-of-fit) and
``cost_analysis()`` (FLOPs/bytes for §Roofline), parses collective bytes
from the post-SPMD HLO, and writes a JSON record consumed by
EXPERIMENTS.md §Dry-run / §Roofline and the perf loop.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import ALIASES, get_config
from repro.launch import mesh as meshlib
from repro.models import ShardingRecipe, build, make_param_specs
from repro.optim.adamw import AdamWConfig
from repro.optim.zero1 import GradSyncConfig
from repro.roofline import analysis as roofline
from repro.roofline.analytic import CellSpec, analytic_cell
from repro.train import build as build_step

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long-context decode needs sub-quadratic attention: SSM/hybrid only.
LONG_OK = {"xlstm-125m", "hymba-1.5b"}
# archs whose params cannot be replicated across data ranks: pure-GSPMD FSDP
FSDP_ARCHS = {"grok-1-314b", "qwen1.5-110b", "llama-3.2-vision-90b"}


def corr_multiplier(cfg) -> float:
    """Two-point scan-unroll correction: corrected = m(u1) + M*(m(u2)-m(u1)).

    M = trips-1 for a single layer scan; for several scans with EQUAL trip
    counts (whisper enc+dec) the same formula is exact; for hybrid (hymba)
    the two SWA scans have near-equal trips and identical bodies, so
    M = mean(trips_i - 1).  0 = no scan (fully unrolled: xlstm)."""
    if cfg.family == "ssm_xlstm":
        return 0.0
    if cfg.family == "hybrid":
        from repro.models.transformer import _hybrid_runs
        scan_trips = [hi - lo for lo, hi, g in _hybrid_runs(cfg)
                      if not g and hi - lo > 1]
        if not scan_trips:
            return 0.0
        return sum(t - 1 for t in scan_trips) / len(scan_trips)
    if cfg.family == "vlm":
        return cfg.n_layers // 5 - 1
    if cfg.family == "encdec":
        assert cfg.enc_layers == cfg.n_layers, \
            "two-point correction needs equal enc/dec trip counts"
        return cfg.n_layers - 1
    return cfg.n_layers - 1


def skip_reason(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch not in LONG_OK:
        return "SKIP(full-attention: 500k decode needs sub-quadratic arch)"
    return None


def make_recipe(arch: str, mesh, *, expand_gqa: bool = False
                ) -> ShardingRecipe:
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    mode = "tp_fsdp" if arch in FSDP_ARCHS else "tp"
    return ShardingRecipe(data_axes=data_axes, model_axis="model", mode=mode,
                          tp_size=mesh.shape["model"], expand_gqa=expand_gqa)


def input_specs(arch: str, shape: str, mesh, recipe) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type correct, sharded, no device allocation."""
    cfg = get_config(arch)
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    bspec = meshlib.sanitize_spec(mesh, P(recipe.data_axes), (b,))
    tok_ns = NamedSharding(mesh, meshlib.sanitize_spec(
        mesh, P(recipe.data_axes), (b, s)))
    out = {}
    if info["kind"] == "train":
        dec = min(cfg.dec_len, s) if cfg.family == "encdec" else s
        out["tokens"] = jax.ShapeDtypeStruct((b, dec), jnp.int32,
                                             sharding=tok_ns)
        out["targets"] = jax.ShapeDtypeStruct((b, dec), jnp.int32,
                                              sharding=tok_ns)
    else:
        dec = min(cfg.dec_len, s) if cfg.family == "encdec" else s
        out["tokens"] = jax.ShapeDtypeStruct((b, dec), jnp.int32,
                                             sharding=tok_ns)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, meshlib.sanitize_spec(
                mesh, P(recipe.data_axes, None, None), (b, s, cfg.d_model))))
    if cfg.family == "vlm":
        sh = (b, cfg.n_image_tokens, cfg.d_model)
        out["image_embeds"] = jax.ShapeDtypeStruct(
            sh, jnp.bfloat16,
            sharding=NamedSharding(mesh, meshlib.sanitize_spec(
                mesh, P(recipe.data_axes, None, None), sh)))
    return out


def _param_structs(model, mesh, recipe):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = make_param_specs(shapes, recipe)
    specs = meshlib.sanitize_specs(mesh, specs, shapes)
    return meshlib.struct_with_sharding(shapes, meshlib.named(mesh, specs))


def _cache_structs(model, params_s, inputs, mesh, recipe, seq, batch):
    extras = {k: v for k, v in inputs.items() if k not in ("tokens",)}
    cache_sh, _ = jax.eval_shape(
        lambda p, t, ex: model.prefill(p, t, seq, **ex),
        params_s, inputs["tokens"], extras)
    specs = jax.tree.map(
        lambda l: meshlib.best_effort_cache_spec(
            mesh, l.shape, batch, recipe.data_axes, recipe.model_axis),
        cache_sh)
    return meshlib.struct_with_sharding(cache_sh, meshlib.named(mesh, specs))


def run_cell(arch: str, shape: str, mesh_name: str, *, grad_sync="circulant",
             schedule="halving", compress=None, remat=True,
             out_dir="reports/dryrun", tag="", correction=True,
             expand_gqa=False, rs_dtype="float32",
             moe_dispatch="global", remat_policy="nothing") -> dict:
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name,
                 "grad_sync": grad_sync, "schedule": schedule,
                 "compress": compress, "remat": remat, "tag": tag,
                 "expand_gqa": expand_gqa, "rs_dtype": rs_dtype,
                 "moe_dispatch": moe_dispatch}
    reason = skip_reason(arch, shape)
    if reason:
        rec["status"] = reason
        return rec
    import dataclasses as _dc0
    cfg = get_config(arch)
    if moe_dispatch != "global":
        cfg = _dc0.replace(cfg, moe_dispatch=moe_dispatch)
    if remat_policy != "nothing":
        cfg = _dc0.replace(cfg, remat_policy=remat_policy)
    mesh = meshlib.make_production_mesh(multi_pod=(mesh_name == "2pod"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    recipe = make_recipe(arch, mesh, expand_gqa=expand_gqa)
    info = SHAPES[shape]
    training = info["kind"] == "train"
    mode = "fsdp_auto" if arch in FSDP_ARCHS else "zero1"
    rec["mode"] = mode if training else "serve"

    def lower_and_compile(cfg_l):
        """Lower+compile the cell's step for a given (possibly unroll-
        modified) config.  Returns (compiled, tokens_global)."""
        with compat.use_mesh(mesh):
            model = build(cfg_l, recipe=recipe, remat=remat)
            params_s = _param_structs(model, mesh, recipe)
            inputs = input_specs(arch, shape, mesh, recipe)

            if training:
                sync = GradSyncConfig(impl=grad_sync, schedule=schedule,
                                      compress=compress, rs_dtype=rs_dtype)
                built = build_step(mode, model, AdamWConfig(), mesh=mesh,
                                   recipe=recipe, sync=sync, remat=remat)
                if mode == "zero1":
                    opt_s = jax.eval_shape(built.init_opt, params_s)
                    opt_s = meshlib.struct_with_sharding(
                        opt_s, built.opt_spec(params_s))
                else:
                    opt_s = jax.eval_shape(built.init_opt, params_s)
                    opt_s = meshlib.struct_with_sharding(
                        opt_s, jax.tree.map(
                            lambda l: NamedSharding(
                                mesh, meshlib.sanitize_spec(
                                    mesh, P(), l.shape)), opt_s))
                    # m/v shard like params (FSDP)
                    pspecs = make_param_specs(params_s, recipe)
                    pspecs = meshlib.sanitize_specs(mesh, pspecs, params_s)
                    opt_s = opt_s._replace(
                        m=meshlib.struct_with_sharding(
                            jax.eval_shape(lambda p: jax.tree.map(
                                lambda l: jnp.zeros(l.shape, jnp.float32), p),
                                params_s),
                            meshlib.named(mesh, pspecs)),
                        v=meshlib.struct_with_sharding(
                            jax.eval_shape(lambda p: jax.tree.map(
                                lambda l: jnp.zeros(l.shape, jnp.float32), p),
                                params_s),
                            meshlib.named(mesh, pspecs)))
                batch_s = dict(inputs)
                lowered = built.step_fn.lower(params_s, opt_s, batch_s)
                tokens_global = info["batch"] * (
                    batch_s["tokens"].shape[1])
                return lowered, tokens_global
            elif info["kind"] == "prefill":
                extras = {k: v for k, v in inputs.items() if k != "tokens"}

                def prefill_fn(p, t, ex):
                    return model.prefill(p, t, info["seq"], **ex)

                lowered = jax.jit(prefill_fn).lower(
                    params_s, inputs["tokens"], extras)
                tokens_global = info["batch"] * inputs["tokens"].shape[1]
                return lowered, tokens_global
            else:  # decode
                prefill_inputs = input_specs(arch, "prefill_32k"
                                             if shape == "decode_32k"
                                             else shape, mesh, recipe)
                # cache sized to this cell's seq
                cache_inputs = dict(prefill_inputs)
                b = info["batch"]
                # rebuild token struct at this cell's batch
                dec = (min(cfg_l.dec_len, info["seq"])
                       if cfg_l.family == "encdec" else info["seq"])
                tok_ns = NamedSharding(mesh, meshlib.sanitize_spec(
                    mesh, P(recipe.data_axes), (b, dec)))
                cache_inputs["tokens"] = jax.ShapeDtypeStruct(
                    (b, dec), jnp.int32, sharding=tok_ns)
                for k in ("frames",):
                    if k in cache_inputs:
                        sh = (b, info["seq"], cfg_l.d_model)
                        cache_inputs[k] = jax.ShapeDtypeStruct(
                            sh, jnp.bfloat16,
                            sharding=NamedSharding(
                                mesh, meshlib.sanitize_spec(
                                    mesh, P(recipe.data_axes, None, None),
                                    sh)))
                if "image_embeds" in cache_inputs:
                    sh = (b, cfg_l.n_image_tokens, cfg_l.d_model)
                    cache_inputs["image_embeds"] = jax.ShapeDtypeStruct(
                        sh, jnp.bfloat16,
                        sharding=NamedSharding(
                            mesh, meshlib.sanitize_spec(
                                mesh, P(recipe.data_axes, None, None), sh)))
                cache_s = _cache_structs(model, params_s, cache_inputs, mesh,
                                         recipe, info["seq"], b)
                token_s = jax.ShapeDtypeStruct(
                    (b,), jnp.int32,
                    sharding=NamedSharding(mesh, meshlib.sanitize_spec(
                        mesh, P(recipe.data_axes), (b,))))
                pos_s = jax.ShapeDtypeStruct((), jnp.int32)

                def decode_fn(p, c, t, pos):
                    return model.decode_step(p, c, t, pos)

                lowered = jax.jit(decode_fn).lower(params_s, cache_s,
                                                   token_s, pos_s)
                tokens_global = info["batch"]  # one token per sequence
                return lowered, tokens_global

    try:
        import dataclasses as _dc
        t0 = time.time()
        lowered1, tokens_global = lower_and_compile(cfg)
        t_lower = time.time() - t0
        compiled = lowered1.compile()
        t_compile = time.time() - t0 - t_lower
        stats1 = roofline.parse_collectives(compiled.as_text())
        ca1 = compat.cost_analysis(compiled)

        # Two-point scan-unroll correction for loop-resident collectives
        # (and HLO flops/bytes diagnostics): metrics(total) =
        # m(u1) + (trips-1) * (m(u2) - m(u1)).
        mult = corr_multiplier(cfg) if correction else 0.0
        if mult > 0:
            cfg2 = _dc.replace(cfg, scan_unroll=2)
            lowered2, _ = lower_and_compile(cfg2)
            compiled2 = lowered2.compile()
            stats2 = roofline.parse_collectives(compiled2.as_text())
            ca2 = compat.cost_analysis(compiled2)
        else:
            stats2, ca2 = stats1, ca1

        def corr(a, b):
            # GSPMD may partition the u2 body slightly differently; floor
            # the extrapolation at the directly measured u1 value so noise
            # cannot produce negative totals.
            return max(a, a + mult * (b - a))

        coll_bytes = corr(stats1.total_bytes, stats2.total_bytes)
        coll_ops = {k: corr(stats1.ops.get(k, 0), stats2.ops.get(k, 0))
                    for k in set(stats1.ops) | set(stats2.ops)}
        coll_bytes_by_op = {
            k: corr(stats1.bytes_by_op.get(k, 0.0),
                    stats2.bytes_by_op.get(k, 0.0))
            for k in set(stats1.bytes_by_op) | set(stats2.bytes_by_op)}
        hlo_flops_corr = corr(float(ca1.get("flops", 0.0)),
                              float(ca2.get("flops", 0.0)))
        hlo_bytes_corr = corr(float(ca1.get("bytes accessed", 0.0)),
                              float(ca2.get("bytes accessed", 0.0)))

        # Analytic compute/memory terms (inner tile loops are invisible to
        # HLO cost analysis — see roofline/analytic.py docstring).
        data_axes = tuple(a for a in mesh.shape if a != "model")
        cell = CellSpec(kind=info["kind"], seq=info["seq"],
                        batch=info["batch"], n_chips=n_chips,
                        tp=mesh.shape["model"],
                        dp_world=int(np.prod([mesh.shape[a]
                                              for a in data_axes])),
                        remat=remat)
        ana = analytic_cell(cfg, cell)

        rl = roofline.Roofline(
            flops_per_chip=ana["flops_per_chip"],
            hbm_bytes_per_chip=ana["hbm_bytes_per_chip"],
            collective_bytes_per_chip=coll_bytes,
            model_flops_per_chip=roofline.model_flops(
                cfg, tokens_global / n_chips, training))

        ma = compiled.memory_analysis()
        rec.update(
            status="OK",
            n_chips=n_chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            corr_multiplier=mult,
            memory=dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                peak_bytes=(ma.argument_size_in_bytes
                            + ma.temp_size_in_bytes),
            ),
            roofline=rl.as_dict(),
            collective_ops=coll_ops,
            collective_bytes_by_op=coll_bytes_by_op,
            hlo_diag=dict(
                flops_corrected=hlo_flops_corr,
                bytes_corrected=hlo_bytes_corr,
                flops_raw=float(ca1.get("flops", 0.0)),
                bytes_raw=float(ca1.get("bytes accessed", 0.0)),
            ),
            tokens_global=tokens_global,
        )
        print(f"[{arch} × {shape} × {mesh_name}] OK  "
              f"compile={t_compile:.0f}s  "
              f"args={ma.argument_size_in_bytes/2**30:.2f}GiB  "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB  "
              f"bottleneck={rl.bottleneck}  "
              f"terms(c/m/x)=({rl.t_compute:.4f},{rl.t_memory:.4f},"
              f"{rl.t_collective:.4f})s  "
              f"roofline_frac={rl.roofline_fraction:.3f}")
    except Exception as e:  # noqa: BLE001 — record the failure, don't die
        rec["status"] = f"ERROR: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{arch} × {shape} × {mesh_name}] FAILED: {e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["1pod", "2pod"], default="1pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--grad-sync", default="circulant",
                    choices=["circulant", "ring", "xla", "allreduce"])
    ap.add_argument("--schedule", default="halving")
    ap.add_argument("--compress", default=None, choices=[None, "int8"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--expand-gqa", action="store_true")
    ap.add_argument("--rs-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--moe-dispatch", default="global",
                    choices=["global", "rowwise"])
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "dots"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-correction", action="store_true",
                    help="skip the second (unroll=2) compile; mesh-pass "
                         "only (2pod sweeps)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ALIASES:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        suffix = f"_{args.tag}" if args.tag else ""
        path = os.path.join(
            args.out, f"{arch}_{shape}_{args.mesh}{suffix}.json")
        if args.skip_existing and os.path.exists(path):
            try:
                if "ERROR" not in json.load(open(path)).get("status", ""):
                    print(f"skip existing {path}")
                    continue
            except Exception:
                pass
        rec = run_cell(arch, shape, args.mesh, grad_sync=args.grad_sync,
                       schedule=args.schedule, compress=args.compress,
                       remat=not args.no_remat, tag=args.tag,
                       correction=not args.no_correction,
                       expand_gqa=args.expand_gqa, rs_dtype=args.rs_dtype,
                       moe_dispatch=args.moe_dispatch,
                       remat_policy=args.remat_policy)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"  -> {path}")


if __name__ == "__main__":
    main()
