"""Production mesh construction + sharding-spec sanitation.

The production target is a TPU v5e pod of 256 chips as a (data=16,
model=16) mesh, and 2 pods = 512 chips as (pod=2, data=16, model=16).
Importing this module NEVER touches jax device state — meshes are built
only inside functions (dryrun.py sets the 512-device XLA flag before any
jax import; tests/benches keep the real 1-device CPU view).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices (dryrun.py sets "
            f"xla_force_host_platform_device_count=512); have "
            f"{len(devices)}")
    return compat.make_mesh(shape, axes, devices=devices)


def make_mesh(shape, axes, *, devices=None) -> Mesh:
    """Mesh over ``devices`` (default: the runtime's).  An explicit
    subset is how the elastic harness builds a p′ < device_count mesh
    after a resize — the surviving rank set, not the physical total."""
    return compat.make_mesh(tuple(shape), tuple(axes), devices=devices)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


def sanitize_spec(mesh: Mesh, spec: P, shape, *, model_axis: str = "model",
                  fallback: bool = True) -> P:
    """Drop sharding on dims the mesh axes don't divide evenly (GSPMD would
    error); replication is always sound.  If the model axis was dropped
    (e.g. 8 experts on a 16-way model axis, 12 heads on 16) RELOCATE it to
    the largest still-unsharded divisible dim — otherwise the leaf (and its
    optimizer state) silently replicates over the whole model axis, which
    for MoE expert stacks is a per-chip memory catastrophe."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    entries = entries[:len(shape)]
    had_model = any(
        (e == model_axis) or (isinstance(e, tuple) and model_axis in e)
        for e in entries)
    out = []
    for dim, entry in zip(shape, entries):
        size = _axis_size(mesh, entry)
        out.append(entry if size > 1 and dim % size == 0 else
                   (entry if size == 1 else None))
    has_model = any(
        (e == model_axis) or (isinstance(e, tuple) and model_axis in e)
        for e in out)
    if fallback and had_model and not has_model and model_axis in mesh.shape:
        msize = mesh.shape[model_axis]
        cand, best = None, 0
        for i, (dim, entry) in enumerate(zip(shape, out)):
            if entry is None and dim % msize == 0 and dim >= msize \
                    and dim > best:
                cand, best = i, dim
        if cand is not None:
            out[cand] = model_axis
    return P(*out)


def sanitize_specs(mesh: Mesh, specs, shapes, *, model_axis: str = "model"):
    """Tree version: specs and shapes are matching pytrees (shapes as
    ShapeDtypeStruct or arrays)."""
    return jax.tree.map(
        lambda sp, sh: sanitize_spec(mesh, sp, sh.shape,
                                     model_axis=model_axis), specs, shapes,
        is_leaf=lambda x: isinstance(x, P))


def named(mesh: Mesh, specs):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def struct_with_sharding(shapes, shardings):
    """ShapeDtypeStructs carrying NamedShardings (dry-run inputs: weak-type
    correct, shardable, no allocation)."""
    return jax.tree.map(
        lambda sh, ns: jax.ShapeDtypeStruct(sh.shape, sh.dtype, sharding=ns),
        shapes, shardings)


def best_effort_cache_spec(mesh: Mesh, shape, global_batch: int,
                           data_axes, model_axis) -> P:
    """Generic cache/state sharding: the dim equal to the global batch goes
    over the data axes; the largest remaining dim divisible by the model
    axis goes over model."""
    entries = [None] * len(shape)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    msize = mesh.shape[model_axis]
    batch_dim = None
    for i, d in enumerate(shape):
        if d == global_batch and d % dsize == 0:
            batch_dim = i
            entries[i] = tuple(data_axes)
            break
    model_dim, best = None, 0
    for i, d in enumerate(shape):
        if i != batch_dim and d % msize == 0 and d > best and d >= msize:
            model_dim, best = i, d
    if model_dim is not None:
        entries[model_dim] = model_axis
    return P(*entries)
