"""Elastic drill harness: injected rank faults → live world resize.

Grown from ``examples/elastic_restart_demo.py`` (whole-process crash +
cold restart) into the full elastic machine: a mid-run SHRINK (a rank
dies, survivors continue at p−1) or GROW (capacity arrives, resume at a
larger p) without abandoning the run — drain to the last step boundary,
re-plan every active collective at the new p (statically verified before
any data moves), reshard the ZeRO-1 state, resume.

    PYTHONPATH=src python -m repro.launch.elastic --arch qwen3-1.7b \
        --scale-down --steps 9 --world 4 --shrink-at-step 5 --fail-rank 2 \
        --seq-len 16 --global-batch 12 --ckpt-every 3

The circulant plans are what make this cheap: they are round-optimal at
ANY p (paper Theorem 1/2), so 4 → 3 is as good a world as 4 — no
power-of-two rebuild, no padded ghost ranks.

``run_drill`` is the programmatic entry (the elastic benchmark worker
and tests call it); it returns the pre/post trajectories, the
controller's :class:`~repro.ft.elastic.RecoveryReport`, and — with
``compare_ref=True`` — an uninterrupted REFERENCE run at p′ restored
from the same checkpoint through the same resize path, so the drill can
assert the resumed trajectory matches it (f32: bitwise).
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, config_fingerprint
from repro.configs import ALIASES
from repro.ft import (ElasticConfig, ElasticController, FailurePlan,
                      FaultEvent, RankFailure, Watchdog, WatchdogConfig,
                      active_specs)
from repro.launch import bootstrap


def _ckpt_extra(sess, step: int, arch: str) -> dict:
    return {"data_cursor": step, "config": config_fingerprint(sess.cfg),
            "world": sess.world, "arch": arch}


def _train_range(sess, start: int, stop: int, *, mgr=None, ckpt_every=None,
                 fplan: FailurePlan | None = None, watchdog=None,
                 arch: str = "", out=None) -> list[tuple[int, float]]:
    """Run steps [start, stop) on ``sess``; returns (step, loss) pairs.
    Raises :class:`RankFailure` at the step a ``rank_loss`` fault fires
    (the step does NOT execute — the rank is gone); rows accumulated so
    far survive in the caller-supplied ``out`` list."""
    if out is None:
        out = []
    with sess.use_mesh():
        for step in range(start, stop):
            if fplan is not None:
                fplan.check(step)
            t0 = time.time()
            metrics = bootstrap.run_step(sess, step)
            loss = float(metrics["loss"])  # blocks: step really ran
            dt = time.time() - t0
            if watchdog is not None:
                slow = fplan.slow_delay(step) if fplan is not None else 0.0
                watchdog.observe(step, dt + slow)
            out.append((step, loss))
            if mgr is not None and ckpt_every and \
                    (step + 1) % ckpt_every == 0:
                mgr.save_async(step + 1, sess.params,
                               bootstrap.opt_flat(sess),
                               _ckpt_extra(sess, step + 1, arch))
    return out


def run_drill(*, arch: str = "qwen3-1.7b", scale_down: bool = True,
              steps: int = 9, seq_len: int = 16, global_batch: int = 12,
              world: int = 4, mp: int = 1,
              shrink_at_step: int | None = None, fail_rank: int = 0,
              grow_at_step: int | None = None, grow_to: int | None = None,
              ckpt_every: int = 3, ckpt_dir: str | None = None,
              schedule: str = "halving", wire_dtype: str | None = None,
              lr: float = 1e-3, warmup: int = 2,
              io_faults: int = 0, io_retries: int = 3,
              io_backoff_s: float = 0.01, recovery_deadline_s: float = 600.0,
              slow_link: tuple[int, float, int] | None = None,
              compare_ref: bool = True, verbose: bool = False) -> dict:
    """One full drill: train at ``world``, resize at the event step,
    resume to ``steps``.  Exactly one of ``shrink_at_step`` /
    ``grow_at_step`` must be given (shrink kills ``fail_rank`` → p−1;
    grow resumes at ``grow_to``).  ``io_faults`` transient checkpoint-IO
    failures are injected at the drain for the controller's retry/backoff
    to absorb.  Returns the trajectories, the recovery report and the
    reference comparison (see module docstring).
    """
    if (shrink_at_step is None) == (grow_at_step is None):
        raise ValueError("give exactly one of shrink_at_step/grow_at_step")
    event_step = shrink_at_step if shrink_at_step is not None \
        else grow_at_step
    if not 0 < event_step < steps:
        raise ValueError(f"event step {event_step} outside (0, {steps})")
    if shrink_at_step is not None:
        new_world = world - 1
        if not 0 <= fail_rank < world:
            raise ValueError(f"fail_rank {fail_rank} outside world {world}")
    else:
        if grow_to is None or grow_to <= world:
            raise ValueError(f"grow needs grow_to > world, got {grow_to}")
        new_world = grow_to

    tmp = None
    if ckpt_dir is None:
        tmp = ckpt_dir = tempfile.mkdtemp(prefix="elastic_drill_")
    try:
        return _run_drill(
            arch=arch, scale_down=scale_down, steps=steps, seq_len=seq_len,
            global_batch=global_batch, world=world, mp=mp,
            event_step=event_step, shrink=shrink_at_step is not None,
            fail_rank=fail_rank, new_world=new_world, ckpt_every=ckpt_every,
            ckpt_dir=ckpt_dir, schedule=schedule, wire_dtype=wire_dtype,
            lr=lr, warmup=warmup, io_faults=io_faults, io_retries=io_retries,
            io_backoff_s=io_backoff_s,
            recovery_deadline_s=recovery_deadline_s, slow_link=slow_link,
            compare_ref=compare_ref, verbose=verbose)
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)


def _run_drill(*, arch, scale_down, steps, seq_len, global_batch, world, mp,
               event_step, shrink, fail_rank, new_world, ckpt_every,
               ckpt_dir, schedule, wire_dtype, lr, warmup, io_faults,
               io_retries, io_backoff_s, recovery_deadline_s, slow_link,
               compare_ref, verbose) -> dict:
    events = []
    if shrink:
        events.append(FaultEvent(step=event_step, kind="rank_loss",
                                 rank=fail_rank))
    if slow_link is not None:
        s, delay, dur = slow_link
        events.append(FaultEvent(step=s, kind="slow_link", delay_s=delay,
                                 duration=dur))
    fplan = FailurePlan(events=tuple(events))

    stragglers: list[int] = []
    wd = Watchdog(cfg=WatchdogConfig(),
                  on_straggler=lambda step, dt: stragglers.append(step))

    def session_at(w):
        return bootstrap.build_session(
            arch=arch, scale_down=scale_down, steps=steps, seq_len=seq_len,
            global_batch=global_batch, dp=w, mp=mp, mode="zero1",
            schedule=schedule, wire_dtype=wire_dtype, lr=lr, warmup=warmup,
            devices=jax.devices()[:w * mp])

    mgr = CheckpointManager(ckpt_dir)
    sess = session_at(world)
    ctl = ElasticController(world, ElasticConfig(
        min_world=1, max_world=jax.device_count() // mp,
        io_retries=io_retries, io_backoff_s=io_backoff_s,
        recovery_deadline_s=recovery_deadline_s, axis_name="data"))

    # -- run at the old world until the event fires --------------------------
    # Shrink: run to `steps` — the injected rank_loss interrupts at the
    # event boundary.  Grow: voluntary resize, stop cleanly there.
    pre: list[tuple[int, float]] = []
    detected_at = event_step
    try:
        _train_range(sess, 0, steps if shrink else event_step, mgr=mgr,
                     ckpt_every=ckpt_every, fplan=fplan, watchdog=wd,
                     arch=arch, out=pre)
        if shrink:
            raise AssertionError("shrink drill never hit its rank_loss")
    except RankFailure as e:
        detected_at = e.step
        if verbose:
            print(f"detected: {e}")

    # -- drain / re-plan / reshard / resume ----------------------------------
    # Transient IO faults target the RECOVERY's own checkpoint IO (the
    # drain save / reshard restore) — the surface the controller's
    # bounded retry/backoff owns.  Armed at step 0 so whichever
    # checkpoint step the recovery touches first trips them.
    io_plan = None
    if io_faults:
        io_plan = FailurePlan(events=(
            FaultEvent(step=0, kind="ckpt_io", duration=io_faults),))
        mgr.io_hook = io_plan.io_hook

    def drain(step):
        mgr.wait()  # surfaces a failed in-flight async save (retried)
        if not shrink:
            # Grow is voluntary: every rank is alive, so the boundary
            # checkpoints synchronously — zero steps lost.
            mgr.save(step, sess.params, bootstrap.opt_flat(sess),
                     _ckpt_extra(sess, step, arch))
        latest = mgr.latest_step()
        if latest is None:
            raise FileNotFoundError(f"no checkpoint to drain to in "
                                    f"{ckpt_dir}")
        return latest

    resumed = {}

    def reshard(w):
        # Session build is cached across IO retries (only the restore
        # is the flaky part worth re-running).
        if "sess" not in resumed:
            resumed["sess"] = session_at(w)
        step, man = bootstrap.restore_session(resumed["sess"], mgr)
        resumed["step"], resumed["manifest"] = step, man
        return resumed["sess"]

    report, new_sess = ctl.recover(
        detected_at, new_world, active_specs(sess.sync),
        drain=drain, reshard=reshard)
    mgr.io_hook = None  # recovery done; post-resume IO is clean
    resumed_step = resumed["step"]
    assert report.drained == resumed_step

    post = _train_range(new_sess, resumed_step, steps, mgr=mgr,
                        ckpt_every=ckpt_every, arch=arch)
    mgr.wait()

    out = {
        "arch": arch, "world": world, "new_world": new_world,
        "kind": "shrink" if shrink else "grow",
        "event_step": event_step, "detected_at": detected_at,
        "resumed_step": resumed_step,
        "lost_steps": detected_at - resumed_step,
        "pre": pre, "post": post, "report": report,
        "stragglers": stragglers,
        "fired": [ev.kind for ev in fplan.fired]
                 + ([ev.kind for ev in io_plan.fired] if io_plan else []),
    }

    # -- reference: uninterrupted run at p' from the same checkpoint ---------
    if compare_ref:
        ref_sess = session_at(new_world)
        ref_step, _ = bootstrap.restore_session(ref_sess, mgr,
                                                step=resumed_step)
        assert ref_step == resumed_step
        ref = _train_range(ref_sess, ref_step, steps)
        # post may have fewer rows than ref (a post-resume checkpoint
        # never truncates it; both cover [resumed_step, steps)).
        assert [s for s, _ in ref] == [s for s, _ in post]
        diffs = [abs(a - b) for (_, a), (_, b) in zip(post, ref)]
        out["ref"] = ref
        out["max_abs_diff"] = max(diffs) if diffs else 0.0
        out["bitwise"] = all(a == b for (_, a), (_, b) in zip(post, ref))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", choices=sorted(ALIASES), default="qwen3-1.7b")
    ap.add_argument("--scale-down", action="store_true")
    ap.add_argument("--steps", type=int, default=9)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--global-batch", type=int, default=12)
    ap.add_argument("--world", type=int, default=4,
                    help="starting data-parallel world size")
    ap.add_argument("--mp", type=int, default=1, help="model-axis size")
    ap.add_argument("--shrink-at-step", type=int, default=None,
                    help="kill --fail-rank at this step; resume at world-1")
    ap.add_argument("--fail-rank", type=int, default=0)
    ap.add_argument("--grow-at-step", type=int, default=None,
                    help="voluntarily resize to --grow-to at this step")
    ap.add_argument("--grow-to", type=int, default=None)
    ap.add_argument("--ckpt-every", type=int, default=3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--schedule", default="halving")
    ap.add_argument("--wire-dtype", default=None, choices=[None, "int8"])
    ap.add_argument("--io-faults", type=int, default=0,
                    help="transient checkpoint-IO failures injected at the "
                         "drain (absorbed by the controller's retry)")
    ap.add_argument("--no-ref", action="store_true",
                    help="skip the uninterrupted reference comparison")
    args = ap.parse_args(argv)

    res = run_drill(
        arch=args.arch, scale_down=args.scale_down, steps=args.steps,
        seq_len=args.seq_len, global_batch=args.global_batch,
        world=args.world, mp=args.mp, shrink_at_step=args.shrink_at_step,
        fail_rank=args.fail_rank, grow_at_step=args.grow_at_step,
        grow_to=args.grow_to, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, schedule=args.schedule,
        wire_dtype=args.wire_dtype, io_faults=args.io_faults,
        compare_ref=not args.no_ref, verbose=True)

    rep = res["report"]
    print(f"\n{res['kind']}: world {res['world']} -> {res['new_world']} "
          f"at step {res['event_step']} "
          f"(resumed from step {res['resumed_step']}, "
          f"{res['lost_steps']} step(s) lost)")
    print(f"re-planned {len(rep.replans)} spec(s) in {rep.replan_us:.0f}us "
          f"(all verified), evicted {rep.evicted} stale plan(s), "
          f"absorbed {rep.io_failures} IO fault(s)")
    for s, l in res["pre"] + res["post"]:
        print(f"step {s:4d}  loss {l:.6f}")
    if "ref" in res:
        tag = "bitwise" if res["bitwise"] else \
            f"max |dloss| {res['max_abs_diff']:.3g}"
        print(f"post-resize trajectory vs uninterrupted p' reference: {tag}")
    return res


if __name__ == "__main__":
    main()
