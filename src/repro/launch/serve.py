"""Serving driver: batched prefill + decode with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --scale-down --batch 4 --prompt-len 16 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALIASES, get_config
from repro.models import build
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), required=True)
    ap.add_argument("--scale-down", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "global", "rowwise", "ep"],
                    help="MoE dispatch layout (MoE archs only); 'ep' "
                         "serves with experts sharded over --ep-devices "
                         "ranks, exchanging dispatch buffers via the "
                         "circulant alltoall plan")
    ap.add_argument("--ep-devices", type=int, default=2,
                    help="mesh size for --moe-dispatch ep")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scale_down:
        cfg = cfg.scaled_down()
    mesh = None
    if args.moe_dispatch is not None:
        if not cfg.is_moe:
            raise SystemExit(
                f"--moe-dispatch given but {args.arch} is not a MoE arch")
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe_dispatch=args.moe_dispatch)
        if args.moe_dispatch == "ep":
            if args.ep_devices > jax.device_count():
                raise SystemExit(
                    f"--ep-devices {args.ep_devices} needs that many "
                    f"devices, have {jax.device_count()} (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count="
                    f"{args.ep_devices})")
            from repro.launch import mesh as meshlib
            mesh = meshlib.make_mesh((args.ep_devices,), (cfg.ep_axis,))
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        extras["image_embeds"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, cfg.n_image_tokens, cfg.d_model)
        ).astype(np.float32))

    engine = ServeEngine(model=model, params=params,
                         max_len=args.prompt_len + args.max_new,
                         temperature=args.temperature, mesh=mesh)
    t0 = time.time()
    out = engine.generate(prompts, args.max_new, extras=extras)
    dt = time.time() - t0
    tps = args.batch * args.max_new / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s incl. "
          f"compile)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {out[b][:12].tolist()}")
    # steady-state decode timing (compiled)
    t0 = time.time()
    out2 = engine.generate(prompts, args.max_new, extras=extras)
    dt2 = time.time() - t0
    print(f"steady-state: {args.batch * args.max_new / dt2:.1f} tok/s")
    return out


if __name__ == "__main__":
    main()
