"""Serving driver: one-shot batched generation, continuous batching, and
multi-replica weight fan-out — all on the shared launch bootstrap.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --scale-down --batch 4 --prompt-len 16 --max-new 16

``--max-batch`` switches to the continuous-batching scheduler (paged KV
cache sized by ``--kv-block-size``); ``--replicas N`` serves data-
parallel over N replicas whose weights were fanned out through the
``kind="broadcast"`` plan (needs N fake/real devices).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALIASES
from repro.launch.bootstrap import build_serve_session


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), required=True)
    ap.add_argument("--scale-down", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "global", "rowwise", "ep"],
                    help="MoE dispatch layout (MoE archs only); 'ep' "
                         "serves with experts sharded over --ep-devices "
                         "ranks, exchanging dispatch buffers via the "
                         "circulant alltoall plan")
    ap.add_argument("--ep-devices", type=int, default=2,
                    help="mesh size for --moe-dispatch ep")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel serving replicas; weights are "
                         "fanned out via the broadcast plan")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="> 0: continuous-batching scheduler with this "
                         "many decode slots (instead of one-shot "
                         "generate)")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="paged KV cache block size (--max-batch mode; "
                         "must divide prompt-len + max-new)")
    args = ap.parse_args(argv)

    try:
        sess = build_serve_session(
            arch=args.arch, max_len=args.prompt_len + args.max_new,
            scale_down=args.scale_down, temperature=args.temperature,
            moe_dispatch=args.moe_dispatch, ep_devices=args.ep_devices,
            replicas=args.replicas)
    except (ValueError, RuntimeError) as e:
        raise SystemExit(str(e))
    cfg = sess.cfg
    if args.replicas > 1:
        st = sess.push_stats
        print(f"broadcast weight fan-out: {st['n_leaves']} leaves, "
              f"{st['bytes']} bytes, {st['rounds']} rounds x "
              f"{args.replicas} replicas")

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        extras["image_embeds"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, cfg.n_image_tokens, cfg.d_model)
        ).astype(np.float32))

    if args.max_batch > 0:
        from repro.serve import Scheduler
        if extras:
            raise SystemExit("--max-batch covers decoder-only archs "
                             "(no prefill extras)")
        sched = Scheduler(sess.engine, max_batch=args.max_batch,
                          kv_block_size=args.kv_block_size)
        t0 = time.time()
        rids = [sched.submit(prompts[b], args.max_new)
                for b in range(args.batch)]
        done = sched.run()
        dt = time.time() - t0
        total = sum(len(done[r]) for r in rids)
        print(f"scheduler: {args.batch} requests, {total} tokens in "
              f"{dt:.2f}s ({total / dt:.1f} tok/s incl. compile; "
              f"{sched.n_decode_steps} decode steps, "
              f"{sched.n_prefills} prefills)")
        for b, r in enumerate(rids[:2]):
            print(f"  req{r}: {done[r][:12].tolist()}")
        return done

    if args.replicas > 1:
        if extras:
            raise SystemExit("--replicas covers decoder-only archs "
                             "(batched prefill extras don't split)")
        gen = sess.replica_set.generate
    else:
        gen = sess.engine.generate
    kw = {"extras": extras} if args.replicas == 1 else {}
    t0 = time.time()
    out = gen(prompts, args.max_new, **kw)
    dt = time.time() - t0
    tps = args.batch * args.max_new / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s incl. "
          f"compile)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {out[b][:12].tolist()}")
    # steady-state decode timing (compiled)
    t0 = time.time()
    gen(prompts, args.max_new, **kw)
    dt2 = time.time() - t0
    print(f"steady-state: {args.batch * args.max_new / dt2:.1f} tok/s")
    return out


if __name__ == "__main__":
    main()
