"""Serving driver: batched prefill + decode with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --scale-down --batch 4 --prompt-len 16 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ALIASES, get_config
from repro.models import build
from repro.serve import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), required=True)
    ap.add_argument("--scale-down", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scale_down:
        cfg = cfg.scaled_down()
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        extras["image_embeds"] = jax.numpy.asarray(rng.standard_normal(
            (args.batch, cfg.n_image_tokens, cfg.d_model)
        ).astype(np.float32))

    engine = ServeEngine(model=model, params=params,
                         max_len=args.prompt_len + args.max_new,
                         temperature=args.temperature)
    t0 = time.time()
    out = engine.generate(prompts, args.max_new, extras=extras)
    dt = time.time() - t0
    tps = args.batch * args.max_new / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s incl. "
          f"compile)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {out[b][:12].tolist()}")
    # steady-state decode timing (compiled)
    t0 = time.time()
    out2 = engine.generate(prompts, args.max_new, extras=extras)
    dt2 = time.time() - t0
    print(f"steady-state: {args.batch * args.max_new / dt2:.1f} tok/s")
    return out


if __name__ == "__main__":
    main()
