"""Training driver: config-driven launcher with checkpointing, watchdog and
restart-safe data cursors.

Runs anywhere: on this CPU container use a small mesh + reduced config
(examples/quickstart.py does exactly that); on a real pod, point it at the
production mesh.  All distribution knobs are CLI flags so the launcher is
the single entry point a cluster scheduler invokes on every host.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --scale-down --steps 50 --mesh 1x1 --mode single
"""
from __future__ import annotations

import argparse
import time

from repro.checkpoint import CheckpointManager, config_fingerprint
from repro.configs import ALIASES
from repro.ft import FailureInjector, Watchdog
from repro.launch import bootstrap


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), required=True)
    ap.add_argument("--scale-down", action="store_true",
                    help="reduced same-family config (CPU runs)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM (data x model), e.g. 4x2; 1x1 = single")
    ap.add_argument("--mode", default=None,
                    choices=[None, "single", "zero1", "fsdp_auto"])
    ap.add_argument("--grad-sync", default="circulant",
                    choices=["circulant", "ring", "xla", "allreduce"])
    ap.add_argument("--schedule", default="halving")
    ap.add_argument("--wire-dtype", default=None, choices=[None, "int8"],
                    help="compressed int8 wire format for the circulant "
                         "gradient sync (quantize-on-send, fused "
                         "dequant-reduce rounds, error feedback)")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the EF-SGD residual for compressed sync")
    ap.add_argument("--compress", default=None, choices=[None, "int8"],
                    help="DEPRECATED alias for --wire-dtype (emits a "
                         "DeprecationWarning; the wire format is part of "
                         "the grad-sync CollectiveSpec now)")
    ap.add_argument("--bucket-bytes", type=int, default=None,
                    help="bucketed, overlapped grad sync: target bytes per "
                         "gradient bucket (e.g. 25000000); each bucket runs "
                         "one circulant RS/AG on the cached plan with rounds "
                         "software-pipelined across buckets. Default: off "
                         "(single-shot per leaf, bitwise-identical legacy "
                         "path). Requires --grad-sync circulant")
    ap.add_argument("--fused-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused Pallas round kernel for the circulant "
                         "collectives (auto = Pallas on TPU, jnp on CPU)")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "global", "rowwise", "ep"],
                    help="MoE dispatch layout (MoE archs only); 'ep' "
                         "shards experts over the model axis and "
                         "exchanges the dispatch buffer via the circulant "
                         "alltoall plan + routed counts via alltoallv")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="failure injection (restart drill)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    d, m = (int(x) for x in args.mesh.split("x"))
    try:
        sess = bootstrap.build_session(
            arch=args.arch, scale_down=args.scale_down, steps=args.steps,
            seq_len=args.seq_len, global_batch=args.global_batch,
            dp=d, mp=m, mode=args.mode, grad_sync=args.grad_sync,
            schedule=args.schedule, wire_dtype=args.wire_dtype,
            error_feedback=not args.no_error_feedback,
            use_fused_kernel={"auto": None, "on": True,
                              "off": False}[args.fused_kernel],
            bucket_bytes=args.bucket_bytes,
            moe_dispatch=args.moe_dispatch,
            lr=args.lr, warmup=args.warmup,
            compress=args.compress)  # deprecated alias; warns
    except (RuntimeError, ValueError) as e:
        raise SystemExit(str(e)) from e

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if mgr.latest_step() is not None:
            start, man = bootstrap.restore_session(sess, mgr)
            print(f"resumed from step {start} "
                  f"(manifest cursor {man.get('data_cursor')})")

    injector = FailureInjector(fail_at_step=args.fail_at_step)
    wd = Watchdog()
    losses = []
    with sess.use_mesh():
        for step in range(start, args.steps):
            injector.check(step)
            t0 = time.time()
            metrics = bootstrap.run_step(sess, step)
            dt = time.time() - t0
            status = wd.observe(step, dt)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f}ms "
                      f"[{status}]")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save_async(
                    step + 1, sess.params, bootstrap.opt_flat(sess),
                    {"data_cursor": step + 1,
                     "config": config_fingerprint(sess.cfg),
                     "mesh": args.mesh, "arch": args.arch,
                     "world": sess.world})
    if mgr:
        mgr.wait()
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
