"""Training driver: config-driven launcher with checkpointing, watchdog and
restart-safe data cursors.

Runs anywhere: on this CPU container use a small mesh + reduced config
(examples/quickstart.py does exactly that); on a real pod, point it at the
production mesh.  All distribution knobs are CLI flags so the launcher is
the single entry point a cluster scheduler invokes on every host.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --scale-down --steps 50 --mesh 1x1 --mode single
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro import compat
from repro.checkpoint import CheckpointManager, config_fingerprint
from repro.configs import ALIASES, get_config
from repro.data import for_model
from repro.ft import FailureInjector, Watchdog
from repro.launch import mesh as meshlib
from repro.models import ShardingRecipe, build
from repro.optim.adamw import AdamWConfig
from repro.optim.zero1 import GradSyncConfig
from repro.train import build as build_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), required=True)
    ap.add_argument("--scale-down", action="store_true",
                    help="reduced same-family config (CPU runs)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM (data x model), e.g. 4x2; 1x1 = single")
    ap.add_argument("--mode", default=None,
                    choices=[None, "single", "zero1", "fsdp_auto"])
    ap.add_argument("--grad-sync", default="circulant",
                    choices=["circulant", "ring", "xla", "allreduce"])
    ap.add_argument("--schedule", default="halving")
    ap.add_argument("--wire-dtype", default=None, choices=[None, "int8"],
                    help="compressed int8 wire format for the circulant "
                         "gradient sync (quantize-on-send, fused "
                         "dequant-reduce rounds, error feedback)")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the EF-SGD residual for compressed sync")
    ap.add_argument("--compress", default=None, choices=[None, "int8"],
                    help="DEPRECATED alias for --wire-dtype (emits a "
                         "DeprecationWarning; the wire format is part of "
                         "the grad-sync CollectiveSpec now)")
    ap.add_argument("--bucket-bytes", type=int, default=None,
                    help="bucketed, overlapped grad sync: target bytes per "
                         "gradient bucket (e.g. 25000000); each bucket runs "
                         "one circulant RS/AG on the cached plan with rounds "
                         "software-pipelined across buckets. Default: off "
                         "(single-shot per leaf, bitwise-identical legacy "
                         "path). Requires --grad-sync circulant")
    ap.add_argument("--fused-kernel", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused Pallas round kernel for the circulant "
                         "collectives (auto = Pallas on TPU, jnp on CPU)")
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "global", "rowwise", "ep"],
                    help="MoE dispatch layout (MoE archs only); 'ep' "
                         "shards experts over the model axis and "
                         "exchanges the dispatch buffer via the circulant "
                         "alltoall plan + routed counts via alltoallv")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="failure injection (restart drill)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scale_down:
        cfg = cfg.scaled_down()
    if args.moe_dispatch is not None:
        if not cfg.is_moe:
            raise SystemExit(
                f"--moe-dispatch given but {args.arch} is not a MoE arch")
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe_dispatch=args.moe_dispatch)
    d, m = (int(x) for x in args.mesh.split("x"))
    mode = args.mode or ("single" if d * m == 1 else "zero1")
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                          total_steps=args.steps)
    pipe = for_model(cfg, seq_len=args.seq_len,
                     global_batch=args.global_batch)

    mesh = None
    recipe = None
    if mode != "single":
        if d * m > jax.device_count():
            raise SystemExit(
                f"mesh {args.mesh} needs {d*m} devices, have "
                f"{jax.device_count()} (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={d*m})")
        mesh = meshlib.make_mesh((d, m), ("data", "model"))
        recipe = ShardingRecipe(data_axes=("data",), model_axis="model")
    model = build(cfg, recipe=recipe)
    sync = GradSyncConfig(impl=args.grad_sync, schedule=args.schedule,
                          wire_dtype=args.wire_dtype,
                          compress=args.compress,  # deprecated alias; warns
                          error_feedback=not args.no_error_feedback,
                          use_fused_kernel={"auto": None, "on": True,
                                            "off": False}[args.fused_kernel],
                          bucket_bytes=args.bucket_bytes)
    built = build_step(mode, model, opt_cfg, mesh=mesh, recipe=recipe,
                       sync=sync)

    params = model.init(jax.random.PRNGKey(0))
    opt = built.init_opt(params)
    if mode == "zero1":
        opt = jax.device_put(opt, built.opt_spec(params))
    start = 0
    opt_leaves, opt_treedef = jax.tree.flatten(opt)

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if mgr.latest_step() is not None:
            start, params, opt_arrs, man = mgr.restore(None, params)
            opt = jax.tree.unflatten(
                opt_treedef, [jnp.asarray(opt_arrs[f"leaf_{i}"])
                              for i in range(len(opt_leaves))])
            print(f"resumed from step {start} "
                  f"(manifest cursor {man.get('data_cursor')})")

    injector = FailureInjector(fail_at_step=args.fail_at_step)
    wd = Watchdog()
    ctx = compat.use_mesh(mesh) if mesh is not None else _null_ctx()
    losses = []
    with ctx:
        for step in range(start, args.steps):
            injector.check(step)
            t0 = time.time()
            batch = pipe.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if mesh is not None:
                batch = {k: jax.device_put(
                    v, NamedSharding(mesh, built.batch_spec))
                    for k, v in batch.items()}
            params, opt, metrics = built.step_fn(params, opt, batch)
            dt = time.time() - t0
            status = wd.observe(step, dt)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f}ms "
                      f"[{status}]")
            if mgr and (step + 1) % args.ckpt_every == 0:
                leaves = jax.tree.leaves(opt)
                mgr.save_async(
                    step + 1, params,
                    {f"leaf_{i}": np.asarray(l)
                     for i, l in enumerate(leaves)},
                    {"data_cursor": step + 1,
                     "config": config_fingerprint(cfg),
                     "mesh": args.mesh, "arch": args.arch})
    if mgr:
        mgr.wait()
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
