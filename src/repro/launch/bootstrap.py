"""Shared session bootstrap — ONE place a runnable session is built.

``launch.train`` (the classic CLI driver), ``launch.elastic`` (the
rank-failure drill harness) and the tests all need the same sequence:
resolve the arch config, build the mesh/recipe, compile the step
function, init params + optimizer state, wire the data pipeline.  Before
the elastic runtime existed that lived inline in ``launch.train.main``;
the elastic controller has to rebuild a session MID-RUN at a different
world size (over a device SUBSET — the survivors of a shrink, the
enlarged set of a grow), so the bootstrap is factored out here and both
entry points ride it.

``launch.serve`` rides the same config/device/mesh resolution through
:func:`build_serve_session`, which assembles the inference stack
instead: a :class:`repro.serve.ReplicaSet` of engines (optionally on an
expert-parallel mesh for MoE decode) with the initial weights fanned out
over the ``kind="broadcast"`` plan.

The restore path is world-aware: :func:`restore_session` reads any
checkpoint and, when it was written at a different data-parallel world,
remaps the optimizer state through
:func:`repro.optim.zero1.resize_zero1_state` (m/v slice + re-pad, EF
mass conservation) before placing it on the session's mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.data import for_model
from repro.launch import mesh as meshlib
from repro.models import ShardingRecipe, build
from repro.optim.adamw import AdamWConfig
from repro.optim.zero1 import GradSyncConfig, resize_zero1_state
from repro.train import build as build_step


@dataclass
class Session:
    """Everything a training loop needs, bundled.

    ``params``/``opt`` are the LIVE state — :func:`run_step` advances
    them in place.  ``world`` is the data-parallel world this session
    was built for (the dp mesh extent; 1 in single mode).
    """

    cfg: Any
    mode: str
    mesh: Any
    recipe: Any
    model: Any
    opt_cfg: AdamWConfig
    sync: GradSyncConfig
    built: Any
    pipe: Any
    world: int
    params: Any = None
    opt: Any = None

    def use_mesh(self):
        from repro import compat
        return compat.use_mesh(self.mesh) if self.mesh is not None \
            else _null_ctx()


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def resolve_cfg(arch: str, *, scale_down: bool = False,
                moe_dispatch: str | None = None):
    """Arch-name → config, with the scale-down and MoE-dispatch knobs
    every entry point exposes resolved identically."""
    cfg = get_config(arch)
    if scale_down:
        cfg = cfg.scaled_down()
    if moe_dispatch is not None:
        if not cfg.is_moe:
            raise ValueError(
                f"moe_dispatch given but {arch} is not a MoE arch")
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe_dispatch=moe_dispatch)
    return cfg


def require_devices(n: int, what: str):
    """First ``n`` runtime devices, with the XLA_FLAGS hint every
    launcher prints when the host platform is under-provisioned."""
    if n > jax.device_count():
        raise RuntimeError(
            f"{what} needs {n} devices, have {jax.device_count()} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={n})")
    return jax.devices()[:n]


def build_session(*, arch: str, scale_down: bool = False, steps: int = 100,
                  seq_len: int = 128, global_batch: int = 8,
                  dp: int = 1, mp: int = 1, mode: str | None = None,
                  grad_sync: str = "circulant", schedule: str = "halving",
                  wire_dtype: str | None = None, error_feedback: bool = True,
                  use_fused_kernel: bool | None = None,
                  bucket_bytes: int | None = None,
                  moe_dispatch: str | None = None,
                  lr: float = 3e-4, warmup: int = 20,
                  compress: str | None = None,
                  devices=None, seed: int = 0,
                  init_state: bool = True) -> Session:
    """Build a runnable :class:`Session` for a ``dp × mp`` mesh.

    ``devices`` may be an explicit device subset (default: the first
    ``dp*mp`` of the runtime's) — the elastic harness passes the
    surviving set when rebuilding at p′ < device_count.  With
    ``init_state=False`` params/opt stay ``None`` (for callers about to
    restore them from a checkpoint anyway).
    """
    cfg = resolve_cfg(arch, scale_down=scale_down,
                      moe_dispatch=moe_dispatch)
    mode = mode or ("single" if dp * mp == 1 else "zero1")
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=warmup, total_steps=steps)
    pipe = for_model(cfg, seq_len=seq_len, global_batch=global_batch)

    mesh = recipe = None
    if mode != "single":
        if devices is None:
            devices = require_devices(dp * mp, f"mesh {dp}x{mp}")
        elif len(devices) != dp * mp:
            raise ValueError(
                f"mesh {dp}x{mp} needs {dp * mp} devices, got "
                f"{len(devices)}")
        mesh = meshlib.make_mesh((dp, mp), ("data", "model"),
                                 devices=devices)
        recipe = ShardingRecipe(data_axes=("data",), model_axis="model")
    model = build(cfg, recipe=recipe)
    sync = GradSyncConfig(impl=grad_sync, schedule=schedule,
                          wire_dtype=wire_dtype,
                          compress=compress,  # deprecated alias; warns
                          error_feedback=error_feedback,
                          use_fused_kernel=use_fused_kernel,
                          bucket_bytes=bucket_bytes)
    built = build_step(mode, model, opt_cfg, mesh=mesh, recipe=recipe,
                       sync=sync)
    sess = Session(cfg=cfg, mode=mode, mesh=mesh, recipe=recipe, model=model,
                   opt_cfg=opt_cfg, sync=sync, built=built, pipe=pipe,
                   world=dp if mode != "single" else 1)
    if init_state:
        sess.params = model.init(jax.random.PRNGKey(seed))
        sess.opt = built.init_opt(sess.params)
        if mode == "zero1":
            sess.opt = jax.device_put(sess.opt,
                                      built.opt_spec(sess.params))
    return sess


@dataclass
class ServeSession:
    """The serving counterpart of :class:`Session`: config + engines.

    ``replica_set`` holds ``replicas`` data-parallel engines whose
    weights were fanned out via the broadcast plan (``push_stats``
    records leaf count / payload bytes / rounds); ``ep_mesh`` is the
    expert-parallel mesh MoE decode runs on (None otherwise).
    """

    cfg: Any
    model: Any
    params: Any
    replica_set: Any
    ep_mesh: Any
    push_stats: dict

    @property
    def engine(self):
        """Engine 0 — the one-replica view (scheduler benches use it)."""
        return self.replica_set.engines[0]


def build_serve_session(*, arch: str, max_len: int,
                        scale_down: bool = False,
                        temperature: float = 0.0,
                        moe_dispatch: str | None = None,
                        ep_devices: int = 2, replicas: int = 1,
                        broadcast_schedule: str = "power2",
                        seed: int = 0) -> ServeSession:
    """Build the serving stack with the SAME config/device resolution as
    :func:`build_session` — arch aliasing, scale-down, MoE dispatch
    override, device-count validation with the XLA_FLAGS hint.

    Weights are initialized once and pushed to every replica through the
    ``kind="broadcast"`` plan (bitwise-verified fan-out); with
    ``moe_dispatch="ep"`` each engine decodes inside a shard_map over the
    expert-parallel mesh, exchanging dispatch buffers via the circulant
    alltoall plan.
    """
    from repro.serve import ReplicaSet
    cfg = resolve_cfg(arch, scale_down=scale_down,
                      moe_dispatch=moe_dispatch)
    ep_mesh = None
    if moe_dispatch == "ep":
        devs = require_devices(ep_devices, f"--moe-dispatch ep x{ep_devices}")
        ep_mesh = meshlib.make_mesh((ep_devices,), (cfg.ep_axis,),
                                    devices=devs)
    if replicas > 1:
        require_devices(replicas, f"{replicas} serving replicas")
    model = build(cfg, recipe=None, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    rs = ReplicaSet(model, max_len, replicas, temperature=temperature,
                    schedule=broadcast_schedule, engine_mesh=ep_mesh)
    stats = rs.push_weights(params)
    return ServeSession(cfg=cfg, model=model, params=params,
                        replica_set=rs, ep_mesh=ep_mesh, push_stats=stats)


def place_batch(sess: Session, batch: dict) -> dict:
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    if sess.mesh is not None:
        batch = {k: jax.device_put(
            v, NamedSharding(sess.mesh, sess.built.batch_spec))
            for k, v in batch.items()}
    return batch


def run_step(sess: Session, step: int) -> dict:
    """One optimizer step at ``step``'s data-cursor batch; advances
    ``sess.params``/``sess.opt`` in place and returns the metrics."""
    batch = place_batch(sess, sess.pipe.batch_at(step))
    sess.params, sess.opt, metrics = sess.built.step_fn(
        sess.params, sess.opt, batch)
    return metrics


def opt_flat(sess: Session) -> dict:
    """Checkpoint form of the optimizer state: gathered host arrays,
    keyed ``leaf_<i>`` in tree-flatten order (the layout
    :func:`restore_session` and ``launch.train`` both use)."""
    return {f"leaf_{i}": np.asarray(l)
            for i, l in enumerate(jax.tree.leaves(sess.opt))}


def restore_session(sess: Session, mgr, step: int | None = None
                    ) -> tuple[int, dict]:
    """Restore ``mgr``'s checkpoint into ``sess``, resizing across
    world-size changes; returns ``(resumed_step, manifest)``.

    The checkpoint's optimizer leaves are GLOBAL (gathered) arrays, so a
    world mismatch is handled entirely on host: unflatten into the
    saved-world :class:`Zero1State` (its treedef does not depend on
    world — only the EF presence, which ``sess.sync`` determines), run
    ``resize_zero1_state`` to ``sess.world``, then place on the mesh.
    """
    s, params, opt_arrs, man = mgr.restore(step, sess.params)
    sess.params = params
    n = sum(1 for k in opt_arrs if k.startswith("leaf_"))
    treedef = jax.tree.structure(sess.opt)
    if n != treedef.num_leaves:
        raise ValueError(
            f"checkpoint has {n} optimizer leaves, session expects "
            f"{treedef.num_leaves} — sync/arch mismatch?")
    leaves = [np.asarray(opt_arrs[f"leaf_{i}"]) for i in range(n)]
    state = jax.tree.unflatten(treedef, leaves)
    if sess.mode == "zero1":
        saved_world = int(man.get("world", sess.world))
        if saved_world != sess.world:
            state = resize_zero1_state(state, sess.params, sess.world,
                                       sess.sync)
        state = jax.device_put(
            jax.tree.map(jnp.asarray, state),
            sess.built.opt_spec(sess.params))
    else:
        state = jax.tree.map(jnp.asarray, state)
    sess.opt = state
    return s, man
