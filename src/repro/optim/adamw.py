"""AdamW on flat (raveled) vectors + LR schedules.

The ZeRO-1 path (optim/zero1.py) runs these kernels on 1/(pod*data)
shards of the fused gradient vector; the replicated baseline runs them on
the full vector.  fp32 moments regardless of param dtype.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    """AdamW + LR-schedule hyperparameters (cosine decay to
    ``min_lr_ratio`` after ``warmup_steps`` of linear warmup; global-norm
    clip at ``clip_norm``)."""
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


class AdamState(NamedTuple):
    """Flat-vector optimizer state: fp32 first/second moments + step."""
    m: jax.Array   # fp32
    v: jax.Array   # fp32
    step: jax.Array  # int32 scalar


def init_state(n: int) -> AdamState:
    """Zero-initialized :class:`AdamState` for an ``n``-element flat
    (shard of a) parameter vector."""
    return AdamState(m=jnp.zeros((n,), jnp.float32),
                     v=jnp.zeros((n,), jnp.float32),
                     step=jnp.zeros((), jnp.int32))


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    """Learning rate at ``step``: linear warmup then cosine decay to
    ``cfg.min_lr_ratio * cfg.lr``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def update_shard(cfg: AdamWConfig, state: AdamState, g, p, clip_scale=1.0):
    """One AdamW step on a (shard of a) flat fp32 gradient.  Returns
    (delta, new_state): delta is the parameter INCREMENT (new_p = p + delta)
    so the caller can allgather deltas or params as it prefers."""
    g = g.astype(jnp.float32) * clip_scale
    p32 = p.astype(jnp.float32)
    step = state.step + 1
    m = cfg.beta1 * state.m + (1 - cfg.beta1) * g
    v = cfg.beta2 * state.v + (1 - cfg.beta2) * g * g
    t = step.astype(jnp.float32)
    mhat = m / (1 - cfg.beta1 ** t)
    vhat = v / (1 - cfg.beta2 ** t)
    lr = lr_at(cfg, step)
    delta = -lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                   + cfg.weight_decay * p32)
    return delta, AdamState(m=m, v=v, step=step)


def global_norm(tree) -> jax.Array:
    """L2 norm over every leaf of ``tree`` (fp32 accumulation)."""
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_scale_from_norm(cfg: AdamWConfig, gnorm) -> jax.Array:
    """Gradient scale factor implementing global-norm clipping."""
    return jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))


# ---------------------------------------------------------------------------
# Pytree variant (FSDP-auto mode: m/v shard exactly like params under GSPMD)
# ---------------------------------------------------------------------------

class TreeAdamState(NamedTuple):
    """Pytree optimizer state: m/v mirror the param tree (shard exactly
    like params under GSPMD in fsdp_auto mode)."""
    m: Any
    v: Any
    step: jax.Array


def init_tree_state(params) -> TreeAdamState:
    """Zero-initialized :class:`TreeAdamState` mirroring ``params``."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TreeAdamState(m=zeros,
                         v=jax.tree.map(jnp.copy, zeros),
                         step=jnp.zeros((), jnp.int32))


def update_tree(cfg: AdamWConfig, state: TreeAdamState, grads, params):
    """One AdamW step on whole pytrees (replicated/GSPMD path).
    Returns ``(new_params, new_state, grad_norm)``."""
    gnorm = global_norm(grads)
    scale = clip_scale_from_norm(cfg, gnorm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    lr = lr_at(cfg, step)
    bc1 = 1 - cfg.beta1 ** t
    bc2 = 1 - cfg.beta2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.beta1 * m + (1 - cfg.beta1) * g
        v2 = cfg.beta2 * v + (1 - cfg.beta2) * g * g
        delta = -lr * ((m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
                       + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) + delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, TreeAdamState(m=new_m, v=new_v, step=step), gnorm
