"""ZeRO-1 distributed optimizer driven by the paper's collectives.

This is the framework's primary integration of Träff's algorithms: every
(large) gradient leaf is REDUCE-SCATTERED (Algorithm 1) across the data
axes along its leading dimension, AdamW updates only the local 1/(pod*data)
shard (optimizer state is never replicated — the ZeRO-1 memory win), and
updated parameter shards are ALLGATHERED back with the reversed schedule
(Algorithm 2's second phase).  Per step and per rank this moves exactly
2(p-1)/p of the gradient volume in 2*ceil(log2 p) collective-permute
rounds per leaf — Theorem 2's optimum.

PER-LEAF, not flat-raveled: leaves keep their tensor-parallel (model-axis)
sharding on inner dimensions — a ravel would force an all-gather over the
model axis and materialize full fp32 gradients per rank (168 GB for a 42B
model).  The leading dim (the layer-stack axis for scanned blocks, vocab
for embeddings) is zero-padded to a multiple of the DP world and sliced
back after the allgather.  Leaves too small to shard profitably (norms,
biases, scalars) are synchronized with a plain psum and updated
replicated — they are <0.1% of parameters.

Grad-sync implementations are pluggable (--grad-sync):
  circulant[:schedule]  paper Algorithm 1/2 (halving default; power2 /
                        fully_connected / sqrt per Corollary 2)
  ring                  p-1-round bandwidth baseline
  xla                   lax.psum_scatter + lax.all_gather
  allreduce             plain replicated allreduce + full optimizer
                        (no ZeRO; memory baseline)
The config compiles to CollectiveSpecs (``GradSyncConfig.rs_spec()`` /
``.ag_spec()``); each data axis executes one cached CollectivePlan, so
the grad sync rides the same plan/execute seam as every other consumer.
Optional compressed gradient sync via wire_dtype='int8' (the circulant
collectives' packed int8 wire format: per-round quantize-on-send + fused
dequant-⊕ rounds) with an EF-SGD error-feedback residual carried in the
optimizer state so convergence is preserved; ``use_fused_kernel`` routes
the circulant rounds' local fold + send assembly through the fused Pallas
round kernel (kernels.fused_round).

Shard layout per leaf: axis-major blocks over ``axis_names`` order —
rank (r0, r1) holds rows [lin * ld_pad/P, (lin+1) * ld_pad/P) with
lin = r0 * p1 + r1; the matching hierarchical AG reassembles exactly.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.core import collectives as C
from repro.core.spec import CollectiveSpec
from repro.kernels import dequantize_blocks, quantize_blocks
from . import adamw


@dataclass(frozen=True)
class GradSyncConfig:
    """How zero1 synchronizes gradients and re-gathers parameter shards.

    The config is declarative: it compiles to :class:`CollectiveSpec`
    objects (:meth:`rs_spec` / :meth:`ag_spec`) and every knob maps onto
    a spec field or a zero1-side policy.  Fields:

    ``impl``
        Sync algorithm: ``'circulant'`` (paper Algorithm 1/2; the only
        impl that supports wire compression and bucketing), ``'ring'``
        (p-1-round bandwidth baseline), ``'xla'`` (psum_scatter /
        all_gather), or ``'allreduce'`` (replicated allreduce + full
        optimizer — the no-ZeRO memory baseline).
    ``schedule``
        Corollary-2 skip schedule for the circulant impl: ``'halving'``
        (default), ``'power2'``, ``'fully_connected'``, ``'sqrt'``.
    ``wire_dtype``
        ``None`` (exact) or ``'int8'``: compress every circulant round's
        send payload onto the packed int8 wire (codes + f32 group scales
        in one buffer; ~4x fewer β bytes, lossy).
    ``compress``
        DEPRECATED alias for ``wire_dtype`` (kept for the kwarg era;
        emits a DeprecationWarning).
    ``error_feedback``
        EF-SGD residual for compressed sync: each rank keeps its local
        quantization error in ``Zero1State.ef`` and adds it back into
        the next step's gradient before quantizing.  Only meaningful
        when the sync is actually lossy (see :attr:`uses_error_feedback`).
    ``quant_group``
        Elements per int8 quantization scale group on the wire.
    ``min_shard_numel``
        Leaves smaller than this stay replicated and are synced with a
        plain psum (norms, biases, scalars — <0.1% of parameters).
    ``rs_dtype``
        Reduce-scatter payload dtype; ``'bfloat16'`` halves the RS link
        volume (§Perf A).  Allgather always runs exact in param dtype.
    ``use_fused_kernel``
        Route the circulant rounds' fold + send assembly through the
        fused Pallas kernel (``kernels/fused_round.py``); ``None`` =
        auto (TPU only).
    ``bucket_bytes``
        ``None`` (default) syncs each leaf in one shot — the legacy
        path, bitwise-identical to pre-bucketing builds.  An int enables
        BUCKETED, OVERLAPPED sync: the flat gradient vector is
        partitioned into ~``bucket_bytes``-sized buckets (see
        :func:`plan_grad_buckets`), each bucket runs one circulant RS
        (and one AG for the updated shards) on the cached plan, and the
        rounds are software-pipelined across buckets
        (``CollectivePlan.reduce_scatter_pipelined``) so bucket b's
        ppermute overlaps bucket b+1's fold.  Requires
        ``impl='circulant'``.
    """

    impl: str = "circulant"       # circulant | ring | xla | allreduce
    schedule: str = "halving"     # Corollary-2 schedule for circulant
    wire_dtype: str | None = None  # None | 'int8': compressed circulant
    #                               rounds (int8 codes + f32 group scales
    #                               packed on the wire; ~4x fewer β bytes)
    compress: str | None = None   # DEPRECATED alias for wire_dtype
    error_feedback: bool = True   # EF-SGD residual for compressed sync:
    #                               each rank keeps its local quantization
    #                               error and adds it back into the next
    #                               step's gradient before quantizing
    quant_group: int = 512
    min_shard_numel: int = 1024   # leaves smaller than this stay replicated
    rs_dtype: str = "float32"     # reduce-scatter payload dtype; 'bfloat16'
    #                               halves the RS link volume (§Perf A)
    use_fused_kernel: bool | None = None  # fused Pallas round kernel for the
    #                               circulant RS/AG; None = auto (TPU only)
    bucket_bytes: int | None = None  # None = single-shot per leaf (legacy,
    #                               bitwise-identical); int = bucketed,
    #                               software-pipelined sync (circulant only)

    def __post_init__(self):
        if self.compress is not None:
            warnings.warn(
                "GradSyncConfig(compress=...) is deprecated; pass "
                "wire_dtype=... — it feeds the CollectiveSpec the grad "
                "sync plans are built from (see GradSyncConfig.rs_spec)",
                DeprecationWarning, stacklevel=3)
        if self.bucket_bytes is not None:
            if self.bucket_bytes <= 0:
                raise ValueError(
                    f"bucket_bytes must be positive, got {self.bucket_bytes}")
            if self.impl != "circulant":
                raise ValueError(
                    "bucket_bytes requires impl='circulant' — the bucketed "
                    "path pipelines circulant plans "
                    f"(got impl={self.impl!r})")

    @property
    def wire(self) -> str | None:
        """Effective wire dtype (``wire_dtype`` wins over the legacy
        ``compress`` spelling)."""
        return self.wire_dtype or self.compress

    def rs_spec(self) -> CollectiveSpec:
        """The reduce-scatter :class:`CollectiveSpec` this config means.

        ``impl='allreduce'`` (the no-ZeRO baseline) shards nothing, but
        its tiny-leaf fallback still wants an xla spec.
        """
        kind = self.impl if self.impl != "allreduce" else "xla"
        if kind != "circulant":
            return CollectiveSpec(kind=kind)
        return CollectiveSpec(
            kind="circulant", schedule=self.schedule,
            use_fused_kernel=self.use_fused_kernel,
            wire_dtype=self.wire if self.wire == "int8" else None,
            wire_group=self.quant_group)

    def ag_spec(self) -> CollectiveSpec:
        """Allgather spec: parameter shards must reassemble EXACTLY, so
        the wire format never applies; ring has no allgather and falls
        back to the circulant schedule (same reversed-skip structure)."""
        kind = "circulant" if self.impl in ("circulant", "ring") else "xla"
        if kind != "circulant":
            return CollectiveSpec(kind=kind)
        return CollectiveSpec(
            kind="circulant", schedule=self.schedule,
            use_fused_kernel=self.use_fused_kernel)

    @property
    def uses_error_feedback(self) -> bool:
        """EF is meaningful only when the sync is actually lossy: the
        circulant impl is the one that honors ``wire_dtype`` (ring/xla
        transmit exactly; allreduce has no sharded RS to compensate)."""
        return (self.error_feedback and self.wire == "int8"
                and self.impl == "circulant")


class Zero1State(NamedTuple):
    """ZeRO-1 optimizer state: per-leaf AdamW moments holding only this
    rank's 1/world shard for sharded (zero) leaves, plus the optional
    EF-SGD residual tree for the compressed wire."""
    m: object        # pytree: sharded fp32 (zero leaves) / full (tiny)
    v: object
    step: jax.Array
    ef: object = None  # error-feedback residuals: per-rank quantization
    #                    error, (world, *leaf) sharded over the data axes
    #                    (zero leaves) / (1, *leaf) replicated (tiny
    #                    leaves, unused); None when EF is off


def data_parallel_world_static(mesh_shape: dict, axis_names) -> int:
    """Product of the data-parallel axis sizes, from static mesh shape
    (usable outside a mesh context, e.g. for state-spec construction)."""
    p = 1
    for a in axis_names:
        p *= mesh_shape[a]
    return p


def is_zero_leaf(shape, world: int, min_numel: int) -> bool:
    """Shard a leaf iff it is big enough and leading-dim padding waste is
    bounded (< 2x)."""
    numel = int(np.prod(shape)) if shape else 0
    if numel < max(min_numel, world):
        return False
    ld = shape[0]
    pad_ld = ld + (-ld) % world
    return pad_ld <= 2 * ld or numel // max(ld, 1) * pad_ld >= min_numel


def leaf_flags(params, world: int, min_numel: int = 1024):
    """Per-leaf :func:`is_zero_leaf` pytree — True where the optimizer
    state is sharded 1/world."""
    return jax.tree.map(
        lambda l: is_zero_leaf(l.shape, world, min_numel), params)


def _pad_lead(x, world: int):
    ld = x.shape[0]
    pad = (-ld) % world
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
    return x


def shard_offset(ld_pad: int, axis_names: Sequence[str]):
    """(row offset, rows per shard) of this rank's slice (axis-major)."""
    p_total = 1
    lin = jnp.zeros((), jnp.int32)
    for a in axis_names:
        lin = lin * compat.axis_size(a) + lax.axis_index(a)
        p_total *= compat.axis_size(a)
    rows = ld_pad // p_total
    return lin * rows, rows


def reduce_scatter_leaf(g, axis_names, sync: GradSyncConfig, world: int):
    """Hierarchical RS along dim 0; returns the averaged local shard.
    One cached :class:`CollectivePlan` per axis (sync.rs_spec())."""
    spec = sync.rs_spec()
    out = _pad_lead(g, world)
    for ax in axis_names:
        out = C.reduce_scatter(out, ax, spec=spec)
    return out / world


def allgather_leaf(shard, ld: int, axis_names, sync: GradSyncConfig):
    """Inverse: hierarchical AG along dim 0, then drop padding rows."""
    spec = sync.ag_spec()
    out = shard
    for ax in reversed(list(axis_names)):
        out = C.allgather(out, ax, spec=spec)
    return out[:ld]


def allreduce_leaf(g, axis_names, sync: GradSyncConfig, world: int):
    """Tiny-leaf path: replicated mean.  Scalars/1-elem rows cannot block-
    partition, so this uses psum (XLA all-reduce) — negligible volume."""
    out = g
    for ax in axis_names:
        out = lax.psum(out, ax)
    return out / world


def ef_quantize(g, residual, group: int):
    """EF-SGD compensation step (per rank, per leaf): add the carried
    residual into the raw gradient, round the sum onto the int8 grid the
    wire will use, and keep the new rounding error as the next step's
    residual.  The quantized gradient is what enters the compressed
    reduce-scatter, so round 0 of the wire re-derives (near-)identical
    codes and the dominant compression error is fed back instead of
    lost.  Per-round requantization error of partial sums is NOT
    recoverable per rank (it mixes contributions) and stays uncompensated
    — standard EF-SGD scope."""
    comp = g.astype(jnp.float32) + residual
    q = dequantize_blocks(quantize_blocks(comp, group=group, backend="jnp"),
                          backend="jnp")
    return q, comp - q


# ---------------------------------------------------------------------------
# Bucketed, overlapped grad sync (GradSyncConfig.bucket_bytes)
# ---------------------------------------------------------------------------

def plan_grad_buckets(shapes: Sequence[tuple], world: int,
                      bucket_bytes: int, itemsize: int = 4
                      ) -> list[list[tuple[int, int, int]]]:
    """Partition the flat gradient vector into size-targeted buckets.

    ``shapes`` are the sharded (zero) leaves' shapes in flat traversal
    order.  Each leaf's padded leading dim splits into ``world`` blocks
    of ``R = ld_pad // world`` shard rows; the partitioner walks the
    leaves in order and greedily fills buckets to ~``bucket_bytes`` of
    full-gradient volume (one shard row accounts for ``world`` gradient
    rows — the bytes every rank moves through the wire for it).

    Returns a list of buckets; each bucket is a list of ``(leaf, lo,
    hi)`` segments meaning shard rows ``[lo, hi)`` of ``shapes[leaf]``.
    Invariants (tested): segments of one leaf are disjoint, in
    increasing ``lo`` order across buckets, and cover ``[0, R)``
    exactly; a leaf larger than ``bucket_bytes`` is split across
    buckets; a row larger than ``bucket_bytes`` gets a bucket of its
    own (never an empty bucket).  Static/host-side: the partition
    depends only on shapes, so it is computed once per compile.
    """
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")
    buckets: list[list[tuple[int, int, int]]] = []
    cur: list[tuple[int, int, int]] = []
    cur_bytes = 0
    for i, shape in enumerate(shapes):
        ld = shape[0]
        rest = int(np.prod(shape[1:])) if len(shape) > 1 else 1
        R = (ld + (-ld) % world) // world
        row_bytes = rest * world * itemsize
        lo = 0
        while lo < R:
            room = bucket_bytes - cur_bytes
            if cur and room < row_bytes:
                buckets.append(cur)
                cur, cur_bytes = [], 0
                room = bucket_bytes
            take = min(R - lo, max(1, room // row_bytes))
            cur.append((i, lo, lo + take))
            cur_bytes += take * row_bytes
            lo += take
            if cur_bytes >= bucket_bytes:
                buckets.append(cur)
                cur, cur_bytes = [], 0
    if cur:
        buckets.append(cur)
    return buckets


def _zero_leaf_meta(flat_g, flat_flags):
    """(zero-leaf indices, per-leaf trailing-row numel) for bucketing."""
    zero_idx = [i for i, f in enumerate(flat_flags) if f]
    rn = {i: max(1, int(np.prod(flat_g[i].shape[1:]))) for i in zero_idx}
    return zero_idx, rn


def _bucket_widths(buckets, zero_idx, rn):
    """Per-bucket column width (shard numel) in the global block matrix."""
    return [sum((hi - lo) * rn[zero_idx[li]] for (li, lo, hi) in b)
            for b in buckets]


def _bucket_vectors(blocks, buckets, zero_idx, rn):
    """Assemble one flat per-bucket vector, interleaved block-major so
    block ``lin`` of the vector is rank ``lin``'s shard data — the layout
    the circulant RS/AG block partition expects.

    The partitioner walks leaves and shard rows in order, so every
    bucket is a CONTIGUOUS column range of the global ``(world, Wtot)``
    block matrix: one concatenate builds the matrix, then each bucket is
    a single slice + reshape (op count matters — assembly sits on the
    training step's critical path)."""
    G = (blocks[zero_idx[0]] if len(zero_idx) == 1 else
         jnp.concatenate([blocks[i] for i in zero_idx], axis=1))
    vecs, off = [], 0
    for w in _bucket_widths(buckets, zero_idx, rn):
        vecs.append(G[:, off:off + w].reshape(-1))
        off += w
    return vecs


def _bucketed_reduce(grads, flags, ef, axis_names, sync: GradSyncConfig,
                     world: int, rs_dt):
    """Bucketed, software-pipelined gradient reduce-scatter.

    Per-element arithmetic is IDENTICAL to the per-leaf path (the fold
    sequence of a circulant RS depends only on the block index, which
    the bucket layout preserves), so the uncompressed bucketed sync is
    bitwise-equal to ``reduce_scatter_leaf``; the int8 wire differs only
    through quantization-group boundaries (within wire tolerances).
    EF residual accounting is per leaf, exactly as in the one-shot path
    — each bucket's wire rounds then transport the same compensated
    rows.  Returns ``(g_red tree, new_ef tree | None)``.
    """
    flat_g, tdef = jax.tree.flatten(grads)
    flat_flags = jax.tree.leaves(flags)
    flat_ef = jax.tree.leaves(ef) if ef is not None else [None] * len(flat_g)
    zero_idx, rn = _zero_leaf_meta(flat_g, flat_flags)
    zset = set(zero_idx)
    out: list = [None] * len(flat_g)
    new_ef = list(flat_ef)
    for i, g in enumerate(flat_g):
        if i not in zset:
            out[i] = allreduce_leaf(g.astype(jnp.float32), axis_names,
                                    sync, world)
    blocks = {}
    for i in zero_idx:
        g = flat_g[i]
        if ef is not None:
            q, err = ef_quantize(g, flat_ef[i][0], sync.quant_group)
            new_ef[i] = err[None]
            g = q
        gp = _pad_lead(g.astype(rs_dt), world)
        blocks[i] = gp.reshape(world, -1)
    buckets = plan_grad_buckets([flat_g[i].shape for i in zero_idx], world,
                                sync.bucket_bytes,
                                jnp.dtype(rs_dt).itemsize)
    vecs = _bucket_vectors(blocks, buckets, zero_idx, rn)
    spec = sync.rs_spec()
    for ax in axis_names:
        vecs = C.reduce_scatter_pipelined(vecs, ax, spec=spec)
    # Each bucket's RS result is this rank's contiguous column range of
    # the global block matrix, so concatenating the bucket results in
    # order gives the rank's full shard vector; per-leaf slices then
    # fall at the leaf block widths.  Single divide, then L slices.
    own = vecs[0] if len(vecs) == 1 else jnp.concatenate(vecs)
    own = (own / world).astype(jnp.float32)
    off = 0
    for i in zero_idx:
        w = blocks[i].shape[1]
        out[i] = own[off:off + w].reshape(-1, *flat_g[i].shape[1:])
        off += w
    g_red = jax.tree.unflatten(tdef, out)
    if ef is None:
        return g_red, None
    return g_red, jax.tree.unflatten(tdef, new_ef)


def _bucketed_allgather(local, params, flags, axis_names,
                        sync: GradSyncConfig, world: int):
    """Bucketed, software-pipelined allgather of updated param shards.

    ``local`` mirrors ``params``: zero leaves hold this rank's updated
    shard ``(R, *rest)``, tiny leaves the full replicated update.  Uses
    the SAME static bucket partition as the grad reduce (same shapes,
    same itemsize) so plans and bucket geometries are shared.  Allgather
    is pure transport, so the result is bitwise-equal to per-leaf
    ``allgather_leaf`` (mixed-dtype buckets promote via ``result_type``
    and cast back — lossless round trips).
    """
    flat_l, tdef = jax.tree.flatten(local)
    flat_p = jax.tree.leaves(params)
    flat_flags = jax.tree.leaves(flags)
    zero_idx, rn = _zero_leaf_meta(flat_p, flat_flags)
    out = list(flat_l)
    buckets = plan_grad_buckets([flat_p[i].shape for i in zero_idx], world,
                                sync.bucket_bytes,
                                jnp.dtype(sync.rs_dtype).itemsize)
    # One flat local-shard vector in leaf order (mixed dtypes promote via
    # result_type and cast back after transport — lossless round trips);
    # each bucket is a contiguous slice of it (see _bucket_vectors).
    dt = jnp.result_type(*[flat_l[i].dtype for i in zero_idx])
    lvec = (flat_l[zero_idx[0]].astype(dt).reshape(-1)
            if len(zero_idx) == 1 else
            jnp.concatenate([flat_l[i].astype(dt).reshape(-1)
                             for i in zero_idx]))
    vecs, off = [], 0
    for w in _bucket_widths(buckets, zero_idx, rn):
        vecs.append(lvec[off:off + w])
        off += w
    spec = sync.ag_spec()
    for ax in reversed(list(axis_names)):
        vecs = C.allgather_pipelined(vecs, ax, spec=spec)
    # Gathered bucket b is (world * w_b,) block-major; re-joining the
    # buckets column-wise rebuilds the global (world, Wtot) block matrix,
    # from which each leaf is one column-range slice.
    G = (vecs[0].reshape(world, -1) if len(vecs) == 1 else
         jnp.concatenate([v.reshape(world, -1) for v in vecs], axis=1))
    off = 0
    for i in zero_idx:
        ld = flat_p[i].shape[0]
        w = (ld + (-ld) % world) // world * rn[i]
        out[i] = (G[:, off:off + w].reshape(-1, *flat_p[i].shape[1:])[:ld]
                  .astype(flat_p[i].dtype))
        off += w
    return jax.tree.unflatten(tdef, out)


def zero1_step(loss_and_grad: Callable, params, opt: Zero1State, batch, *,
               axis_names: Sequence[str], opt_cfg: adamw.AdamWConfig,
               sync: GradSyncConfig):
    """One manual-region training step (inside shard_map over the data
    axes; the model axis stays auto/GSPMD).  Returns (params', opt',
    metrics)."""
    loss, grads = loss_and_grad(params, batch)
    world = 1
    for a in axis_names:
        world *= compat.axis_size(a)
    flags = jax.tree.map(
        lambda l: is_zero_leaf(l.shape, world, sync.min_shard_numel), params)
    use_zero = sync.impl != "allreduce"

    # --- reduce: shard big leaves (Algorithm 1), psum tiny ones ---
    rs_dt = jnp.dtype(sync.rs_dtype)
    use_ef = sync.uses_error_feedback and opt.ef is not None
    bucketed = use_zero and sync.bucket_bytes is not None

    def reduce_one(g, flag):
        if flag and use_zero:
            g = g.astype(rs_dt)
            out = reduce_scatter_leaf(g, axis_names, sync, world)
            return out.astype(jnp.float32)
        return allreduce_leaf(g.astype(jnp.float32), axis_names, sync, world)

    if bucketed:
        # Bucketed, pipelined sync: bucket b's round-k ppermute overlaps
        # bucket b+1's fold (see _bucketed_reduce; bucket_bytes=None
        # keeps the per-leaf one-shot path below, bitwise-identical).
        g_red, ef_out = _bucketed_reduce(
            grads, flags, opt.ef if use_ef else None, axis_names, sync,
            world, rs_dt)
        new_ef = ef_out if use_ef else opt.ef
    elif use_ef:
        # Compressed sync with error feedback: compensate, quantize, and
        # carry the rounding error (see ef_quantize).  ``e`` arrives as
        # this rank's (1, *leaf) shard of the (world, *leaf) state.
        def reduce_one_ef(g, flag, e):
            if flag and use_zero:
                q, err = ef_quantize(g, e[0], sync.quant_group)
                out = reduce_scatter_leaf(q.astype(rs_dt), axis_names,
                                          sync, world)
                return out.astype(jnp.float32), err[None]
            return (allreduce_leaf(g.astype(jnp.float32), axis_names,
                                   sync, world), e)

        pairs = jax.tree.map(reduce_one_ef, grads, flags, opt.ef)
        ispair = lambda x: (isinstance(x, tuple) and len(x) == 2
                            and not isinstance(x, jax.Array))
        g_red = jax.tree.map(lambda o: o[0], pairs, is_leaf=ispair)
        new_ef = jax.tree.map(lambda o: o[1], pairs, is_leaf=ispair)
    else:
        g_red = jax.tree.map(reduce_one, grads, flags)
        new_ef = opt.ef

    # --- global grad norm: shards partition the reduced grad exactly, so
    # one psum of the summed shard sq-norms + the (replicated) tiny-leaf
    # sq-norms gives the global norm ---
    flat_flags = jax.tree.leaves(flags)
    flat_g = jax.tree.leaves(g_red)
    shard_sq = sum((jnp.sum(jnp.square(g)) for g, f in
                    zip(flat_g, flat_flags) if f and use_zero),
                   start=jnp.zeros((), jnp.float32))
    tiny_sq = sum((jnp.sum(jnp.square(g)) for g, f in
                   zip(flat_g, flat_flags) if not (f and use_zero)),
                  start=jnp.zeros((), jnp.float32))
    for ax in axis_names:
        shard_sq = lax.psum(shard_sq, ax)
    gnorm = jnp.sqrt(shard_sq + tiny_sq)
    scale = adamw.clip_scale_from_norm(opt_cfg, gnorm)

    # --- AdamW on shards ---
    step = opt.step + 1
    t = step.astype(jnp.float32)
    lr = adamw.lr_at(opt_cfg, step)
    bc1 = 1 - opt_cfg.beta1 ** t
    bc2 = 1 - opt_cfg.beta2 ** t

    def update_one(p, g, m, v, flag):
        if flag and use_zero:
            ld = p.shape[0]
            p_pad = _pad_lead(p, world)
            off, rows = shard_offset(p_pad.shape[0], axis_names)
            p_loc = lax.dynamic_slice_in_dim(p_pad, off, rows, axis=0)
        else:
            p_loc = p
        g = g * scale
        m2 = opt_cfg.beta1 * m + (1 - opt_cfg.beta1) * g
        v2 = opt_cfg.beta2 * v + (1 - opt_cfg.beta2) * g * g
        delta = -lr * ((m2 / bc1) / (jnp.sqrt(v2 / bc2) + opt_cfg.eps)
                       + opt_cfg.weight_decay * p_loc.astype(jnp.float32))
        new_loc = (p_loc.astype(jnp.float32) + delta).astype(p.dtype)
        if flag and use_zero and not bucketed:
            # Bucketed mode defers the gather: shards from all leaves are
            # re-bucketed and allgathered pipelined below.
            new_p = allgather_leaf(new_loc, p.shape[0], axis_names, sync)
        else:
            new_p = new_loc
        return new_p, m2, v2

    out = jax.tree.map(update_one, params, g_red, opt.m, opt.v, flags)
    istup = lambda x: isinstance(x, tuple) and len(x) == 3 \
        and not isinstance(x, jax.Array)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=istup)
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=istup)
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=istup)
    if bucketed:
        new_params = _bucketed_allgather(new_params, params, flags,
                                         axis_names, sync, world)

    mloss = loss
    for ax in axis_names:
        mloss = lax.pmean(mloss, ax)
    metrics = {"loss": mloss, "grad_norm": gnorm,
               "lr": adamw.lr_at(opt_cfg, step)}
    return (new_params,
            Zero1State(m=new_m, v=new_v, step=step, ef=new_ef), metrics)


# ---------------------------------------------------------------------------
# State construction / specs (used by train.steps)
# ---------------------------------------------------------------------------

def init_zero1_state(params, world: int, sync: GradSyncConfig) -> Zero1State:
    """GLOBAL optimizer state arrays: zero leaves get (ld_pad, *rest) fp32
    (to be sharded over the data axes), tiny leaves full fp32 replicas.
    With compressed sync + error feedback, every leaf also gets an EF
    residual: (world, *leaf) for zero leaves — one full-leaf residual PER
    DATA RANK, sharded so each rank keeps exactly its own — and a dummy
    (1, *leaf) replica for tiny leaves (psum'd exactly; never read)."""
    use_zero = sync.impl != "allreduce"

    def mk(l):
        if use_zero and is_zero_leaf(l.shape, world, sync.min_shard_numel):
            ld_pad = l.shape[0] + (-l.shape[0]) % world
            return jnp.zeros((ld_pad, *l.shape[1:]), jnp.float32)
        return jnp.zeros(l.shape, jnp.float32)

    zeros = jax.tree.map(mk, params)
    ef = None
    if sync.uses_error_feedback:
        def mk_ef(l):
            n = world if is_zero_leaf(l.shape, world,
                                      sync.min_shard_numel) else 1
            return jnp.zeros((n, *l.shape), jnp.float32)

        ef = jax.tree.map(mk_ef, params)
    return Zero1State(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32), ef=ef)


def resize_zero1_state(state: Zero1State, params, new_world: int,
                       sync: GradSyncConfig) -> Zero1State:
    """Remap a GLOBAL (gathered) :class:`Zero1State` to a new data-parallel
    world size — the elastic reshard step (ft/elastic.py).

    Inputs are the checkpointed, host-side global views: zero leaves'
    ``m``/``v`` are ``(ld_pad_old, *rest)`` (leading dim padded to the
    OLD world), tiny leaves are full replicas.  Only the leaf's true
    leading dim (from ``params``) and the NEW world matter:

    * ``m``/``v``: drop the old padding rows (``[:ld]`` — padded rows
      are zero by construction: padded gradient rows are zero, so the
      moments never leave zero there) and re-pad to the new world's
      multiple.  A leaf whose :func:`is_zero_leaf` flag flips between
      worlds is handled by the same slice+pad (tiny leaves store exactly
      ``ld`` rows).  The round trip p→p′→p is lossless.
    * ``ef`` (EF-SGD residuals, ``(old_world, *leaf)`` — one full-leaf
      residual per rank): resized by MASS CONSERVATION — row 0 of the
      new ``(new_world, *leaf)`` state is the sum over all old rank
      rows, remaining rows zero.  Semantics: each rank adds its residual
      into its local gradient before quantization and the reduce-scatter
      SUMS ranks, so only the total ``sum_r ef_r`` enters the reduced
      gradient; per-rank attribution carries no information across a
      resize (the rank set itself changed).  Shrink and grow are the
      same operation, and the residual mass survives p→p′→p exactly.
    * ``step``: unchanged.
    """
    if new_world < 1:
        raise ValueError(f"new_world must be >= 1, got {new_world}")
    use_zero = sync.impl != "allreduce"

    def rs_mv(mv, l):
        if not l.shape:
            return jnp.asarray(mv)  # scalar leaf: always replicated
        ld = l.shape[0]
        arr = np.asarray(mv)[:ld]
        if use_zero and is_zero_leaf(l.shape, new_world,
                                     sync.min_shard_numel):
            pad = (-ld) % new_world
            if pad:
                arr = np.concatenate(
                    [arr, np.zeros((pad, *arr.shape[1:]), arr.dtype)])
        return jnp.asarray(arr)

    def rs_ef(e, l):
        rows = new_world if (use_zero and is_zero_leaf(
            l.shape, new_world, sync.min_shard_numel)) else 1
        out = np.zeros((rows, *l.shape), np.float32)
        out[0] = np.asarray(e, np.float32).sum(axis=0)
        return jnp.asarray(out)

    new_m = jax.tree.map(rs_mv, state.m, params)
    new_v = jax.tree.map(rs_mv, state.v, params)
    new_ef = None
    if state.ef is not None:
        if not sync.uses_error_feedback:
            raise ValueError(
                "state carries EF residuals but sync does not use error "
                "feedback — resize would silently drop residual mass")
        new_ef = jax.tree.map(rs_ef, state.ef, params)
    return Zero1State(m=new_m, v=new_v, step=jnp.asarray(state.step),
                      ef=new_ef)


def zero1_state_specs(params, world: int, sync: GradSyncConfig,
                      collective_axes):
    """Manual-axis PartitionSpecs for the optimizer state (dim 0 over the
    data axes for zero leaves; replicated otherwise).  EF residuals are
    sharded on their per-rank leading axis."""
    from jax.sharding import PartitionSpec as P
    use_zero = sync.impl != "allreduce"

    def spec(l):
        if use_zero and is_zero_leaf(l.shape, world, sync.min_shard_numel):
            return P(collective_axes)
        return P()

    m_specs = jax.tree.map(spec, params)
    ef_specs = None
    if sync.uses_error_feedback:
        ef_specs = jax.tree.map(spec, params)
    return Zero1State(m=m_specs, v=jax.tree.map(lambda s: s, m_specs),
                      step=P(), ef=ef_specs)
