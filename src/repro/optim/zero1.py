"""ZeRO-1 distributed optimizer driven by the paper's collectives.

This is the framework's primary integration of Träff's algorithms: every
(large) gradient leaf is REDUCE-SCATTERED (Algorithm 1) across the data
axes along its leading dimension, AdamW updates only the local 1/(pod*data)
shard (optimizer state is never replicated — the ZeRO-1 memory win), and
updated parameter shards are ALLGATHERED back with the reversed schedule
(Algorithm 2's second phase).  Per step and per rank this moves exactly
2(p-1)/p of the gradient volume in 2*ceil(log2 p) collective-permute
rounds per leaf — Theorem 2's optimum.

PER-LEAF, not flat-raveled: leaves keep their tensor-parallel (model-axis)
sharding on inner dimensions — a ravel would force an all-gather over the
model axis and materialize full fp32 gradients per rank (168 GB for a 42B
model).  The leading dim (the layer-stack axis for scanned blocks, vocab
for embeddings) is zero-padded to a multiple of the DP world and sliced
back after the allgather.  Leaves too small to shard profitably (norms,
biases, scalars) are synchronized with a plain psum and updated
replicated — they are <0.1% of parameters.

Grad-sync implementations are pluggable (--grad-sync):
  circulant[:schedule]  paper Algorithm 1/2 (halving default; power2 /
                        fully_connected / sqrt per Corollary 2)
  ring                  p-1-round bandwidth baseline
  xla                   lax.psum_scatter + lax.all_gather
  allreduce             plain replicated allreduce + full optimizer
                        (no ZeRO; memory baseline)
The config compiles to CollectiveSpecs (``GradSyncConfig.rs_spec()`` /
``.ag_spec()``); each data axis executes one cached CollectivePlan, so
the grad sync rides the same plan/execute seam as every other consumer.
Optional compressed gradient sync via wire_dtype='int8' (the circulant
collectives' packed int8 wire format: per-round quantize-on-send + fused
dequant-⊕ rounds) with an EF-SGD error-feedback residual carried in the
optimizer state so convergence is preserved; ``use_fused_kernel`` routes
the circulant rounds' local fold + send assembly through the fused Pallas
round kernel (kernels.fused_round).

Shard layout per leaf: axis-major blocks over ``axis_names`` order —
rank (r0, r1) holds rows [lin * ld_pad/P, (lin+1) * ld_pad/P) with
lin = r0 * p1 + r1; the matching hierarchical AG reassembles exactly.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.core import collectives as C
from repro.core.spec import CollectiveSpec
from repro.kernels import dequantize_blocks, quantize_blocks
from . import adamw


@dataclass(frozen=True)
class GradSyncConfig:
    impl: str = "circulant"       # circulant | ring | xla | allreduce
    schedule: str = "halving"     # Corollary-2 schedule for circulant
    wire_dtype: str | None = None  # None | 'int8': compressed circulant
    #                               rounds (int8 codes + f32 group scales
    #                               packed on the wire; ~4x fewer β bytes)
    compress: str | None = None   # DEPRECATED alias for wire_dtype
    error_feedback: bool = True   # EF-SGD residual for compressed sync:
    #                               each rank keeps its local quantization
    #                               error and adds it back into the next
    #                               step's gradient before quantizing
    quant_group: int = 512
    min_shard_numel: int = 1024   # leaves smaller than this stay replicated
    rs_dtype: str = "float32"     # reduce-scatter payload dtype; 'bfloat16'
    #                               halves the RS link volume (§Perf A)
    use_fused_kernel: bool | None = None  # fused Pallas round kernel for the
    #                               circulant RS/AG; None = auto (TPU only)

    def __post_init__(self):
        if self.compress is not None:
            warnings.warn(
                "GradSyncConfig(compress=...) is deprecated; pass "
                "wire_dtype=... — it feeds the CollectiveSpec the grad "
                "sync plans are built from (see GradSyncConfig.rs_spec)",
                DeprecationWarning, stacklevel=3)

    @property
    def wire(self) -> str | None:
        """Effective wire dtype (``wire_dtype`` wins over the legacy
        ``compress`` spelling)."""
        return self.wire_dtype or self.compress

    def rs_spec(self) -> CollectiveSpec:
        """The reduce-scatter :class:`CollectiveSpec` this config means.

        ``impl='allreduce'`` (the no-ZeRO baseline) shards nothing, but
        its tiny-leaf fallback still wants an xla spec.
        """
        kind = self.impl if self.impl != "allreduce" else "xla"
        if kind != "circulant":
            return CollectiveSpec(kind=kind)
        return CollectiveSpec(
            kind="circulant", schedule=self.schedule,
            use_fused_kernel=self.use_fused_kernel,
            wire_dtype=self.wire if self.wire == "int8" else None,
            wire_group=self.quant_group)

    def ag_spec(self) -> CollectiveSpec:
        """Allgather spec: parameter shards must reassemble EXACTLY, so
        the wire format never applies; ring has no allgather and falls
        back to the circulant schedule (same reversed-skip structure)."""
        kind = "circulant" if self.impl in ("circulant", "ring") else "xla"
        if kind != "circulant":
            return CollectiveSpec(kind=kind)
        return CollectiveSpec(
            kind="circulant", schedule=self.schedule,
            use_fused_kernel=self.use_fused_kernel)

    @property
    def uses_error_feedback(self) -> bool:
        """EF is meaningful only when the sync is actually lossy: the
        circulant impl is the one that honors ``wire_dtype`` (ring/xla
        transmit exactly; allreduce has no sharded RS to compensate)."""
        return (self.error_feedback and self.wire == "int8"
                and self.impl == "circulant")


class Zero1State(NamedTuple):
    m: object        # pytree: sharded fp32 (zero leaves) / full (tiny)
    v: object
    step: jax.Array
    ef: object = None  # error-feedback residuals: per-rank quantization
    #                    error, (world, *leaf) sharded over the data axes
    #                    (zero leaves) / (1, *leaf) replicated (tiny
    #                    leaves, unused); None when EF is off


def data_parallel_world_static(mesh_shape: dict, axis_names) -> int:
    p = 1
    for a in axis_names:
        p *= mesh_shape[a]
    return p


def is_zero_leaf(shape, world: int, min_numel: int) -> bool:
    """Shard a leaf iff it is big enough and leading-dim padding waste is
    bounded (< 2x)."""
    numel = int(np.prod(shape)) if shape else 0
    if numel < max(min_numel, world):
        return False
    ld = shape[0]
    pad_ld = ld + (-ld) % world
    return pad_ld <= 2 * ld or numel // max(ld, 1) * pad_ld >= min_numel


def leaf_flags(params, world: int, min_numel: int = 1024):
    return jax.tree.map(
        lambda l: is_zero_leaf(l.shape, world, min_numel), params)


def _pad_lead(x, world: int):
    ld = x.shape[0]
    pad = (-ld) % world
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
    return x


def shard_offset(ld_pad: int, axis_names: Sequence[str]):
    """(row offset, rows per shard) of this rank's slice (axis-major)."""
    p_total = 1
    lin = jnp.zeros((), jnp.int32)
    for a in axis_names:
        lin = lin * compat.axis_size(a) + lax.axis_index(a)
        p_total *= compat.axis_size(a)
    rows = ld_pad // p_total
    return lin * rows, rows


def reduce_scatter_leaf(g, axis_names, sync: GradSyncConfig, world: int):
    """Hierarchical RS along dim 0; returns the averaged local shard.
    One cached :class:`CollectivePlan` per axis (sync.rs_spec())."""
    spec = sync.rs_spec()
    out = _pad_lead(g, world)
    for ax in axis_names:
        out = C.reduce_scatter(out, ax, spec=spec)
    return out / world


def allgather_leaf(shard, ld: int, axis_names, sync: GradSyncConfig):
    """Inverse: hierarchical AG along dim 0, then drop padding rows."""
    spec = sync.ag_spec()
    out = shard
    for ax in reversed(list(axis_names)):
        out = C.allgather(out, ax, spec=spec)
    return out[:ld]


def allreduce_leaf(g, axis_names, sync: GradSyncConfig, world: int):
    """Tiny-leaf path: replicated mean.  Scalars/1-elem rows cannot block-
    partition, so this uses psum (XLA all-reduce) — negligible volume."""
    out = g
    for ax in axis_names:
        out = lax.psum(out, ax)
    return out / world


def ef_quantize(g, residual, group: int):
    """EF-SGD compensation step (per rank, per leaf): add the carried
    residual into the raw gradient, round the sum onto the int8 grid the
    wire will use, and keep the new rounding error as the next step's
    residual.  The quantized gradient is what enters the compressed
    reduce-scatter, so round 0 of the wire re-derives (near-)identical
    codes and the dominant compression error is fed back instead of
    lost.  Per-round requantization error of partial sums is NOT
    recoverable per rank (it mixes contributions) and stays uncompensated
    — standard EF-SGD scope."""
    comp = g.astype(jnp.float32) + residual
    q = dequantize_blocks(quantize_blocks(comp, group=group, backend="jnp"),
                          backend="jnp")
    return q, comp - q


def zero1_step(loss_and_grad: Callable, params, opt: Zero1State, batch, *,
               axis_names: Sequence[str], opt_cfg: adamw.AdamWConfig,
               sync: GradSyncConfig):
    """One manual-region training step (inside shard_map over the data
    axes; the model axis stays auto/GSPMD).  Returns (params', opt',
    metrics)."""
    loss, grads = loss_and_grad(params, batch)
    world = 1
    for a in axis_names:
        world *= compat.axis_size(a)
    flags = jax.tree.map(
        lambda l: is_zero_leaf(l.shape, world, sync.min_shard_numel), params)
    use_zero = sync.impl != "allreduce"

    # --- reduce: shard big leaves (Algorithm 1), psum tiny ones ---
    rs_dt = jnp.dtype(sync.rs_dtype)
    use_ef = sync.uses_error_feedback and opt.ef is not None

    def reduce_one(g, flag):
        if flag and use_zero:
            g = g.astype(rs_dt)
            out = reduce_scatter_leaf(g, axis_names, sync, world)
            return out.astype(jnp.float32)
        return allreduce_leaf(g.astype(jnp.float32), axis_names, sync, world)

    if use_ef:
        # Compressed sync with error feedback: compensate, quantize, and
        # carry the rounding error (see ef_quantize).  ``e`` arrives as
        # this rank's (1, *leaf) shard of the (world, *leaf) state.
        def reduce_one_ef(g, flag, e):
            if flag and use_zero:
                q, err = ef_quantize(g, e[0], sync.quant_group)
                out = reduce_scatter_leaf(q.astype(rs_dt), axis_names,
                                          sync, world)
                return out.astype(jnp.float32), err[None]
            return (allreduce_leaf(g.astype(jnp.float32), axis_names,
                                   sync, world), e)

        pairs = jax.tree.map(reduce_one_ef, grads, flags, opt.ef)
        ispair = lambda x: (isinstance(x, tuple) and len(x) == 2
                            and not isinstance(x, jax.Array))
        g_red = jax.tree.map(lambda o: o[0], pairs, is_leaf=ispair)
        new_ef = jax.tree.map(lambda o: o[1], pairs, is_leaf=ispair)
    else:
        g_red = jax.tree.map(reduce_one, grads, flags)
        new_ef = opt.ef

    # --- global grad norm: shards partition the reduced grad exactly, so
    # one psum of the summed shard sq-norms + the (replicated) tiny-leaf
    # sq-norms gives the global norm ---
    flat_flags = jax.tree.leaves(flags)
    flat_g = jax.tree.leaves(g_red)
    shard_sq = sum((jnp.sum(jnp.square(g)) for g, f in
                    zip(flat_g, flat_flags) if f and use_zero),
                   start=jnp.zeros((), jnp.float32))
    tiny_sq = sum((jnp.sum(jnp.square(g)) for g, f in
                   zip(flat_g, flat_flags) if not (f and use_zero)),
                  start=jnp.zeros((), jnp.float32))
    for ax in axis_names:
        shard_sq = lax.psum(shard_sq, ax)
    gnorm = jnp.sqrt(shard_sq + tiny_sq)
    scale = adamw.clip_scale_from_norm(opt_cfg, gnorm)

    # --- AdamW on shards ---
    step = opt.step + 1
    t = step.astype(jnp.float32)
    lr = adamw.lr_at(opt_cfg, step)
    bc1 = 1 - opt_cfg.beta1 ** t
    bc2 = 1 - opt_cfg.beta2 ** t

    def update_one(p, g, m, v, flag):
        if flag and use_zero:
            ld = p.shape[0]
            p_pad = _pad_lead(p, world)
            off, rows = shard_offset(p_pad.shape[0], axis_names)
            p_loc = lax.dynamic_slice_in_dim(p_pad, off, rows, axis=0)
        else:
            p_loc = p
        g = g * scale
        m2 = opt_cfg.beta1 * m + (1 - opt_cfg.beta1) * g
        v2 = opt_cfg.beta2 * v + (1 - opt_cfg.beta2) * g * g
        delta = -lr * ((m2 / bc1) / (jnp.sqrt(v2 / bc2) + opt_cfg.eps)
                       + opt_cfg.weight_decay * p_loc.astype(jnp.float32))
        new_loc = (p_loc.astype(jnp.float32) + delta).astype(p.dtype)
        if flag and use_zero:
            new_p = allgather_leaf(new_loc, p.shape[0], axis_names, sync)
        else:
            new_p = new_loc
        return new_p, m2, v2

    out = jax.tree.map(update_one, params, g_red, opt.m, opt.v, flags)
    istup = lambda x: isinstance(x, tuple) and len(x) == 3 \
        and not isinstance(x, jax.Array)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=istup)
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=istup)
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=istup)

    mloss = loss
    for ax in axis_names:
        mloss = lax.pmean(mloss, ax)
    metrics = {"loss": mloss, "grad_norm": gnorm,
               "lr": adamw.lr_at(opt_cfg, step)}
    return (new_params,
            Zero1State(m=new_m, v=new_v, step=step, ef=new_ef), metrics)


# ---------------------------------------------------------------------------
# State construction / specs (used by train.steps)
# ---------------------------------------------------------------------------

def init_zero1_state(params, world: int, sync: GradSyncConfig) -> Zero1State:
    """GLOBAL optimizer state arrays: zero leaves get (ld_pad, *rest) fp32
    (to be sharded over the data axes), tiny leaves full fp32 replicas.
    With compressed sync + error feedback, every leaf also gets an EF
    residual: (world, *leaf) for zero leaves — one full-leaf residual PER
    DATA RANK, sharded so each rank keeps exactly its own — and a dummy
    (1, *leaf) replica for tiny leaves (psum'd exactly; never read)."""
    use_zero = sync.impl != "allreduce"

    def mk(l):
        if use_zero and is_zero_leaf(l.shape, world, sync.min_shard_numel):
            ld_pad = l.shape[0] + (-l.shape[0]) % world
            return jnp.zeros((ld_pad, *l.shape[1:]), jnp.float32)
        return jnp.zeros(l.shape, jnp.float32)

    zeros = jax.tree.map(mk, params)
    ef = None
    if sync.uses_error_feedback:
        def mk_ef(l):
            n = world if is_zero_leaf(l.shape, world,
                                      sync.min_shard_numel) else 1
            return jnp.zeros((n, *l.shape), jnp.float32)

        ef = jax.tree.map(mk_ef, params)
    return Zero1State(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32), ef=ef)


def zero1_state_specs(params, world: int, sync: GradSyncConfig,
                      collective_axes):
    """Manual-axis PartitionSpecs for the optimizer state (dim 0 over the
    data axes for zero leaves; replicated otherwise).  EF residuals are
    sharded on their per-rank leading axis."""
    from jax.sharding import PartitionSpec as P
    use_zero = sync.impl != "allreduce"

    def spec(l):
        if use_zero and is_zero_leaf(l.shape, world, sync.min_shard_numel):
            return P(collective_axes)
        return P()

    m_specs = jax.tree.map(spec, params)
    ef_specs = None
    if sync.uses_error_feedback:
        ef_specs = jax.tree.map(spec, params)
    return Zero1State(m=m_specs, v=jax.tree.map(lambda s: s, m_specs),
                      step=P(), ef=ef_specs)
