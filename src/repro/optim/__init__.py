from .adamw import (AdamWConfig, AdamState, TreeAdamState, init_state,  # noqa: F401
                    init_tree_state, update_shard, update_tree, lr_at)
from .zero1 import GradSyncConfig, zero1_step  # noqa: F401
