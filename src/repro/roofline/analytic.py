"""Analytic FLOP / HBM-byte accounting per (arch × shape × mesh).

WHY THIS EXISTS: XLA's ``compiled.cost_analysis()`` counts a ``while``
(scan) body ONCE, not × trip-count (verified empirically — see
EXPERIMENTS.md §Roofline methodology).  Layer scans, flash-attention tile
loops and SSM chunk scans therefore make raw HLO numbers meaningless for
whole-step rooflines.  We use:

  * compute & memory terms  — the closed-form model below (validated
    against FULLY-UNROLLED compiles of reduced configs in
    tests/test_roofline.py, and reported next to the raw HLO numbers),
  * collective term         — measured from post-SPMD HLO text with the
    two-point scan-unroll correction (exact: collectives appear only at
    layer level or outside loops).

Conventions: flops count multiply-accumulates as 2 ops; attention is
counted as implemented (our flash loop computes ALL S_q×S_k tiles — the
causal-skip saving is a §Perf item, so the baseline honestly charges full
rectangles); backward = 2× forward; full remat adds ~1× forward for the
rematerialized region.  All outputs are PER CHIP (global / n_chips),
assuming the sharding spreads work evenly (GSPMD imbalance shows up as the
gap vs HLO diagnostics).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CellSpec:
    kind: str          # train | prefill | decode
    seq: int           # context length
    batch: int         # global batch
    n_chips: int
    tp: int            # model-axis size
    dp_world: int      # product of data axes
    remat: bool = True


def _attn_proj_flops(cfg) -> float:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return 2 * d * h * dh + 2 * 2 * d * hkv * dh + 2 * h * dh * d


def _attn_score_flops(cfg, s_ctx: float) -> float:
    """Per token: QK^T + PV against s_ctx keys."""
    return 4 * s_ctx * cfg.n_heads * cfg.head_dim


def _ffn_flops(cfg) -> float:
    return 3 * 2 * cfg.d_model * cfg.d_ff


def _moe_flops(cfg) -> float:
    # capacity-padded dispatch: cf * K experts' worth of SwiGLU + router
    return (cfg.capacity_factor * cfg.experts_per_token * _ffn_flops(cfg)
            + 2 * cfg.d_model * cfg.n_experts)


def _mamba_flops(cfg) -> float:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    return (2 * d * 2 * d_in            # in_proj
            + 2 * cfg.ssm_conv * d_in   # depthwise conv
            + 2 * d_in * (1 + 2 * n)    # dt, B, C projections
            + 10 * d_in * n             # scan element ops
            + 2 * d_in * n              # y = h·C
            + 2 * d_in * d)             # out_proj


def _mlstm_flops(cfg) -> float:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ch = cfg.mlstm_chunk
    return (4 * 2 * d * h * dh          # q,k,v,ogate projections
            + 2 * 2 * d * h             # i,f gates
            + 4 * ch * h * dh           # intra-chunk scores+accum (per tok)
            + 6 * dh * dh * h           # state read + update
            + 2 * h * dh * d)           # out proj


def _slstm_flops(cfg) -> float:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    return (2 * d * 4 * h * dh          # input projections
            + 4 * 2 * dh * dh * h       # recurrent R matmuls
            + 30 * h * dh               # gates/elementwise
            + 2 * h * dh * d)           # out proj


def _layer_flops_per_token(cfg, s_ctx: float) -> float:
    """One decoder-layer forward, per token, context length s_ctx."""
    fam = cfg.family
    if fam == "ssm_xlstm":
        # alternating mLSTM / sLSTM
        return (_mlstm_flops(cfg) + _slstm_flops(cfg)) / 2
    f = _attn_proj_flops(cfg) + _attn_score_flops(cfg, s_ctx)
    if fam == "hybrid":
        f += _mamba_flops(cfg)
    if cfg.is_moe:
        f += _moe_flops(cfg)
    elif cfg.d_ff:
        f += _ffn_flops(cfg)
    return f


def _cross_layer_flops_per_token(cfg, n_mem: int) -> float:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return (2 * d * h * dh + 2 * h * dh * d       # q, o proj
            + _attn_score_flops(cfg, n_mem)
            + _ffn_flops(cfg))


def _mem_kv_proj_flops(cfg, n_mem: int) -> float:
    """Projecting memory K/V for ONE cross-attn layer."""
    return n_mem * 2 * 2 * cfg.d_model * cfg.n_kv_heads * cfg.head_dim


def forward_flops_global(cfg, seq: int, batch: int, kind: str) -> float:
    """Whole-model forward FLOPs for the cell (global, all chips)."""
    fam = cfg.family
    tokens = batch * seq

    if kind == "decode":
        # one new token against a cache of length `seq`
        tok = batch
        if fam == "ssm_xlstm":
            per_layer = (_mlstm_flops(cfg) + _slstm_flops(cfg)) / 2
            core = cfg.n_layers * per_layer * tok
        elif fam == "hybrid":
            per_layer = []
            for i in range(cfg.n_layers):
                ctx = seq if i in cfg.global_attn_layers else min(
                    cfg.sliding_window, seq)
                per_layer.append(_attn_proj_flops(cfg)
                                 + _attn_score_flops(cfg, ctx)
                                 + _mamba_flops(cfg) + _ffn_flops(cfg))
            core = sum(per_layer) * tok
        elif fam == "encdec":
            dec = cfg.n_layers * (_attn_proj_flops(cfg)
                                  + _attn_score_flops(cfg, cfg.dec_len)
                                  + _cross_layer_flops_per_token(cfg, seq))
            core = dec * tok
        elif fam == "vlm":
            from repro.models.vlm import SELF_PER_GROUP
            ng = cfg.n_layers // (SELF_PER_GROUP + 1)
            core = (ng * SELF_PER_GROUP * (_attn_proj_flops(cfg)
                                           + _attn_score_flops(cfg, seq))
                    + ng * _cross_layer_flops_per_token(cfg,
                                                        cfg.n_image_tokens)
                    + ng * SELF_PER_GROUP * _ffn_flops(cfg)) * tok
        else:
            ctx = min(cfg.sliding_window, seq) if cfg.sliding_window else seq
            core = cfg.n_layers * _layer_flops_per_token(cfg, ctx) * tok
        head = 2 * cfg.d_model * cfg.vocab_size * tok
        return core + head

    # full-sequence passes (train / prefill).  Our flash loop computes all
    # S^2 tiles -> charge full rectangles (baseline honesty).
    s_ctx = seq
    if fam == "encdec":
        enc = cfg.enc_layers * (_attn_proj_flops(cfg)
                                + _attn_score_flops(cfg, seq)
                                + _ffn_flops(cfg)) * batch * seq
        dec_tok = batch * min(cfg.dec_len, seq)
        dec = cfg.n_layers * (_attn_proj_flops(cfg)
                              + _attn_score_flops(cfg, min(cfg.dec_len, seq))
                              + _cross_layer_flops_per_token(cfg, seq)
                              - _ffn_flops(cfg) + 2 * _ffn_flops(cfg)) * dec_tok
        memproj = cfg.n_layers * _mem_kv_proj_flops(cfg, seq) * batch
        head_tok = dec_tok
        core = enc + dec + memproj
    elif fam == "vlm":
        from repro.models.vlm import SELF_PER_GROUP
        ng = cfg.n_layers // (SELF_PER_GROUP + 1)
        core = (ng * SELF_PER_GROUP * (_attn_proj_flops(cfg)
                                       + _attn_score_flops(cfg, s_ctx)
                                       + _ffn_flops(cfg))
                + ng * _cross_layer_flops_per_token(cfg, cfg.n_image_tokens)
                ) * tokens
        core += ng * _mem_kv_proj_flops(cfg, cfg.n_image_tokens) * batch
        head_tok = tokens
    elif fam == "hybrid":
        per = 0.0
        for i in range(cfg.n_layers):
            ctx = s_ctx if i in cfg.global_attn_layers else min(
                cfg.sliding_window, s_ctx)
            per += (_attn_proj_flops(cfg) + _attn_score_flops(cfg, ctx)
                    + _mamba_flops(cfg) + _ffn_flops(cfg))
        core = per * tokens
        head_tok = tokens
    elif fam == "ssm_xlstm":
        core = cfg.n_layers * ((_mlstm_flops(cfg) + _slstm_flops(cfg)) / 2
                               ) * tokens
        head_tok = tokens
    else:
        core = cfg.n_layers * _layer_flops_per_token(cfg, s_ctx) * tokens
        head_tok = tokens
    head = 2 * cfg.d_model * cfg.vocab_size * head_tok
    return core + head


def cell_flops_per_chip(cfg, cell: CellSpec) -> float:
    fwd = forward_flops_global(cfg, cell.seq, cell.batch, cell.kind)
    if cell.kind == "train":
        mult = 3.0  # fwd + bwd(2x)
        if cell.remat:
            mult += 1.0  # recompute fwd
        total = fwd * mult
        # optimizer elementwise (~24 flops/param over the DP world)
        total += 24.0 * cfg.param_count()
    else:
        total = fwd
    return total / cell.n_chips


# ---------------------------------------------------------------------------
# HBM bytes
# ---------------------------------------------------------------------------

def _param_bytes(cfg) -> float:
    return 2.0 * cfg.param_count()  # bf16


def cell_hbm_bytes_per_chip(cfg, cell: CellSpec) -> float:
    d, v = cfg.d_model, cfg.vocab_size
    L = cfg.n_layers + cfg.enc_layers
    n_chips = cell.n_chips
    pb_chip = _param_bytes(cfg) / cell.tp  # params replicated over data
    if cell.kind == "train":
        b_loc_tokens = cell.batch * cell.seq / cell.dp_world
        # params: read fwd + remat-fwd + bwd; grads write+read (bf16);
        passes = 3 if cell.remat else 2
        t = pb_chip * (passes + 2)
        # optimizer: m,v read+write fp32 on 1/world shards + param shard rw
        n_shard = cfg.param_count() / cell.dp_world / cell.tp
        t += n_shard * (4 * 4 + 2 * 2 + 2 * 2)
        # residual stream activations saved at layer boundaries (remat):
        t += L * b_loc_tokens * d * 2 * 2  # write + re-read, bf16
        # per-layer working tensors ~ 6 streams of (tok, d) x passes
        t += passes * L * b_loc_tokens * d * 2 * 6
        # logits fwd+bwd (vocab sharded over tp)
        t += 3 * cell.batch * cell.seq / cell.dp_world * v / cell.tp * 2
        return t
    if cell.kind == "prefill":
        tok_chip = cell.batch * cell.seq / cell.dp_world
        t = pb_chip
        t += L * tok_chip * d * 2 * 4          # activations through layers
        # KV cache write
        t += (cfg.n_layers * cell.batch * cell.seq * cfg.n_kv_heads
              * cfg.head_dim * 2 * 2) / n_chips
        return t
    # decode: params + full KV cache read per token step
    t = pb_chip
    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
        kv_len = cell.seq
        t += (cfg.n_layers * cell.batch * kv_len * cfg.n_kv_heads
              * cfg.head_dim * 2 * 2) / n_chips
    if cfg.family == "moe":
        # only active experts' weights needed per decode microbatch — but
        # weights are resident; count resident read of active fraction
        act = cfg.active_param_count() / cfg.param_count()
        t = _param_bytes(cfg) * act / cell.tp + (t - pb_chip)
    return t


def analytic_cell(cfg, cell: CellSpec) -> dict:
    return {
        "flops_per_chip": cell_flops_per_chip(cfg, cell),
        "hbm_bytes_per_chip": cell_hbm_bytes_per_chip(cfg, cell),
    }
