"""Render reports/dryrun JSONs into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.roofline.report [--dir reports/dryrun]
                                                   [--mesh 1pod] [--tag ...]
"""
from __future__ import annotations

import argparse
import json
import os


def load(d: str, mesh: str, tag: str = ""):
    rows = []
    suffix = f"_{tag}" if tag else ""
    for fn in sorted(os.listdir(d)):
        if not fn.endswith(f"_{mesh}{suffix}.json"):
            continue
        if not tag and fn.count("_") > 2:
            # exclude tagged variants when untagged requested
            base = fn[:-len(f"_{mesh}.json")]
            pass
        rows.append(json.load(open(os.path.join(d, fn))))
    return rows


def fmt_bytes(b):
    return f"{b / 2**30:.1f}"


def render(rows, *, show_hlo=False) -> str:
    out = []
    out.append("| arch | shape | mode | status | peak GiB/chip | t_compute "
               "| t_memory | t_collective | bottleneck | useful/HLO | "
               "roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows = sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        arch, shape = r["arch"], r["shape"]
        st = r.get("status", "?")
        if st != "OK":
            short = "SKIP" if st.startswith("SKIP") else "ERROR"
            note = st.split("(", 1)[-1].rstrip(")") if "(" in st else st
            out.append(f"| {arch} | {shape} | {r.get('mode', '')} | {short}:"
                       f" {note[:48]} | | | | | | | |")
            continue
        rl = r["roofline"]
        m = r["memory"]
        peak = (m["argument_bytes"] + m["temp_bytes"]) / 2**30
        fit = "" if peak <= 16 else " ⚠"
        out.append(
            f"| {arch} | {shape} | {r.get('mode', '')} | OK | "
            f"{peak:.1f}{fit} | {rl['t_compute_s']:.4f} | "
            f"{rl['t_memory_s']:.4f} | {rl['t_collective_s']:.4f} | "
            f"{rl['bottleneck']} | {rl['useful_flops_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.4f} |")
    return "\n".join(out)


def render_dryrun(rows) -> str:
    out = []
    out.append("| arch | shape | mesh | status | compile s | args GiB | "
               "temp GiB | collective ops (corrected) |")
    out.append("|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows = sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in rows:
        st = r.get("status", "?")
        if st != "OK":
            short = "SKIP" if st.startswith("SKIP") else st[:60]
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{short} | | | | |")
            continue
        m = r["memory"]
        ops = r.get("collective_ops", {})
        ops_s = " ".join(f"{k.replace('collective-', 'c')}:{int(v)}"
                         for k, v in sorted(ops.items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
            f"{r.get('compile_s', '')} | "
            f"{fmt_bytes(m['argument_bytes'])} | "
            f"{fmt_bytes(m['temp_bytes'])} | {ops_s} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    ap.add_argument("--mesh", default="1pod")
    ap.add_argument("--tag", default="")
    ap.add_argument("--kind", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    rows = load(args.dir, args.mesh, args.tag)
    print(render(rows) if args.kind == "roofline" else render_dryrun(rows))


if __name__ == "__main__":
    main()
