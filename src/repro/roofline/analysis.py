"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step per chip
(TPU v5e constants):

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
  memory     = HLO_bytes_accessed / HBM_bw       (819 GB/s)
  collective = effective_collective_bytes / link_bw  (~50 GB/s/link ICI)

``cost_analysis()`` provides per-device FLOPs and bytes.  Collective bytes
are NOT in cost_analysis: we parse the post-SPMD HLO text and sum, per
collective op, the bytes that actually cross links per participating
device:

  collective-permute     size                  (one send per device)
  all-gather             out * (g-1)/g
  reduce-scatter         out * (g-1)            (= in * (g-1)/g)
  all-reduce             2 * size * (g-1)/g     (RS + AG decomposition)
  all-to-all             size * (g-1)/g

with g parsed from replica_groups (explicit or iota form).

MODEL_FLOPS = 6·N·D for training cells (N = total params dense / active
params MoE; D = tokens per chip per step) and 2·N·D for inference cells
(forward only) — the useful-FLOPs yardstick; ratio to HLO FLOPs exposes
remat/redundancy waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro import compat

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s
LINK_BW = 50e9            # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_bytes(type_str: str) -> tuple[int, dict]:
    """(total bytes, per-dtype byte breakdown) of an HLO type string.
    The breakdown is what makes a compressed (s8-wire) collective visible
    next to its uncompressed (f32/bf16) peer in the roofline report."""
    total = 0
    by_dtype: dict[str, int] = {}
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes = n * _DTYPE_BYTES[dtype]
        total += nbytes
        by_dtype[dtype] = by_dtype.get(dtype, 0) + nbytes
    return total, by_dtype


def _group_size(line: str) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2  # conservative default


@dataclass
class CollectiveStats:
    ops: dict = field(default_factory=dict)        # op -> count
    bytes_by_op: dict = field(default_factory=dict)  # op -> effective bytes
    raw_bytes_by_op: dict = field(default_factory=dict)
    raw_bytes_by_dtype: dict = field(default_factory=dict)  # s8/f32/... ->
    #                               raw payload bytes (compressed-wire audit)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.ops.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan post-SPMD HLO for collective ops; returns per-device effective
    link bytes.  Start/done pairs are counted once (via -start)."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) ([\w\-]+)\(", line)
        if not m:
            continue
        type_str, opname = m.groups()
        base = opname.replace("-start", "")
        if base.endswith("-done") or base not in COLLECTIVE_OPS:
            continue
        size, size_by_dtype = _shape_bytes(type_str)
        g = _group_size(line)
        if base == "collective-permute":
            eff = size
        elif base == "all-gather":
            eff = size * (g - 1) / g
        elif base == "reduce-scatter":
            eff = size * (g - 1)
        elif base == "all-reduce":
            eff = 2 * size * (g - 1) / g
        else:  # all-to-all
            eff = size * (g - 1) / g
        stats.ops[base] = stats.ops.get(base, 0) + 1
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + eff
        stats.raw_bytes_by_op[base] = (stats.raw_bytes_by_op.get(base, 0)
                                       + size)
        for dt, nb in size_by_dtype.items():
            stats.raw_bytes_by_dtype[dt] = (
                stats.raw_bytes_by_dtype.get(dt, 0) + nb)
    return stats


@dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops_per_chip: float
    collectives: CollectiveStats | None = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return (self.model_flops_per_chip / self.flops_per_chip
                if self.flops_per_chip else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term-bound step time that is useful
        compute: (MODEL_FLOPS/peak) / max-term.  1.0 = perfectly
        compute-bound with zero waste."""
        t_star = max(self.t_compute, self.t_memory, self.t_collective)
        if t_star == 0:
            return 0.0
        return (self.model_flops_per_chip / PEAK_FLOPS) / t_star

    def as_dict(self) -> dict:
        d = {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "model_flops_per_chip": self.model_flops_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }
        if self.collectives:
            d["collective_ops"] = self.collectives.ops
            d["collective_bytes_by_op"] = self.collectives.bytes_by_op
            d["collective_bytes_by_dtype"] = \
                self.collectives.raw_bytes_by_dtype
        return d


def model_flops(cfg, tokens_per_chip: float, training: bool) -> float:
    """6·N·D (train) or 2·N·D (inference) with N = active params."""
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    return (6.0 if training else 2.0) * n * tokens_per_chip


def analyze(compiled, cfg, *, tokens_global: float, n_chips: int,
            training: bool) -> Roofline:
    ca = compat.cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return Roofline(
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbm,
        collective_bytes_per_chip=stats.total_bytes,
        model_flops_per_chip=model_flops(cfg, tokens_global / n_chips,
                                         training),
        collectives=stats,
    )
