"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step per chip
(TPU v5e constants):

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
  memory     = HLO_bytes_accessed / HBM_bw       (819 GB/s)
  collective = effective_collective_bytes / link_bw  (~50 GB/s/link ICI)

``cost_analysis()`` provides per-device FLOPs and bytes.  Collective bytes
are NOT in cost_analysis: they come from ``parse_collectives`` in
``repro.analysis.hlo_budget`` — the repo's single HLO collective parser
(effective link bytes per op, async start/done pairs counted once),
re-exported here for callers that import it from the roofline namespace.

MODEL_FLOPS = 6·N·D for training cells (N = total params dense / active
params MoE; D = tokens per chip per step) and 2·N·D for inference cells
(forward only) — the useful-FLOPs yardstick; ratio to HLO FLOPs exposes
remat/redundancy waste.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro import compat
from repro.analysis.hlo_budget import (  # noqa: F401  (re-exports)
    COLLECTIVE_OPS,
    CollectiveStats,
    parse_collectives,
)

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s
LINK_BW = 50e9            # B/s per ICI link


@dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops_per_chip: float
    collectives: CollectiveStats | None = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return (self.model_flops_per_chip / self.flops_per_chip
                if self.flops_per_chip else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term-bound step time that is useful
        compute: (MODEL_FLOPS/peak) / max-term.  1.0 = perfectly
        compute-bound with zero waste."""
        t_star = max(self.t_compute, self.t_memory, self.t_collective)
        if t_star == 0:
            return 0.0
        return (self.model_flops_per_chip / PEAK_FLOPS) / t_star

    def as_dict(self) -> dict:
        d = {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "model_flops_per_chip": self.model_flops_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }
        if self.collectives:
            d["collective_ops"] = self.collectives.ops
            d["collective_bytes_by_op"] = self.collectives.bytes_by_op
            d["collective_bytes_by_dtype"] = \
                self.collectives.raw_bytes_by_dtype
        return d


def model_flops(cfg, tokens_per_chip: float, training: bool) -> float:
    """6·N·D (train) or 2·N·D (inference) with N = active params."""
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    return (6.0 if training else 2.0) * n * tokens_per_chip


def analyze(compiled, cfg, *, tokens_global: float, n_chips: int,
            training: bool) -> Roofline:
    ca = compat.cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return Roofline(
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbm,
        collective_bytes_per_chip=stats.total_bytes,
        model_flops_per_chip=model_flops(cfg, tokens_global / n_chips,
                                         training),
        collectives=stats,
    )
