from .analysis import (CollectiveStats, Roofline, analyze, model_flops,  # noqa: F401
                       parse_collectives)
