"""phi-3.5-MoE 42B (6.6B active)  [hf:microsoft/Phi-3.5-MoE-instruct; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    n_experts=16, experts_per_token=2,
)
