"""xLSTM-125M  [arXiv:2405.04517; unverified]
12L d_model=768 4H d_ff=0 vocab=50304 — alternating sLSTM + mLSTM blocks."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm_xlstm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50304,
    mlstm_chunk=256,
)
