"""grok-1 314B MoE  [hf:xai-org/grok-1; unverified]
64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts top-2."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, experts_per_token=2,
)
