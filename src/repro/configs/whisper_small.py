"""Whisper-small  [arXiv:2212.04356; unverified]
12L enc + 12L dec, d_model=768 12H d_ff=3072 vocab=51865 — conv frontend
stubbed to precomputed frame embeddings (assignment spec)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    head_dim=64, d_ff=3072, vocab_size=51865,
    dec_len=448,
)
