"""Hymba-1.5B  [arXiv:2411.13676; hf]
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16 —
parallel attention + mamba heads; SWA everywhere except 3 global layers
(first / middle / last, Hymba recipe)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, ssm_expand=2,
    sliding_window=1024, global_attn_layers=(0, 15, 31),
)
