"""Architecture configs (assigned pool).  get_config(name) -> ModelConfig."""
import importlib

ARCHS = [
    "grok_1_314b", "phi35_moe_42b", "xlstm_125m", "internlm2_1_8b",
    "qwen3_4b", "qwen15_110b", "qwen3_1_7b", "whisper_small",
    "llama32_vision_90b", "hymba_1_5b",
]

# CLI ids (match the assignment table) -> module names
ALIASES = {
    "grok-1-314b": "grok_1_314b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "xlstm-125m": "xlstm_125m",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-4b": "qwen3_4b",
    "qwen1.5-110b": "qwen15_110b",
    "qwen3-1.7b": "qwen3_1_7b",
    "whisper-small": "whisper_small",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "hymba-1.5b": "hymba_1_5b",
}


def get_config(name: str):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ALIASES}
