"""Version-adaptive JAX compatibility layer — the single import point for
every JAX API whose surface moved between the 0.4.x line and the current
(0.8.x) line.  Policy (see README §Supported JAX versions): repro code
NEVER calls `jax.shard_map` / `jax.set_mesh` / `Compiled.cost_analysis()`
directly; it calls the shims below, which present the NEW-style surface
and adapt down to whatever the installed JAX provides.  When an API moves
again, this module is the only file that changes (tests/test_compat.py
smoke-checks every shim under the installed JAX so drift fails loudly in
one place).

Shims:

  shard_map(...)       new-style signature (`axis_names=`, `check_vma=`);
                       falls back to `jax.experimental.shard_map.shard_map`
                       with `auto=` / `check_rep=` on 0.4.x.
  use_mesh(mesh)       context manager activating `mesh`: `jax.set_mesh`
                       where it exists, else the legacy `with mesh:` entry
                       (which is what makes bare-PartitionSpec
                       `with_sharding_constraint` calls resolvable on
                       0.4.x).
  cost_analysis(c)     always a flat `dict` (0.4.x returns a one-element
                       list of dicts; newer JAX returns the dict itself).
  ppermute(x, ...)     pytree-aware `lax.ppermute` (single call point for
                       the circulant collectives' per-round sends).
  make_mesh(...)       `jax.make_mesh` where present, manual `Mesh`
                       construction otherwise.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax import lax


def _parse_version(v: str) -> tuple[int, ...]:
    parts = []
    for tok in v.split(".")[:3]:
        digits = "".join(ch for ch in tok if ch.isdigit())
        parts.append(int(digits) if digits else 0)
    return tuple(parts)


JAX_VERSION: tuple[int, ...] = _parse_version(jax.__version__)

HAS_NATIVE_SHARD_MAP: bool = hasattr(jax, "shard_map")
HAS_SET_MESH: bool = hasattr(jax, "set_mesh")
HAS_MAKE_MESH: bool = hasattr(jax, "make_mesh")

# 0.4.x accepts partial-manual regions (legacy ``auto=``), but the XLA it
# bundles cannot SPMD-partition collective-permute / all-gather instructions
# created inside a manual subgroup (hard CHECK crash in spmd_partitioner.cc).
# Callers that mix manual-axis ppermute collectives with auto (GSPMD) axes
# must fall back to a fully-manual region when this is False.
SUPPORTS_PARTIAL_MANUAL_COLLECTIVES: bool = HAS_NATIVE_SHARD_MAP


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              axis_names: set | frozenset | None = None,
              check_vma: bool | None = None) -> Callable:
    """New-style ``jax.shard_map`` signature on every supported JAX.

    ``axis_names`` is the set of MANUAL mesh axes (None = all axes manual,
    the common full-manual case).  On 0.4.x this maps to the legacy
    ``auto=`` complement; ``check_vma`` maps to ``check_rep``.  Partial-
    manual regions force replication checking off on 0.4.x (the legacy
    checker does not support auto axes).
    """
    if HAS_NATIVE_SHARD_MAP:
        kw: dict[str, Any] = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)

    from jax.experimental.shard_map import shard_map as _legacy_shard_map
    kw = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kw["auto"] = auto
            kw["check_rep"] = False  # legacy checker can't handle auto axes
    if check_vma is not None:
        kw["check_rep"] = kw.get("check_rep", True) and check_vma
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# Mesh construction / activation
# ---------------------------------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None):
    """``jax.make_mesh`` where available, manual Mesh assembly otherwise."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if HAS_MAKE_MESH:
        if devices is not None:
            return jax.make_mesh(axis_shapes, axis_names, devices=devices)
        return jax.make_mesh(axis_shapes, axis_names)
    from jax.sharding import Mesh
    devs = list(devices) if devices is not None else jax.devices()
    n = int(np.prod(axis_shapes))
    if len(devs) < n:
        raise ValueError(f"mesh {axis_shapes} needs {n} devices, "
                         f"have {len(devs)}")
    return Mesh(np.asarray(devs[:n]).reshape(axis_shapes), axis_names)


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate ``mesh`` for the enclosed region.

    New JAX: ``jax.set_mesh`` (required for explicit-sharding jnp ops and
    bare-spec constraints).  0.4.x: the legacy ``with mesh:`` context,
    which is what lets ``with_sharding_constraint(x, P(...))`` with a bare
    PartitionSpec resolve axis names.
    """
    if HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


# ---------------------------------------------------------------------------
# Compiled-artifact introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """Normalized ``Compiled.cost_analysis()``: always a flat dict.

    JAX <= 0.4.x returns a list with one dict per program (a jitted
    function has exactly one); newer JAX returns the dict directly.
    Returns {} when the backend provides no analysis.
    """
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    if isinstance(ca, (list, tuple)):
        for entry in ca:
            if isinstance(entry, dict):
                return dict(entry)
        return {}
    raise TypeError(f"unrecognized cost_analysis() return: {type(ca)!r}")


# ---------------------------------------------------------------------------
# Collective primitives
# ---------------------------------------------------------------------------

HAS_LAX_AXIS_SIZE: bool = hasattr(lax, "axis_size")


def axis_size(axis_name: str) -> int:
    """Static size of a mapped axis at trace time.

    ``lax.axis_size`` where it exists; on 0.4.x ``lax.psum(1, axis)``
    constant-folds to the Python int the schedule computation needs.
    """
    if HAS_LAX_AXIS_SIZE:
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def ppermute(x, axis_name: str, perm: Sequence[tuple[int, int]]):
    """Pytree-aware ``lax.ppermute`` (safe for compressed payload trees)."""
    return jax.tree.map(
        lambda leaf: lax.ppermute(leaf, axis_name, perm), x)
