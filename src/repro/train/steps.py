"""Train-step builders — three execution modes over one model API.

  zero1      partial-manual shard_map: data axes MANUAL (the paper's
             circulant collectives drive grad reduce-scatter + param
             allgather; optimizer state sharded 1/P), model axis AUTO
             (GSPMD tensor-parallel).  Default for archs whose TP-sharded
             params fit per chip.
  fsdp_auto  pure GSPMD: params/m/v sharded over (data+model) via
             NamedSharding; XLA inserts its own collectives.  For the
             >=90B archs.
  single     plain jit, no mesh — CPU smoke tests and the quickstart.

Every mode returns (step_fn, init_opt_fn, shardings) with the same
signature:  step_fn(params, opt, batch) -> (params, opt, metrics).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.models import ModelApi, ShardingRecipe, make_param_specs
from repro.optim import adamw as adamw_mod
from repro.optim.adamw import (AdamWConfig, AdamState, TreeAdamState,
                               init_state, init_tree_state, update_tree)
from repro.optim.zero1 import (GradSyncConfig, Zero1State, init_zero1_state,
                               zero1_state_specs, zero1_step)


@dataclass
class BuiltStep:
    step_fn: Callable          # (params, opt, batch) -> (params, opt, metrics)
    init_opt: Callable         # (params) -> opt state (matching sharding)
    in_shardings: Any = None   # for dry-run lowering
    batch_spec: Any = None
    param_spec_tree: Any = None
    opt_spec: Any = None


def flat_param_len(params, world: int) -> int:
    """Padded fused-gradient length (static, from leaf shapes)."""
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    return n + ((-n) % world)


def collective_specs(sync: GradSyncConfig, model_cfg=None,
                     ep_world: int | None = None
                     ) -> tuple[tuple[str, Any], ...]:
    """Every :class:`CollectiveSpec` a zero1 step executes, as
    ``(role, spec)`` pairs.

    Role ``"data"``: the grad-sync reduce-scatter/allgather pair — one
    plan per data axis.  Role ``"ep"``: the MoE expert-dispatch
    alltoall(v) pair, present only when ``model_cfg`` uses
    ``moe_dispatch='ep'`` (``ep_world`` is that axis's size).  This is
    the ONE enumeration both the ``build_zero1`` pre-flight and the
    elastic controller's re-plan (``ft.elastic.active_specs``) consume,
    so a spec added to the step cannot silently skip either verifier.
    """
    out: list[tuple[str, Any]] = [("data", sync.rs_spec()),
                                  ("data", sync.ag_spec())]
    if model_cfg is not None and getattr(model_cfg, "is_moe", False) \
            and getattr(model_cfg, "moe_dispatch", "global") == "ep":
        if ep_world is None:
            raise ValueError(
                "moe_dispatch='ep' config needs ep_world to enumerate its "
                "dispatch specs")
        from repro.models.dispatch import ep_collective_specs
        out += [("ep", sp) for sp in ep_collective_specs(model_cfg, ep_world)]
    return tuple(out)


# ---------------------------------------------------------------------------
# single (no mesh)
# ---------------------------------------------------------------------------

def build_single(model: ModelApi, opt_cfg: AdamWConfig) -> BuiltStep:
    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_opt, gnorm = update_tree(opt_cfg, opt, grads, params)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm,
                                     "lr": adamw_mod.lr_at(opt_cfg,
                                                           new_opt.step)}

    return BuiltStep(step_fn=step_fn, init_opt=init_tree_state)


# ---------------------------------------------------------------------------
# zero1 (manual data axes via the paper's collectives)
# ---------------------------------------------------------------------------

def build_zero1(model: ModelApi, mesh: Mesh, recipe: ShardingRecipe,
                opt_cfg: AdamWConfig, sync: GradSyncConfig,
                remat: bool = True) -> BuiltStep:
    # Collective order: fastest axis first (intra-pod before cross-pod) so
    # the full-volume first RS phase stays on fast links (DESIGN §2).
    collective_axes = tuple(reversed(recipe.data_axes))
    world = int(np.prod([mesh.shape[a] for a in recipe.data_axes]))

    # Compile the grad-sync CollectivePlans up front: a bad sync config
    # (unknown schedule, wire×op conflict, ...) fails HERE with a config
    # error instead of mid-trace, and the per-axis plans are warm in the
    # cache before the first step traces.  Each plan then goes through
    # the static verifier (Theorem 1 partition, deadlock-freedom, row
    # tables) — the same pre-flight an elastic re-plan at a new world
    # size would run before trusting the fresh geometry.
    from repro.analysis.verify import assert_verified
    from repro.core.plan import plan as _plan
    for ax in collective_axes:
        for role, sp in collective_specs(sync):
            assert_verified(_plan(sp, p=mesh.shape[ax], axis_name=ax))

    # Bucketed sync: compute the static bucket partition from the model's
    # abstract param shapes NOW (jax.eval_shape — no allocation) so a bad
    # bucket_bytes / partition fails at build time, not mid-trace, and
    # assert every bucket's segments are well-formed.  The RS/AG plans
    # verified above are the ones each bucket executes (plan geometry is
    # shape-independent, so one cached plan serves every bucket).
    if sync.bucket_bytes is not None:
        from repro.optim.zero1 import is_zero_leaf, plan_grad_buckets
        abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        zshapes = [l.shape for l in jax.tree.leaves(abs_params)
                   if is_zero_leaf(l.shape, world, sync.min_shard_numel)]
        buckets = plan_grad_buckets(zshapes, world, sync.bucket_bytes,
                                    jnp.dtype(sync.rs_dtype).itemsize)
        covered = {}
        for b in buckets:
            if not b:
                raise ValueError("bucket partitioner produced empty bucket")
            for (li, lo, hi) in b:
                if not 0 <= lo < hi:
                    raise ValueError(f"bad segment ({li}, {lo}, {hi})")
                covered[li] = covered.get(li, 0) + (hi - lo)
        for li, shape in enumerate(zshapes):
            rows = (shape[0] + (-shape[0]) % world) // world
            if covered.get(li, 0) != rows:
                raise ValueError(
                    f"bucket partition covers {covered.get(li, 0)}/{rows} "
                    f"shard rows of leaf {li} {shape}")

    # Expert-parallel MoE dispatch exchanges over cfg.ep_axis INSIDE the
    # step, so that axis must be manual too — and its alltoall(v) plans
    # can fail fast / pre-warm here, like the grad-sync plans above.
    ep = (model.cfg.is_moe
          and getattr(model.cfg, "moe_dispatch", "global") == "ep")
    if ep:
        ep_axis = model.cfg.ep_axis
        if ep_axis not in mesh.shape:
            raise ValueError(
                f"moe_dispatch='ep' exchanges over mesh axis {ep_axis!r}, "
                f"which is not in mesh {dict(mesh.shape)}")
        for role, sp in collective_specs(sync, model.cfg,
                                         mesh.shape[ep_axis]):
            if role == "ep":
                assert_verified(_plan(sp, p=mesh.shape[ep_axis],
                                      axis_name=ep_axis))

    # Inside the manual region the data axes are already per-shard: the
    # inner model must only constrain over the AUTO (model) axis.  On JAX
    # builds whose XLA cannot partition ppermutes inside a manual subgroup
    # (0.4.x — see compat.SUPPORTS_PARTIAL_MANUAL_COLLECTIVES) the whole
    # step instead runs manual over EVERY mesh axis: model-axis ranks hold
    # full replicas (TP constraints dropped), while the data-axis circulant
    # collectives — the part under test — are unchanged.  ep dispatch
    # likewise needs its exchange axis manual, so it always takes the
    # fully-manual route (expert weights replicated per rank; each rank
    # slices its own experts inside the region).
    from dataclasses import replace as _dc_replace
    from repro.models import build as _build_model
    if compat.SUPPORTS_PARTIAL_MANUAL_COLLECTIVES and not ep:
        inner_recipe = _dc_replace(recipe, data_axes=())
        manual_axes = set(recipe.data_axes)
    else:
        inner_recipe = None
        manual_axes = None  # full manual
    inner_model = _build_model(model.cfg, recipe=inner_recipe, remat=remat)

    def inner(params, opt, batch):
        return zero1_step(
            jax.value_and_grad(inner_model.loss), params, opt, batch,
            axis_names=collective_axes, opt_cfg=opt_cfg, sync=sync)

    # Manual-axis specs: params replicated over data axes (model axis is
    # auto — rides on the arrays' NamedShardings); batch sharded over data;
    # opt m/v PER-LEAF sharded over dim 0 (zero leaves) or replicated
    # (tiny leaves / the no-ZeRO allreduce baseline).  With compressed
    # gradient sync (sync.wire == 'int8') + error feedback, the opt state
    # additionally carries per-rank EF residuals (Zero1State.ef) whose
    # leading axis is sharded one-row-per-rank over the data axes —
    # zero1_state_specs emits the matching specs, so the shard_map
    # in/out_specs below pick them up with no special-casing here.
    pspec = P()
    batch_spec = P(recipe.data_axes)

    def batch_specs_for(batch):
        return jax.tree.map(lambda _: batch_spec, batch)

    def opt_specs_for(params):
        return zero1_state_specs(params, world, sync, collective_axes)

    # NB: must run under jit — JAX 0.8.2's EAGER shard_map dispatch with
    # check_vma=False + partial-auto axes trips an internal _unmatch spec
    # check (it builds P(all mesh axes) but validates against manual-only).
    # check_vma=False is also what lets sync.use_fused_kernel route the
    # collectives through pallas_call (no replication rule on 0.4.x).
    @jax.jit
    def step_fn(params, opt, batch):
        ospecs = opt_specs_for(params)
        f = compat.shard_map(
            inner, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: pspec, params), ospecs,
                      batch_specs_for(batch)),
            out_specs=(jax.tree.map(lambda _: pspec, params), ospecs,
                       {"loss": P(), "grad_norm": P(), "lr": P()}),
            axis_names=manual_axes,
            check_vma=False)
        return f(params, opt, batch)

    def init_opt(params):
        return init_zero1_state(params, world, sync)

    def opt_sharding(params):
        ospecs = opt_specs_for(params)
        return jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                            is_leaf=lambda x: isinstance(x, P))

    return BuiltStep(
        step_fn=step_fn, init_opt=init_opt,
        batch_spec=batch_spec,
        opt_spec=opt_sharding,
    )


# ---------------------------------------------------------------------------
# fsdp_auto (pure GSPMD)
# ---------------------------------------------------------------------------

def build_fsdp_auto(model: ModelApi, mesh: Mesh, recipe: ShardingRecipe,
                    opt_cfg: AdamWConfig) -> BuiltStep:
    batch_spec = P(recipe.data_axes)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_opt, gnorm = update_tree(opt_cfg, opt, grads, params)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm,
                                     "lr": adamw_mod.lr_at(opt_cfg,
                                                           new_opt.step)}

    return BuiltStep(step_fn=step_fn, init_opt=init_tree_state,
                     batch_spec=batch_spec)


def build(mode: str, model: ModelApi, opt_cfg: AdamWConfig,
          mesh: Mesh | None = None, recipe: ShardingRecipe | None = None,
          sync: GradSyncConfig | None = None, remat: bool = True) -> BuiltStep:
    if mode == "single":
        return build_single(model, opt_cfg)
    if mode == "zero1":
        return build_zero1(model, mesh, recipe, opt_cfg,
                           sync or GradSyncConfig(), remat=remat)
    if mode == "fsdp_auto":
        return build_fsdp_auto(model, mesh, recipe, opt_cfg)
    raise ValueError(f"unknown mode {mode}")
