from .steps import BuiltStep, build, flat_param_len  # noqa: F401
