from .manager import CheckpointManager, config_fingerprint, reshard_flat  # noqa: F401
