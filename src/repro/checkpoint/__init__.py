from .manager import (CheckpointError, CheckpointManager,  # noqa: F401
                      config_fingerprint, reshard_flat)
