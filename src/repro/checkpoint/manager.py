"""Sharded checkpointing: atomic, async, elastic.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json
  manifest: step, config name/hash, mesh shape, data cursor, flat-param
            length (for elastic re-shard validation).

* Atomic: written to step_<N>.tmp then os.rename'd — a crash never leaves
  a half-checkpoint that restore() would pick up.  Stale ``step_<N>.tmp``
  directories (and final dirs missing their manifest) left by a crash
  are swept at startup so retention pruning never trips over them.
* Async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes on a background thread, double-buffered — the step loop never
  blocks on disk.  A background write failure is surfaced as a
  :class:`CheckpointError` on the NEXT ``save``/``save_async``/``wait``
  call (never swallowed).
* Elastic: optimizer m/v are stored as FULL flat vectors (gathered from
  shards); ``restore`` re-shards to ANY data-parallel world size — scaling
  from e.g. 4 hosts to 2 or 8 between runs changes nothing but slicing.
  ``restore(None, ...)`` falls back to the previous completed checkpoint
  when the newest one is truncated/corrupt (an explicit ``step`` never
  falls back — the caller asked for that exact checkpoint).
* Retention: keep_last completed checkpoints (older ones pruned).
* Fault injection: an optional ``io_hook(step)`` runs before every
  write/read — ``ft.FailurePlan.io_hook`` raises transient
  ``CheckpointIOError``\\ s through it, which the elastic controller's
  bounded retry/backoff must absorb.

On multi-host deployments each host would write its own process-local
shard files; the manifest/atomic-rename/cursor discipline is identical.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import warnings
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A (possibly background) checkpoint write failed; carries the step
    whose save failed as ``.step``.  Chained from the original error."""

    def __init__(self, step: int, cause: BaseException):
        super().__init__(f"checkpoint save of step {step} failed: {cause!r}")
        self.step = step


def _tree_to_flat_dict(tree, prefix="p"):
    leaves, treedef = jax.tree.flatten(tree)
    return ({f"{prefix}_{i}": np.asarray(l) for i, l in enumerate(leaves)},
            treedef)


def config_fingerprint(cfg) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


@dataclass
class Snapshot:
    step: int
    arrays: dict[str, np.ndarray]
    manifest: dict[str, Any]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 io_hook: Callable[[int], None] | None = None):
        self.dir = directory
        self.keep_last = keep_last
        self.io_hook = io_hook
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self._sweep_stale()

    def _sweep_stale(self) -> list[str]:
        """Remove crash leftovers: ``step_<N>.tmp`` dirs (a write died
        before the atomic rename) and final dirs missing their manifest
        (should be impossible under the rename discipline, but a partial
        copy restored from external storage can produce one).  Returns
        the swept names (for logging/tests)."""
        swept = []
        for name in sorted(os.listdir(self.dir)):
            path = os.path.join(self.dir, name)
            if not (name.startswith("step_") and os.path.isdir(path)):
                continue
            stale = name.endswith(".tmp") or not os.path.exists(
                os.path.join(path, "manifest.json"))
            if stale:
                shutil.rmtree(path, ignore_errors=True)
                swept.append(name)
        return swept

    # -- save ---------------------------------------------------------------

    def _snapshot(self, step, params, opt_flat: dict, extra: dict) -> Snapshot:
        arrays, treedef = _tree_to_flat_dict(params)
        for k, v in opt_flat.items():
            arrays[f"opt_{k}"] = np.asarray(v)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_param_leaves": sum(1 for k in arrays if k.startswith("p_")),
            **extra,
        }
        return Snapshot(int(step), arrays, manifest)

    def _write(self, snap: Snapshot):
        if self.io_hook is not None:
            self.io_hook(snap.step)
        final = os.path.join(self.dir, f"step_{snap.step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **snap.arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(snap.manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()

    def _prune(self):
        steps = self.completed_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def save(self, step, params, opt_flat: dict, extra: dict | None = None):
        self.wait()  # surface a pending async failure before writing more
        try:
            self._write(self._snapshot(step, params, opt_flat, extra or {}))
        except CheckpointError:
            raise
        except BaseException as e:
            raise CheckpointError(int(step), e) from e

    def save_async(self, step, params, opt_flat: dict,
                   extra: dict | None = None):
        """Snapshot now (device->host copy), write in background.

        Surfaces the PREVIOUS background write's failure (if any) as a
        :class:`CheckpointError` before starting the new write."""
        self.wait()  # double-buffer: at most one outstanding write
        snap = self._snapshot(step, params, opt_flat, extra or {})

        def run():
            try:
                self._write(snap)
            except BaseException as e:  # surfaced on next save*/wait call
                self._error = CheckpointError(snap.step, e)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore ------------------------------------------------------------

    def completed_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.completed_steps()
        return steps[-1] if steps else None

    def _read(self, step: int):
        """Raw (manifest, npz) of one checkpoint dir; raises on any
        corruption (truncated manifest, bad zip, missing keys)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        data.files  # force the zip directory read — surfaces truncation
        return manifest, data

    def restore(self, step: int | None, params_template):
        """Returns (step, params, opt_arrays dict, manifest).

        ``step=None`` restores the newest checkpoint, falling back to
        the previous completed one if the newest is truncated/corrupt
        (each skip warns).  An explicit ``step`` never falls back.
        Template-shape mismatches are caller errors and always raise.
        """
        if step is None:
            candidates = list(reversed(self.completed_steps()))
            if not candidates:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        else:
            candidates = [step]
        manifest = data = None
        errors = []
        for i, s in enumerate(candidates):
            # The io_hook runs OUTSIDE the corruption fallback: a hook
            # failure models a TRANSIENT IO fault (retryable — the
            # elastic controller's backoff owns it), not a corrupt
            # checkpoint, so it must propagate instead of silently
            # falling back to an older step.
            if self.io_hook is not None:
                self.io_hook(s)
            try:
                manifest, data = self._read(s)
                step = s
                break
            except Exception as e:
                errors.append((s, e))
                if i + 1 < len(candidates):
                    warnings.warn(
                        f"checkpoint step_{s} is unreadable ({e!r}); "
                        f"falling back to step_{candidates[i + 1]}",
                        RuntimeWarning, stacklevel=2)
        if data is None:
            raise CheckpointError(candidates[-1], errors[-1][1]) \
                from errors[-1][1]
        leaves, treedef = jax.tree.flatten(params_template)
        if len(leaves) != manifest["n_param_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_param_leaves']} param leaves, "
                f"template has {len(leaves)} — config mismatch?")
        new_leaves = []
        for i, tmpl in enumerate(leaves):
            arr = data[f"p_{i}"]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != template "
                                 f"{tmpl.shape}")
            new_leaves.append(arr.astype(tmpl.dtype))
        params = jax.tree.unflatten(treedef, new_leaves)
        opt = {k[len("opt_"):]: data[k] for k in data.files
               if k.startswith("opt_")}
        return step, params, opt, manifest


def reshard_flat(full: np.ndarray, world: int, rank: int) -> np.ndarray:
    """Elastic slice of a stored full flat vector for a new DP world size."""
    n = full.shape[0]
    pad = (-n) % world
    if pad:
        full = np.concatenate([full, np.zeros(pad, full.dtype)])
    shard = full.shape[0] // world
    return full[rank * shard:(rank + 1) * shard]
