"""Sharded checkpointing: atomic, async, elastic.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json
  manifest: step, config name/hash, mesh shape, data cursor, flat-param
            length (for elastic re-shard validation).

* Atomic: written to step_<N>.tmp then os.rename'd — a crash never leaves
  a half-checkpoint that restore() would pick up.
* Async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes on a background thread, double-buffered — the step loop never
  blocks on disk.
* Elastic: optimizer m/v are stored as FULL flat vectors (gathered from
  shards); ``restore`` re-shards to ANY data-parallel world size — scaling
  from e.g. 4 hosts to 2 or 8 between runs changes nothing but slicing.
* Retention: keep_last completed checkpoints (older ones pruned).

On multi-host deployments each host would write its own process-local
shard files; the manifest/atomic-rename/cursor discipline is identical.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from dataclasses import dataclass
from typing import Any, Callable

import jax
import numpy as np


def _tree_to_flat_dict(tree, prefix="p"):
    leaves, treedef = jax.tree.flatten(tree)
    return ({f"{prefix}_{i}": np.asarray(l) for i, l in enumerate(leaves)},
            treedef)


def config_fingerprint(cfg) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


@dataclass
class Snapshot:
    step: int
    arrays: dict[str, np.ndarray]
    manifest: dict[str, Any]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ---------------------------------------------------------------

    def _snapshot(self, step, params, opt_flat: dict, extra: dict) -> Snapshot:
        arrays, treedef = _tree_to_flat_dict(params)
        for k, v in opt_flat.items():
            arrays[f"opt_{k}"] = np.asarray(v)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "n_param_leaves": sum(1 for k in arrays if k.startswith("p_")),
            **extra,
        }
        return Snapshot(int(step), arrays, manifest)

    def _write(self, snap: Snapshot):
        final = os.path.join(self.dir, f"step_{snap.step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **snap.arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(snap.manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()

    def _prune(self):
        steps = self.completed_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def save(self, step, params, opt_flat: dict, extra: dict | None = None):
        self._write(self._snapshot(step, params, opt_flat, extra or {}))

    def save_async(self, step, params, opt_flat: dict,
                   extra: dict | None = None):
        """Snapshot now (device->host copy), write in background."""
        self.wait()  # double-buffer: at most one outstanding write
        snap = self._snapshot(step, params, opt_flat, extra or {})

        def run():
            try:
                self._write(snap)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore ------------------------------------------------------------

    def completed_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.completed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None, params_template):
        """Returns (step, params, opt_arrays dict, manifest)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves, treedef = jax.tree.flatten(params_template)
        if len(leaves) != manifest["n_param_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_param_leaves']} param leaves, "
                f"template has {len(leaves)} — config mismatch?")
        new_leaves = []
        for i, tmpl in enumerate(leaves):
            arr = data[f"p_{i}"]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != template "
                                 f"{tmpl.shape}")
            new_leaves.append(arr.astype(tmpl.dtype))
        params = jax.tree.unflatten(treedef, new_leaves)
        opt = {k[len("opt_"):]: data[k] for k in data.files
               if k.startswith("opt_")}
        return step, params, opt, manifest


def reshard_flat(full: np.ndarray, world: int, rank: int) -> np.ndarray:
    """Elastic slice of a stored full flat vector for a new DP world size."""
    n = full.shape[0]
    pad = (-n) % world
    if pad:
        full = np.concatenate([full, np.zeros(pad, full.dtype)])
    shard = full.shape[0] // world
    return full[rank * shard:(rank + 1) * shard]
