from .pipeline import DataConfig, SyntheticPipeline, for_model  # noqa: F401
