"""Deterministic, seekable synthetic token pipeline.

Every batch is a pure function of (seed, step) — ``batch_at(step)`` —
so restart/elastic-reshard resume is exact by construction: the
checkpoint stores only the step cursor.  Host sharding: each host
materializes only its slice of the global batch (here: single host
materializes all; the slicing API is what a multi-host launcher calls).

The stream is Zipf-distributed tokens with a shifted-window structure so
the LM task is learnable (loss decreases) — used by the quickstart
example and the convergence test.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality extras (stubs per assignment)
    frames_dim: int = 0       # encdec: frame-embedding dim (d_model)
    frames_len: int = 0
    image_tokens: int = 0     # vlm: number of patch embeddings
    image_dim: int = 0
    dec_len: int = 0          # encdec: decoder text length


class SyntheticPipeline:
    """batch_at(step) -> dict of numpy arrays (tokens/targets [+frames/
    image_embeds]).  Learnable structure: t_{i+1} = (a * t_i + b) % V with
    per-sequence (a, b) drawn from a small set, plus noise."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))

    def batch_at(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        cfg = self.cfg
        if cfg.global_batch % n_hosts:
            raise ValueError(f"batch {cfg.global_batch} % hosts {n_hosts}")
        rng = self._rng(step)
        b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        # Per-sequence affine recurrences over a reduced alphabet.
        alpha = max(2, min(v, 257))
        a = rng.choice([1, 2, 3, 5], size=(b, 1))
        c = rng.integers(1, alpha, size=(b, 1))
        t0 = rng.integers(0, alpha, size=(b, 1))
        seq = np.empty((b, s + 1), np.int64)
        seq[:, 0] = t0[:, 0]
        for i in range(s):
            seq[:, i + 1] = (a[:, 0] * seq[:, i] + c[:, 0]) % alpha
        noise = rng.random((b, s + 1)) < 0.05
        seq = np.where(noise, rng.integers(0, alpha, (b, s + 1)), seq)
        tokens = seq[:, :-1].astype(np.int32)
        targets = seq[:, 1:].astype(np.int32)
        lo = host_id * (b // n_hosts)
        hi = lo + b // n_hosts
        out = {"tokens": tokens[lo:hi], "targets": targets[lo:hi]}
        if cfg.frames_dim:
            out["frames"] = rng.standard_normal(
                (hi - lo, cfg.frames_len, cfg.frames_dim)).astype(np.float32)
        if cfg.image_tokens:
            out["image_embeds"] = rng.standard_normal(
                (hi - lo, cfg.image_tokens, cfg.image_dim)).astype(np.float32)
        return out

    def batches(self, start_step: int = 0, host_id: int = 0, n_hosts: int = 1):
        step = start_step
        while True:
            yield step, self.batch_at(step, host_id, n_hosts)
            step += 1


def for_model(model_cfg, seq_len: int, global_batch: int,
              seed: int = 0) -> SyntheticPipeline:
    """Pipeline wired to a ModelConfig's modality extras."""
    kw = dict(vocab_size=model_cfg.vocab_size, seq_len=seq_len,
              global_batch=global_batch, seed=seed)
    if model_cfg.family == "encdec":
        kw.update(frames_dim=model_cfg.d_model, frames_len=seq_len,
                  seq_len=min(model_cfg.dec_len, seq_len),
                  dec_len=min(model_cfg.dec_len, seq_len))
    if model_cfg.family == "vlm":
        kw.update(image_tokens=model_cfg.n_image_tokens,
                  image_dim=model_cfg.d_model)
    return SyntheticPipeline(DataConfig(**kw))
