"""Finding/report plumbing shared by the four analysis passes.

A :class:`Finding` is one violated invariant, carrying enough structure
for both the human rendering (``--all`` console output) and the
machine-readable JSON report the CI ``analysis`` gate consumes.  This
module is dependency-light on purpose: it must import before (and
without) jax so ``python -m repro.analysis`` can set ``XLA_FLAGS``
ahead of the first jax import.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class Finding:
    """One violated invariant.

    ``pass_name`` is the emitting pass (verify / jaxpr / hlo / repo);
    ``rule`` a stable kebab-case identifier (what ratchet entries key
    on); ``where`` the subject (a ``file:line`` or a ``spec@p`` label);
    ``message`` the human explanation.
    """

    pass_name: str
    rule: str
    where: str
    message: str

    @property
    def key(self) -> str:
        """Ratchet key: location x rule, stable across reruns."""
        return f"{self.where}::{self.rule}"

    def render(self) -> str:
        return f"[{self.pass_name}/{self.rule}] {self.where}: {self.message}"


@dataclass
class Report:
    """Aggregated findings of one ``repro.analysis`` run."""

    findings: list[Finding] = field(default_factory=list)
    passes_run: list[str] = field(default_factory=list)
    waived: list[Finding] = field(default_factory=list)  # ratchet-exempted

    def extend(self, pass_name: str, findings: list[Finding]) -> None:
        self.passes_run.append(pass_name)
        self.findings.extend(findings)

    @property
    def ok(self) -> bool:
        return not self.findings

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.pass_name] = out.get(f.pass_name, 0) + 1
        return out

    def as_dict(self) -> dict:
        return {
            "ok": self.ok,
            "passes_run": self.passes_run,
            "n_findings": len(self.findings),
            "findings_by_pass": self.counts(),
            "findings": [asdict(f) for f in self.findings],
            "waived": [asdict(f) for f in self.waived],
        }

    def as_json(self) -> str:
        return json.dumps(self.as_dict(), indent=2)
