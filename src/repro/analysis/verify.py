"""Static plan verifier: prove a built :class:`CollectivePlan` correct
without executing a collective.

The paper's correctness claims are trace-time properties of the
schedule, so they are all checkable on the plan object alone, at any p,
with no devices:

* **Theorem 1** — the per-round send windows partition ``{1..p-1}``
  (every block leaves each rank exactly once) and the round count is
  ``len(get_skips(p, schedule))`` == ``ceil(log2 p)`` for the optimal
  schedules (2x for allreduce: RS + the reversed AG stack).
* **Deadlock-freedom** — every round's sends/recvs form one circulant
  permutation of the axis: each rank sends exactly once and receives
  exactly once, matched pairs, no self-sends at p > 1 (``0 < skip <
  p``), and receives land only in still-live blocks (fold-liveness).
* **Corollary 3** — the non-uniform row tables are well-formed: a
  symbolic delivery simulation shows every rank's contribution to every
  destination row is folded exactly once, and each table's wire width
  equals the analytic worst-windowed-count-sum bound from
  ``cost_model.nonuniform_round_widths``.
* **Alltoall(v)** — the A2A round tables route every (src, dst) entry
  to its destination exactly once along the Bruck hop trajectories,
  with wire widths equal to ``cost_model.alltoallv_round_widths``.
* **Broadcast** (Träff, arXiv:2407.18004) — a block-level replay of the
  AG rounds shows every rank receives every block exactly once (no
  double delivery even at non-power-of-two p, where binomial trees
  fail) and ends holding all p blocks, in the schedule's round count.

All checks run against the plan's OWN fields (not regenerated ones), so
a corrupted plan — dropped skip, swapped table rows, inflated width,
duplicated send — is flagged (mutation-killed in tests/test_analysis.py).
This is the cheap pre-flight ``plan()`` consumers (steps pre-compile,
elastic re-planning) call before trusting a fresh plan.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import (alltoallv_round_widths,
                                   nonuniform_round_widths)
from repro.core.plan import _BASELINE_KINDS, CollectivePlan, plan
from repro.core.schedule import ceil_log2, get_skips, is_valid_schedule
from repro.core.spec import CollectiveSpec

from .report import Finding

OPTIMAL_SCHEDULES = ("halving", "power2")   # exactly ceil(log2 p) rounds


def _finding(rule: str, where: str, message: str) -> Finding:
    return Finding(pass_name="verify", rule=rule, where=where,
                   message=message)


# ---------------------------------------------------------------------------
# Circulant structure: skips, rounds, permutations
# ---------------------------------------------------------------------------

def _check_rounds(pl: CollectivePlan, where: str) -> list[Finding]:
    p, spec = pl.p, pl.spec
    out: list[Finding] = []
    if not (len(pl.skips) == len(pl.rs_rounds) == len(pl.rs_send_blocks)
            == len(pl.rs_recv_blocks)):
        out.append(_finding(
            "round-structure", where,
            f"inconsistent round counts: {len(pl.skips)} skips, "
            f"{len(pl.rs_rounds)} rounds, {len(pl.rs_send_blocks)} send "
            f"windows, {len(pl.rs_recv_blocks)} recv windows"))
        return out  # downstream checks index by round
    if tuple(rp.skip for rp in pl.rs_rounds) != pl.skips:
        out.append(_finding(
            "round-structure", where,
            f"skips {pl.skips} disagree with round plans "
            f"{tuple(rp.skip for rp in pl.rs_rounds)}"))
    if pl.ag_rounds != tuple(reversed(pl.rs_rounds)):
        out.append(_finding(
            "ag-mirror", where,
            "allgather rounds are not the reversed reduce-scatter stack "
            "(Theorem 2 needs the AG phase to replay RS backwards)"))
    # Deadlock-freedom: each round is one circulant permutation —
    # rank i sends to (i + s) mod p, a bijection with no fixed point
    # whenever 0 < s < p.
    for k, s in enumerate(pl.skips):
        if not (0 < s < p):
            out.append(_finding(
                "self-send", where,
                f"round {k}: skip {s} outside (0, {p}) — rank i would "
                f"send to itself (deadlock/no-op at p>1)"))
            continue
        pairs = {(i, (i + s) % p) for i in range(p)}
        senders = {a for a, _ in pairs}
        receivers = {b for _, b in pairs}
        if senders != set(range(p)) or receivers != set(range(p)):
            out.append(_finding(
                "round-permutation", where,
                f"round {k}: skip {s} does not induce a permutation"))
    # Schedule validity: distinct decreasing skips ending in 1, every
    # 0 < i < p a sum of distinct skips, fold-liveness s_{k-1} <= 2 s_k.
    if p > 1 and not is_valid_schedule(p, pl.skips):
        out.append(_finding(
            "schedule-invalid", where,
            f"skips {pl.skips} violate the Corollary 2 preconditions "
            f"(distinct decreasing, last=1, subset-sum reach, "
            f"fold-liveness) at p={p}"))
    # Round optimality: the plan must carry exactly the schedule's
    # rounds; for the optimal schedules that is ceil(log2 p) (Theorem 1),
    # and allreduce = RS + reversed AG = 2 ceil(log2 p) (Theorem 2).
    want = len(get_skips(p, spec.schedule, group=spec.group))
    if len(pl.skips) != want:
        out.append(_finding(
            "round-count", where,
            f"{len(pl.skips)} RS rounds, schedule {spec.schedule!r} "
            f"defines {want}"))
    if spec.schedule in OPTIMAL_SCHEDULES and p > 1:
        q = ceil_log2(p)
        if len(pl.rs_rounds) != q:
            out.append(_finding(
                "round-count", where,
                f"{len(pl.rs_rounds)} RS rounds != ceil(log2 {p}) = {q}"))
        if len(pl.rs_rounds) + len(pl.ag_rounds) != 2 * q:
            out.append(_finding(
                "round-count", where,
                f"allreduce rounds {len(pl.rs_rounds)}+{len(pl.ag_rounds)}"
                f" != 2*ceil(log2 {p}) = {2 * q}"))
    return out


def _check_partition(pl: CollectivePlan, where: str) -> list[Finding]:
    """Theorem 1: the RS send windows partition {1..p-1} exactly."""
    p = pl.p
    out: list[Finding] = []
    seen: set[int] = set()
    for k, win in enumerate(pl.rs_send_blocks):
        wset = set(win)
        if len(wset) != len(win):
            out.append(_finding(
                "duplicate-send", where,
                f"round {k}: send window {win} repeats a block"))
        dup = seen & wset
        if dup:
            out.append(_finding(
                "duplicate-send", where,
                f"round {k}: blocks {sorted(dup)} already sent in an "
                f"earlier round (each block must leave a rank once)"))
        seen |= wset
    if p > 1 and seen != set(range(1, p)):
        missing = sorted(set(range(1, p)) - seen)
        extra = sorted(seen - set(range(1, p)))
        out.append(_finding(
            "theorem1-partition", where,
            f"send windows do not partition {{1..{p - 1}}}: "
            f"missing {missing}, out-of-range {extra}"))
    for k, (rp, win, recv) in enumerate(zip(pl.rs_rounds, pl.rs_send_blocks,
                                            pl.rs_recv_blocks)):
        if tuple(win) != tuple(range(rp.lo, rp.hi)):
            out.append(_finding(
                "window-mismatch", where,
                f"round {k}: send window {win} != contiguous "
                f"[{rp.lo}, {rp.hi})"))
        if tuple(recv) != tuple(range(0, len(tuple(win)))):
            out.append(_finding(
                "window-mismatch", where,
                f"round {k}: recv window {recv} must be "
                f"[0, {len(tuple(win))})"))
    return out


def _check_delivery(pl: CollectivePlan, where: str) -> list[Finding]:
    """Symbolic fold replay of the RS rounds (rank-rotated offsets).

    ``shape[j]`` = set of source offsets folded into rotated block j;
    a duplicate fold or a fold into an already-sent block is flagged,
    and at the end block 0 must hold every source exactly once.
    """
    p = pl.p
    if p == 1 or len(pl.skips) != len(pl.rs_send_blocks):
        return []
    out: list[Finding] = []
    shape: list[set[int]] = [{0} for _ in range(p)]
    dead: set[int] = set()
    for k, (s, win) in enumerate(zip(pl.skips, pl.rs_send_blocks)):
        if not (0 < s < p):
            return out  # already flagged by round-permutation
        for j in win:
            if not (0 <= j < p):
                out.append(_finding(
                    "window-mismatch", where,
                    f"round {k}: send block {j} out of range [0, {p})"))
                continue
            if j in dead:
                out.append(_finding(
                    "duplicate-send", where,
                    f"round {k}: block {j} re-sent after leaving the "
                    f"live buffer (its partial sum is stale)"))
                continue
            tgt = j - s
            if tgt < 0:
                out.append(_finding(
                    "fold-target", where,
                    f"round {k}: block {j} with skip {s} folds into "
                    f"negative offset {tgt}"))
                continue
            if tgt in dead or tgt in win:
                out.append(_finding(
                    "fold-liveness", where,
                    f"round {k}: block {j} folds into {tgt}, which is "
                    f"dead or leaving this round (contribution lost)"))
                continue
            inc = {(o - s) % p for o in shape[j]}
            dup = shape[tgt] & inc
            if dup:
                out.append(_finding(
                    "duplicate-contribution", where,
                    f"round {k}: sources {sorted(dup)} folded into "
                    f"block {tgt} twice"))
            shape[tgt] |= inc
        dead |= {j for j in win if 0 <= j < p}
    if shape[0] != set(range(p)):
        missing = sorted(set(range(p)) - shape[0])
        out.append(_finding(
            "incomplete-reduction", where,
            f"final block holds {len(shape[0])}/{p} contributions; "
            f"missing source offsets {missing}"))
    return out


def _check_broadcast(pl: CollectivePlan, where: str) -> list[Finding]:
    """Block-level replay of the AG rounds in absolute coordinates.

    ``have[r]`` = absolute blocks held by rank r (initially its own).
    Round k with skip s ships rank r's rotated prefix to (r - s) mod p;
    rotated index i on rank r is absolute block (r + i) mod p.  Every
    send must be held, every delivery must be NEW (the broadcast paper's
    exactly-once invariant), and all ranks must end with all p blocks.
    """
    p = pl.p
    out: list[Finding] = []
    if len(pl.ag_rounds) != len(pl.ag_send_blocks) or \
            len(pl.ag_rounds) != len(pl.ag_recv_blocks):
        out.append(_finding(
            "round-structure", where,
            f"inconsistent ag structure: {len(pl.ag_rounds)} rounds, "
            f"{len(pl.ag_send_blocks)} send windows, "
            f"{len(pl.ag_recv_blocks)} recv windows"))
        return out
    have = [{r} for r in range(p)]
    for k, (rp, win, recv) in enumerate(zip(pl.ag_rounds, pl.ag_send_blocks,
                                            pl.ag_recv_blocks)):
        s = rp.skip
        if not (0 < s < p):
            return out  # already flagged by self-send
        if tuple(recv) != tuple(i + s for i in win):
            out.append(_finding(
                "window-mismatch", where,
                f"ag round {k}: recv window {recv} is not the send "
                f"window shifted by skip {s}"))
        moved = []
        for r in range(p):
            blocks = {(r + i) % p for i in win}
            miss = blocks - have[r]
            if miss:
                out.append(_finding(
                    "send-before-receive", where,
                    f"ag round {k}: rank {r} sends blocks {sorted(miss)} "
                    f"it does not hold yet"))
            moved.append((r, (r - s) % p, blocks))
        for src, dst, blocks in moved:
            dup = blocks & have[dst]
            if dup:
                out.append(_finding(
                    "duplicate-delivery", where,
                    f"ag round {k}: rank {dst} receives blocks "
                    f"{sorted(dup)} it already holds (every rank must "
                    f"receive every block exactly once)"))
            have[dst] |= blocks
    full = set(range(p))
    for r in range(p):
        if have[r] != full:
            miss = sorted(full - have[r])
            out.append(_finding(
                "incomplete-broadcast", where,
                f"rank {r} ends holding {len(have[r])}/{p} blocks; "
                f"missing {miss[:8]}"))
    return out


# ---------------------------------------------------------------------------
# Non-uniform (Corollary 3) row tables
# ---------------------------------------------------------------------------

def _table_rows(tab: np.ndarray, r: int, sentinel: int,
                where: str, k: int, out: list[Finding]) -> list[int]:
    rows = []
    for v in tab[r].tolist():
        if v == sentinel:
            continue
        if not (0 <= v < sentinel):
            out.append(_finding(
                "table-range", where,
                f"round {k}: table row {r} holds {v}, outside "
                f"[0, {sentinel}]"))
            continue
        rows.append(v)
    return rows


def _check_nonuniform(pl: CollectivePlan, where: str) -> list[Finding]:
    layout, p = pl.layout, pl.p
    out: list[Finding] = []
    counts, offs, N = layout.counts, layout.offsets, layout.total
    spec = pl.spec

    for phase, tables in (("rs", pl.rs_row_tables),
                          ("ag", pl.ag_row_tables)):
        if tables is None:
            out.append(_finding(
                "table-missing", where,
                f"non-uniform plan carries no {phase} row tables"))
            continue
        want = nonuniform_round_widths(counts, spec.schedule, spec.group,
                                       phase=phase)
        got = tuple(t.shape[1] for t in tables)
        if got != want:
            out.append(_finding(
                "width-bound", where,
                f"{phase} table widths {got} != analytic worst-windowed-"
                f"count-sum bound {want} (Corollary 3)"))
        for k, t in enumerate(tables):
            if t.shape[0] != p:
                out.append(_finding(
                    "table-shape", where,
                    f"{phase} round {k}: table has {t.shape[0]} rows "
                    f"for axis size {p}"))

    if out or len(pl.skips) != len(pl.rs_row_tables or ()):
        return out

    # RS delivery: contrib[r][row] = source ranks folded into buffer row
    # `row` on rank r.  Receiver (r + s) folds the sender's rows through
    # ITS view of the same table — exactly what _rs_nonuniform executes.
    contrib = [{row: {r} for row in range(N)} for r in range(p)]
    for k, s in enumerate(pl.skips):
        tab = pl.rs_row_tables[k]
        moved = []
        for r in range(p):
            rows = _table_rows(tab, r, N, where, k, out)
            if len(rows) != len(set(rows)):
                out.append(_finding(
                    "duplicate-send", where,
                    f"rs round {k}: table row {r} gathers a buffer row "
                    f"twice"))
            moved.append((r, (r + s) % p, rows))
        for src, dst, rows in moved:
            for row in rows:
                payload = contrib[src][row]
                dup = contrib[dst][row] & payload
                if dup:
                    out.append(_finding(
                        "duplicate-contribution", where,
                        f"rs round {k}: ranks {sorted(dup)} contribute "
                        f"row {row} to rank {dst} twice"))
                contrib[dst][row] |= payload
    full = set(range(p))
    for r in range(p):
        own = range(offs[r], offs[r] + counts[r])
        short = [row for row in own if contrib[r][row] != full]
        if short:
            out.append(_finding(
                "incomplete-reduction", where,
                f"rank {r}: rows {short[:8]} of its own block miss "
                f"contributions after all rs rounds"))

    # AG delivery: have[r] = blocks held; every send must be held, every
    # receive new, and all ranks must end with every block.
    if pl.ag_row_tables is not None and len(pl.ag_rounds) == len(
            pl.ag_row_tables):
        # Zero-count blocks carry no rows: they are vacuously gathered
        # and never appear in a table, so track only non-empty blocks.
        nonempty = {b for b in range(p) if counts[b] > 0}
        have = [{r} & nonempty for r in range(p)]
        block_of = {}
        for b in range(p):
            for row in range(offs[b], offs[b] + counts[b]):
                block_of[row] = b
        for k, rp in enumerate(pl.ag_rounds):
            tab = pl.ag_row_tables[k]
            s = rp.skip
            moved = []
            for r in range(p):
                rows = _table_rows(tab, r, N, where, k, out)
                blocks = {block_of[row] for row in rows}
                miss = blocks - have[r]
                if miss:
                    out.append(_finding(
                        "send-before-receive", where,
                        f"ag round {k}: rank {r} sends blocks "
                        f"{sorted(miss)} it does not hold yet"))
                # Completeness: the gathered rows must cover each sent
                # block entirely (a dropped row truncates the block).
                rowset = set(rows)
                for b in blocks & have[r]:
                    whole = set(range(offs[b], offs[b] + counts[b]))
                    if not whole <= rowset:
                        out.append(_finding(
                            "partial-block", where,
                            f"ag round {k}: rank {r} sends only part of "
                            f"block {b}"))
                moved.append((r, (r - s) % p, blocks))
            for src, dst, blocks in moved:
                for b in blocks:
                    if b in have[dst] and b != dst:
                        out.append(_finding(
                            "duplicate-delivery", where,
                            f"ag round {k}: rank {dst} receives block "
                            f"{b} it already holds"))
                have[dst] |= blocks
        for r in range(p):
            if have[r] != nonempty:
                out.append(_finding(
                    "incomplete-gather", where,
                    f"rank {r} ends the ag phase holding "
                    f"{len(have[r])}/{len(nonempty)} non-empty blocks"))
    return out


# ---------------------------------------------------------------------------
# Alltoall(v) round tables
# ---------------------------------------------------------------------------

def _check_a2a(pl: CollectivePlan, where: str) -> list[Finding]:
    a2a, p, spec = pl.a2a, pl.p, pl.spec
    out: list[Finding] = []
    counts = a2a.counts
    total = a2a.total

    want = alltoallv_round_widths(counts, spec.schedule, spec.group)
    if a2a.round_widths != want:
        out.append(_finding(
            "width-bound", where,
            f"alltoallv round widths {a2a.round_widths} != analytic "
            f"worst-windowed-count-sum bound {want}"))
    if len(a2a.round_tables) != len(pl.skips):
        out.append(_finding(
            "round-structure", where,
            f"{len(a2a.round_tables)} a2a round tables for "
            f"{len(pl.skips)} rounds"))
        return out

    offs = a2a.pair_offsets
    row_pair = {}
    for s in range(p):
        for d in range(p):
            for row in range(int(offs[s, d]), int(offs[s, d]) + counts[s][d]):
                row_pair[row] = (s, d)

    # Seed well-formedness: rank r must place exactly its own (r, *)
    # rows into the pair layout.
    for r in range(p):
        dst_rows = [int(v) for v in a2a.seed_dst[r] if v != total]
        own = [row for row in range(total) if row_pair[row][0] == r]
        if sorted(dst_rows) != own:
            out.append(_finding(
                "seed-mismatch", where,
                f"rank {r} seeds rows other than its own (src={r}) "
                f"pair rows"))

    # Hop replay: held[r] = buffer rows present on rank r.  Each round's
    # gather must be held, each delivery must be new.
    held = [set(int(v) for v in a2a.seed_dst[r] if v != total)
            for r in range(p)]
    for k, (s, tab) in enumerate(zip(pl.skips, a2a.round_tables)):
        moved = []
        for r in range(p):
            rows = _table_rows(tab, r, total, where, k, out)
            if len(rows) != len(set(rows)):
                out.append(_finding(
                    "duplicate-send", where,
                    f"a2a round {k}: table row {r} gathers a buffer row "
                    f"twice"))
            miss = set(rows) - held[r]
            if miss:
                out.append(_finding(
                    "send-before-receive", where,
                    f"a2a round {k}: rank {r} forwards rows it does not "
                    f"hold (e.g. {sorted(miss)[:4]})"))
            moved.append((r, (r + s) % p, set(rows)))
        for src, dst, rows in moved:
            dup = rows & held[dst]
            if dup:
                out.append(_finding(
                    "duplicate-delivery", where,
                    f"a2a round {k}: rank {dst} receives rows it "
                    f"already holds (e.g. {sorted(dup)[:4]})"))
            held[dst] |= rows
    for r in range(p):
        need = {row for row in range(total) if row_pair[row][1] == r}
        miss = need - held[r]
        if miss:
            out.append(_finding(
                "undelivered-entry", where,
                f"rank {r} never receives its (src,dst={r}) rows "
                f"(e.g. {sorted(miss)[:4]})"))
        out_rows = [int(v) for v in a2a.out_rows[r] if v != total]
        if sorted(out_rows) != sorted(need):
            out.append(_finding(
                "output-gather", where,
                f"rank {r}'s output gather rows do not equal its "
                f"destination pair rows"))
    return out


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def verify_plan(pl: CollectivePlan) -> list[Finding]:
    """All static checks applicable to ``pl``; [] == verified."""
    where = f"{pl.spec.label}@p={pl.p}"
    if pl.spec.kind in _BASELINE_KINDS:
        return []  # baselines carry no circulant structure to verify
    if pl.p == 1:
        return []
    out = _check_rounds(pl, where)
    out += _check_partition(pl, where)
    if pl.spec.kind == "broadcast":
        # Broadcast runs the AG phase only: the delivery claim is the
        # block-level exactly-once replay, not the RS fold simulation.
        return out + _check_broadcast(pl, where)
    out += _check_delivery(pl, where)
    if pl.layout is not None:
        out += _check_nonuniform(pl, where)
    if pl.a2a is not None:
        out += _check_a2a(pl, where)
    return out


def verify(spec: CollectiveSpec | None = None, p: int | None = None,
           axis_name: str = "x", **kw) -> list[Finding]:
    """Build (or fetch the cached) plan for ``spec`` x ``p`` and verify."""
    return verify_plan(plan(spec, p=p, axis_name=axis_name, **kw))


def assert_verified(pl: CollectivePlan) -> CollectivePlan:
    """Pre-flight hook: raise if ``pl`` fails any static check.

    Cheap (pure trace-time set arithmetic, no devices) — callers that
    build plans dynamically (steps pre-compile, elastic re-planning)
    run this before trusting a fresh plan.
    """
    findings = verify_plan(pl)
    if findings:
        raise AssertionError(
            "plan failed static verification:\n  "
            + "\n  ".join(f.render() for f in findings))
    return pl


def registry_specs(p: int) -> list[CollectiveSpec]:
    """Representative spec registry for the sweep: every backend family
    x schedule, plus the conformance count patterns for the ragged
    forms."""
    from repro.core.conformance import (alltoallv_counts_cases,
                                        nonuniform_counts_cases,
                                        two_level_group)

    specs = []
    for sched in ("halving", "power2", "fully_connected", "sqrt"):
        specs.append(CollectiveSpec(schedule=sched))
    specs.append(CollectiveSpec(schedule="two_level",
                                group=two_level_group(p)))
    specs.append(CollectiveSpec(use_fused_kernel=True))
    specs.append(CollectiveSpec(wire_dtype="int8"))
    specs.append(CollectiveSpec(op="max"))
    for counts in nonuniform_counts_cases(p).values():
        specs.append(CollectiveSpec(counts=counts))
    for counts in alltoallv_counts_cases(p).values():
        specs.append(CollectiveSpec(counts=counts))
    for sched in OPTIMAL_SCHEDULES:
        specs.append(CollectiveSpec(kind="broadcast", schedule=sched))
    for kind in _BASELINE_KINDS:
        specs.append(CollectiveSpec(kind=kind))
    return specs


def run(ps=(2, 3, 5, 8, 16)) -> list[Finding]:
    """Verify the full spec registry at every ``p``; [] == all clean."""
    findings: list[Finding] = []
    for p in ps:
        for spec in registry_specs(p):
            try:
                findings += verify(spec, p=p)
            except Exception as e:  # plan construction itself failed
                findings.append(_finding(
                    "plan-build-error", f"{spec.label}@p={p}",
                    f"{type(e).__name__}: {e}"))
    return findings
