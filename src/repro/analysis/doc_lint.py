"""Markdown link & anchor checker for the docs tree.

Validates every inline link in ``README.md`` + ``docs/*.md`` so docs rot
fails CI (the docs job runs this next to the markdown doctests):

* **relative file links** must resolve to an existing file inside the
  repo (``docs/paper_map.md`` linking ``../src/repro/core/plan.py``);
* **anchors** (``#section`` alone, or ``file.md#section``) must match a
  heading in the target file, using GitHub's slug rules (lowercase,
  spaces → ``-``, punctuation stripped, duplicate slugs suffixed
  ``-1``, ``-2``, ...);
* ``http(s)://`` / ``mailto:`` targets are skipped (no network in CI),
  as are links that resolve *outside* the repo root — those are
  GitHub-site-relative URLs (the CI badge) that cannot be validated
  locally;
* absolute filesystem targets (``/src/...``) are findings: links must
  be relative so they work on GitHub, in local checkouts, and in
  rendered docs alike.

Fenced code blocks and inline code spans are stripped before scanning,
so ``[i](j)``-shaped expressions in code samples are not treated as
links.

Pure stdlib (``re`` + ``pathlib``); no jax import.  Run standalone:

    PYTHONPATH=src python -m repro.analysis.doc_lint [--root DIR]

Exits non-zero on any finding.  ``tests/test_docs.py`` runs the same
check in-process as part of tier-1.
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys
from dataclasses import dataclass

__all__ = ["Finding", "check_file", "doc_files", "heading_slugs", "run"]

# Inline links AND images: [text](target) / ![alt](target "title").
_LINK = re.compile(r"!?\[[^\]\[]*\]\(\s*(<[^>]*>|[^)\s]+)(?:\s+\"[^\"]*\")?\s*\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
_CODE_SPAN = re.compile(r"`[^`]*`")
_MD_INLINE = re.compile(r"[*_`]|\[([^\]]*)\]\([^)]*\)")  # formatting to strip
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


@dataclass(frozen=True)
class Finding:
    """One broken link: ``file:line`` plus a human-readable message."""
    file: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: {self.message}"


def _slugify(heading: str) -> str:
    """GitHub heading slug: strip formatting, lowercase, spaces → '-'."""
    text = _MD_INLINE.sub(lambda m: m.group(1) or "", heading)
    text = text.strip().lower()
    out = []
    for ch in text:
        if ch.isalnum() or ch in "-_":
            out.append(ch)
        elif ch in " \t":
            out.append("-")
        # everything else (punctuation, arrows, ...) is dropped
    return "".join(out)


def heading_slugs(text: str) -> set[str]:
    """All GitHub anchor slugs defined by ``text``'s ATX headings."""
    seen: dict[str, int] = {}
    slugs: set[str] = set()
    in_fence = False
    for line in text.splitlines():
        if line.lstrip().startswith(("```", "~~~")):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if not m:
            continue
        slug = _slugify(m.group(2))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def _scannable_lines(text: str):
    """Yield ``(lineno, line)`` with fenced blocks and code spans blanked."""
    in_fence = False
    for i, line in enumerate(text.splitlines(), start=1):
        if line.lstrip().startswith(("```", "~~~")):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        yield i, _CODE_SPAN.sub("", line)


def check_file(md_path: pathlib.Path, root: pathlib.Path) -> list[Finding]:
    """Validate every link in one markdown file against the repo tree."""
    rel = md_path.relative_to(root).as_posix()
    text = md_path.read_text()
    own_slugs = heading_slugs(text)
    out: list[Finding] = []
    for lineno, line in _scannable_lines(text):
        for m in _LINK.finditer(line):
            target = m.group(1).strip("<>")
            if target.startswith(_SKIP_SCHEMES):
                continue
            if target.startswith("#"):  # same-file anchor
                if target[1:] not in own_slugs:
                    out.append(Finding(rel, lineno,
                        f"anchor {target!r} matches no heading in this file"))
                continue
            if target.startswith("/"):
                out.append(Finding(rel, lineno,
                    f"absolute link {target!r}; use a repo-relative path"))
                continue
            path_part, _, anchor = target.partition("#")
            dest = (md_path.parent / path_part).resolve()
            try:
                dest.relative_to(root.resolve())
            except ValueError:
                # GitHub-site-relative (e.g. the ../../actions CI badge):
                # points outside the checkout, nothing to validate locally.
                continue
            if not dest.exists():
                out.append(Finding(rel, lineno,
                    f"broken link {target!r}: {path_part} does not exist"))
                continue
            if anchor:
                if dest.suffix != ".md":
                    out.append(Finding(rel, lineno,
                        f"anchor on non-markdown target {target!r}"))
                elif anchor not in heading_slugs(dest.read_text()):
                    out.append(Finding(rel, lineno,
                        f"broken anchor {target!r}: no heading "
                        f"#{anchor} in {path_part}"))
    return out


def doc_files(root: pathlib.Path) -> list[pathlib.Path]:
    """The checked set: README.md plus every markdown file under docs/."""
    files = []
    readme = root / "README.md"
    if readme.exists():
        files.append(readme)
    files.extend(sorted((root / "docs").glob("**/*.md")))
    return files


def run(root: pathlib.Path | str = ".") -> list[Finding]:
    """Check the whole docs surface; returns all findings (empty = clean)."""
    root = pathlib.Path(root)
    out: list[Finding] = []
    for md in doc_files(root):
        out.extend(check_file(md, root))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="markdown link/anchor checker (README.md + docs/*.md)")
    ap.add_argument("--root", default=".", help="repo root (default: cwd)")
    args = ap.parse_args(argv)
    findings = run(args.root)
    for f in findings:
        print(f)
    files = doc_files(pathlib.Path(args.root))
    print(f"doc_lint: {len(files)} files, {len(findings)} findings "
          f"{'FAIL' if findings else 'OK'}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
