"""Jaxpr lint: trace the registered backends and the zero1 grad-sync
entrypoints, then walk the jaxprs for trace-level invariants no test
asserts directly:

* every ``ppermute`` runs over the expected mesh axis, and its ``perm``
  is a single circulant shift ``{(i, (i+s) mod p)}`` — the deadlock-free
  pattern the paper's round structure guarantees;
* the int8-wire fold path accumulates in f32 even for bf16 payloads
  (dequantized codes must not be folded in half precision);
* every registry spec is hashable and re-planning is an identity (a
  spec that misses the lru cache retraces on every jit call);
* tracing repro entrypoints raises no DeprecationWarning from repro
  modules (the raw-``impl`` string path must not be reachable from
  spec-driven code).

Tracing shard_map bodies needs ``p`` fake devices: run via
``python -m repro.analysis --jaxpr`` (the CLI sets
``XLA_FLAGS=--xla_force_host_platform_device_count`` before jax loads)
or from a process configured the same way.
"""
from __future__ import annotations

import warnings

from .report import Finding

AXIS = "x"
BLK = 4


def _finding(rule: str, where: str, message: str) -> Finding:
    return Finding(pass_name="jaxpr", rule=rule, where=where,
                   message=message)


def _walk_eqns(jaxpr):
    """Yield every eqn of ``jaxpr`` and of any jaxpr nested in params
    (shard_map bodies, scans, conds, pallas_call kernels).  Duck-typed
    (``.eqns`` / ``.jaxpr``) so no version-sensitive ``jax.core``
    isinstance checks are needed."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            stack = [val]
            while stack:
                v = stack.pop()
                if hasattr(v, "jaxpr") and hasattr(v, "consts"):
                    yield from _walk_eqns(v.jaxpr)   # ClosedJaxpr
                elif hasattr(v, "eqns"):
                    yield from _walk_eqns(v)         # Jaxpr
                elif isinstance(v, (tuple, list)):
                    stack.extend(v)


def _is_circulant_perm(perm, p: int) -> bool:
    pairs = set(tuple(pr) for pr in perm)
    if len(pairs) != p:
        return False
    for s in range(1, p):
        if pairs == {(i, (i + s) % p) for i in range(p)}:
            return True
    return False


def _axis_names(param) -> tuple:
    if isinstance(param, (tuple, list, set, frozenset)):
        return tuple(param)
    return (param,)


def _check_jaxpr(jaxpr, p: int, where: str, *,
                 wired: bool) -> list[Finding]:
    out: list[Finding] = []
    n_ppermute = 0
    for eqn in _walk_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "ppermute":
            n_ppermute += 1
            axes = _axis_names(eqn.params.get("axis_name"))
            if tuple(axes) != (AXIS,):
                out.append(_finding(
                    "ppermute-axis", where,
                    f"ppermute over axis {axes}, expected ({AXIS!r},) — "
                    f"a stray axis would address a different mesh "
                    f"dimension"))
            perm = eqn.params.get("perm", ())
            if not _is_circulant_perm(perm, p):
                out.append(_finding(
                    "non-circulant-perm", where,
                    f"ppermute perm {tuple(perm)[:4]}... is not a single "
                    f"circulant shift of the {p}-ring (deadlock-freedom "
                    f"relies on one matched permutation per round)"))
        elif wired and name in ("add", "max", "min") and eqn.outvars:
            aval = eqn.outvars[0].aval
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and dtype.kind == "f" and \
                    dtype.itemsize < 4:
                out.append(_finding(
                    "low-precision-accumulation", where,
                    f"{name} accumulates in {dtype} on the int8-wire "
                    f"fold path; dequantized rounds must fold in f32"))
    if n_ppermute == 0:
        out.append(_finding(
            "no-collective", where,
            "trace contains no ppermute (backend wiring broken?)"))
    return out


def _trace_cases(p: int):
    """(label, spec, traced jaxpr, wired) for the backend registry and
    the zero1 leaf entrypoints."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import collectives as C
    from repro.core.spec import CollectiveSpec
    from repro.optim import zero1

    if jax.device_count() < p:
        raise RuntimeError(
            f"jaxpr lint needs {p} devices, have {jax.device_count()} — "
            f"run via `python -m repro.analysis --jaxpr` (it forces the "
            f"host platform device count before jax loads)")
    mesh = compat.make_mesh((p,), (AXIS,))

    def shmap(fn, dtype=jnp.float32, n=p * BLK, check_vma=None):
        f = compat.shard_map(lambda v: fn(v[0])[None], mesh=mesh,
                             in_specs=(P(AXIS),), out_specs=P(AXIS),
                             check_vma=check_vma)
        return jax.make_jaxpr(f)(jnp.zeros((p, n), dtype)).jaxpr

    nonuni = tuple((i * 5 + 3) % 7 for i in range(p))
    if sum(nonuni) == 0:
        nonuni = (1,) * p
    a2a_counts = tuple(tuple((i + 2 * j + 1) % 3 for j in range(p))
                       for i in range(p))
    in_h = max(max(sum(row) for row in a2a_counts), 1)

    cases = []
    for label, spec, dtype in (
            ("rs/jnp", CollectiveSpec(), jnp.float32),
            ("ar/jnp", CollectiveSpec(), jnp.float32),
            ("rs/fused", CollectiveSpec(use_fused_kernel=True), jnp.float32),
            ("rs/int8", CollectiveSpec(wire_dtype="int8"), jnp.float32),
            ("rs/int8-bf16", CollectiveSpec(wire_dtype="int8"),
             jnp.bfloat16),
            ("ar/int8-bf16", CollectiveSpec(wire_dtype="int8"),
             jnp.bfloat16)):
        coll = C.allreduce if label.startswith("ar/") else C.reduce_scatter
        cv = False if "fused" in label else None
        jx = shmap(lambda v, s=spec, c=coll: c(v, AXIS, spec=s),
                   dtype=dtype, check_vma=cv)
        cases.append((label, spec, jx, spec.wired))

    spec = CollectiveSpec(counts=nonuni)
    cases.append(("rs/nonuniform", spec,
                  shmap(lambda v, s=spec: C.reduce_scatter(v, AXIS, spec=s),
                        n=sum(nonuni)), False))
    spec = CollectiveSpec(counts=a2a_counts)
    cases.append(("a2a/alltoallv", spec,
                  shmap(lambda v, s=spec: C.alltoall(v, AXIS, spec=s),
                        n=in_h), False))

    # zero1 grad-sync entrypoints (what steps.build_zero1 pre-plans).
    for label, sync in (("zero1/plain", zero1.GradSyncConfig()),
                        ("zero1/int8", zero1.GradSyncConfig(
                            wire_dtype="int8"))):
        def leaves(g, _s=sync):
            shard = zero1.reduce_scatter_leaf(g, (AXIS,), _s, p)
            return zero1.allgather_leaf(shard, g.shape[0], (AXIS,), _s)
        n = int(np.lcm(p, 4)) * p
        cases.append((label, sync.rs_spec(), shmap(leaves, n=n),
                      sync.rs_spec().wired))
    return cases


def lint(p: int = 8) -> list[Finding]:
    from repro.core.plan import plan

    out: list[Finding] = []
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        cases = _trace_cases(p)
    for w in caught:
        if w.category is DeprecationWarning and \
                "repro" in str(w.filename):
            out.append(_finding(
                "deprecated-impl-dispatch", f"registry@p={p}",
                f"tracing the registry raised a DeprecationWarning from "
                f"{w.filename}:{w.lineno}: {w.message}"))
    for label, spec, jaxpr, wired in cases:
        where = f"{label}@p={p}"
        try:
            hash(spec)
        except TypeError as e:
            out.append(_finding(
                "unhashable-spec", where,
                f"spec is unhashable ({e}) — jit static args would "
                f"retrace on every call"))
            continue
        if plan(spec, p=p, axis_name=AXIS) is not plan(spec, p=p,
                                                       axis_name=AXIS):
            out.append(_finding(
                "plan-cache-miss", where,
                "plan() returns a fresh object for an identical spec — "
                "the lru cache is broken (retrace risk)"))
        out.extend(_check_jaxpr(jaxpr, p, where, wired=wired))
    return out
