"""``python -m repro.analysis`` — run the static-analysis passes and
emit a machine-readable JSON report; exit non-zero on any finding.

    python -m repro.analysis --all                 # all four passes
    python -m repro.analysis --verify --p 2,3,5,8,16
    python -m repro.analysis --repo --update-ratchet
    python -m repro.analysis --all --json report.json

The jaxpr and hlo passes trace/compile shard_map programs and need fake
devices, so the device count is forced into ``XLA_FLAGS`` HERE, before
the first jax import (the package ``__init__`` is deliberately
jax-free; any inherited device-count flag is stripped first because XLA
honors the LAST occurrence).
"""
import argparse
import os
import re
import sys

_DEVICES = 8

_inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                    os.environ.get("XLA_FLAGS", ""))
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_DEVICES} " + _inherited)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.analysis.report import Report  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when no pass is chosen)")
    ap.add_argument("--verify", action="store_true",
                    help="static plan verifier over the spec registry")
    ap.add_argument("--jaxpr", action="store_true",
                    help="jaxpr lint of the backends + zero1 entrypoints")
    ap.add_argument("--hlo", action="store_true",
                    help="compiled-HLO round/byte audit")
    ap.add_argument("--repo", action="store_true",
                    help="repo-invariant AST lint")
    ap.add_argument("--p", default="2,3,5,8,16",
                    help="comma-separated axis sizes for --verify")
    ap.add_argument("--root", default=None,
                    help="repo root for --repo (default: auto-detect)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the JSON report to PATH ('-' = stdout)")
    ap.add_argument("--update-ratchet", action="store_true",
                    help="record current repo-lint findings as exemptions")
    args = ap.parse_args(argv)

    chosen = args.verify or args.jaxpr or args.hlo or args.repo
    run_all = args.all or not chosen
    report = Report()

    if run_all or args.verify:
        from repro.analysis import verify
        ps = tuple(int(tok) for tok in args.p.split(",") if tok)
        report.extend("verify", verify.run(ps))
    if run_all or args.jaxpr:
        from repro.analysis import jaxpr_lint
        report.extend("jaxpr", jaxpr_lint.lint(p=_DEVICES))
    if run_all or args.hlo:
        from repro.analysis import hlo_budget
        report.extend("hlo", hlo_budget.audit(p=_DEVICES))
    if run_all or args.repo:
        from repro.analysis import repo_lint
        root = args.root or _find_root()
        if args.update_ratchet:
            repo_lint.save_ratchet(root, repo_lint.lint_repo(root))
            print(f"ratchet updated: {os.path.join(root, repo_lint.RATCHET_FILE)}")
        fresh, waived = repo_lint.run(root)
        report.extend("repo", fresh)
        report.waived.extend(waived)

    out = report.as_json()
    if args.json == "-":
        print(out)
    elif args.json:
        with open(args.json, "w") as f:
            f.write(out + "\n")
    for f in report.findings:
        print("FINDING " + f.render())
    for f in report.waived:
        print("waived  " + f.render())
    n = len(report.findings)
    print(f"repro.analysis: passes={','.join(report.passes_run)} "
          f"findings={n} waived={len(report.waived)} "
          f"{'OK' if report.ok else 'FAIL'}")
    return 0 if report.ok else 1


def _find_root() -> str:
    """Repo root = nearest ancestor of this file holding pyproject.toml
    (src/repro/analysis -> repo)."""
    d = os.path.dirname(os.path.abspath(__file__))
    for _ in range(6):
        if os.path.exists(os.path.join(d, "pyproject.toml")):
            return d
        d = os.path.dirname(d)
    return os.getcwd()


if __name__ == "__main__":
    sys.exit(main())
