"""The one HLO/StableHLO collective counter and byte-budget parser.

Every place the repo inspects compiler output for collectives goes
through here: ``core/conformance.py`` (Theorem 1/2 round counts),
``benchmarks/_wire_worker.py`` (codes+scales byte budgets),
``benchmarks/_plan_worker.py`` / ``_a2a_worker.py`` (plan-dispatch round
deltas), ``roofline/analysis.py`` (collective roofline term) and the
test/example helpers.  Before this module each had its own regex; the
repo-lint rule ``hlo-counter-outside-budget`` keeps it that way.

Two textual formats appear in practice:

* **lowered StableHLO** (``jitted.lower(...).as_text()``) — collectives
  are ``stablehlo.collective_permute`` ops, one token per op;
* **compiled post-SPMD HLO** (``compiled.as_text()``) — collectives are
  ``collective-permute`` instructions, possibly split into async
  ``collective-permute-start`` / ``-done`` pairs whose start instruction
  has a *tuple* result type ``(operand, result[, u32[], u32[]])``.

``parse_collectives`` handles the HLO form (start counted once, done
skipped, tuple payload counted once — not summed across the operand AND
result aliases); ``count_collective_permutes`` accepts either form.

This module is jax-free (pure ``re``): it must be importable before the
CLI sets ``XLA_FLAGS``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_INSTR_RE = re.compile(r"%?[\w.\-]+ = (.+?) ([\w\-]+)\(")
_MLIR_CP_RE = re.compile(r"\bcollective_permute\b")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _dims_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(type_str: str, opname: str) -> tuple[int, dict]:
    """(payload bytes, per-dtype byte breakdown) of an HLO result type.

    Sync ops have a plain array type: sum every array in it (there is
    one).  Async ``*-start`` ops have a TUPLE type aliasing the operand
    and the result buffer (plus u32 context scalars on some backends);
    counting every tuple element would double-count the payload, so the
    result entry — index 1 of the tuple — is counted alone.
    """
    shapes = _SHAPE_RE.findall(type_str)
    if opname.endswith("-start") and type_str.lstrip().startswith("("):
        if len(shapes) >= 2:
            shapes = [shapes[1]]
        elif shapes:
            shapes = [shapes[0]]
    total = 0
    by_dtype: dict[str, int] = {}
    for dtype, dims in shapes:
        if dtype not in _DTYPE_BYTES:
            continue
        nbytes = _dims_elems(dims) * _DTYPE_BYTES[dtype]
        total += nbytes
        by_dtype[dtype] = by_dtype.get(dtype, 0) + nbytes
    return total, by_dtype


def _group_size(line: str) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2  # conservative default


@dataclass
class CollectiveStats:
    ops: dict = field(default_factory=dict)        # op -> count
    bytes_by_op: dict = field(default_factory=dict)  # op -> effective bytes
    raw_bytes_by_op: dict = field(default_factory=dict)
    raw_bytes_by_dtype: dict = field(default_factory=dict)  # s8/f32/... ->
    #                               raw payload bytes (compressed-wire audit)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.ops.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Scan post-SPMD HLO for collective ops; returns per-device effective
    link bytes.  Start/done pairs are counted once (via -start), and a
    start's tuple result type contributes its payload once."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _INSTR_RE.match(line)
        if not m:
            continue
        type_str, opname = m.groups()
        base = opname.replace("-start", "")
        if base.endswith("-done") or base not in COLLECTIVE_OPS:
            continue
        size, size_by_dtype = _shape_bytes(type_str, opname)
        g = _group_size(line)
        if base == "collective-permute":
            eff = size
        elif base == "all-gather":
            eff = size * (g - 1) / g
        elif base == "reduce-scatter":
            eff = size * (g - 1)
        elif base == "all-reduce":
            eff = 2 * size * (g - 1) / g
        else:  # all-to-all
            eff = size * (g - 1) / g
        stats.ops[base] = stats.ops.get(base, 0) + 1
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + eff
        stats.raw_bytes_by_op[base] = (stats.raw_bytes_by_op.get(base, 0)
                                       + size)
        for dt, nb in size_by_dtype.items():
            stats.raw_bytes_by_dtype[dt] = (
                stats.raw_bytes_by_dtype.get(dt, 0) + nb)
    return stats


def count_collective_permutes(text: str) -> int:
    """Collective-permute op count of lowered StableHLO OR compiled HLO.

    StableHLO spells the op ``stablehlo.collective_permute`` (one token
    per op, never async); compiled HLO spells it ``collective-permute``
    with possible ``-start``/``-done`` splitting, which
    :func:`parse_collectives` normalizes to one count per pair.
    """
    n = len(_MLIR_CP_RE.findall(text))
    if n:
        return n
    return parse_collectives(text).ops.get("collective-permute", 0)


def count_collective_permutes_lowered(jitted, shape, dtype="float32") -> int:
    """Count for a jitted fn lowered at an f32 (by default) input of
    ``shape`` — the shared convenience the conformance harness, bench
    workers and examples previously each reimplemented."""
    import jax  # deferred: this module must import jax-free
    import jax.numpy as jnp

    aval = jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
    return count_collective_permutes(jitted.lower(aval).as_text())


def audit(p: int = 8):
    """CLI pass: compile a small spec set on ``p`` fake devices and check
    the compiled-HLO collective structure with THIS parser — round
    counts == Theorem 1/2, the int8 wire moves s8 payloads, and the
    async-aware byte accounting stays below the f32 payload volume.

    Needs ``p`` devices (run via ``python -m repro.analysis --hlo``).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import collectives as C
    from repro.core.schedule import ceil_log2
    from repro.core.spec import CollectiveSpec

    from .report import Finding

    if jax.device_count() < p:
        raise RuntimeError(
            f"hlo audit needs {p} devices, have {jax.device_count()} — "
            f"run via `python -m repro.analysis --hlo`")
    mesh = compat.make_mesh((p,), ("x",))
    findings = []

    def stats_for(spec, coll, n=p * 256):
        fn = jax.jit(compat.shard_map(
            lambda v, s=spec: getattr(C, coll)(v[0], "x", spec=s)[None],
            mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))
        x = jnp.zeros((p, n), jnp.float32)
        return parse_collectives(fn.lower(x).compile().as_text())

    q = ceil_log2(p)
    cases = [
        ("rs/f32", CollectiveSpec(), "reduce_scatter", q),
        ("ar/f32", CollectiveSpec(), "allreduce", 2 * q),
        ("rs/int8", CollectiveSpec(wire_dtype="int8"), "reduce_scatter", q),
    ]
    payload = {}
    for label, spec, coll, want in cases:
        st = stats_for(spec, coll)
        got = st.ops.get("collective-permute", 0)
        payload[label] = st.raw_bytes_by_op.get("collective-permute", 0)
        if got != want:
            findings.append(Finding(
                pass_name="hlo", rule="round-count", where=f"{label}@p={p}",
                message=f"{got} collective-permutes in compiled HLO, "
                        f"want {want} (Theorem 1/2)"))
        if label == "rs/int8" and st.raw_bytes_by_dtype.get("s8", 0) == 0:
            findings.append(Finding(
                pass_name="hlo", rule="wire-dtype", where=f"{label}@p={p}",
                message="int8-wire compile moves no s8 payload bytes"))
    if payload.get("rs/int8", 0) >= payload.get("rs/f32", 1):
        findings.append(Finding(
            pass_name="hlo", rule="wire-bytes", where=f"rs/int8@p={p}",
            message=f"compressed wire payload {payload.get('rs/int8')} B "
                    f"not below the f32 payload "
                    f"{payload.get('rs/f32')} B — byte accounting or "
                    f"wire format regressed"))
    return findings
