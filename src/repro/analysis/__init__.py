"""Static analysis for the circulant-collective stack: four gated passes
behind one CLI (``python -m repro.analysis --all``).

  verify      plan verifier — Theorem 1 partition, deadlock-freedom,
              Corollary 3 row tables, alltoallv delivery (no devices)
  jaxpr       trace the backend registry + zero1 entrypoints; lint the
              jaxprs (ppermute axis/perm, f32 fold, retrace risks)
  hlo         the ONE collective-permute counter / byte parser, plus a
              compiled-HLO round/byte audit
  repo        ast-based repo invariants (imports, pallas, spec funnel,
              one HLO counter), ratcheted in analysis_ratchet.json

This ``__init__`` stays jax-free: ``python -m repro.analysis`` imports
it before ``__main__`` can set ``XLA_FLAGS``, so anything importing jax
must be pulled in lazily by the passes that need it.
"""
from .report import Finding, Report  # noqa: F401

__all__ = ["Finding", "Report"]
