"""Repo-invariant AST lint: hard-won structural rules, machine-checked.

Each rule encodes an invariant the codebase converged on the hard way:

* ``jax-experimental-outside-compat`` — every ``jax.experimental`` /
  ``shard_map`` import lives in ``compat.py`` (the single file that
  changes when a JAX API moves).  Pre-existing exemptions (the Pallas
  kernel modules import ``jax.experimental.pallas`` directly) are
  ratcheted, not grandfathered invisibly.
* ``pallas-call-outside-kernels`` — ``pallas_call`` appears only under
  ``src/repro/kernels/`` (interpret-mode gating and TPU lowering live
  there).
* ``spec-funnel`` — the public collective wrappers in
  ``core/collectives.py`` all funnel through ``plan()`` / ``_dispatch``
  (which resolves via ``as_spec``): no wrapper may grow a private
  dispatch path.
* ``bare-impl-string`` — no ``impl="..."`` string dispatch outside
  ``tests/`` (the deprecated kwarg-era path; tests keep exercising its
  DeprecationWarning on purpose).
* ``hlo-counter-outside-budget`` — nobody counts ``collective_permute``
  strings or regexes outside ``analysis/hlo_budget.py``: exactly one
  HLO collective counter exists.
* ``public-missing-docstring`` — every public top-level function and
  class in ``src/repro/core/`` and ``src/repro/optim/`` carries a
  docstring (these two packages are the library surface the docs tree
  maps to the paper; an undocumented public callable there is a docs
  regression, ratcheted shrink-only like everything else).
* ``serve-collectives-via-plan`` — modules under ``src/repro/serve/``
  never call ``lax.ppermute``-family collectives directly: serving
  communicates only through the ``plan()``/``as_spec`` dispatchers, so
  every collective it issues carries the verified round structure the
  serving CI gates assert against.

Adding a rule: write a ``_rule_*`` visitor hook below, give it a stable
kebab-case id, and (if the repo already violates it) run
``python -m repro.analysis --repo --update-ratchet`` to record the
pre-existing findings in ``analysis_ratchet.json`` — new violations
still fail while the ratchet holds the old ones visible.

Pure ``ast`` + ``pathlib``; no jax import.
"""
from __future__ import annotations

import ast
import json
from pathlib import Path

from .report import Finding

SCAN_DIRS = ("src", "tests", "benchmarks", "examples")
RATCHET_FILE = "analysis_ratchet.json"

COMPAT_FILE = "src/repro/compat.py"
KERNELS_DIR = "src/repro/kernels/"
BUDGET_FILE = "src/repro/analysis/hlo_budget.py"
COLLECTIVES_FILE = "src/repro/core/collectives.py"

_CP_TOKENS = ("collective_permute", "collective-permute")


def _finding(rule: str, rel: str, line: int, message: str) -> Finding:
    return Finding(pass_name="repo", rule=rule, where=f"{rel}:{line}",
                   message=message)


def _const_str(node) -> str | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _call_name(node: ast.Call) -> str | None:
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


# ---------------------------------------------------------------------------
# Per-file rules
# ---------------------------------------------------------------------------

def _rule_jax_experimental(tree, rel: str) -> list[Finding]:
    if rel == COMPAT_FILE:
        return []
    out = []
    for node in ast.walk(tree):
        mods: list[str] = []
        if isinstance(node, ast.Import):
            mods = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module:
            mods = [node.module]
            if node.module == "jax":
                for a in node.names:
                    if a.name == "shard_map":
                        out.append(_finding(
                            "jax-experimental-outside-compat", rel,
                            node.lineno,
                            "shard_map import outside compat.py (use "
                            "repro.compat.shard_map)"))
        for mod in mods:
            if mod == "jax.experimental" or \
                    mod.startswith("jax.experimental."):
                out.append(_finding(
                    "jax-experimental-outside-compat", rel, node.lineno,
                    f"import of {mod} outside compat.py (version-"
                    f"sensitive surface; go through repro.compat)"))
        if isinstance(node, ast.Attribute) and node.attr == "shard_map" \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "jax":
            out.append(_finding(
                "jax-experimental-outside-compat", rel, node.lineno,
                "jax" + ".shard_map outside compat.py (use "  # split: keep
                "repro.compat.shard_map)"))  # THIS file out of the gate
    return out


def _rule_pallas_call(tree, rel: str) -> list[Finding]:
    if rel.startswith(KERNELS_DIR):
        return []
    out = []
    for node in ast.walk(tree):
        name = None
        if isinstance(node, ast.Name) and node.id == "pallas_call":
            name = node.id
        elif isinstance(node, ast.Attribute) and node.attr == "pallas_call":
            name = node.attr
        if name:
            out.append(_finding(
                "pallas-call-outside-kernels", rel, node.lineno,
                "pallas_call outside src/repro/kernels/ (kernel lowering "
                "and interpret gating live there)"))
    return out


def _rule_bare_impl(tree, rel: str) -> list[Finding]:
    if rel.startswith("tests/"):
        return []  # deprecation tests exercise the legacy path on purpose
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg == "impl" and _const_str(kw.value) is not None:
                out.append(_finding(
                    "bare-impl-string", rel, node.lineno,
                    f"impl={_const_str(kw.value)!r} string dispatch is "
                    f"deprecated; pass spec=CollectiveSpec(...)"))
    return out


def _rule_hlo_counter(tree, rel: str) -> list[Finding]:
    if rel == BUDGET_FILE:
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        consts = [s for a in node.args if (s := _const_str(a)) is not None]
        if name == "count" and any(
                tok in s for s in consts for tok in _CP_TOKENS):
            out.append(_finding(
                "hlo-counter-outside-budget", rel, node.lineno,
                'hand-rolled .count("collective_permute") — use '
                "repro.analysis.hlo_budget.count_collective_permutes"))
        if isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "re" \
                and any(tok in s for s in consts for tok in _CP_TOKENS):
            out.append(_finding(
                "hlo-counter-outside-budget", rel, node.lineno,
                "hand-rolled collective-permute regex — use "
                "repro.analysis.hlo_budget"))
    return out


_WRAPPER_PREFIXES = ("circulant_", "hierarchical_")
_DISPATCHERS = {"reduce_scatter", "allreduce", "allgather", "alltoall",
                "broadcast"}
_FUNNEL_CALLS = {"plan", "_dispatch", "as_spec"}


def _rule_spec_funnel(tree, rel: str) -> list[Finding]:
    if rel != COLLECTIVES_FILE:
        return []
    out = []
    fns = [n for n in tree.body if isinstance(n, ast.FunctionDef)]
    wrappers = {f.name for f in fns
                if f.name.startswith(_WRAPPER_PREFIXES)
                or f.name in _DISPATCHERS}
    for f in fns:
        if f.name not in wrappers:
            continue
        called = set()
        for node in ast.walk(f):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                if name:
                    called.add(name)
        if not called & (_FUNNEL_CALLS | wrappers):
            out.append(_finding(
                "spec-funnel", rel, f.lineno,
                f"public wrapper {f.name}() does not funnel through "
                f"plan()/_dispatch (as_spec) or a sibling wrapper"))
    return out


_DOCSTRING_DIRS = ("src/repro/core/", "src/repro/optim/")


def _rule_public_docstring(tree, rel: str) -> list[Finding]:
    if not rel.startswith(_DOCSTRING_DIRS):
        return []
    out = []
    for node in tree.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            continue
        if node.name.startswith("_"):
            continue
        if ast.get_docstring(node) is None:
            kind = "class" if isinstance(node, ast.ClassDef) else "function"
            out.append(_finding(
                "public-missing-docstring", rel, node.lineno,
                f"public {kind} {node.name} has no docstring (core/ and "
                f"optim/ are the documented library surface)"))
    return out


_FT_DIR = "src/repro/ft/"
_WORLD_READS = ("device_count", "local_device_count", "process_count",
                "devices", "local_devices", "axis_size", "process_index")


def _rule_ft_world(tree, rel: str) -> list[Finding]:
    """Rank/world-size reads inside ``repro.ft`` must go through
    ``ElasticController.world``: during a resize the runtime's device
    count and the logical world disagree by construction, so a direct
    ``jax.device_count()``-style read in fault-tolerance code is a
    latent split-brain bug."""
    if not rel.startswith(_FT_DIR):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name in _WORLD_READS:
            out.append(_finding(
                "ft-world-via-controller", rel, node.lineno,
                f"{name}() read inside ft/ — the live world must come "
                f"from ElasticController.world (runtime device counts "
                f"are stale mid-resize)"))
    return out


_SERVE_DIR = "src/repro/serve/"
_RAW_COLLECTIVES = ("ppermute", "psum", "psum_scatter", "pmax", "pmin",
                    "all_gather", "all_to_all")


def _rule_serve_collectives(tree, rel: str) -> list[Finding]:
    """Serving modules get collectives only via ``plan()`` / ``as_spec``
    dispatchers: a raw ``lax.ppermute``-family call inside
    ``repro.serve`` bypasses the verified plan layer (round counts,
    exactly-once delivery) that the serving gates assert against."""
    if not rel.startswith(_SERVE_DIR):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name in _RAW_COLLECTIVES:
            out.append(_finding(
                "serve-collectives-via-plan", rel, node.lineno,
                f"raw {name}() inside serve/ — serving communicates "
                f"only through plan()/as_spec dispatchers (the "
                f"verified collective layer)"))
    return out


_RULES = (_rule_jax_experimental, _rule_pallas_call, _rule_bare_impl,
          _rule_hlo_counter, _rule_spec_funnel, _rule_public_docstring,
          _rule_ft_world, _rule_serve_collectives)


# ---------------------------------------------------------------------------
# Driver + ratchet
# ---------------------------------------------------------------------------

def _iter_py_files(root: Path):
    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            yield path


def lint_repo(root: str | Path = ".") -> list[Finding]:
    """Raw findings over the repo tree (ratchet NOT applied)."""
    root = Path(root)
    findings: list[Finding] = []
    for path in _iter_py_files(root):
        rel = path.relative_to(root).as_posix()
        try:
            tree = ast.parse(path.read_text(), filename=rel)
        except SyntaxError as e:
            findings.append(_finding("syntax-error", rel, e.lineno or 0,
                                     str(e)))
            continue
        for rule in _RULES:
            findings.extend(rule(tree, rel))
    return findings


def load_ratchet(root: str | Path = ".") -> set[str]:
    path = Path(root) / RATCHET_FILE
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("repo_lint", []))


def save_ratchet(root: str | Path, findings: list[Finding]) -> None:
    path = Path(root) / RATCHET_FILE
    data = {
        "_comment": (
            "Pre-existing repro.analysis repo-lint exemptions. Entries are "
            "'<file>::<rule>'. Shrink-only: remove entries as the "
            "violations are fixed; --update-ratchet regenerates."),
        "repo_lint": sorted({ratchet_key(f) for f in findings}),
    }
    path.write_text(json.dumps(data, indent=2) + "\n")


def ratchet_key(f: Finding) -> str:
    """Ratchet entries key on file x rule (no line number: unrelated
    edits must not invalidate an exemption)."""
    return f"{f.where.rsplit(':', 1)[0]}::{f.rule}"


def run(root: str | Path = ".") -> tuple[list[Finding], list[Finding]]:
    """(new findings, ratchet-waived findings) for the repo at ``root``."""
    ratchet = load_ratchet(root)
    fresh, waived = [], []
    for f in lint_repo(root):
        (waived if ratchet_key(f) in ratchet else fresh).append(f)
    return fresh, waived
