"""Model + sharding configuration for every supported architecture family.

One ``ModelConfig`` schema covers: dense decoders (GQA, qk-norm, QKV-bias),
MoE, SSM (mamba-style and xLSTM), hybrid attn+SSM (hymba), encoder-decoder
(whisper) and cross-attention VLM (llama-3.2-vision).  Family selects the
model builder in ``registry.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm_xlstm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- attention flavor ---
    qk_norm: bool = False          # qwen3-style per-head RMS norm on q, k
    qkv_bias: bool = False         # qwen1.5-style bias on QKV projections
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # 0 = full attention

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_dispatch: str = "global"   # global | rowwise (§Perf C) | ep
    #                                (expert parallel via circulant
    #                                alltoall; needs ep_axis manual)
    ep_axis: str = "model"         # mesh axis ep dispatch exchanges over

    # --- SSM / hybrid ---
    ssm_state: int = 0             # mamba state size (hymba: 16)
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_conv: int = 4              # depthwise conv kernel
    mlstm_chunk: int = 256         # chunked-parallel mLSTM chunk length
    global_attn_layers: tuple[int, ...] = ()  # hymba: full-attn layer ids

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    dec_len: int = 512             # decoder text length for enc-dec cells

    # --- VLM (llama-3.2-vision) ---
    cross_attn_every: int = 0      # every k-th layer is a cross-attn layer
    n_image_tokens: int = 0

    # --- dry-run/roofline instrumentation ---
    scan_unroll: int = 1   # unroll factor for the layer scan (two-point
    #                        HLO-cost correction; see roofline/analysis.py)
    remat_policy: str = "nothing"  # nothing | dots  (§Perf D: 'dots' saves
    #                                matmul/collective outputs so the remat
    #                                pass doesn't repeat fwd TP collectives)

    # --- numerics ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, \
            f"GQA needs n_heads % n_kv_heads == 0 ({self.n_heads}/{self.n_kv_heads})"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def scaled_down(self, **overrides) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2 if self.family != "vlm" else 5),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=128,
            n_experts=min(self.n_experts, 4),
            mlstm_chunk=8,
            n_image_tokens=8 if self.n_image_tokens else 0,
            enc_layers=min(self.enc_layers, 2),
            dec_len=8 if self.enc_layers else self.dec_len,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            global_attn_layers=tuple(
                i for i in self.global_attn_layers
                if i < min(self.n_layers, 2)) or ((0,) if self.global_attn_layers else ()),
            cross_attn_every=self.cross_attn_every,
            dtype="float32",
        )
        small.update(overrides)
        return replace(self, **small)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head); used for
        MODEL_FLOPS = 6*N*D and checkpoint size estimates."""
        d, dh = self.d_model, self.head_dim
        h, hkv = self.n_heads, self.n_kv_heads
        attn = d * h * dh + 2 * d * hkv * dh + h * dh * d  # q, k+v, o
        if self.qkv_bias:
            attn += (h + 2 * hkv) * dh
        dense_ffn = 3 * d * self.d_ff                       # gate, up, down
        if self.is_moe:
            ffn = self.n_experts * dense_ffn + d * self.n_experts  # + router
        else:
            ffn = dense_ffn
        norms = 2 * d
        per_layer = attn + ffn + norms
        if self.family == "ssm_xlstm":
            d_in = self.ssm_expand * d
            mlstm = (3 * d * d_in + d_in * d + 2 * d_in)     # qkv+o+gates approx
            per_layer = mlstm + norms + dense_ffn if self.d_ff else mlstm + norms
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            ssm = (2 * d * d_in + d_in * d + d_in * (2 * self.ssm_state + 2)
                   + d_in * self.ssm_conv)
            per_layer = attn + ssm + dense_ffn + 3 * d
        layers = self.n_layers * per_layer
        if self.enc_layers:
            layers += self.enc_layers * (attn + dense_ffn + norms)
            layers += self.n_layers * (2 * d * hkv * dh + d * h * dh // max(h // h, 1))  # cross kv+q approx
        if self.cross_attn_every:
            n_cross = self.n_layers // (self.cross_attn_every)
            layers += n_cross * attn // 2
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        return emb + layers + head

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        expert_ffn = 3 * self.d_model * self.d_ff
        inactive = (self.n_experts - self.experts_per_token) * expert_ffn
        return full - self.n_layers * inactive


@dataclass(frozen=True)
class ShardingRecipe:
    """Named mesh axes used by with_sharding_constraint hooks + param specs.

    mode:
      'tp'       params replicated over data, sharded over model (ZeRO-1
                 handles the optimizer memory over data) — small/mid models.
      'tp_fsdp'  params additionally sharded over (pod, data) on a weight
                 axis — the >=90B models.
    """
    data_axes: tuple[str, ...] = ("data",)    # ('pod', 'data') multi-pod
    model_axis: str = "model"
    mode: str = "tp"
    # sequence-parallel attention (context parallelism) for long prefill:
    sequence_parallel: bool = False
    # model-axis size (0 = unknown); enables GQA head expansion when
    # kv-heads don't divide the axis (§Perf B: avoids GSPMD refactoring
    # between (hkv, g) and H shardings that forces full rematerialization)
    tp_size: int = 0
    expand_gqa: bool = False

    @property
    def batch_axes(self):
        return self.data_axes

    @property
    def fsdp_axes(self):
        return self.data_axes if self.mode == "tp_fsdp" else ()
