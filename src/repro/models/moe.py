"""Token-choice top-k MoE — parameter init + the dispatch-mode router.

The dispatch pipeline itself (router → dispatch → expert FFN → combine)
lives in :mod:`repro.models.dispatch` as composable stages; this module
keeps the historical entry points (``init_moe`` / ``moe_ffn`` /
``moe_ffn_rowwise``) and selects the layout from ``cfg.moe_dispatch``:

  global    one flat token pool per call (SPMD-friendly static shapes);
  rowwise   per-sequence pools (§Perf C) — the same stages vmapped over
            the batch dim so GSPMD never gathers the full token set;
  ep        expert parallelism over the manual mesh axis ``cfg.ep_axis``:
            the (E, C, d) dispatch buffer is exchanged with the circulant
            alltoall plan (paper §4, ceil(log2 p) collective-permutes)
            and the ragged per-expert routed-token counts with the
            alltoallv table backend — see ``dispatch.moe_ffn_ep``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .dispatch import (capacity, moe_ffn_ep, moe_ffn_global,  # noqa: F401
                       moe_ffn_rowwise)
from .layers import dense_init


def init_moe(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype),
    }


def _capacity(cfg, n_tokens: int) -> int:
    """Historical alias for :func:`repro.models.dispatch.capacity`."""
    return capacity(cfg, n_tokens)


_DISPATCH = {
    "global": moe_ffn_global,
    "rowwise": moe_ffn_rowwise,
    "ep": moe_ffn_ep,
}


def moe_ffn(p, cfg, x, recipe=None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).  Dispatch layout
    selected by ``cfg.moe_dispatch`` (global | rowwise | ep)."""
    mode = getattr(cfg, "moe_dispatch", "global")
    try:
        fn = _DISPATCH[mode]
    except KeyError:
        raise ValueError(
            f"unknown moe_dispatch {mode!r}; have {sorted(_DISPATCH)}"
        ) from None
    return fn(p, cfg, x, recipe)
