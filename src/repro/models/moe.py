"""Token-choice top-k MoE with sort-based capacity dispatch.

SPMD-friendly static shapes throughout: tokens are argsorted by expert
assignment, positioned within their expert via a counts/starts prefix sum,
dropped beyond capacity C = ceil(cf * N * K / E), gathered into an
(E, C, d) buffer, run through batched expert FFNs (one einsum), and
scatter-added back weighted by their router gates.  This is the standard
"dropping" MoE used by production JAX LLM stacks; EP shards the (E, ...)
dimension over the model axis.

Beyond-paper integration (§Perf): when the mesh axis is manual, the
(E, C, d) dispatch buffer can be exchanged with ``circulant_alltoall``
(paper §4) instead of GSPMD's all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding as shd
from .layers import dense_init


def init_moe(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, ff), dtype),
        "w_up": dense_init(ks[2], (e, d, ff), dtype),
        "w_down": dense_init(ks[3], (e, ff, d), dtype),
    }


def _capacity(cfg, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.experts_per_token
            / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def moe_ffn(p, cfg, x, recipe=None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).  Dispatch layout
    selected by cfg.moe_dispatch: 'global' (one token pool) or 'rowwise'
    (§Perf C: per-sequence pools — argsort/cumsum/scatter stay batch-local,
    so GSPMD never gathers the full token set to one partition)."""
    if getattr(cfg, "moe_dispatch", "global") == "rowwise":
        return moe_ffn_rowwise(p, cfg, x, recipe)
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(n, d)

    # --- Router (fp32) ---
    logits = xf.astype(jnp.float32) @ p["router"]          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)             # (N, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch-style).
    frac_tokens = jnp.zeros(e).at[expert_idx.reshape(-1)].add(1.0) / (n * k)
    mean_probs = probs.mean(0)
    aux = e * jnp.sum(frac_tokens * mean_probs) * cfg.router_aux_coef

    # --- Sort-based dispatch ---
    cap = _capacity(cfg, n)
    flat_e = expert_idx.reshape(-1)                        # (N*K,)
    sort_idx = jnp.argsort(flat_e)                         # stable
    sorted_e = flat_e[sort_idx]
    counts = jnp.zeros(e, jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # trash slot
    token_of = (sort_idx // k).astype(jnp.int32)
    gate_of = gate.reshape(-1)[sort_idx]

    slot_token = jnp.full(e * cap + 1, n, jnp.int32).at[slot].set(token_of)
    slot_gate = jnp.zeros(e * cap + 1, jnp.float32).at[slot].set(gate_of)
    slot_token, slot_gate = slot_token[:-1], slot_gate[:-1]

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    h = xpad[slot_token].reshape(e, cap, d)                # (E, C, d)
    if recipe is not None:
        h = shd.constrain(h, jax.sharding.PartitionSpec(
            recipe.model_axis, None, None))

    # --- Batched expert SwiGLU ---
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])     # (E, C, d)

    # --- Combine ---
    yf = y.reshape(e * cap, d) * slot_gate[:, None].astype(y.dtype)
    out = jnp.zeros((n + 1, d), y.dtype).at[slot_token].add(yf)[:n]
    return out.reshape(b, s, d), aux


def moe_ffn_rowwise(p, cfg, x, recipe=None):
    """Per-sequence dispatch (§Perf C): every sort/positioning/scatter op
    carries the batch dim, which stays sharded over the data axes — XLA's
    sort on a sharded dim otherwise all-gathers the full token pool.
    Capacity is per sequence: C_b = ceil(cf * S * K / E).  Token dropping
    is per-sequence (slightly stricter than global dropping; same expected
    load)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = _capacity(cfg, s)

    logits = x.astype(jnp.float32) @ p["router"]              # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                # (B, S, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    frac = jnp.zeros((b, e)).at[
        jnp.arange(b)[:, None], expert_idx.reshape(b, -1)].add(1.0) / (s * k)
    aux = e * jnp.mean(jnp.sum(frac * probs.mean(1), axis=-1)) \
        * cfg.router_aux_coef

    flat_e = expert_idx.reshape(b, s * k)                     # (B, S*K)
    sort_idx = jnp.argsort(flat_e, axis=1)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    counts = jnp.zeros((b, e), jnp.int32).at[
        jnp.arange(b)[:, None], flat_e].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32), jnp.cumsum(counts, axis=1)[:, :-1]],
        axis=1)
    pos_in_e = (jnp.arange(s * k, dtype=jnp.int32)[None]
                - jnp.take_along_axis(starts, sorted_e, axis=1))
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
    token_of = (sort_idx // k).astype(jnp.int32)
    gate_of = jnp.take_along_axis(gate.reshape(b, s * k), sort_idx, axis=1)

    rows = jnp.arange(b)[:, None]
    slot_token = jnp.full((b, e * cap + 1), s, jnp.int32
                          ).at[rows, slot].set(token_of)[:, :-1]
    slot_gate = jnp.zeros((b, e * cap + 1), jnp.float32
                          ).at[rows, slot].set(gate_of)[:, :-1]

    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    h = jnp.take_along_axis(
        xpad, slot_token[..., None], axis=1).reshape(b, e, cap, d)
    if recipe is not None:
        h = shd.constrain(h, jax.sharding.PartitionSpec(
            recipe.batch_axes, recipe.model_axis, None, None))

    g2 = jax.nn.silu(jnp.einsum("becd,edf->becf", h, p["w_gate"]))
    u = jnp.einsum("becd,edf->becf", h, p["w_up"])
    y = jnp.einsum("becf,efd->becd", g2 * u, p["w_down"])     # (B,E,C,d)

    yf = (y.reshape(b, e * cap, d)
          * slot_gate[..., None].astype(y.dtype))
    out = jnp.zeros((b, s + 1, d), y.dtype).at[rows, slot_token].add(yf)[:, :s]
    return out, aux
