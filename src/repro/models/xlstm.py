"""xLSTM LM (alternating mLSTM / sLSTM blocks, xLSTM paper arXiv:2405.04517).

d_ff = 0 in the assigned config: blocks are pure sequence mixers with
residuals (the mLSTM block carries its own up/down projections via qkv/out;
sLSTM mixes per-head state).  Even layers are mLSTM (parallelizable,
chunked), odd layers sLSTM (true recurrence).  Decode state is O(1) in
sequence length — this arch runs the long_500k cell.

Layers are unrolled at trace time (12 layers, heterogeneous states).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding as shd
from . import ssm
from .config import ModelConfig
from .layers import cross_entropy_loss, dense_init, dtype_of, embed_init, rmsnorm


def _is_mlstm(i: int) -> bool:
    return i % 2 == 0


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg)
    keys = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        kl = keys[i]
        mixer = (ssm.init_mlstm(kl, cfg, dtype) if _is_mlstm(i)
                 else ssm.init_slstm(kl, cfg, dtype))
        layers.append({"norm": jnp.ones((cfg.d_model,), dtype),
                       "mixer": mixer})
    return {
        "embed": embed_init(keys[-3], (cfg.vocab_size, cfg.d_model), dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), dtype),
    }


def _forward(params, cfg, x, states=None, collect_states=False, recipe=None):
    new_states = []
    for i, lp in enumerate(params["layers"]):
        h = rmsnorm(x, lp["norm"], cfg.norm_eps)
        st = states[i] if states is not None else None
        if _is_mlstm(i):
            y, ns = ssm.mlstm_forward(lp["mixer"], cfg, h, state=st)
        else:
            y, ns = ssm.slstm_forward(lp["mixer"], cfg, h, state=st)
        x = shd.act_btd(x + y, recipe)
        new_states.append(ns)
    return x, (new_states if collect_states else None)


def forward_logits(params, cfg: ModelConfig, tokens, recipe=None,
                   remat: bool = True):
    x = params["embed"][tokens].astype(dtype_of(cfg))
    x, _ = _forward(params, cfg, x, recipe=recipe)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"].astype(x.dtype), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch, recipe=None, remat: bool = True):
    logits, _ = forward_logits(params, cfg, batch["tokens"], recipe)
    return cross_entropy_loss(logits, batch["targets"], batch.get("mask"))


def init_cache(cfg: ModelConfig, batch: int, max_len: int, recipe=None):
    return [ssm.mlstm_init_state(cfg, batch) if _is_mlstm(i)
            else ssm.slstm_init_state(cfg, batch)
            for i in range(cfg.n_layers)]


def prefill(params, cfg: ModelConfig, tokens, max_len: int, recipe=None):
    x = params["embed"][tokens].astype(dtype_of(cfg))
    x, states = _forward(params, cfg, x, collect_states=True, recipe=recipe)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"].astype(x.dtype)
    return states, logits


def decode_step(params, cfg: ModelConfig, cache, token, pos, recipe=None):
    x = params["embed"][token][:, None].astype(dtype_of(cfg))
    x, states = _forward(params, cfg, x, states=cache, collect_states=True,
                         recipe=recipe)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, 0] @ params["lm_head"].astype(x.dtype)
    return states, logits
