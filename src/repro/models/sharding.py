"""Activation sharding-constraint hooks (GSPMD side of the hybrid scheme).

The model code is recipe-agnostic: every hook is a no-op when recipe is
None (CPU smoke tests), and emits ``with_sharding_constraint`` with the
recipe's axis names when lowering on the production mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from .config import ShardingRecipe


def _norm(entry):
    """Normalize spec entries: empty axis tuples (manual-region recipes
    strip the data axes) become None."""
    if isinstance(entry, tuple) and len(entry) == 0:
        return None
    return entry


def constrain(x, spec: P | None):
    if spec is None:
        return x
    spec = P(*(_norm(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, spec)


def act_btd(x, recipe: ShardingRecipe | None):
    """(batch, seq, d_model): batch over data axes; seq over model when
    sequence-parallel (context parallelism), else unsharded."""
    if recipe is None:
        return x
    seq = recipe.model_axis if recipe.sequence_parallel else None
    return constrain(x, P(_norm(recipe.batch_axes), seq, None))


def _div_ok(recipe, dim: int) -> bool:
    tp = getattr(recipe, "tp_size", 0)
    return tp == 0 or dim % tp == 0


def act_bthd(x, recipe: ShardingRecipe | None):
    """(batch, seq, heads, head_dim): heads over the model axis (skipped
    when heads don't divide the axis — e.g. whisper's 12 heads on 16)."""
    if recipe is None:
        return x
    m = recipe.model_axis if _div_ok(recipe, x.shape[2]) else None
    return constrain(x, P(_norm(recipe.batch_axes), None, m, None))


def act_btf(x, recipe: ShardingRecipe | None):
    """(batch, seq, d_ff): hidden over the model axis."""
    if recipe is None:
        return x
    m = recipe.model_axis if _div_ok(recipe, x.shape[2]) else None
    return constrain(x, P(_norm(recipe.batch_axes), None, m))


def act_btv(x, recipe: ShardingRecipe | None):
    """(batch, seq, vocab): vocab over the model axis."""
    if recipe is None:
        return x
    m = recipe.model_axis if _div_ok(recipe, x.shape[2]) else None
    return constrain(x, P(_norm(recipe.batch_axes), None, m))


def cache_bthd(x, recipe: ShardingRecipe | None):
    """KV cache (batch, S_max, kv_heads, head_dim): batch over data; kv
    heads over model when they divide, else replicated over model."""
    if recipe is None:
        return x
    return constrain(x, P(_norm(recipe.batch_axes), None, None, None))
