"""Whisper-style encoder-decoder backbone.

The conv frontend is a STUB per the assignment: ``batch["frames"]`` holds
precomputed frame embeddings (B, S_enc, d_model).  Encoder: non-causal
self-attention stack.  Decoder: causal self-attention + cross-attention to
the encoded audio + FFN, trained on text tokens (dec_len).

Cells: train_4k     — enc frames S, dec tokens dec_len, loss on text.
       prefill_32k  — encode S frames + decoder prefill.
       decode_32k   — one decoder step cross-attending a 32k-frame memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import sharding as shd
from .config import ModelConfig
from .layers import (remat_policy_of,
                     cross_entropy_loss, dense_init, dtype_of, embed_init,
                     ffn, init_ffn, rmsnorm)


def _init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ffn": init_ffn(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "norm_x": jnp.ones((cfg.d_model,), dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "xattn": attn.init_attention(k2, cfg, dtype, cross=True),
        "ffn": init_ffn(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
        jax.random.split(k1, cfg.enc_layers))
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
        jax.random.split(k2, cfg.n_layers))
    return {
        "enc_layers": enc,
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "dec_layers": dec,
        "embed": embed_init(k3, (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(k4, (cfg.d_model, cfg.vocab_size), dtype),
    }


def encode(params, cfg, frames, recipe=None, remat: bool = True):
    """frames: (B, S_enc, d_model) stub embeddings -> encoded memory."""
    x = frames.astype(dtype_of(cfg))
    x = shd.act_btd(x, recipe)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, lp):
        h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        a, _ = attn.self_attention(lp["attn"], cfg, h, positions,
                                   causal=False, recipe=recipe)
        x = x + a
        x = x + ffn(lp["ffn"], rmsnorm(x, lp["norm2"], cfg.norm_eps))
        return shd.act_btd(x, recipe), None

    if remat:
        body = jax.checkpoint(
            body, policy=remat_policy_of(cfg))
    x, _ = jax.lax.scan(body, x, params["enc_layers"], unroll=cfg.scan_unroll)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _decoder(params, cfg, tokens, memory, recipe=None, remat: bool = True,
             want_cache: bool = False):
    x = params["embed"][tokens].astype(dtype_of(cfg))
    x = shd.act_btd(x, recipe)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, lp):
        h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        a, kv = attn.self_attention(lp["attn"], cfg, h, positions,
                                    recipe=recipe)
        x = x + a
        mem_kv = attn.project_memory(lp["xattn"], cfg, memory)
        x = x + attn.cross_attention(
            lp["xattn"], cfg, rmsnorm(x, lp["norm_x"], cfg.norm_eps), mem_kv,
            recipe)
        x = x + ffn(lp["ffn"], rmsnorm(x, lp["norm2"], cfg.norm_eps))
        cache = {"k": kv[0], "v": kv[1],
                 "mem_k": mem_kv[0], "mem_v": mem_kv[1]} if want_cache else None
        return shd.act_btd(x, recipe), cache

    if remat and not want_cache:
        body = jax.checkpoint(
            body, policy=remat_policy_of(cfg))
    x, caches = jax.lax.scan(body, x, params["dec_layers"],
                             unroll=cfg.scan_unroll)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, caches


def loss_fn(params, cfg, batch, recipe=None, remat: bool = True):
    memory = encode(params, cfg, batch["frames"], recipe, remat)
    x, _ = _decoder(params, cfg, batch["tokens"], memory, recipe, remat)
    logits = x @ params["lm_head"].astype(x.dtype)
    logits = shd.act_btv(logits, recipe)
    return cross_entropy_loss(logits, batch["targets"], batch.get("mask"))


def forward_logits(params, cfg, tokens, recipe=None, remat: bool = True,
                   frames=None):
    memory = encode(params, cfg, frames, recipe, remat)
    x, _ = _decoder(params, cfg, tokens, memory, recipe, remat)
    return x @ params["lm_head"].astype(x.dtype), jnp.zeros((), jnp.float32)


def prefill(params, cfg, tokens, max_len: int, recipe=None, frames=None):
    """Encode audio + run the decoder prompt.  Cache holds per-layer self
    kv (padded to max_len over DECODER positions) + projected memory kv."""
    b, s = tokens.shape
    memory = encode(params, cfg, frames, recipe, remat=False)
    x, caches = _decoder(params, cfg, tokens, memory, recipe, remat=False,
                         want_cache=True)
    logits = x[:, -1] @ params["lm_head"].astype(x.dtype)
    dtype = dtype_of(cfg)
    dec_max = max(max_len, s)
    full = {
        "k": jnp.zeros((cfg.n_layers, b, dec_max, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
        "v": jnp.zeros((cfg.n_layers, b, dec_max, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
        "mem_k": caches["mem_k"], "mem_v": caches["mem_v"],
    }
    full["k"] = jax.lax.dynamic_update_slice_in_dim(
        full["k"], caches["k"].astype(dtype), 0, axis=2)
    full["v"] = jax.lax.dynamic_update_slice_in_dim(
        full["v"], caches["v"].astype(dtype), 0, axis=2)
    return full, logits


def decode_step(params, cfg, cache, token, pos, recipe=None):
    x = params["embed"][token][:, None].astype(dtype_of(cfg))

    def body(x, inp):
        lp, lc = inp
        h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        kvc = attn.KVCache(lc["k"], lc["v"])
        a, new_kv = attn.decode_self_attention(lp["attn"], cfg, h, kvc, pos)
        x = x + a
        x = x + attn.cross_attention(
            lp["xattn"], cfg, rmsnorm(x, lp["norm_x"], cfg.norm_eps),
            (lc["mem_k"], lc["mem_v"]))
        x = x + ffn(lp["ffn"], rmsnorm(x, lp["norm2"], cfg.norm_eps))
        return x, {"k": new_kv.k, "v": new_kv.v,
                   "mem_k": lc["mem_k"], "mem_v": lc["mem_v"]}

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache),
                                unroll=cfg.scan_unroll)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, 0] @ params["lm_head"].astype(x.dtype)
    return new_cache, logits
