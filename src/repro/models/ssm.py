"""State-space and recurrent sequence mixers.

* ``mamba``  — selective diagonal SSM (hymba's SSM heads): chunked scan —
  within-chunk associative scan, sequential carry across chunks — bounding
  the (B, chunk, d_inner, state) working set to VMEM-friendly sizes
  instead of materializing the full (B, S, d_inner, state) tensor
  (the TPU adaptation of mamba's fused CUDA scan; DESIGN §2).
* ``mlstm``  — xLSTM's matrix-memory LSTM in chunkwise-parallel form:
  intra-chunk masked quadratic + inter-chunk recurrent (C, n) state.
  O(S·chunk) work, O(1)-state decode — this is what makes long_500k
  runnable for the ssm/hybrid archs.
* ``slstm``  — xLSTM's scalar-memory LSTM with exponential gating and the
  paper's m-stabilizer, true recurrence via lax.scan (with per-head
  recurrent weights R).

Numerics note (DESIGN §4): mLSTM uses a sigmoid input gate rather than the
xLSTM paper's unbounded exp gate so that the chunkwise-parallel form is
stable in fp32/bf16 without per-step max tracking; sLSTM keeps the exact
exp gating + stabilizer since its sequential scan makes that free.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_init




def _pick_chunk(s: int, chunk: int) -> int:
    """Largest divisor of s that is <= chunk (assigned shapes are powers of
    two so this stays at the configured chunk; odd smoke lengths degrade
    gracefully)."""
    ch = max(1, min(chunk, s))
    while s % ch:
        ch -= 1
    return ch

# ---------------------------------------------------------------------------
# Mamba-style selective SSM (hymba SSM heads)
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    h: jax.Array      # (B, d_inner, state)
    conv: jax.Array   # (B, conv_k - 1, d_inner) rolling conv window


def init_mamba(key, cfg, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], (d, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, d_in), dtype, scale=0.5),
        "w_dt": dense_init(ks[2], (d_in, 1), dtype),
        "dt_bias": jnp.zeros((d_in,), dtype),
        "w_B": dense_init(ks[3], (d_in, n), dtype),
        "w_C": dense_init(ks[4], (d_in, n), dtype),
        "A_log": jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)
                         )[None, :].repeat(d_in, 0).astype(dtype),
        "D": jnp.ones((d_in,), dtype),
        "w_out": dense_init(ks[5], (d_in, d), dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv along seq.  x (B,S,din), w (K,din).
    state: (B,K-1,din) previous tail or None (zeros)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return out, new_state


def _ssm_scan_chunk(a, b, h0):
    """Linear recurrence h_t = a_t * h_{t-1} + b_t within one chunk via
    associative scan.  a, b: (B, L, d_in, n); h0: (B, d_in, n)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = aa * h0[:, None] + bb
    return h, h[:, -1]


def mamba_forward(p, cfg, x, *, chunk: int = 256, state: MambaState | None = None):
    """x: (B, S, d) -> (y (B, S, d), final MambaState).  S % chunk == 0 or
    S < chunk (single chunk)."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    xz = x @ p["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_state = state.conv if state is not None else None
    xs, conv_tail = _causal_conv(xs, p["conv_w"], conv_state)
    xs = jax.nn.silu(xs)
    dt = jax.nn.softplus(xs @ p["w_dt"] + p["dt_bias"])       # (B,S,d_in)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (d_in, n)
    Bm = xs @ p["w_B"]                                         # (B,S,n)
    Cm = xs @ p["w_C"]                                         # (B,S,n)
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)         # (B,S,d_in,n)
    bterm = (dt * xs).astype(jnp.float32)[..., None] * Bm[:, :, None, :].astype(jnp.float32)
    h0 = (state.h if state is not None
          else jnp.zeros((b, d_in, n), jnp.float32))
    ch = _pick_chunk(s, chunk)
    nch = s // ch

    def step(h_carry, inputs):
        a_c, b_c = inputs                                      # (B,ch,din,n)
        h_all, h_last = _ssm_scan_chunk(a_c, b_c, h_carry)
        return h_last, h_all

    a_ch = a.reshape(b, nch, ch, d_in, n).swapaxes(0, 1)
    b_ch = bterm.reshape(b, nch, ch, d_in, n).swapaxes(0, 1)
    h_last, h_seq = jax.lax.scan(step, h0, (a_ch, b_ch))
    h_seq = h_seq.swapaxes(0, 1).reshape(b, s, d_in, n)
    y = jnp.einsum("bsdn,bsn->bsd", h_seq, Cm.astype(jnp.float32))
    y = (y + p["D"].astype(jnp.float32) * xs.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    return out, MambaState(h=h_last, conv=conv_tail)


def mamba_decode_step(p, cfg, x, state: MambaState):
    """x: (B, 1, d) one token; O(1) state update."""
    out, new_state = mamba_forward(p, cfg, x, chunk=1, state=state)
    return out, new_state


def mamba_init_state(cfg, batch, dtype=jnp.float32) -> MambaState:
    d_in = cfg.ssm_expand * cfg.d_model
    return MambaState(
        h=jnp.zeros((batch, d_in, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype))


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory), chunkwise parallel
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    C: jax.Array    # (B, H, dk, dv)
    n: jax.Array    # (B, H, dk)


def init_mlstm(key, cfg, dtype):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (d, h, dh), dtype),
        "wk": dense_init(ks[1], (d, h, dh), dtype),
        "wv": dense_init(ks[2], (d, h, dh), dtype),
        "wi": dense_init(ks[3], (d, h), dtype),    # input gate (per head)
        "wf": dense_init(ks[4], (d, h), dtype),    # forget gate
        "wo_gate": dense_init(ks[5], (d, h, dh), dtype),  # output gate
        "wo": dense_init(ks[6], (h, dh, d), dtype),
        "f_bias": jnp.full((h,), 3.0, dtype),      # init toward remembering
        "i_bias": jnp.zeros((h,), dtype),
    }


def _mlstm_chunk(q, k, v, lf, li, C0, n0):
    """One chunk.  q,k,v: (B,L,H,dh); lf,li: (B,L,H) log gates (<= 0).
    C0: (B,H,dk,dv); n0: (B,H,dk).  Returns h (B,L,H,dh), C1, n1."""
    bsz, L, H, dh = q.shape
    f32 = jnp.float32
    q, k, v = (t.astype(f32) for t in (q, k, v))
    q = q * (dh ** -0.5)  # scale ONCE so intra (q·k) and inter (q·C, q·n)
    #                       paths stay consistent across chunk boundaries
    lf, li = lf.astype(f32), li.astype(f32)
    cf = jnp.cumsum(lf, axis=1)                    # inclusive prefix
    # Inter-chunk: decay from chunk start to t.
    decay_t = jnp.exp(cf)                          # (B,L,H)
    h_inter = jnp.einsum("blhk,bhkv->blhv", q, C0) * decay_t[..., None]
    d_inter = jnp.einsum("blhk,bhk->blh", q, n0) * decay_t
    # Intra-chunk: w[t,s] = exp(cf_t - cf_s + li_s) for s <= t.
    g = li - cf                                    # (B,L,H)
    logw = cf[:, :, None, :] + g[:, None, :, :]    # (B, t, s, H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(mask[None, :, :, None], jnp.exp(logw), 0.0)
    scores = jnp.einsum("blhk,bshk->blsh", q, k)
    wsc = w * scores
    h_intra = jnp.einsum("blsh,bshv->blhv", wsc, v)
    d_intra = jnp.einsum("blsh->blh", wsc)
    denom = jnp.maximum(jnp.abs(d_inter + d_intra), 1.0)
    h = (h_inter + h_intra) / denom[..., None]
    # State update to end of chunk.
    decay_L = jnp.exp(cf[:, -1])                   # (B,H)
    sdecay = jnp.exp(cf[:, -1:, :] - cf + li)      # (B,L,H)
    C1 = (C0 * decay_L[..., None, None]
          + jnp.einsum("blh,blhk,blhv->bhkv", sdecay, k, v))
    n1 = n0 * decay_L[..., None] + jnp.einsum("blh,blhk->bhk", sdecay, k)
    return h, C1, n1


def mlstm_forward(p, cfg, x, *, state: MLSTMState | None = None):
    b, s, d = x.shape
    h_, dh = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    lf = jax.nn.log_sigmoid(x @ p["wf"] + p["f_bias"])   # (B,S,H) <= 0
    li = jax.nn.log_sigmoid(x @ p["wi"] + p["i_bias"])   # sigmoid input gate
    ch = _pick_chunk(s, cfg.mlstm_chunk)
    nch = s // ch
    C0 = (state.C if state is not None
          else jnp.zeros((b, h_, dh, dh), jnp.float32))
    n0 = (state.n if state is not None
          else jnp.zeros((b, h_, dh), jnp.float32))

    def step(carry, inp):
        C, n = carry
        qc, kc, vc, lfc, lic = inp
        hout, C2, n2 = _mlstm_chunk(qc, kc, vc, lfc, lic, C, n)
        return (C2, n2), hout

    resh = lambda t: t.reshape(b, nch, ch, *t.shape[2:]).swapaxes(0, 1)
    (C1, n1), hs = jax.lax.scan(step, (C0, n0),
                                (resh(q), resh(k), resh(v), resh(lf), resh(li)))
    hseq = hs.swapaxes(0, 1).reshape(b, s, h_, dh)
    og = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, p["wo_gate"]))
    out = jnp.einsum("bshk,hkd->bsd", (hseq * og).astype(x.dtype), p["wo"])
    return out, MLSTMState(C=C1, n=n1)


def mlstm_decode_step(p, cfg, x, state: MLSTMState):
    return mlstm_forward(p, cfg, x, state=state)


def mlstm_init_state(cfg, batch) -> MLSTMState:
    return MLSTMState(
        C=jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                    jnp.float32),
        n=jnp.zeros((batch, cfg.n_heads, cfg.head_dim), jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar memory, exp gating + stabilizer, true recurrence)
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, dh)
    n: jax.Array   # (B, H, dh)
    m: jax.Array   # (B, H, dh) stabilizer
    h: jax.Array   # (B, H, dh) recurrent output


def init_slstm(key, cfg, dtype):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 3)
    return {
        # input projections for gates (z, i, f, o): (d, 4, H, dh)
        "w_x": dense_init(ks[0], (d, 4, h, dh), dtype),
        # per-head recurrent weights: (4, H, dh, dh)
        "r_h": dense_init(ks[1], (4, h, dh, dh), dtype, scale=0.05),
        "bias": jnp.zeros((4, h, dh), dtype),
        "wo": dense_init(ks[2], (h, dh, d), dtype),
        "f_bias_extra": jnp.full((h, dh), 3.0, dtype),
    }


def slstm_step(p, x_proj_t, state: SLSTMState) -> tuple[jax.Array, SLSTMState]:
    """x_proj_t: (B, 4, H, dh) precomputed input contribution at step t."""
    f32 = jnp.float32
    rec = jnp.einsum("bhk,ghkl->bghl", state.h.astype(f32),
                     p["r_h"].astype(f32))
    pre = x_proj_t.astype(f32) + rec + p["bias"].astype(f32)
    z = jnp.tanh(pre[:, 0])
    li = pre[:, 1]                                    # log-space exp gate
    lf = pre[:, 2] + p["f_bias_extra"].astype(f32)
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(lf + state.m, li)             # stabilizer
    i_s = jnp.exp(li - m_new)
    f_s = jnp.exp(lf + state.m - m_new)
    c_new = f_s * state.c + i_s * z
    n_new = f_s * state.n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return h_new, SLSTMState(c=c_new, n=n_new, m=m_new, h=h_new)


def slstm_forward(p, cfg, x, *, state: SLSTMState | None = None):
    b, s, d = x.shape
    h_, dh = cfg.n_heads, cfg.head_dim
    x_proj = jnp.einsum("bsd,dghk->bsghk", x, p["w_x"])  # (B,S,4,H,dh)
    st = state if state is not None else slstm_init_state(cfg, b)

    def step(carry, xp_t):
        h_new, new_state = slstm_step(p, xp_t, carry)
        return new_state, h_new

    final, hs = jax.lax.scan(step, st, x_proj.swapaxes(0, 1))
    hseq = hs.swapaxes(0, 1)                          # (B,S,H,dh)
    out = jnp.einsum("bshk,hkd->bsd", hseq.astype(x.dtype), p["wo"])
    return out, final


def slstm_decode_step(p, cfg, x, state: SLSTMState):
    out, new_state = slstm_forward(p, cfg, x, state=state)
    return out, new_state


def slstm_init_state(cfg, batch) -> SLSTMState:
    shp = (batch, cfg.n_heads, cfg.head_dim)
    z = jnp.zeros(shp, jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full(shp, -1e30, jnp.float32), h=z)
