from .config import ModelConfig, ShardingRecipe  # noqa: F401
from .registry import ModelApi, build, make_param_specs  # noqa: F401
