"""Decoder-only LM covering the dense, MoE and hybrid (hymba) families.

Layers are stacked-pytree + ``lax.scan`` (compact HLO: compile time and
program size are per-layer, not per-model).  The hybrid family (hymba) is
instead UNROLLED at trace time: its per-layer global-vs-sliding-window
flag must stay static so each layer makes exactly one attention call with
a static window.  Three entry points:

  loss(params, batch)                    — training (causal LM)
  prefill(params, tokens) -> (cache, logits)
  decode_step(params, cache, token, pos) -> (cache, logits)

Hybrid (hymba) blocks run attention heads and mamba heads in PARALLEL on
the same normed input and fuse via per-branch RMS norms (Hymba §2; meta
tokens omitted — DESIGN §4).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import sharding as shd
from . import ssm
from .config import ModelConfig
from .layers import (remat_policy_of,
                     cross_entropy_loss, dense_init, dtype_of, embed_init,
                     ffn, init_ffn, rmsnorm)
from .moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention(ks[0], cfg, dtype),
    }
    if cfg.family == "hybrid":
        p["mamba"] = ssm.init_mamba(ks[1], cfg, dtype)
        p["norm_attn_out"] = jnp.ones((cfg.d_model,), dtype)
        p["norm_ssm_out"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.is_moe:
        p["moe"] = init_moe(ks[2], cfg, dtype)
    elif cfg.d_ff > 0:
        p["ffn"] = init_ffn(ks[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg)
    k_emb, k_layers, k_head, k_norm = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                       dtype)
    return params


def _layer_slice(layers, i):
    return jax.tree.map(lambda a: a[i], layers)


def _hybrid_runs(cfg):
    """Partition [0, n_layers) into maximal contiguous runs of equal
    is_global flag: [(lo, hi, is_global), ...]."""
    runs = []
    lo = 0
    for i in range(1, cfg.n_layers + 1):
        flag_prev = (i - 1) in cfg.global_attn_layers
        if i == cfg.n_layers or (i in cfg.global_attn_layers) != flag_prev:
            runs.append((lo, i, flag_prev))
            lo = i
    return runs


# ---------------------------------------------------------------------------
# Layer forward (training / prefill)
# ---------------------------------------------------------------------------

def _layer_forward(lp, cfg: ModelConfig, x, positions, is_global: bool,
                   recipe, want_cache: bool):
    """is_global is a STATIC python bool.  Returns (x, aux, cache|None)."""
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        window = 0 if is_global else cfg.sliding_window
        a, kv = attn.self_attention(lp["attn"], cfg, h, positions,
                                    window=window, recipe=recipe)
        m, mstate = ssm.mamba_forward(
            lp["mamba"], cfg, h, chunk=min(cfg.mlstm_chunk, h.shape[1]))
        mix = 0.5 * (rmsnorm(a, lp["norm_attn_out"], cfg.norm_eps)
                     + rmsnorm(m, lp["norm_ssm_out"], cfg.norm_eps))
        x = x + mix
    else:
        mstate = None
        a, kv = attn.self_attention(lp["attn"], cfg, h, positions,
                                    window=cfg.sliding_window, recipe=recipe)
        x = x + a
    x = shd.act_btd(x, recipe)
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        y, aux = moe_ffn(lp["moe"], cfg, rmsnorm(x, lp["norm2"], cfg.norm_eps),
                         recipe)
        x = x + y
    elif cfg.d_ff > 0:
        x = x + ffn(lp["ffn"], rmsnorm(x, lp["norm2"], cfg.norm_eps))
    x = shd.act_btd(x, recipe)
    cache = None
    if want_cache:
        cache = {"k": kv[0], "v": kv[1]}
        if mstate is not None:
            cache["mamba"] = mstate
    return x, aux, cache


def _stack_forward(params, cfg, x, positions, recipe, want_cache: bool,
                   remat: bool):
    """Hybrid: trace-time unroll (static per-layer windows).
    Others: lax.scan over stacked layer params."""
    if cfg.family == "hybrid":
        # Contiguous runs of same-window layers SCAN (compact HLO, fast
        # SPMD compile); the few global-attention layers are unrolled so
        # is_global stays static per call.
        aux_sum = jnp.zeros((), jnp.float32)
        cache_chunks = []
        fwd = _layer_forward
        if remat:
            fwd = jax.checkpoint(
                _layer_forward,
                policy=remat_policy_of(cfg),
                static_argnums=(1, 4, 5, 6))  # cfg, is_global, recipe, want

        def swa_body(carry, lp):
            x, aux_sum = carry
            x, aux, cache = fwd(lp, cfg, x, positions, False, recipe,
                                want_cache)
            return (x, aux_sum + aux), cache

        for lo, hi, is_global in _hybrid_runs(cfg):
            if is_global or hi - lo == 1:
                for i in range(lo, hi):
                    lp = _layer_slice(params["layers"], i)
                    x, aux, cache = fwd(lp, cfg, x, positions, is_global,
                                        recipe, want_cache)
                    aux_sum = aux_sum + aux
                    if want_cache:
                        cache_chunks.append(
                            jax.tree.map(lambda a: a[None], cache))
            else:
                seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
                (x, aux_sum), caches = jax.lax.scan(
                    swa_body, (x, aux_sum), seg, unroll=cfg.scan_unroll)
                if want_cache:
                    cache_chunks.append(caches)
        stacked = (jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                *cache_chunks) if want_cache else None)
        return x, aux_sum, stacked

    def body(carry, lp):
        x, aux_sum = carry
        x, aux, cache = _layer_forward(lp, cfg, x, positions, False, recipe,
                                       want_cache)
        return (x, aux_sum + aux), cache

    if remat:
        body = jax.checkpoint(
            body, policy=remat_policy_of(cfg))
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["layers"],
        unroll=cfg.scan_unroll)
    return x, aux, caches


# ---------------------------------------------------------------------------
# Public: loss / logits
# ---------------------------------------------------------------------------

def forward_logits(params, cfg: ModelConfig, tokens, recipe=None,
                   remat: bool = True):
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dtype_of(cfg))
    x = shd.act_btd(x, recipe)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, aux, _ = _stack_forward(params, cfg, x, positions, recipe,
                               want_cache=False, remat=remat)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    return shd.act_btv(logits, recipe), aux


def loss_fn(params, cfg: ModelConfig, batch, recipe=None, remat: bool = True):
    logits, aux = forward_logits(params, cfg, batch["tokens"], recipe, remat)
    return cross_entropy_loss(logits, batch["targets"],
                              batch.get("mask")) + aux


# ---------------------------------------------------------------------------
# Public: prefill / decode with cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, recipe=None):
    dtype = dtype_of(cfg)
    kv_len = min(max_len, cfg.sliding_window) if (
        cfg.family == "hybrid" and cfg.sliding_window) else max_len
    # NOTE: hybrid SWA layers only ever attend within the window, but the
    # global layers need full length; we size every layer to max_len for
    # scan homogeneity (a paged cache would split them; see DESIGN §3).
    kv = {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
    }
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        kv["mamba"] = ssm.MambaState(
            h=jnp.zeros((cfg.n_layers, batch, d_in, cfg.ssm_state),
                        jnp.float32),
            conv=jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, d_in),
                           dtype))
    return kv


def prefill(params, cfg: ModelConfig, tokens, max_len: int, recipe=None):
    """Run the prompt, return (cache, last-token logits)."""
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dtype_of(cfg))
    x = shd.act_btd(x, recipe)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, _, caches = _stack_forward(params, cfg, x, positions, recipe,
                                  want_cache=True, remat=False)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x[:, -1] @ head.astype(x.dtype)
    cache = init_cache(cfg, b, max_len, recipe)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], caches["k"].astype(cache["k"].dtype), 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], caches["v"].astype(cache["v"].dtype), 0, axis=2)
    if cfg.family == "hybrid":
        cache["mamba"] = caches["mamba"]
    return cache, logits


def _decode_layer(lp, cfg, x, layer_cache, pos, is_global: bool, recipe):
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    kvc = attn.KVCache(layer_cache["k"], layer_cache["v"])
    if cfg.family == "hybrid":
        window = 0 if is_global else cfg.sliding_window
        a, new_kv = attn.decode_self_attention(lp["attn"], cfg, h, kvc, pos,
                                               window, recipe)
        m, mstate = ssm.mamba_decode_step(lp["mamba"], cfg, h,
                                          layer_cache["mamba"])
        mix = 0.5 * (rmsnorm(a, lp["norm_attn_out"], cfg.norm_eps)
                     + rmsnorm(m, lp["norm_ssm_out"], cfg.norm_eps))
        x = x + mix
    else:
        mstate = None
        a, new_kv = attn.decode_self_attention(lp["attn"], cfg, h, kvc, pos,
                                               cfg.sliding_window, recipe)
        x = x + a
    if cfg.is_moe:
        y, _ = moe_ffn(lp["moe"], cfg, rmsnorm(x, lp["norm2"], cfg.norm_eps),
                       recipe)
        x = x + y
    elif cfg.d_ff > 0:
        x = x + ffn(lp["ffn"], rmsnorm(x, lp["norm2"], cfg.norm_eps))
    out_cache = {"k": new_kv.k, "v": new_kv.v}
    if mstate is not None:
        out_cache["mamba"] = mstate
    return x, out_cache


def decode_step(params, cfg: ModelConfig, cache, token, pos, recipe=None):
    """token: (B,) int32; pos: scalar int32 (whole batch at one offset)
    or (B,) int32 per-request offsets (continuous batching).  Returns
    (cache, logits)."""
    x = params["embed"][token][:, None].astype(dtype_of(cfg))

    if cfg.family == "hybrid":
        cache_chunks = []

        def swa_body(x, inp):
            lp, lc = inp
            x, nc = _decode_layer(lp, cfg, x, lc, pos, False, recipe)
            return x, nc

        for lo, hi, is_global in _hybrid_runs(cfg):
            if is_global or hi - lo == 1:
                for i in range(lo, hi):
                    lp = _layer_slice(params["layers"], i)
                    lc = _layer_slice(cache, i)
                    x, nc = _decode_layer(lp, cfg, x, lc, pos, is_global,
                                          recipe)
                    cache_chunks.append(jax.tree.map(lambda a: a[None], nc))
            else:
                seg_p = jax.tree.map(lambda a: a[lo:hi], params["layers"])
                seg_c = jax.tree.map(lambda a: a[lo:hi], cache)
                x, ncs = jax.lax.scan(swa_body, x, (seg_p, seg_c),
                                      unroll=cfg.scan_unroll)
                cache_chunks.append(ncs)
        new_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                 *cache_chunks)
    else:
        def body(x, inp):
            lp, lc = inp
            x, nc = _decode_layer(lp, cfg, x, lc, pos, False, recipe)
            return x, nc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                    unroll=cfg.scan_unroll)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x[:, 0] @ head.astype(x.dtype)
    return new_cache, logits
