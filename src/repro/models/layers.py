"""Shared neural building blocks (pure JAX, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (LeCun-ish)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale if scale is not None else fan_in ** -0.5
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, gamma, eps: float):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(x, gamma, eps: float):
    """Per-head qk-norm (qwen3): x (..., H, dh), gamma (dh,)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(dh, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN (SwiGLU)
# ---------------------------------------------------------------------------

def init_ffn(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def ffn(params, x):
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy_loss(logits, targets, mask=None):
    """Mean token cross-entropy in fp32.  logits (..., V), targets (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def remat_policy_of(cfg):
    import jax
    if getattr(cfg, "remat_policy", "nothing") == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable
