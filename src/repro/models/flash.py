"""Chunked online-softmax attention (flash-style) in pure JAX.

Long-sequence cells (train_4k, prefill_32k) cannot materialize S×S score
tensors (32k² fp32 = 4 GiB per head); this computes attention in
(q_chunk × k_chunk) tiles with the standard running-max/running-sum
rescaling, O(S·chunk) live memory.  The per-q-chunk body is wrapped in
``jax.checkpoint`` so the backward pass recomputes tile scores instead of
saving them (the flash-backward memory law).

GQA grouping, causal masking and sliding windows are handled via position
arithmetic per tile — no global mask tensor ever exists.  On TPU this
lowers to MXU-sized einsums over VMEM-resident tiles; the same structure
is what a hand-written Pallas flash kernel would express (kept in XLA-land
here because the paper's kernels are the collectives, not attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _tile_mask(q_pos, k_pos, causal: bool, window: int):
    """(cq, ck) boolean mask from absolute positions."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    return ok


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, chunk_q: int = 512,
                    chunk_k: int = 1024):
    """q: (B, Sq, H, dh); k, v: (B, Sk, Hkv, dh).  H = G * Hkv.
    Positions are implicit: q token i has position q_offset + i, k token j
    has position j (standard prefill/training layout).
    Returns (B, Sq, H, dh)."""
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    cq = min(chunk_q, sq)
    while sq % cq:
        cq -= 1
    ck = min(chunk_k, sk)
    while sk % ck:
        ck -= 1
    nq, nk = sq // cq, sk // ck
    scale = dh ** -0.5
    qg = q.reshape(b, nq, cq, hkv, g, dh).astype(jnp.float32) * scale
    kc = k.reshape(b, nk, ck, hkv, dh).astype(jnp.float32)
    vc = v.reshape(b, nk, ck, hkv, dh).astype(jnp.float32)

    @functools.partial(jax.checkpoint, policy=None)
    def q_chunk_body(qi_idx, q_tile):
        """q_tile: (B, cq, Hkv, G, dh) -> out tile."""
        q_pos = q_offset + qi_idx * cq + jnp.arange(cq)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj_idx, k_tile, v_tile = inp
            k_pos = kj_idx * ck + jnp.arange(ck)
            s = jnp.einsum("bqkgd,bckd->bkgqc", q_tile, k_tile)
            mask = _tile_mask(q_pos, k_pos, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            pr = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pr.sum(-1)
            acc_new = (acc * alpha[..., None]
                       + jnp.einsum("bkgqc,bckd->bkgqd", pr, v_tile))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kc.swapaxes(0, 1), vc.swapaxes(0, 1)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, Hkv, G, cq, dh)

    def outer(_, inp):
        qi_idx, q_tile = inp
        return None, q_chunk_body(qi_idx, q_tile)

    _, tiles = jax.lax.scan(outer, None,
                            (jnp.arange(nq), qg.swapaxes(0, 1)))
    # tiles: (nq, B, Hkv, G, cq, dh) -> (B, Sq, H, dh)
    out = tiles.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, dh)
    return out.astype(q.dtype)
