"""Llama-3.2-Vision-style decoder: interleaved gated cross-attention layers.

100 layers = 20 groups of [4 self-attention layers + 1 gated cross-attn
layer].  The vision tower is a STUB per the assignment:
``batch["image_embeds"]`` holds precomputed patch embeddings
(B, n_image_tokens, d_model).  Cross layers use tanh-gated residuals
(zero-init gate: the model starts as a pure LM, the Llama-3.2 recipe).

Scan is over groups; the 4 self layers inside a group are unrolled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import sharding as shd
from .config import ModelConfig
from .layers import (remat_policy_of,
                     cross_entropy_loss, dense_init, dtype_of, embed_init,
                     ffn, init_ffn, rmsnorm)

SELF_PER_GROUP = 4


def _n_groups(cfg) -> int:
    assert cfg.n_layers % (SELF_PER_GROUP + 1) == 0, \
        f"vlm needs n_layers % {SELF_PER_GROUP + 1} == 0, got {cfg.n_layers}"
    return cfg.n_layers // (SELF_PER_GROUP + 1)


def _init_self_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ffn": init_ffn(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_cross_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "xattn": attn.init_attention(k1, cfg, dtype, cross=True),
        "ffn": init_ffn(k2, cfg.d_model, cfg.d_ff, dtype),
        "gate_attn": jnp.zeros((), dtype),
        "gate_ffn": jnp.zeros((), dtype),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg)
    ng = _n_groups(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    self_keys = jax.random.split(k1, ng * SELF_PER_GROUP).reshape(
        ng, SELF_PER_GROUP, 2)
    selfs = jax.vmap(jax.vmap(lambda k: _init_self_layer(k, cfg, dtype)))(
        self_keys)
    crosses = jax.vmap(lambda k: _init_cross_layer(k, cfg, dtype))(
        jax.random.split(k2, ng))
    return {
        "embed": embed_init(k3, (cfg.vocab_size, cfg.d_model), dtype),
        "self_layers": selfs,     # (G, 4, ...)
        "cross_layers": crosses,  # (G, ...)
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(k4, (cfg.d_model, cfg.vocab_size), dtype),
    }


def _self_layer(lp, cfg, x, positions, recipe, want_cache):
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    a, kv = attn.self_attention(lp["attn"], cfg, h, positions, recipe=recipe)
    x = x + a
    x = x + ffn(lp["ffn"], rmsnorm(x, lp["norm2"], cfg.norm_eps))
    cache = {"k": kv[0], "v": kv[1]} if want_cache else None
    return shd.act_btd(x, recipe), cache


def _cross_layer(lp, cfg, x, img_kv, recipe):
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    a = attn.cross_attention(lp["xattn"], cfg, h, img_kv, recipe)
    x = x + jnp.tanh(lp["gate_attn"]) * a
    y = ffn(lp["ffn"], rmsnorm(x, lp["norm2"], cfg.norm_eps))
    x = x + jnp.tanh(lp["gate_ffn"]) * y
    return shd.act_btd(x, recipe)


def _stack(params, cfg, x, positions, image_embeds, recipe, remat,
           want_cache=False):
    def group_body(x, gp):
        sp, cp = gp  # self params (4, ...), cross params
        caches = []
        for i in range(SELF_PER_GROUP):
            lp = jax.tree.map(lambda a: a[i], sp)
            x, c = _self_layer(lp, cfg, x, positions, recipe, want_cache)
            caches.append(c)
        img_kv = attn.project_memory(cp["xattn"], cfg, image_embeds)
        x = _cross_layer(cp, cfg, x, img_kv, recipe)
        stacked = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
                   if want_cache else None)
        return x, stacked

    if remat and not want_cache:
        group_body = jax.checkpoint(
            group_body, policy=remat_policy_of(cfg))
    x, caches = jax.lax.scan(group_body, x,
                             (params["self_layers"], params["cross_layers"]),
                             unroll=cfg.scan_unroll)
    return x, caches


def forward_logits(params, cfg, tokens, recipe=None, remat: bool = True,
                   image_embeds=None):
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dtype_of(cfg))
    x = shd.act_btd(x, recipe)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    img = image_embeds.astype(dtype_of(cfg))
    x, _ = _stack(params, cfg, x, positions, img, recipe, remat)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(x.dtype)
    return shd.act_btv(logits, recipe), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch, recipe=None, remat: bool = True):
    logits, _ = forward_logits(params, cfg, batch["tokens"], recipe, remat,
                               image_embeds=batch["image_embeds"])
    return cross_entropy_loss(logits, batch["targets"], batch.get("mask"))


def prefill(params, cfg, tokens, max_len: int, recipe=None, image_embeds=None):
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dtype_of(cfg))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    img = image_embeds.astype(dtype_of(cfg))
    x, caches = _stack(params, cfg, x, positions, img, recipe, remat=False,
                       want_cache=True)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, -1] @ params["lm_head"].astype(x.dtype)
    ng = _n_groups(cfg)
    dtype = dtype_of(cfg)
    full = {
        "k": jnp.zeros((ng, SELF_PER_GROUP, b, max_len, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
        "v": jnp.zeros((ng, SELF_PER_GROUP, b, max_len, cfg.n_kv_heads,
                        cfg.head_dim), dtype),
    }
    full["k"] = jax.lax.dynamic_update_slice_in_dim(
        full["k"], caches["k"].astype(dtype), 0, axis=3)
    full["v"] = jax.lax.dynamic_update_slice_in_dim(
        full["v"], caches["v"].astype(dtype), 0, axis=3)
    # Project image kv once; reused every decode step.
    def proj(cp):
        return attn.project_memory(cp["xattn"], cfg, img)
    full["img_k"], full["img_v"] = jax.vmap(proj)(params["cross_layers"])
    return full, logits


def decode_step(params, cfg, cache, token, pos, recipe=None):
    x = params["embed"][token][:, None].astype(dtype_of(cfg))

    def group_body(x, inp):
        sp, cp, kc, vc, ik, iv = inp
        new_k, new_v = [], []
        for i in range(SELF_PER_GROUP):
            lp = jax.tree.map(lambda a: a[i], sp)
            h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
            kvc = attn.KVCache(kc[i], vc[i])
            a, nkv = attn.decode_self_attention(lp["attn"], cfg, h, kvc, pos)
            x = x + a
            x = x + ffn(lp["ffn"], rmsnorm(x, lp["norm2"], cfg.norm_eps))
            new_k.append(nkv.k)
            new_v.append(nkv.v)
        x = _cross_layer(cp, cfg, x, (ik, iv), None)
        return x, (jnp.stack(new_k), jnp.stack(new_v))

    x, (nk, nv) = jax.lax.scan(
        group_body, x,
        (params["self_layers"], params["cross_layers"],
         cache["k"], cache["v"], cache["img_k"], cache["img_v"]),
        unroll=cfg.scan_unroll)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x[:, 0] @ params["lm_head"].astype(x.dtype)
    new_cache = {"k": nk, "v": nv,
                 "img_k": cache["img_k"], "img_v": cache["img_v"]}
    return new_cache, logits
