"""MoE dispatch stages — router → dispatch → expert FFN → combine.

One set of composable stages behind every ``cfg.moe_dispatch`` mode:

  global    one flat token pool (the stages applied directly);
  rowwise   per-sequence pools (§Perf C) — the SAME stages under
            ``jax.vmap`` over the batch dim, so argsort/cumsum/scatter
            keep a batch axis and GSPMD never gathers the full token set
            to one partition;
  ep        expert parallelism over a MANUAL mesh axis (``cfg.ep_axis``):
            the local ``(E, C, d)`` dispatch buffer is exchanged with the
            circulant alltoall plan (paper §4 — ``ceil(log2 p)``
            collective-permutes per exchange) and the ragged per-expert
            routed-token counts with the alltoallv table backend, experts
            run on their owner rank, and results return by the reverse
            exchange.

The stages all use SPMD-friendly static shapes: tokens are argsorted by
expert assignment, positioned within their expert via a counts/starts
prefix sum, dropped beyond capacity ``C = min(ceil(cf·N·K/E) rounded up
to 8, N·K)``, gathered into an ``(E, C, d)`` buffer, run through batched
expert FFNs (one einsum), and scatter-added back weighted by their
router gates — the standard "dropping" MoE of production JAX LLM stacks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.core.spec import CollectiveSpec
from . import sharding as shd


def capacity(cfg, n_tokens: int) -> int:
    """Per-expert slot count for an ``n_tokens`` pool.

    ``ceil(cf · N · K / E)`` rounded up to a multiple of 8 (TPU lane
    friendliness), clamped to ``N·K`` — a pool can never fill more than
    N·K slots total, so tiny pools (N·K < E) must not blow up to an
    all-padding buffer — and to at least 1.
    """
    n, k = n_tokens, cfg.experts_per_token
    c = int(cfg.capacity_factor * n * k / cfg.n_experts) + 1
    c = max(8, -(-c // 8) * 8)  # round up to multiple of 8
    return max(1, min(c, n * k))


# ---------------------------------------------------------------------------
# Stages (flat token pool; vmap for per-sequence pools)
# ---------------------------------------------------------------------------

def route(router_w, cfg, x):
    """Router stage.  ``x``: (*B, n, d) → (gate (*B, n, K) renormalized,
    expert_idx (*B, n, K), probs (*B, n, E) fp32)."""
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return gate, expert_idx, probs


def aux_loss(cfg, probs, expert_idx):
    """Switch-style load-balancing loss, averaged over leading batch dims
    (matches the historical per-pool scatter-add numerics: the one-hot
    token counts are exact integers, so the fraction is bitwise equal)."""
    e = cfg.n_experts
    n, k = expert_idx.shape[-2], expert_idx.shape[-1]
    frac = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum((-3, -2)) \
        / (n * k)
    mean_probs = probs.mean(-2)
    per_pool = e * jnp.sum(frac * mean_probs, axis=-1)
    return jnp.mean(per_pool) * cfg.router_aux_coef


def dispatch_tables(cfg, expert_idx, gate, cap: int):
    """Sort-based capacity dispatch over ONE flat pool.

    ``expert_idx``/``gate``: (n, K).  Returns ``(slot_token, slot_gate,
    routed)`` where ``slot_token[e*cap + c]`` is the token filling slot c
    of expert e (``n`` = the padded trash token when empty),
    ``slot_gate`` its renormalized router weight, and ``routed[e]`` the
    number of slots expert e actually filled (counts clipped to ``cap`` —
    the per-expert token loads the ep mode ships over alltoallv).
    """
    n, k = expert_idx.shape
    e = cfg.n_experts
    flat_e = expert_idx.reshape(-1)                        # (n*K,)
    sort_idx = jnp.argsort(flat_e)                         # stable
    sorted_e = flat_e[sort_idx]
    counts = jnp.zeros(e, jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # trash slot
    token_of = (sort_idx // k).astype(jnp.int32)
    gate_of = gate.reshape(-1)[sort_idx]

    slot_token = jnp.full(e * cap + 1, n, jnp.int32).at[slot].set(token_of)
    slot_gate = jnp.zeros(e * cap + 1, jnp.float32).at[slot].set(gate_of)
    return (slot_token[:-1], slot_gate[:-1],
            jnp.minimum(counts, cap).astype(jnp.int32))


def gather_tokens(xf, slot_token, e: int, cap: int):
    """Fill the (E, C, d) dispatch buffer: slot → token row (the trash
    token gathers a zero row, so unfilled slots are exactly zero)."""
    n, d = xf.shape
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    return xpad[slot_token].reshape(e, cap, d)


def expert_ffn(p, h):
    """Batched expert SwiGLU.  ``h``: (*B, E, C, d) against stacked
    expert weights (E, d, ff) — the E axis must line up with the weights'
    leading axis (ep passes its local expert slice)."""
    g = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", h, p["w_gate"]))
    u = jnp.einsum("...ecd,edf->...ecf", h, p["w_up"])
    return jnp.einsum("...ecf,efd->...ecd", g * u, p["w_down"])


def combine(y, slot_token, slot_gate, n: int):
    """Scatter-add expert outputs back to their tokens, gate-weighted.
    ``y``: (E, C, d) flat-pool expert outputs → (n, d)."""
    e_cap, d = y.shape[0] * y.shape[1], y.shape[2]
    yf = y.reshape(e_cap, d) * slot_gate[:, None].astype(y.dtype)
    return jnp.zeros((n + 1, d), y.dtype).at[slot_token].add(yf)[:n]


# ---------------------------------------------------------------------------
# moe_dispatch="global" — one flat pool
# ---------------------------------------------------------------------------

def moe_ffn_global(p, cfg, x, recipe=None):
    b, s, d = x.shape
    n = b * s
    e = cfg.n_experts
    xf = x.reshape(n, d)
    gate, expert_idx, probs = route(p["router"], cfg, xf)
    aux = aux_loss(cfg, probs, expert_idx)
    cap = capacity(cfg, n)
    slot_token, slot_gate, _ = dispatch_tables(cfg, expert_idx, gate, cap)
    h = gather_tokens(xf, slot_token, e, cap)              # (E, C, d)
    if recipe is not None:
        h = shd.constrain(h, jax.sharding.PartitionSpec(
            recipe.model_axis, None, None))
    y = expert_ffn(p, h)                                   # (E, C, d)
    out = combine(y, slot_token, slot_gate, n)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# moe_dispatch="rowwise" — per-sequence pools (§Perf C) = vmapped stages
# ---------------------------------------------------------------------------

def moe_ffn_rowwise(p, cfg, x, recipe=None):
    """Per-sequence dispatch: every sort/positioning/scatter op carries
    the batch dim (the stages under ``vmap``), which stays sharded over
    the data axes — XLA's sort on a sharded dim otherwise all-gathers the
    full token pool.  Capacity is per sequence: ``C_b = capacity(S)``.
    Token dropping is per-sequence (slightly stricter than global
    dropping; same expected load)."""
    b, s, d = x.shape
    e = cfg.n_experts
    cap = capacity(cfg, s)

    gate, expert_idx, probs = route(p["router"], cfg, x)   # (B, S, ·)
    aux = aux_loss(cfg, probs, expert_idx)

    tables = jax.vmap(functools.partial(dispatch_tables, cfg, cap=cap))
    slot_token, slot_gate, _ = tables(expert_idx, gate)
    h = jax.vmap(functools.partial(gather_tokens, e=e, cap=cap))(
        x, slot_token)                                     # (B, E, C, d)
    if recipe is not None:
        h = shd.constrain(h, jax.sharding.PartitionSpec(
            recipe.batch_axes, recipe.model_axis, None, None))
    y = expert_ffn(p, h)                                   # (B, E, C, d)
    out = jax.vmap(functools.partial(combine, n=s))(y, slot_token, slot_gate)
    return out, aux


# ---------------------------------------------------------------------------
# moe_dispatch="ep" — expert parallelism over a manual mesh axis
# ---------------------------------------------------------------------------

def expert_owners(e: int, pe: int) -> tuple[int, ...]:
    """Experts owned per rank (contiguous blocks, low ranks get the
    remainder): ragged when ``e % pe != 0`` — the static per-pair
    raggedness the counts exchange ships over alltoallv."""
    base, rem = divmod(e, pe)
    return tuple(base + (j < rem) for j in range(pe))


def ep_collective_specs(cfg, pe: int) -> tuple[CollectiveSpec, ...]:
    """The CollectiveSpecs ep dispatch executes on axis ``cfg.ep_axis``
    (exposed so train-step builders can fail fast and pre-warm the plan
    cache): the uniform circulant alltoall moving the padded dispatch
    buffer (out and back) and the ragged alltoallv moving the per-expert
    routed-token counts."""
    own = expert_owners(cfg.n_experts, pe)
    counts = tuple(own for _ in range(pe))   # [src][dst] = experts of dst
    return (CollectiveSpec(), CollectiveSpec(counts=counts))


def _ep_pad_table(own: tuple[int, ...], pe: int, own_max: int) -> np.ndarray:
    """(pe, pe·own_max) gather table: padded (src, local-expert) slot →
    row of the rank's ragged alltoallv output (src-major, ``own[r]``
    real experts per src), sentinel = the zero row appended past it."""
    out_h = max(pe * o for o in own)
    tab = np.full((pe, pe * own_max), out_h, dtype=np.int32)
    for r in range(pe):
        for src in range(pe):
            tab[r, src * own_max: src * own_max + own[r]] = np.arange(
                src * own[r], (src + 1) * own[r], dtype=np.int32)
    return tab


def _ep_expert_grid(own: tuple[int, ...], e: int) -> tuple[np.ndarray,
                                                           np.ndarray]:
    """Static index maps between the real contiguous expert numbering and
    the owner-padded grid (owner j holds padded slots [j·own_max,
    (j+1)·own_max), the first ``own[j]`` of them real).

    Returns ``(pad_idx, inv_idx)``: ``pad_idx[slot]`` is the real expert
    filling a padded slot (sentinel ``e`` — a zero row — for phantom
    slots), ``inv_idx[expert]`` the padded slot of a real expert.
    """
    pe, own_max = len(own), max(own)
    off = np.concatenate([[0], np.cumsum(own)]).astype(np.int32)
    pad_idx = np.full(pe * own_max, e, dtype=np.int32)
    inv_idx = np.zeros(e, dtype=np.int32)
    for j in range(pe):
        for i in range(own[j]):
            pad_idx[j * own_max + i] = off[j] + i
            inv_idx[off[j] + i] = j * own_max + i
    return pad_idx, inv_idx


def moe_ffn_ep(p, cfg, x, recipe=None):
    """Expert-parallel MoE dispatch over the manual axis ``cfg.ep_axis``.

    Must run inside a shard_map region binding that axis, with the expert
    weights replicated over it (each rank slices its own experts).  Per
    layer call: route + dispatch locally, exchange the capacity-padded
    ``(E_pad, C, d)`` buffer to the expert owners with the circulant
    alltoall plan (``ceil(log2 p)`` collective-permutes), exchange the
    ragged per-expert routed-token counts with the alltoallv backend
    (``e % p`` experts make the per-pair counts genuinely non-uniform),
    run the local experts' FFN on their gathered slots (masked to the
    routed counts, so phantom/over-capacity slots are exactly zero),
    reverse the exchange, and combine locally.  The aux loss psums the
    per-rank router statistics, so it equals the global-pool loss.
    """
    axis = cfg.ep_axis
    try:
        pe = compat.axis_size(axis)
    except Exception as err:  # NameError-ish: axis not bound
        raise ValueError(
            f"moe_dispatch='ep' needs mesh axis {axis!r} bound as a MANUAL "
            f"axis (run inside shard_map; see ModelConfig.ep_axis)"
        ) from err
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(n, d)

    gate, expert_idx, probs = route(p["router"], cfg, xf)
    # Aux loss on the GLOBAL pool statistics: the load fraction and mean
    # router probs are linear in the tokens, so pmean-ing them before the
    # product reproduces the single-pool loss exactly.
    frac = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum((0, 1)) \
        / (n * k)
    mean_probs = probs.mean(0)
    frac = lax.pmean(frac, axis)
    mean_probs = lax.pmean(mean_probs, axis)
    aux = e * jnp.sum(frac * mean_probs) * cfg.router_aux_coef

    cap = capacity(cfg, n)
    slot_token, slot_gate, routed = dispatch_tables(cfg, expert_idx, gate,
                                                    cap)
    h = gather_tokens(xf, slot_token, e, cap)              # (E, C, d)

    own = expert_owners(e, pe)
    own_max = max(own)
    buf_spec, cnt_spec = ep_collective_specs(cfg, pe)
    from repro.core.plan import plan as _plan
    buf_plan = _plan(buf_spec, p=pe, axis_name=axis)
    cnt_plan = _plan(cnt_spec, p=pe, axis_name=axis)

    # --- exchange routed counts (ragged alltoallv: one int32 row per
    # REAL expert, destination-ordered because ownership is contiguous —
    # every rank sends exactly e rows, so the wire input needs no pad).
    assert cnt_plan.a2a.in_height == e, (cnt_plan.a2a.in_height, e)
    cnt_in = routed.reshape(e, 1)
    cnt_out = cnt_plan.alltoall(cnt_in)        # (max_r pe·own_r, 1)
    # Lay the ragged (src-major) count rows into the padded (pe, own_max)
    # grid; phantom experts read the appended zero row.
    cz = jnp.concatenate([cnt_out[:, 0], jnp.zeros((1,), jnp.int32)])
    r = lax.axis_index(axis)
    pad_tab = _ep_pad_table(own, pe, own_max)
    cnt_grid = jnp.take(cz, lax.dynamic_index_in_dim(
        jnp.asarray(pad_tab), r, axis=0, keepdims=False))  # (pe·own_max,)
    cnt_grid = cnt_grid.reshape(pe, own_max)   # [src, local expert]

    # --- exchange the dispatch buffer (uniform alltoall over the
    # owner-padded expert grid; phantom slots carry zero rows).
    pad_idx, inv_idx = _ep_expert_grid(own, e)
    hz = jnp.concatenate([h, jnp.zeros((1, cap, d), h.dtype)], axis=0)
    blocks = hz[pad_idx].reshape(pe, own_max * cap, d)
    got = buf_plan.alltoall(blocks)            # row j = from rank j
    hloc = got.reshape(pe, own_max, cap, d)    # [src, local expert, slot]
    # Mask slots past each (src, expert) routed count: over-capacity and
    # phantom slots are exactly zero entering the FFN.
    mask = jnp.arange(cap) < cnt_grid[..., None]
    hloc = jnp.where(mask[..., None], hloc, 0).astype(hloc.dtype)
    hloc = jnp.swapaxes(hloc, 0, 1)            # (own_max, pe, C, d)

    # --- local experts: this rank's contiguous weight slice (clip-mode
    # take — phantom positions borrow some real expert's weights but only
    # ever see the zero rows masked above, so their outputs are zero).
    off = np.concatenate([[0], np.cumsum(own)]).astype(np.int32)
    start = lax.dynamic_index_in_dim(jnp.asarray(off[:pe]), r, keepdims=False)
    w_idx = start + jnp.arange(own_max)
    w_loc = {key: jnp.take(p[key], w_idx, axis=0)
             for key in ("w_gate", "w_up", "w_down")}
    y = expert_ffn(w_loc, hloc.reshape(own_max, pe * cap, d))
    y = y.reshape(own_max, pe, cap, d)

    # --- reverse exchange: owners return slots to their source ranks.
    back = buf_plan.alltoall(
        jnp.swapaxes(y, 0, 1).reshape(pe, own_max * cap, d))
    y_all = back.reshape(pe * own_max, cap, d)[inv_idx]  # padded → real
    out = combine(y_all, slot_token, slot_gate, n)
    return out.reshape(b, s, d), aux
