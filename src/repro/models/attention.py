"""GQA attention: full / sliding-window / cross, train + prefill + decode.

Covers every attention flavor in the assigned pool: GQA grouping
(all archs), qk-norm (qwen3), QKV bias (qwen1.5), sliding window (hymba),
cross-attention (whisper decoder, llama-3.2-vision image layers).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import sharding as shd
from .config import ModelConfig
from .layers import apply_rope, dense_init, head_rmsnorm

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array   # (B, S_max, Hkv, dh)
    v: jax.Array


def init_attention(key, cfg: ModelConfig, dtype, *, cross: bool = False,
                   n_heads: int | None = None, n_kv: int | None = None):
    h = n_heads if n_heads is not None else cfg.n_heads
    hkv = n_kv if n_kv is not None else cfg.n_kv_heads
    d, dh = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), dtype),
        "wk": dense_init(ks[1], (d, hkv, dh), dtype),
        "wv": dense_init(ks[2], (d, hkv, dh), dtype),
        "wo": dense_init(ks[3], (h, dh, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((hkv, dh), dtype)
        p["bv"] = jnp.zeros((hkv, dh), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


# ---------------------------------------------------------------------------
# Masks (additive, fp32)
# ---------------------------------------------------------------------------

def causal_mask(s: int, window: int = 0) -> jax.Array:
    q = jnp.arange(s)[:, None]
    k = jnp.arange(s)[None, :]
    ok = k <= q
    if window > 0:
        ok &= k > q - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def decode_mask(s_max: int, pos, window: int = 0) -> jax.Array:
    """Mask over a cache of length s_max for the single query at ``pos``.

    pos: scalar int array → (1, s_max) mask, or (B,) per-request
    positions (continuous batching: every slot decodes at its own
    offset) → (B, 1, 1, 1, s_max), broadcasting against the sdpa score
    layout (b, k, g, s, t)."""
    k = jnp.arange(s_max)
    if pos.ndim == 0:
        ok = k <= pos
        if window > 0:
            ok &= k > pos - window
        return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)[None, :]
    ok = k[None, :] <= pos[:, None]
    if window > 0:
        ok &= k[None, :] > (pos - window)[:, None]
    return jnp.where(ok, 0.0, NEG_INF).astype(
        jnp.float32)[:, None, None, None, :]


# ---------------------------------------------------------------------------
# Core scaled-dot-product with GQA grouping
# ---------------------------------------------------------------------------

def _project_q(p, cfg, x, positions, *, rope=True):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"], cfg.norm_eps)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
    return q


def _project_kv(p, cfg, x, positions, *, rope=True):
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        k = head_rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope and positions is not None:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def sdpa(q, k, v, mask, recipe=None):
    """q: (B,S,H,dh), k/v: (B,T,Hkv,dh), mask: broadcastable to (S,T) or
    (B,1,S,T).  GQA: H = G*Hkv."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, dh)
    scale = dh ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = scores + mask  # mask broadcasts over (b?,k,g) dims
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, dh).astype(q.dtype)


FLASH_THRESHOLD = 1024  # use chunked online-softmax above this seq length


def _maybe_expand_gqa(k, v, cfg, recipe):
    """§Perf B: when kv-heads don't divide the model axis but full heads
    do, materialize the GQA broadcast so every attention tensor keeps ONE
    consistent head sharding (H/tp) — GSPMD otherwise flip-flops between
    (hkv, g) factorizations and falls back to full rematerialization
    (replication) around the flash tiles."""
    if recipe is None or not getattr(recipe, "expand_gqa", False):
        return k, v
    tp = getattr(recipe, "tp_size", 0)
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    if tp and hkv % tp != 0 and h % tp == 0 and h != hkv:
        g = h // hkv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    return k, v


def self_attention(p, cfg: ModelConfig, x, positions, *, causal=True,
                   window: int = 0, recipe=None):
    """Training / prefill full-sequence self attention.  Returns (out, kv)
    so prefill can seed the cache.  Dispatches to chunked flash attention
    for long sequences (no S×S tensor is ever materialized)."""
    from .flash import flash_attention
    s = x.shape[1]
    q = _project_q(p, cfg, x, positions)
    k, v = _project_kv(p, cfg, x, positions)
    # Expanded copies feed the COMPUTE only; the returned kv (cache) stays
    # in compact GQA form.
    k_c, v_c = _maybe_expand_gqa(k, v, cfg, recipe)
    q = shd.act_bthd(q, recipe)
    k_c = shd.act_bthd(k_c, recipe)
    v_c = shd.act_bthd(v_c, recipe)
    if s > FLASH_THRESHOLD:
        out = flash_attention(q, k_c, v_c, causal=causal, window=window)
    else:
        mask = causal_mask(s, window) if causal else jnp.zeros((), jnp.float32)
        out = sdpa(q, k_c, v_c, mask)
    out = shd.act_bthd(out, recipe)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"]), (k, v)


def cross_attention(p, cfg: ModelConfig, x, memory_kv, recipe=None):
    """x: (B,S,d) queries; memory_kv: precomputed (k, v) from the encoder
    output or image embeddings (no rope, no mask)."""
    q = _project_q(p, cfg, x, None, rope=False)
    k, v = memory_kv
    out = sdpa(q, k, v, jnp.zeros((), jnp.float32))
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def project_memory(p, cfg: ModelConfig, memory):
    """Precompute cross-attention K/V from encoder output / image embeds."""
    return _project_kv(p, cfg, memory, None, rope=False)


def decode_self_attention(p, cfg: ModelConfig, x, cache: KVCache, pos,
                          window: int = 0, recipe=None):
    """One-token decode: x (B,1,d), cache (B,S_max,Hkv,dh).

    ``pos`` is a scalar (the whole batch decodes at one offset — the
    one-shot ``generate`` path) or a (B,) vector of per-request offsets
    (continuous batching, where staggered arrivals put every slot at its
    own position).  Appends projected kv at ``pos`` and attends over the
    cache; the scalar and vector paths compute identical values when all
    entries of the vector equal the scalar."""
    positions = pos[None, None] if pos.ndim == 0 else pos[:, None]
    q = _project_q(p, cfg, x, positions)
    k_new, v_new = _project_kv(p, cfg, x, positions)
    if pos.ndim == 0:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), pos, axis=1)
    else:
        upd = jax.vmap(
            lambda c, n, q_: jax.lax.dynamic_update_slice_in_dim(
                c, n, q_, axis=0))
        k = upd(cache.k, k_new.astype(cache.k.dtype), pos)
        v = upd(cache.v, v_new.astype(cache.v.dtype), pos)
    mask = decode_mask(k.shape[1], pos, window)
    out = sdpa(q, k, v, mask)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"]), KVCache(k, v)
