"""Model registry: one API over every architecture family, plus rule-based
parameter sharding specs (TP over 'model', optional FSDP over data axes).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from . import encdec, transformer, vlm, xlstm
from .config import ModelConfig, ShardingRecipe

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "hybrid": transformer,
    "ssm_xlstm": xlstm,
    "encdec": encdec,
    "vlm": vlm,
}


class ModelApi(NamedTuple):
    cfg: ModelConfig
    init: Callable                 # (key) -> params
    loss: Callable                 # (params, batch) -> scalar
    forward_logits: Callable       # (params, tokens, **extras) -> (logits, aux)
    prefill: Callable              # (params, tokens, max_len, **ex) -> (cache, logits)
    decode_step: Callable          # (params, cache, token, pos) -> (cache, logits)
    param_specs: Callable          # (params_or_shapes) -> PartitionSpec pytree


def build(cfg: ModelConfig, recipe: ShardingRecipe | None = None,
          remat: bool = True) -> ModelApi:
    mod = _FAMILY_MODULES[cfg.family]
    return ModelApi(
        cfg=cfg,
        init=lambda key: mod.init_params(cfg, key),
        loss=lambda params, batch: mod.loss_fn(params, cfg, batch, recipe,
                                               remat),
        forward_logits=lambda params, tokens, **ex: mod.forward_logits(
            params, cfg, tokens, recipe, remat, **ex),
        prefill=lambda params, tokens, max_len, **ex: mod.prefill(
            params, cfg, tokens, max_len, recipe, **ex),
        decode_step=lambda params, cache, token, pos: mod.decode_step(
            params, cfg, cache, token, pos, recipe),
        param_specs=lambda params: make_param_specs(params, recipe),
    )


# ---------------------------------------------------------------------------
# Sharding rules (leaf-name based; stacked layer dims padded with None)
# ---------------------------------------------------------------------------

def _rules(fsdp):
    """name -> base spec (innermost dims).  fsdp is an axis tuple or None."""
    f = fsdp
    return {
        # embeddings / heads
        "embed": (("model", f)),
        "lm_head": ((f, "model")),
        # attention
        "wq": (f, "model", None), "wk": (f, "model", None),
        "wv": (f, "model", None), "wo": ("model", None, f),
        "wo_gate": (f, "model", None),
        "bq": ("model", None), "bk": ("model", None), "bv": ("model", None),
        # dense ffn
        "w_gate": (f, "model"), "w_up": (f, "model"), "w_down": ("model", f),
        # moe (expert-parallel over 'model')
        "moe.w_gate": ("model", f, None), "moe.w_up": ("model", f, None),
        "moe.w_down": ("model", None, f), "router": (None, None),
        # mamba
        "w_in": (f, "model"), "w_out": ("model", f),
        "w_dt": ("model", None), "w_B": ("model", None), "w_C": ("model", None),
        "A_log": ("model", None), "D": ("model",), "conv_w": (None, "model"),
        "dt_bias": ("model",),
        # mlstm / slstm
        "wi": (f, "model"), "wf": (f, "model"),
        "w_x": (f, None, "model", None), "r_h": (None, "model", None, None),
    }


def _leaf_name(path) -> tuple[str, str]:
    """(name, qualified) — qualified includes the parent dict key."""
    names = [k.key for k in path if isinstance(k, DictKey)]
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    return name, f"{parent}.{name}"


def make_param_specs(params, recipe: ShardingRecipe | None):
    """PartitionSpec pytree matching ``params`` (arrays or ShapeDtypeStructs).

    TP rule set above; when recipe.mode == 'tp_fsdp' the designated weight
    dim is additionally sharded over the data axes (FSDP).  Leading stacked
    dims (scan layers / vlm groups) are padded with None.  Unknown leaves
    replicate.
    """
    if recipe is None:
        return jax.tree.map(lambda _: P(), params)
    fsdp = tuple(recipe.fsdp_axes) if recipe.fsdp_axes else None
    rules = _rules(fsdp)

    def spec_for(path, leaf):
        name, qual = _leaf_name(path)
        base = rules.get(qual, rules.get(name))
        ndim = len(leaf.shape)
        if base is None:
            return P(*([None] * ndim))
        base = tuple(base)
        if ndim < len(base):  # scalar-ish leaf (smoke config edge): replicate
            return P(*([None] * ndim))
        pad = ndim - len(base)
        spec = (None,) * pad + base
        # Replace 'model' with the recipe's model axis name.
        spec = tuple(recipe.model_axis if s == "model" else s for s in spec)
        # Drop shardings that do not divide the dim evenly — GSPMD would
        # error; replication is always sound.
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, params)
