"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-30


def block_reduce_ref(a: jax.Array, b: jax.Array, *, op: str = "add") -> jax.Array:
    return {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op](a, b)


def fused_round_ref(live: jax.Array, received: jax.Array, *, nb: int,
                    next_lo: int, op: str = "add"
                    ) -> tuple[jax.Array, jax.Array | None]:
    """jnp oracle for kernels.fused_round: fold + keep/send split."""
    lo = live.shape[0]
    head = block_reduce_ref(live[:nb], received, op=op)
    new = jnp.concatenate([head, live[nb:lo]], axis=0)
    if next_lo == lo:
        return new, None
    return new[:next_lo], new[next_lo:lo]


def permute_rows_ref(x: jax.Array, perm) -> jax.Array:
    return x[jnp.asarray(tuple(int(i) for i in perm))]


def quantize_ref(x: jax.Array, *, group: int = 512
                 ) -> tuple[jax.Array, jax.Array]:
    rows, cols = x.shape
    g = min(group, cols)
    xg = x.astype(jnp.float32).reshape(rows, cols // g, g)
    amax = jnp.max(jnp.abs(xg), axis=2)                    # (rows, cols/g)
    scale = amax / 127.0 + _EPS
    q = jnp.clip(jnp.round(xg / scale[..., None]), -127, 127)
    return q.reshape(rows, cols).astype(jnp.int8), scale


def dequant_ref(codes: jax.Array, scales: jax.Array, *, group: int = 512
                ) -> jax.Array:
    rows, cols = codes.shape
    g = min(group, cols)
    qg = codes.astype(jnp.float32).reshape(rows, cols // g, g)
    return (qg * scales[..., None]).reshape(rows, cols)


def dequant_add_ref(acc, codes, scales, *, group: int = 512):
    return (acc.astype(jnp.float32)
            + dequant_ref(codes, scales, group=group)).astype(acc.dtype)
