"""Pure-jnp oracles for every Pallas kernel (the allclose references).

The quantized-round oracles (``quantize_ref`` / ``dequant_ref`` /
``fused_round_dq_ref``) use the exact same elementwise expressions and
f32 accumulation as the kernels, so on the interpret path the kernel and
the reference are BITWISE equal — the conformance harness relies on this
to hold the fused compressed path to the jnp compressed path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Shared with the kernels — the bitwise kernel-vs-oracle contract depends
# on both sides using the exact same constants and op shapes.
from .quantize import _EPS, _INV127


def block_reduce_ref(a: jax.Array, b: jax.Array, *, op: str = "add") -> jax.Array:
    return {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op](a, b)


def fused_round_ref(live: jax.Array, received: jax.Array, *, nb: int,
                    next_lo: int, op: str = "add"
                    ) -> tuple[jax.Array, jax.Array | None]:
    """jnp oracle for kernels.fused_round: fold + keep/send split."""
    lo = live.shape[0]
    head = block_reduce_ref(live[:nb], received, op=op)
    new = jnp.concatenate([head, live[nb:lo]], axis=0)
    if next_lo == lo:
        return new, None
    return new[:next_lo], new[next_lo:lo]


def permute_rows_ref(x: jax.Array, perm) -> jax.Array:
    return x[jnp.asarray(tuple(int(i) for i in perm))]


def _pad_cols(x: jax.Array, g: int) -> jax.Array:
    pc = (-x.shape[1]) % g
    return jnp.pad(x, ((0, 0), (0, pc))) if pc else x


def quantize_ref(x: jax.Array, *, group: int = 512
                 ) -> tuple[jax.Array, jax.Array]:
    rows, cols = x.shape
    g = min(group, cols)
    xp = _pad_cols(x.astype(jnp.float32), g)
    xg = xp.reshape(rows, -1, g)
    amax = jnp.max(jnp.abs(xg), axis=2)                    # (rows, ng)
    scale = amax * _INV127 + _EPS
    q = jnp.clip(jnp.round(xg / scale[..., None]), -127, 127)
    codes = q.reshape(rows, xp.shape[1]).astype(jnp.int8)
    return codes[:, :cols], scale


def dequant_ref(codes: jax.Array, scales: jax.Array, *, group: int = 512
                ) -> jax.Array:
    rows, cols = codes.shape
    g = min(group, cols)
    qp = _pad_cols(codes.astype(jnp.float32), g)
    qg = qp.reshape(rows, -1, g)
    return (qg * scales[..., None]).reshape(rows, qp.shape[1])[:, :cols]


def dequant_add_ref(acc, codes, scales, *, group: int = 512):
    return (acc.astype(jnp.float32)
            + dequant_ref(codes, scales, group=group)).astype(acc.dtype)


def fused_round_dq_ref(
    live: jax.Array,
    codes: jax.Array,
    scales: jax.Array,
    *,
    nb: int,
    next_lo: int,
    op: str = "add",
    group: int = 512,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """jnp oracle for the compressed circulant round
    (kernels.fused_round.fused_round_dq): dequantize the received int8
    payload, ⊕-fold it into the f32 live-buffer head, split keep/send,
    and REQUANTIZE the next round's send rows.

    Returns ``(keep, (send_codes, send_scales))``, with the send pair
    ``None`` on the final round (``next_lo == lo``).
    """
    lo = live.shape[0]
    deq = dequant_ref(codes, scales, group=group)
    head = block_reduce_ref(live[:nb].astype(jnp.float32), deq, op=op)
    new = jnp.concatenate([head, live[nb:lo].astype(jnp.float32)], axis=0)
    if next_lo == lo:
        return new, None
    send_codes, send_scales = quantize_ref(new[next_lo:lo], group=group)
    return new[:next_lo], (send_codes, send_scales)
