"""Pallas TPU kernels: int8 symmetric group quantization for compressed
communication rounds (β-term reducer, DESIGN §3).

``quantize``    : f32/bf16 (rows, cols) → int8 codes + f32 scales, one
                  scale per (row_tile=1, col_tile) group.
``dequant_add`` : fused decompress-and-reduce — acc + codes * scale in one
                  VMEM pass (the receive side of a compressed round; fuses
                  the paper's ⊕ with decompression so the int8 payload is
                  never materialized as f32 in HBM).

Group layout: scales[i, g] covers codes[i, g*G:(g+1)*G].  G = col_tile.
Target: TPU; validated on CPU via interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_GROUP = 512  # elements per quantization group (one scale each)
_EPS = 1e-30


def _quantize_kernel(x_ref, codes_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)          # (rt, G)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # (rt, 1)
    scale = amax / 127.0 + _EPS
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    codes_ref[...] = q
    scale_ref[...] = scale


def quantize(
    x: jax.Array,
    *,
    group: int = DEFAULT_GROUP,
    row_tile: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with per-(row, group) scales."""
    if x.ndim != 2:
        raise ValueError(f"need 2-D input, got {x.shape}")
    rows, cols = x.shape
    g = min(group, cols)
    rt = min(row_tile, rows)
    if rows % rt or cols % g:
        raise ValueError(f"shape {x.shape} not divisible by ({rt},{g})")
    grid = (rows // rt, cols // g)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rt, g), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((rt, g), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.int8),
            jax.ShapeDtypeStruct((rows, cols // g), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def _dequant_add_kernel(acc_ref, codes_ref, scale_ref, o_ref):
    acc = acc_ref[...].astype(jnp.float32)
    q = codes_ref[...].astype(jnp.float32)
    s = scale_ref[...]                            # (rt, 1) broadcast
    o_ref[...] = (acc + q * s).astype(o_ref.dtype)


def dequant_add(
    acc: jax.Array,
    codes: jax.Array,
    scales: jax.Array,
    *,
    group: int = DEFAULT_GROUP,
    row_tile: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Fused ``acc + dequant(codes, scales)`` (the compressed-round ⊕)."""
    rows, cols = codes.shape
    g = min(group, cols)
    rt = min(row_tile, rows)
    if acc.shape != codes.shape:
        raise ValueError(f"acc {acc.shape} vs codes {codes.shape}")
    if scales.shape != (rows, cols // g):
        raise ValueError(f"scales {scales.shape}, want {(rows, cols // g)}")
    if rows % rt or cols % g:
        raise ValueError(f"shape {codes.shape} not divisible by ({rt},{g})")
    grid = (rows // rt, cols // g)
    return pl.pallas_call(
        _dequant_add_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, g), lambda i, j: (i, j)),
            pl.BlockSpec((rt, g), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rt, g), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(acc.shape, acc.dtype),
        interpret=interpret,
    )(acc, codes, scales)
