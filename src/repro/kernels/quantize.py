"""Pallas TPU kernels: int8 symmetric group quantization for compressed
communication rounds (β-term reducer, DESIGN §3).

``quantize``    : f32/bf16 (rows, cols) → int8 codes + f32 scales, one
                  scale per (row_tile=1, col_tile) group.
``dequant_add`` : fused decompress-and-reduce — acc + codes * scale in one
                  VMEM pass (the receive side of a compressed round; fuses
                  the paper's ⊕ with decompression so the int8 payload is
                  never materialized as f32 in HBM).

Group layout: scales[i, g] covers codes[i, g*G:(g+1)*G].  G = col_tile.
Ragged shapes (rows not divisible by ``row_tile``, cols not divisible by
``group``) are zero-padded internally and sliced back — the last group of
a row may cover fewer than G real elements; its scale is the amax of the
real elements (zero padding never raises an amax).

The int8 WIRE FORMAT for compressed collective rounds is also defined
here: one contiguous int8 buffer per round, ``[codes | scale bytes]``
along the column axis, so a compressed round still ppermutes exactly ONE
array — the lowered HLO keeps one collective-permute per round and the
bytes on the wire are exactly ``cols + 4*ceil(cols/G)`` per row.
``pack_wire`` / ``unpack_wire`` convert between (codes, scales) and the
wire buffer via same-width bitcasts (f32 ↔ u32 ↔ 4×u8), which every
supported JAX lowers on every backend.

Target: TPU; validated on CPU via interpret=True.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_GROUP = 512  # elements per quantization group (one scale each)
_EPS = 1e-30
# Explicit reciprocal: a literal ``amax / 127.0`` is rewritten to a
# reciprocal-multiply by XLA in some contexts but not others (jit vs pallas
# interpret), producing 1-ulp scale drift between the kernel and the jnp
# reference.  A constant multiply is the same single IEEE op everywhere.
_INV127 = 1.0 / 127.0


def wire_ngroups(cols: int, group: int = DEFAULT_GROUP) -> int:
    """Number of (per-row) quantization groups covering ``cols`` columns."""
    g = min(group, cols)
    return -(-cols // g)


def wire_width(cols: int, group: int = DEFAULT_GROUP) -> int:
    """int8 wire-buffer columns for ``cols`` payload columns: codes plus
    four scale bytes per group (the compressed round's β-term bytes/row)."""
    return cols + 4 * wire_ngroups(cols, group)


def pad2d(x: jax.Array, row_mult: int, col_mult: int) -> jax.Array:
    """Zero-pad a 2-D array so rows/cols are multiples of the tile grid.

    THE shared ragged-shape padding helper: the quantize/dequant kernels,
    the jitted kernel wrappers (``kernels.ops``) and the collective
    plan's wire backends all pad through here instead of re-deriving the
    ``(-n) % m`` arithmetic locally (leading-axis *block* padding is the
    plan's ``BlockLayout.pad`` — driven by the counts table)."""
    rows, cols = x.shape
    pr, pc = (-rows) % row_mult, (-cols) % col_mult
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


_pad2 = pad2d  # internal alias used by the kernels below


def _quantize_kernel(x_ref, codes_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)          # (rt, G)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)  # (rt, 1)
    scale = amax * _INV127 + _EPS
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    codes_ref[...] = q
    scale_ref[...] = scale


def quantize(
    x: jax.Array,
    *,
    group: int = DEFAULT_GROUP,
    row_tile: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with per-(row, group) scales.

    Any 2-D shape: ragged rows/cols are zero-padded to the (row_tile,
    group) grid internally and sliced back.  Returns ``codes`` of
    ``x.shape`` and ``scales`` of ``(rows, ceil(cols / min(group, cols)))``.
    """
    if x.ndim != 2:
        raise ValueError(f"need 2-D input, got {x.shape}")
    rows, cols = x.shape
    g = min(group, cols)
    rt = min(row_tile, rows)
    xp = _pad2(x, rt, g)
    rp, cp = xp.shape
    grid = (rp // rt, cp // g)
    codes, scales = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rt, g), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((rt, g), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, cp), jnp.int8),
            jax.ShapeDtypeStruct((rp, cp // g), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    if (rp, cp) != (rows, cols):
        codes = codes[:rows, :cols]
        scales = scales[:rows]
    return codes, scales


def _dequant_add_kernel(acc_ref, codes_ref, scale_ref, o_ref):
    acc = acc_ref[...].astype(jnp.float32)
    q = codes_ref[...].astype(jnp.float32)
    s = scale_ref[...]                            # (rt, 1) broadcast
    o_ref[...] = (acc + q * s).astype(o_ref.dtype)


def dequant_add(
    acc: jax.Array,
    codes: jax.Array,
    scales: jax.Array,
    *,
    group: int = DEFAULT_GROUP,
    row_tile: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Fused ``acc + dequant(codes, scales)`` (the compressed-round ⊕).

    Ragged shapes are zero-padded internally (zero codes dequantize to 0,
    so padding never perturbs the accumulator) and sliced back.
    """
    rows, cols = codes.shape
    g = min(group, cols)
    rt = min(row_tile, rows)
    ng = wire_ngroups(cols, g)
    if acc.shape != codes.shape:
        raise ValueError(f"acc {acc.shape} vs codes {codes.shape}")
    if scales.shape != (rows, ng):
        raise ValueError(f"scales {scales.shape}, want {(rows, ng)}")
    accp = _pad2(acc, rt, g)
    codesp = _pad2(codes, rt, g)
    rp, cp = codesp.shape
    scalesp = scales if rp == rows else jnp.pad(scales, ((0, rp - rows),
                                                         (0, 0)))
    grid = (rp // rt, cp // g)
    out = pl.pallas_call(
        _dequant_add_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rt, g), lambda i, j: (i, j)),
            pl.BlockSpec((rt, g), lambda i, j: (i, j)),
            pl.BlockSpec((rt, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rt, g), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, cp), acc.dtype),
        interpret=interpret,
    )(accp, codesp, scalesp)
    if (rp, cp) != (rows, cols):
        out = out[:rows, :cols]
    return out


# ---------------------------------------------------------------------------
# int8 wire format: [codes | scale bytes] in ONE int8 buffer per round
# ---------------------------------------------------------------------------

def pack_wire(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """Pack int8 codes (rows, cols) + f32 scales (rows, ng) into one
    contiguous int8 buffer (rows, cols + 4*ng) — the compressed round's
    single ppermute payload."""
    rows, ng = scales.shape
    u = lax.bitcast_convert_type(scales, jnp.uint32)          # (rows, ng)
    sb = jnp.stack([(u >> (8 * k)) & 0xFF for k in range(4)],
                   axis=-1).astype(jnp.uint8)                 # (rows, ng, 4)
    sb = lax.bitcast_convert_type(sb.reshape(rows, 4 * ng), jnp.int8)
    return jnp.concatenate([codes, sb], axis=1)


def unpack_wire(wire: jax.Array, cols: int, *,
                group: int = DEFAULT_GROUP) -> tuple[jax.Array, jax.Array]:
    """Inverse of ``pack_wire``: split a (rows, wire_width(cols, group))
    int8 buffer back into codes (rows, cols) and f32 scales (rows, ng)."""
    rows = wire.shape[0]
    ng = wire_ngroups(cols, group)
    if wire.shape[1] != cols + 4 * ng:
        raise ValueError(
            f"wire has {wire.shape[1]} cols, want {cols + 4 * ng} "
            f"(cols={cols}, group={group})")
    codes = wire[:, :cols]
    sb = lax.bitcast_convert_type(wire[:, cols:], jnp.uint8)
    sb = sb.reshape(rows, ng, 4).astype(jnp.uint32)
    u = sum(sb[..., k] << (8 * k) for k in range(4)).astype(jnp.uint32)
    return codes, lax.bitcast_convert_type(u, jnp.float32)
