"""Pallas TPU kernel: the fused circulant ROUND — Algorithm 1's hot loop.

Each reduce-scatter round k of the circulant collectives does two local
memory operations after the ppermute delivers T:

  (a) fold the received blocks into the live buffer head,
      ``R[:nb] = R[:nb] ⊕ T``            (the paper's γ-term), and
  (b) assemble the NEXT round's send blocks ``R[s_{k+1} : s_k]`` into a
      contiguous send buffer for the next collective-permute.

Done with plain jnp ops that is a reduce + a concatenate + a slice — three
HBM round-trips over the live buffer.  The fused kernel does both in ONE
pass: every input row is read once, every output row written once, and the
round's ppermute payload comes out contiguous.  Rows are the paper's
blocks (the live buffer is viewed as ``(blocks, block_numel)``); the fold
boundary ``nb`` and the keep/send split ``next_lo`` are trace-time
constants from the schedule, so the kernel body is pure static slicing —
no masks, no predicates, bitwise-identical to the jnp path.

Layout of one round (live buffer has ``lo`` rows, ``nb`` received rows,
next round keeps ``next_lo`` rows and sends ``lo - next_lo``)::

      row         0 ......... nb ........ lo
      value       op(live,T)  |  live (copied through)
      routed to   keep[0:next_lo]  |  send[0:lo-next_lo]   (split at next_lo)

``nb`` may straddle ``next_lo`` in either direction (halving schedules
fold past the split; fully_connected folds only row 0) — both boundaries
are static, so each output region is an unrolled pair of row-slices.

Target: TPU (grid over VPU-aligned column tiles).  On CPU the kernel runs
under ``interpret=True`` as a gridless whole-buffer call — the
interpreter's per-grid-step overhead dominates otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from . import quantize as _qz
from .block_reduce import DEFAULT_COL_TILE, _OPS


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def resolve_fused(use_fused_kernel: bool | None) -> bool:
    """Auto-selection rule for the ``use_fused_kernel`` kwarg.

    ``True``/``False`` are explicit.  ``None`` (auto) enables the fused
    Pallas path only on TPU with a native post-0.4.x shard_map
    (``compat.HAS_NATIVE_SHARD_MAP``): on CPU the kernel would run in
    interpret mode, which is for
    validation rather than speed, and the legacy 0.4.x shard_map has no
    replication rule for pallas_call — auto must not change the default
    behavior of call sites that keep replication checking on, so there
    the jnp fallback is preserved (opt in with ``use_fused_kernel=True``
    plus ``check_vma=False``).
    """
    if use_fused_kernel is None:
        return (jax.default_backend() == "tpu"
                and compat.HAS_NATIVE_SHARD_MAP)
    return bool(use_fused_kernel)


def _store_rows(ref, lo_idx: int, hi_idx: int, val):
    """Static row-range store; whole-ref stores skip the interpreter's
    sliced-update path (measurably cheaper in interpret mode)."""
    if lo_idx == 0 and hi_idx == ref.shape[0]:
        ref[...] = val
    else:
        ref[lo_idx:hi_idx] = val


def _round_body(x_ref, t_ref, keep_ref, send_ref, *, op: str, nb: int,
                next_lo: int, lo: int):
    """Shared kernel body; ``send_ref`` is None on the final round."""
    reduce_fn = _OPS[op]
    folded = reduce_fn(x_ref[:nb], t_ref[...])
    a = min(nb, next_lo)
    if a:
        _store_rows(keep_ref, 0, a, folded[:a] if a < nb else folded)
    if a < next_lo:
        _store_rows(keep_ref, a, next_lo, x_ref[a:next_lo])
    if send_ref is None:
        return
    if nb > next_lo:
        _store_rows(send_ref, 0, nb - next_lo, folded[next_lo:nb])
    b = max(nb, next_lo)
    if b < lo:
        _store_rows(send_ref, b - next_lo, lo - next_lo, x_ref[b:lo])


def _kernel_keep_send(x_ref, t_ref, keep_ref, send_ref, *, op, nb, next_lo, lo):
    _round_body(x_ref, t_ref, keep_ref, send_ref, op=op, nb=nb,
                next_lo=next_lo, lo=lo)


def _kernel_keep_only(x_ref, t_ref, keep_ref, *, op, nb, next_lo, lo):
    _round_body(x_ref, t_ref, keep_ref, None, op=op, nb=nb,
                next_lo=next_lo, lo=lo)


def fused_round(
    live: jax.Array,
    received: jax.Array,
    *,
    nb: int,
    next_lo: int,
    op: str = "add",
    col_tile: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array | None]:
    """One fused circulant round over 2-D ``(blocks, block_numel)`` buffers.

    ``live``: the ``(lo, cols)`` live buffer; ``received``: the
    ``(nb, cols)`` ppermuted payload.  Returns ``(keep, send)`` where
    ``keep`` is rows ``[0, next_lo)`` of the new live buffer and ``send``
    is rows ``[next_lo, lo)`` (the next round's contiguous payload), or
    ``None`` when ``next_lo == lo`` (final round).  Requires
    ``1 <= nb <= lo`` and ``1 <= next_lo <= lo`` — schedule validity
    (fold-liveness, see ``core.schedule``) guarantees both.
    """
    if live.ndim != 2 or received.ndim != 2:
        raise ValueError(
            f"need 2-D buffers, got {live.shape} and {received.shape}")
    lo, cols = live.shape
    if received.shape != (nb, cols):
        raise ValueError(
            f"received shape {received.shape} != ({nb}, {cols})")
    if not (1 <= nb <= lo and 1 <= next_lo <= lo):
        raise ValueError(
            f"invalid round: nb={nb}, next_lo={next_lo}, lo={lo}")
    if interpret is None:
        interpret = _interpret_default()
    final = next_lo == lo  # last round: no send output
    kernel = functools.partial(
        _kernel_keep_only if final else _kernel_keep_send,
        op=op, nb=nb, next_lo=next_lo, lo=lo)
    out_shape: object = jax.ShapeDtypeStruct((next_lo, cols), live.dtype)
    if not final:
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((lo - next_lo, cols), live.dtype)]
    kw: dict = {"interpret": True}
    if not interpret:
        # Compiled (TPU): grid over VPU-aligned column tiles, whole rows
        # per step.  In interpret mode a gridless whole-buffer call is
        # used instead — the interpreter's per-grid-step slicing/masking
        # machinery costs more than any tiling could win on CPU.
        ct = min(DEFAULT_COL_TILE if col_tile is None else col_tile, cols)
        out_specs: object = pl.BlockSpec((next_lo, ct), lambda j: (0, j))
        if not final:
            out_specs = [out_specs,
                         pl.BlockSpec((lo - next_lo, ct), lambda j: (0, j))]
        kw = {
            "grid": (pl.cdiv(cols, ct),),
            "in_specs": [
                pl.BlockSpec((lo, ct), lambda j: (0, j)),
                pl.BlockSpec((nb, ct), lambda j: (0, j)),
            ],
            "out_specs": out_specs,
        }
    res = pl.pallas_call(kernel, out_shape=out_shape, **kw)(live, received)
    if final:
        return res, None
    return res[0], res[1]


# ---------------------------------------------------------------------------
# Compressed (int8 wire) round: dequant + ⊕-fold + requant-next-send,
# one HBM traversal (the wire_dtype="int8" hot loop)
# ---------------------------------------------------------------------------

def _dq_round_body(x_ref, c_ref, s_ref, keep_ref, send_c_ref, send_s_ref, *,
                   op: str, nb: int, next_lo: int, lo: int, g: int):
    """Compressed-round kernel body; ``send_*`` refs are None on the final
    round.  Same static keep/send routing as ``_round_body``, but the
    received payload arrives as int8 codes + f32 scales (dequantized in
    VMEM, never materialized as f32 in HBM) and the next round's send rows
    leave requantized.  Elementwise expressions mirror ``ref.quantize_ref``
    / ``ref.dequant_ref`` exactly so the interpret path is bitwise-equal
    to the jnp reference path."""
    reduce_fn = _OPS[op]
    cols = c_ref.shape[1]
    q = c_ref[...].astype(jnp.float32).reshape(nb, cols // g, g)
    deq = (q * s_ref[...][..., None]).reshape(nb, cols)
    folded = reduce_fn(x_ref[:nb], deq)
    a = min(nb, next_lo)
    if a:
        _store_rows(keep_ref, 0, a, folded[:a] if a < nb else folded)
    if a < next_lo:
        _store_rows(keep_ref, a, next_lo, x_ref[a:next_lo])
    if send_c_ref is None:
        return
    parts = []
    if nb > next_lo:
        parts.append(folded[next_lo:nb])
    b = max(nb, next_lo)
    if b < lo:
        parts.append(x_ref[b:lo])
    send = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    ns = lo - next_lo
    sg = send.reshape(ns, cols // g, g)
    amax = jnp.max(jnp.abs(sg), axis=2)
    scale = amax * _qz._INV127 + _qz._EPS
    codes = jnp.clip(jnp.round(sg / scale[..., None]), -127, 127)
    send_c_ref[...] = codes.reshape(ns, cols).astype(jnp.int8)
    send_s_ref[...] = scale


def _dq_kernel_keep_send(x_ref, c_ref, s_ref, keep_ref, send_c_ref,
                         send_s_ref, *, op, nb, next_lo, lo, g):
    _dq_round_body(x_ref, c_ref, s_ref, keep_ref, send_c_ref, send_s_ref,
                   op=op, nb=nb, next_lo=next_lo, lo=lo, g=g)


def _dq_kernel_keep_only(x_ref, c_ref, s_ref, keep_ref, *, op, nb, next_lo,
                         lo, g):
    _dq_round_body(x_ref, c_ref, s_ref, keep_ref, None, None, op=op, nb=nb,
                   next_lo=next_lo, lo=lo, g=g)


def fused_round_dq(
    live: jax.Array,
    codes: jax.Array,
    scales: jax.Array,
    *,
    nb: int,
    next_lo: int,
    op: str = "add",
    group: int = _qz.DEFAULT_GROUP,
    col_tile: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """One fused COMPRESSED circulant round over 2-D buffers.

    ``live``: (lo, cols) f32 accumulation buffer, ``cols`` divisible by
    the quantization group ``g = min(group, cols)``; ``codes``/``scales``:
    the received int8 payload for ``nb`` blocks.  In ONE pass: dequantize,
    ⊕-fold into the buffer head, emit ``keep`` rows [0, next_lo), and
    requantize rows [next_lo, lo) as the next round's ``(codes, scales)``
    send pair (``None`` when ``next_lo == lo``, the final round).
    jnp oracle: ``ref.fused_round_dq_ref`` (bitwise-equal in interpret).
    """
    if live.ndim != 2 or codes.ndim != 2:
        raise ValueError(
            f"need 2-D buffers, got {live.shape} and {codes.shape}")
    lo, cols = live.shape
    g = min(group, cols)
    if cols % g:
        raise ValueError(f"cols {cols} not divisible by group {g}")
    ng = cols // g
    if codes.shape != (nb, cols):
        raise ValueError(f"codes shape {codes.shape} != ({nb}, {cols})")
    if scales.shape != (nb, ng):
        raise ValueError(f"scales shape {scales.shape} != ({nb}, {ng})")
    if not (1 <= nb <= lo and 1 <= next_lo <= lo):
        raise ValueError(
            f"invalid round: nb={nb}, next_lo={next_lo}, lo={lo}")
    if interpret is None:
        interpret = _interpret_default()
    final = next_lo == lo
    ns = lo - next_lo
    kernel = functools.partial(
        _dq_kernel_keep_only if final else _dq_kernel_keep_send,
        op=op, nb=nb, next_lo=next_lo, lo=lo, g=g)
    out_shape: object = jax.ShapeDtypeStruct((next_lo, cols), jnp.float32)
    if not final:
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((ns, cols), jnp.int8),
                     jax.ShapeDtypeStruct((ns, ng), jnp.float32)]
    kw: dict = {"interpret": True}
    if not interpret:
        # Compiled (TPU): column tiles aligned to whole quantization
        # groups so each grid step owns its scales slice.
        ct = DEFAULT_COL_TILE if col_tile is None else col_tile
        ct = min(cols, max(g, (ct // g) * g))
        out_specs: object = pl.BlockSpec((next_lo, ct), lambda j: (0, j))
        if not final:
            out_specs = [out_specs,
                         pl.BlockSpec((ns, ct), lambda j: (0, j)),
                         pl.BlockSpec((ns, ct // g), lambda j: (0, j))]
        kw = {
            "grid": (pl.cdiv(cols, ct),),
            "in_specs": [
                pl.BlockSpec((lo, ct), lambda j: (0, j)),
                pl.BlockSpec((nb, ct), lambda j: (0, j)),
                pl.BlockSpec((nb, ct // g), lambda j: (0, j)),
            ],
            "out_specs": out_specs,
        }
    res = pl.pallas_call(kernel, out_shape=out_shape, **kw)(
        live, codes, scales)
    if final:
        return res, None
    return res[0], (res[1], res[2])


def quantize_rows(x: jax.Array, *, group: int = _qz.DEFAULT_GROUP,
                  interpret: bool | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Pallas group-quantize with the fused-round interpret default — the
    round-0 send quantization of the compressed collectives."""
    if interpret is None:
        interpret = _interpret_default()
    return _qz.quantize(x, group=group, row_tile=1, interpret=interpret)


def _permute_kernel(x_ref, o_ref, *, perm: tuple[int, ...]):
    for dst, src in enumerate(perm):
        o_ref[dst : dst + 1] = x_ref[src : src + 1]


def permute_rows(
    x: jax.Array,
    perm,
    *,
    col_tile: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Static row permutation ``out[i] = x[perm[i]]`` in one HBM pass.

    Used by the fused alltoall to lay the final slot into source-rank
    order (the permutation is trace-time metadata, so it unrolls into
    static row copies — no gather indices materialized).
    """
    perm = tuple(int(i) for i in perm)
    rows, cols = x.shape
    if sorted(perm) != list(range(rows)):
        raise ValueError(f"perm {perm} is not a permutation of 0..{rows - 1}")
    if interpret is None:
        interpret = _interpret_default()
    kw: dict = {"interpret": True}
    if not interpret:
        ct = min(DEFAULT_COL_TILE if col_tile is None else col_tile, cols)
        kw = {
            "grid": (pl.cdiv(cols, ct),),
            "in_specs": [pl.BlockSpec((rows, ct), lambda j: (0, j))],
            "out_specs": pl.BlockSpec((rows, ct), lambda j: (0, j)),
        }
    return pl.pallas_call(
        functools.partial(_permute_kernel, perm=perm),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        **kw,
    )(x)
