"""Pallas TPU kernel: fused block reduction — the ⊕ hot loop of Algorithm 1.

Each communication round folds the received blocks T into the live buffer
head: ``R[:nb] = R[:nb] ⊕ T``.  On TPU this is the paper's γ-term; done
naively it is three HBM round-trips per element.  The kernel streams both
operands HBM→VMEM in (row_tile, col_tile) blocks aligned to the VPU lanes
(8×128), reduces in VMEM, and writes back one result tile — exactly one
read of each operand and one write of the result.

Target: TPU (MXU/VPU); validated on CPU via ``interpret=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-aligned default tiles: 8 sublanes x 128 lanes for fp32; rows are
# multiplied up for bf16-friendly (16, 128) packing by ops.py.
DEFAULT_ROW_TILE = 256
DEFAULT_COL_TILE = 512

# Shared named-⊕ table (fused_round.py imports it; ref.py/collectives
# mirror the same names for their jnp paths).
_OPS = {
    "add": lambda a, b: a + b,
    "max": jnp.maximum,
    "min": jnp.minimum,
}


def _block_reduce_kernel(a_ref, b_ref, o_ref, *, op: str):
    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] = _OPS[op](a, b)


def block_reduce(
    a: jax.Array,
    b: jax.Array,
    *,
    op: str = "add",
    row_tile: int = DEFAULT_ROW_TILE,
    col_tile: int = DEFAULT_COL_TILE,
    interpret: bool = False,
) -> jax.Array:
    """Elementwise ``a ⊕ b`` for 2-D (rows, cols) operands with explicit
    VMEM tiling.  Shapes must be tile-divisible (ops.py pads)."""
    if a.shape != b.shape or a.ndim != 2:
        raise ValueError(f"need equal 2-D shapes, got {a.shape} vs {b.shape}")
    rows, cols = a.shape
    rt, ct = min(row_tile, rows), min(col_tile, cols)
    if rows % rt or cols % ct:
        raise ValueError(f"shape {a.shape} not divisible by tile ({rt},{ct})")
    grid = (rows // rt, cols // ct)
    spec = pl.BlockSpec((rt, ct), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_block_reduce_kernel, op=op),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)
