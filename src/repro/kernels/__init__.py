"""Pallas TPU kernels for the paper's compute hot spots.

block_reduce — the per-round ⊕ fold of Algorithm 1 (γ term).
quantize     — int8 group quantization + fused dequant-add for compressed
               communication rounds (β term).

Each kernel has a pure-jnp oracle in ref.py; ops.py holds the jitted,
shape-flexible public wrappers.
"""
from .ops import (  # noqa: F401
    dequant_accumulate,
    dequantize_blocks,
    fused_block_reduce,
    make_compressors,
    quantize_blocks,
)
