"""Pallas TPU kernels for the paper's compute hot spots.

block_reduce   — the per-round ⊕ fold of Algorithm 1 (γ term), standalone.
fused_round    — the whole local side of a circulant round: ⊕-fold of the
                 received blocks PLUS contiguous layout of the next
                 round's send blocks, one HBM pass (the collectives' hot
                 path).
fused_round_dq — the compressed-round variant: dequantize the received
                 int8 payload + ⊕-fold + requantize the next round's
                 send rows, one HBM pass (the wire_dtype="int8" hot path).
quantize       — int8 group quantization + fused dequant-add, plus the
                 packed [codes | scale bytes] wire format
                 (pack_wire/unpack_wire) for compressed communication
                 rounds (β term).

Each kernel has a pure-jnp oracle in ref.py; ops.py holds the jitted,
shape-flexible public wrappers.
"""
from .fused_round import (  # noqa: F401
    fused_round,
    fused_round_dq,
    permute_rows,
    quantize_rows,
    resolve_fused,
)
from .ops import (  # noqa: F401
    dequant_accumulate,
    dequantize_blocks,
    fused_block_reduce,
    make_compressors,
    quantize_blocks,
)
from .quantize import (  # noqa: F401
    DEFAULT_GROUP,
    pack_wire,
    pad2d,
    unpack_wire,
    wire_ngroups,
    wire_width,
)
